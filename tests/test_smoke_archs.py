"""Per-architecture smoke tests (deliverable f).

Every assigned arch instantiates a REDUCED same-family variant (2 layers,
d_model ≤ 512, ≤ 4 experts) and runs one forward + one train step + one
decode step on CPU, asserting output shapes and no NaNs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.models import build_model
from repro.training import AdamWConfig, init_train_state, make_train_step

B, S = 2, 24


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.arch_type in ("vlm", "encdec"):
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_shapes_no_nans(arch):
    cfg = ARCHS[arch].reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    logits, aux = model.forward_train(params, _batch(cfg, key))
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jnp.isfinite(jnp.asarray(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.slow
def test_reduced_train_step(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    state, metrics = step(state, batch, jax.random.PRNGKey(2))
    assert jnp.isfinite(metrics["loss"])
    assert int(metrics["step"]) == 1
    # one more step: params actually move
    state2, metrics2 = step(state, batch, jax.random.PRNGKey(3))
    assert jnp.isfinite(metrics2["loss"])
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state2.params)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_decode_step(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    fe = None
    if cfg.arch_type in ("encdec",):
        fe = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model))
    lg, cache = model.prefill(params, toks, slots=S + 8, frontend=fe)
    assert lg.shape == (B, S, cfg.vocab)
    tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    lg1, cache = model.decode_step(params, tok, cache, pos)
    assert lg1.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg1)))


def test_exact_assigned_configs():
    """The full (non-reduced) configs carry the exact assigned numbers."""
    expect = {
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = ARCHS[arch]
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (L, d, h, kv, ff, v), arch
    assert ARCHS["mamba2-130m"].ssm_state == 128
    assert ARCHS["zamba2-1.2b"].ssm_state == 64
    assert ARCHS["llama4-maverick-400b-a17b"].experts_per_tok == 1
    assert ARCHS["arctic-480b"].experts_per_tok == 2
    assert ARCHS["arctic-480b"].moe_dense_residual
    assert ARCHS["qwen3-14b"].qk_norm
    assert ARCHS["qwen2.5-3b"].qkv_bias
