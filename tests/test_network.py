"""Link delay-model regression tests (sim/network.py bugfixes).

Pinned behaviors: symmetric jitter truncation keeps the sampled mean
one-way delay at the analytic ``expected_one_way_ms`` (the old one-sided
cut biased it upward); ``recent_rtt_ms`` is built from EXPLICITLY paired
outbound/return delays (``record_rtt`` with the caller's exchange sum)
instead of doubling a mixed mean (which double-counted serialization and
mixed window/verdict payload sizes) — delivery-order pairing is gone
entirely, since pipelined speculation interleaves directions; and the
verdict payload grows with γ as its contract (per-position logprobs)
promises.
"""

import random

import numpy as np
import pytest

from repro.sim.events import Environment
from repro.sim.network import (Link, LinkSpec, expected_one_way_ms,
                               expected_rtt_ms, sample_one_way_ms,
                               verdict_payload_bytes, window_payload_bytes)


def test_jitter_truncation_symmetric_mean():
    """Sampled mean one-way delay == analytic expectation, including when
    4·jitter_ms exceeds 0.9·RTT/2 (the regime the old asymmetric
    truncation biased upward)."""
    rng = random.Random(0)
    for spec in (LinkSpec(rtt_ms=10.0, jitter_ms=1.0),
                 LinkSpec(rtt_ms=2.0, jitter_ms=4.0),      # old bias regime
                 LinkSpec(rtt_ms=40.0, jitter_ms=8.0)):
        n = 20000
        mean = sum(sample_one_way_ms(spec, rng) for _ in range(n)) / n
        expect = expected_one_way_ms(spec)
        # symmetric truncation preserves the mean; tolerance covers
        # sampling noise (std ≈ jitter/2/√n)
        assert abs(mean - expect) < 0.05 * max(1.0, expect), (spec, mean)


def test_one_way_delay_positive_and_causal():
    rng = random.Random(1)
    spec = LinkSpec(rtt_ms=1.0, jitter_ms=50.0)   # jitter >> rtt
    for _ in range(2000):
        d = sample_one_way_ms(spec, rng)
        assert d > 0.0
        # bounded by half_rtt + truncation bound + serialization
        assert d <= 0.5 * 1.0 * 1.9 + expected_one_way_ms(spec, 64) + 1e-9


def test_recent_rtt_pairs_send_and_verdict():
    """recent_rtt_ms reconstructs the round trip from explicitly PAIRED
    one-way delays: with asymmetric payloads the estimate matches the
    analytic out+back RTT, not 2× either direction."""
    env = Environment()
    # huge payload asymmetry on a thin pipe makes direction mixing obvious
    spec = LinkSpec(rtt_ms=10.0, jitter_ms=0.0, bandwidth_gbps=0.001)
    link = Link(env, spec, random.Random(0))
    out_b, back_b = 10_000, 100
    for _ in range(8):
        link.transfer(out_b)       # window out
        d_out = link.last_delay_ms
        link.transfer(back_b)      # verdict back
        link.record_rtt(d_out + link.last_delay_ms)
    expect = expected_rtt_ms(spec, out_b, back_b)
    assert link.recent_rtt_ms == pytest.approx(expect, rel=1e-6)
    # transfers alone (no completed exchange recorded) must not contribute
    # half-pairs — the estimate falls back to the spec RTT
    link2 = Link(env, spec, random.Random(0))
    link2.transfer(out_b)
    assert link2.recent_rtt_ms == spec.rtt_ms


def test_recent_rtt_robust_to_interleaved_drafters():
    """A Link is shared by every drafter routed to its target: two
    drafters' outbound windows can interleave, so pairing must come from
    the caller's explicit exchange sums, not delivery order."""
    env = Environment()
    spec = LinkSpec(rtt_ms=10.0, jitter_ms=0.0, bandwidth_gbps=0.001)
    link = Link(env, spec, random.Random(0))
    out_b, back_b = 10_000, 100
    for _ in range(4):
        # drafter A and B both send windows before either verdict returns
        link.transfer(out_b)
        a_out = link.last_delay_ms
        link.transfer(out_b)
        b_out = link.last_delay_ms
        link.transfer(back_b)
        link.record_rtt(a_out + link.last_delay_ms)
        link.transfer(back_b)
        link.record_rtt(b_out + link.last_delay_ms)
    expect = expected_rtt_ms(spec, out_b, back_b)
    # order-based pairing would have produced out+out (two big
    # serializations) and back+back (two small) estimates instead
    assert link.recent_rtt_ms == pytest.approx(expect, rel=1e-6)


def test_recent_rtt_fallback_before_any_pair():
    env = Environment()
    link = Link(env, LinkSpec(rtt_ms=7.5), random.Random(0))
    assert link.recent_rtt_ms == 7.5


def test_verdict_payload_scales_with_gamma():
    """The verdict carries per-position logprobs: payload must grow with
    γ, and stay smaller than the window payload (ids + probs) it answers."""
    sizes = [verdict_payload_bytes(g) for g in (1, 4, 8, 12)]
    assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes)
    for g in (1, 4, 8, 12):
        assert verdict_payload_bytes(g) > verdict_payload_bytes(0)
        assert verdict_payload_bytes(g) < window_payload_bytes(g) + 48


def test_window_payload_monotone_in_node_count():
    """Node-count pricing: strictly monotone in n_nodes at fixed γ, and a
    degenerate 1-branch tree (T = 1 + γ) costs MORE than the plain chain
    at the same γ — the tree frame ships a parent table the chain frame
    doesn't need."""
    for g in (1, 3, 8):
        sizes = [window_payload_bytes(g, n_nodes=n) for n in range(1, 40)]
        assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes)
        assert window_payload_bytes(g, n_nodes=1 + g) > \
            window_payload_bytes(g)


def test_tree_window_msg_payload_matches_model():
    """WindowMsg.payload_bytes must equal the analytic node-count price
    byte for byte, for chains and trees alike, scaled by active rows."""
    from repro.distributed import WindowMsg
    for (g, b, n_active) in [(3, 1, 2), (4, 3, 1), (2, 4, 5), (3, 2, 0)]:
        T = 1 + g * b
        parent = np.zeros((T,), np.int32)
        toks = np.zeros((n_active or 1, T), np.int32)
        tree = WindowMsg(tokens=toks, gamma=g, n_active=n_active,
                         n_nodes=T, branches=b, parent=parent)
        assert tree.payload_bytes == \
            max(1, n_active) * window_payload_bytes(g, n_nodes=T)
        chain = WindowMsg(tokens=toks[:, :g], gamma=g, n_active=n_active)
        assert chain.payload_bytes == \
            max(1, n_active) * window_payload_bytes(g)
