"""Link delay-model regression tests (sim/network.py bugfixes).

Pinned behaviors: symmetric jitter truncation keeps the sampled mean
one-way delay at the analytic ``expected_one_way_ms`` (the old one-sided
cut biased it upward); ``recent_rtt_ms`` is built from EXPLICITLY paired
outbound/return delays (``record_rtt`` with the caller's exchange sum)
instead of doubling a mixed mean (which double-counted serialization and
mixed window/verdict payload sizes) — delivery-order pairing is gone
entirely, since pipelined speculation interleaves directions; and the
verdict payload grows with γ as its contract (per-position logprobs)
promises.
"""

import random

import numpy as np
import pytest

from repro.sim.events import Environment
from repro.sim.network import (Link, LinkSpec, expected_one_way_ms,
                               expected_rtt_ms, sample_one_way_ms,
                               verdict_payload_bytes, window_payload_bytes)


def test_jitter_truncation_symmetric_mean():
    """Sampled mean one-way delay == analytic expectation, including when
    4·jitter_ms exceeds 0.9·RTT/2 (the regime the old asymmetric
    truncation biased upward)."""
    rng = random.Random(0)
    for spec in (LinkSpec(rtt_ms=10.0, jitter_ms=1.0),
                 LinkSpec(rtt_ms=2.0, jitter_ms=4.0),      # old bias regime
                 LinkSpec(rtt_ms=40.0, jitter_ms=8.0)):
        n = 20000
        mean = sum(sample_one_way_ms(spec, rng) for _ in range(n)) / n
        expect = expected_one_way_ms(spec)
        # symmetric truncation preserves the mean; tolerance covers
        # sampling noise (std ≈ jitter/2/√n)
        assert abs(mean - expect) < 0.05 * max(1.0, expect), (spec, mean)


def test_one_way_delay_positive_and_causal():
    rng = random.Random(1)
    spec = LinkSpec(rtt_ms=1.0, jitter_ms=50.0)   # jitter >> rtt
    for _ in range(2000):
        d = sample_one_way_ms(spec, rng)
        assert d > 0.0
        # bounded by half_rtt + truncation bound + serialization
        assert d <= 0.5 * 1.0 * 1.9 + expected_one_way_ms(spec, 64) + 1e-9


def test_recent_rtt_pairs_send_and_verdict():
    """recent_rtt_ms reconstructs the round trip from explicitly PAIRED
    one-way delays: with asymmetric payloads the estimate matches the
    analytic out+back RTT, not 2× either direction."""
    env = Environment()
    # huge payload asymmetry on a thin pipe makes direction mixing obvious
    spec = LinkSpec(rtt_ms=10.0, jitter_ms=0.0, bandwidth_gbps=0.001)
    link = Link(env, spec, random.Random(0))
    out_b, back_b = 10_000, 100
    for _ in range(8):
        link.transfer(out_b)       # window out
        d_out = link.last_delay_ms
        link.transfer(back_b)      # verdict back
        link.record_rtt(d_out + link.last_delay_ms)
    expect = expected_rtt_ms(spec, out_b, back_b)
    assert link.recent_rtt_ms == pytest.approx(expect, rel=1e-6)
    # transfers alone (no completed exchange recorded) must not contribute
    # half-pairs — the estimate falls back to the spec RTT
    link2 = Link(env, spec, random.Random(0))
    link2.transfer(out_b)
    assert link2.recent_rtt_ms == spec.rtt_ms


def test_recent_rtt_robust_to_interleaved_drafters():
    """A Link is shared by every drafter routed to its target: two
    drafters' outbound windows can interleave, so pairing must come from
    the caller's explicit exchange sums, not delivery order."""
    env = Environment()
    spec = LinkSpec(rtt_ms=10.0, jitter_ms=0.0, bandwidth_gbps=0.001)
    link = Link(env, spec, random.Random(0))
    out_b, back_b = 10_000, 100
    for _ in range(4):
        # drafter A and B both send windows before either verdict returns
        link.transfer(out_b)
        a_out = link.last_delay_ms
        link.transfer(out_b)
        b_out = link.last_delay_ms
        link.transfer(back_b)
        link.record_rtt(a_out + link.last_delay_ms)
        link.transfer(back_b)
        link.record_rtt(b_out + link.last_delay_ms)
    expect = expected_rtt_ms(spec, out_b, back_b)
    # order-based pairing would have produced out+out (two big
    # serializations) and back+back (two small) estimates instead
    assert link.recent_rtt_ms == pytest.approx(expect, rel=1e-6)


def test_recent_rtt_fallback_before_any_pair():
    env = Environment()
    link = Link(env, LinkSpec(rtt_ms=7.5), random.Random(0))
    assert link.recent_rtt_ms == 7.5


def test_verdict_payload_scales_with_gamma():
    """The verdict carries per-position logprobs: payload must grow with
    γ, and stay smaller than the window payload (ids + probs) it answers."""
    sizes = [verdict_payload_bytes(g) for g in (1, 4, 8, 12)]
    assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes)
    for g in (1, 4, 8, 12):
        assert verdict_payload_bytes(g) > verdict_payload_bytes(0)
        assert verdict_payload_bytes(g) < window_payload_bytes(g) + 48


def test_window_payload_monotone_in_node_count():
    """Node-count pricing: strictly monotone in n_nodes at fixed γ, and a
    degenerate 1-branch tree (T = 1 + γ) costs MORE than the plain chain
    at the same γ — the tree frame ships a parent table the chain frame
    doesn't need."""
    for g in (1, 3, 8):
        sizes = [window_payload_bytes(g, n_nodes=n) for n in range(1, 40)]
        assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes)
        assert window_payload_bytes(g, n_nodes=1 + g) > \
            window_payload_bytes(g)


def test_tree_window_msg_payload_matches_model():
    """WindowMsg.payload_bytes must equal the analytic node-count price
    byte for byte, for chains and trees alike, scaled by active rows."""
    from repro.distributed import WindowMsg
    for (g, b, n_active) in [(3, 1, 2), (4, 3, 1), (2, 4, 5), (3, 2, 0)]:
        T = 1 + g * b
        parent = np.zeros((T,), np.int32)
        toks = np.zeros((n_active or 1, T), np.int32)
        tree = WindowMsg(tokens=toks, gamma=g, n_active=n_active,
                         n_nodes=T, branches=b, parent=parent)
        assert tree.payload_bytes == \
            max(1, n_active) * window_payload_bytes(g, n_nodes=T)
        chain = WindowMsg(tokens=toks[:, :g], gamma=g, n_active=n_active)
        assert chain.payload_bytes == \
            max(1, n_active) * window_payload_bytes(g)


# --------------------------------------------------------------------------
# Wire hardening: the byte seam must fail loudly, never cryptically
# --------------------------------------------------------------------------

from repro.distributed import (InProcessTransport, SocketTransport,
                               TransportProtocolError, VerdictMsg, WindowMsg,
                               decode_verdict, decode_window, encode_verdict,
                               encode_window)


def _window(B=2, G=3, tree=False, **kw):
    T = 1 + G if tree else G
    msg = WindowMsg(tokens=np.arange(B * T, dtype=np.int32).reshape(B, T),
                    gamma=G, n_active=B, round_id=5, **kw)
    if tree:
        msg.n_nodes = T
        msg.parent = np.maximum(np.arange(T, dtype=np.int32) - 1, 0)
    return msg


def _verdict(B=2, D=0):
    z = np.arange(B, dtype=np.int32)
    path = np.arange(B * D, dtype=np.int32).reshape(B, D) if D else None
    return VerdictMsg(n_accepted=z, num_new=z + 1, next_token=z + 2,
                      last_token=z + 3, done=np.array([False, True][:B] or
                                                      [False]),
                      gamma=3, n_active=B, round_id=5, path=path)


def test_encode_window_refuses_q_probs():
    """q_probs are the temperature>0 draft distributions — device
    passthrough only. Serializing a window that carries them would
    silently break the stochastic accept rule downstream, so the encoder
    must refuse, not drop."""
    msg = _window()
    msg.q_probs = np.zeros((2, 3, 128), np.float32)
    with pytest.raises(ValueError, match="q_probs"):
        encode_window(msg)


@pytest.mark.parametrize("tree", [False, True])
def test_decode_window_rejects_every_truncated_prefix(tree):
    blob = encode_window(_window(tree=tree))
    got = decode_window(blob)
    np.testing.assert_array_equal(got.tokens, _window(tree=tree).tokens)
    for cut in range(len(blob)):
        with pytest.raises(ValueError):
            decode_window(blob[:cut])


@pytest.mark.parametrize("D", [0, 2])
def test_decode_verdict_rejects_every_truncated_prefix(D):
    blob = encode_verdict(_verdict(D=D))
    got = decode_verdict(blob)
    np.testing.assert_array_equal(got.num_new, _verdict(D=D).num_new)
    for cut in range(len(blob)):
        with pytest.raises(ValueError):
            decode_verdict(blob[:cut])


def test_decode_rejects_wrong_magic_and_names_offset():
    wmsg, vmsg = _window(), _verdict()
    wire_w, wire_v = encode_window(wmsg), encode_verdict(vmsg)
    # a verdict handed to the window decoder (crossed streams) dies on
    # the magic, before any header field is trusted
    with pytest.raises(ValueError, match="magic.*offset 0"):
        decode_window(wire_v)
    with pytest.raises(ValueError, match="magic.*offset 0"):
        decode_verdict(wire_w)
    # trailing garbage is corruption, not silence — and the error names
    # the offset where the declared payload ended
    with pytest.raises(ValueError, match=f"offset {len(wire_w)}"):
        decode_window(wire_w + b"\x00\x00")
    with pytest.raises(ValueError, match="mismatch"):
        decode_verdict(wire_v + b"junk")


def test_decode_rejects_implausible_header_counts():
    blob = bytearray(encode_window(_window()))
    # corrupt the declared batch count (offset 16: 4s q i i -> B field)
    import struct as _struct
    _struct.pack_into("<i", blob, 20, -3)
    with pytest.raises(ValueError, match="implausible"):
        decode_window(bytes(blob))


def test_transport_recv_on_empty_stream_is_protocol_error():
    """A recv/discard with nothing in flight used to escape as a bare
    IndexError from the deque; it must surface as a descriptive
    TransportProtocolError naming the stream."""
    tr = InProcessTransport()
    with pytest.raises(TransportProtocolError, match="'window'"):
        tr.recv_window()
    with pytest.raises(TransportProtocolError, match="'verdict'"):
        tr.recv_verdict()
    with pytest.raises(TransportProtocolError, match="discard_window"):
        tr.discard_window()


def test_checked_transport_reports_transport_errors_as_violations():
    """CheckedTransport translates transport-level protocol errors into
    ProtocolViolation at the offending call: a q_probs-bearing window
    hitting the socket codec is refused by encode_window, and the checker
    reports the refusal instead of leaking a codec ValueError."""
    from repro.analysis import CheckedTransport, ProtocolViolation
    tr = CheckedTransport(SocketTransport.loopback())
    try:
        msg = _window()
        msg.q_probs = np.zeros((2, 3, 128), np.float32)
        with pytest.raises(ProtocolViolation, match="transport protocol"):
            tr.post_window(msg)
    finally:
        tr._inner.close()


# ------------------------------------------------------------ frame layer

def test_socket_frame_roundtrip_and_rejections():
    import socket as _socket

    from repro.distributed.socket_transport import (_FRAME_HDR,
                                                    _MAX_FRAME_BYTES,
                                                    FRAME_WINDOW, recv_frame,
                                                    send_frame)
    a, b = _socket.socketpair()
    try:
        a.settimeout(5.0)
        b.settimeout(5.0)
        payload = encode_window(_window())
        send_frame(a, FRAME_WINDOW, payload, delay_ms=1.5)
        kind, got, _ready, delay = recv_frame(b)
        assert kind == FRAME_WINDOW and got == payload and delay == 1.5
        np.testing.assert_array_equal(decode_window(got).tokens,
                                      _window().tokens)
        # unknown frame kind is refused at the sender
        with pytest.raises(TransportProtocolError, match="kind"):
            send_frame(a, 77, b"x")
        # oversize length is refused before any allocation at the receiver
        a.sendall(_FRAME_HDR.pack(b"DSDF", FRAME_WINDOW, 0.0, 0.0,
                                  _MAX_FRAME_BYTES + 1))
        with pytest.raises(TransportProtocolError, match="frame bound"):
            recv_frame(b)
        # line noise dies on the frame magic
        a.sendall(_FRAME_HDR.pack(b"XXXX", FRAME_WINDOW, 0.0, 0.0, 0))
        with pytest.raises(TransportProtocolError, match="magic"):
            recv_frame(b)
        # peer hanging up mid-frame is a protocol error, not an EOFError
        a.sendall(_FRAME_HDR.pack(b"DSDF", FRAME_WINDOW, 0.0, 0.0, 64))
        a.close()
        with pytest.raises(TransportProtocolError, match="closed"):
            recv_frame(b)
    finally:
        for s in (a, b):
            try:
                s.close()
            except OSError:
                pass


def test_socket_loopback_transport_roundtrip_counts_wire_bytes():
    tr = SocketTransport.loopback()
    try:
        w = _window()
        tr.post_window(w)
        got, _ = tr.recv_window()
        np.testing.assert_array_equal(got.tokens, w.tokens)
        v = _verdict()
        v.round_id = w.round_id
        tr.post_verdict(v)
        got_v, _ = tr.recv_verdict()
        np.testing.assert_array_equal(got_v.last_token, v.last_token)
        assert tr.in_flight == 0
        # wire_bytes counts ACTUAL framed bytes; bytes_sent stays the
        # modeled payload accounting the sim shares
        assert tr.wire_bytes >= len(encode_window(w)) + len(encode_verdict(v))
        assert tr.bytes_sent == w.payload_bytes + v.payload_bytes
    finally:
        tr.close()
