"""Shared scenario table + builders for the conformance harness.

This is the ONE fixture module for the distributed/session test files:
tiny model-pair configs (dense / ssm / hybrid), engine builders (random
pair for bit-identity anchors, noised-copy pair for controlled acceptance
rates), transport builders, window-policy factories and the scenario
grid the conformance tests sweep. ``test_distributed.py`` and
``test_session.py`` import their fixtures from here instead of redefining
them.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import CheckedTransport
from repro.configs.base import ModelConfig
from repro.core.engine import SpecDecodeEngine
from repro.core.session import DecodeSession
from repro.core.window import (AWCWindowPolicy, DynamicWindowPolicy,
                               StaticWindowPolicy)
from repro.distributed import (EmulatedLinkTransport, InProcessTransport,
                               SocketTransport)
from repro.sim.network import LinkSpec

# ----------------------------------------------------------- model configs

DRAFT = ModelConfig(name="d", arch_type="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                    dtype="float32", remat=False)
TARGETS = {
    "dense": dataclasses.replace(DRAFT, name="t", n_layers=3, n_kv_heads=4),
    "ssm": ModelConfig(name="ts", arch_type="ssm", n_layers=2, d_model=64,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
                       ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                       dtype="float32", remat=False, tie_embeddings=True),
    "hybrid": ModelConfig(name="th", arch_type="hybrid", n_layers=4,
                          d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                          head_dim=16, vocab=128, ssm_state=16,
                          ssm_head_dim=16, ssm_chunk=8, attn_every=2,
                          dtype="float32", remat=False),
}
GAMMA = 3


def make_engine(family: str = "dense", temperature: float = 0.0,
                seed: int = 7, **kw) -> SpecDecodeEngine:
    """Random independent draft/target pair (low acceptance — the
    bit-identity anchor: greedy commits are draft-invariant)."""
    return SpecDecodeEngine(DRAFT, TARGETS[family], temperature=temperature,
                            key=jax.random.PRNGKey(seed), **kw)


def noised_draft_params(target_params, scale: float, seed: int = 42):
    """Draft = target + N(0, (scale·std)²) per tensor: same architecture,
    controllably-degraded predictions → tunable acceptance rate."""
    leaves, treedef = jax.tree.flatten(target_params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        if isinstance(leaf, jax.Array) and leaf.ndim > 0:
            leaf = leaf + scale * jnp.std(leaf) * jax.random.normal(
                k, leaf.shape, leaf.dtype)
        out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def make_noised_engine(family: str = "dense", noise: float = 0.01,
                       seed: int = 0, **kw) -> SpecDecodeEngine:
    """Same-architecture draft/target where the draft is a noised copy of
    the target (acceptance ≈ 0.8 at noise 0.01) — high enough that
    pipeline hits and partial-accept rollbacks both occur. The draft
    family equals the target family, so recurrent-draft rollback paths
    get exercised for ssm/hybrid."""
    from repro.models.model import build_model
    cfg = TARGETS[family]
    tparams = build_model(cfg).init_params(jax.random.PRNGKey(seed))
    return SpecDecodeEngine(cfg, cfg, draft_params=noised_draft_params(
        tparams, noise), target_params=tparams, temperature=0.0,
        key=jax.random.PRNGKey(seed), **kw)


# -------------------------------------------------------------- transports

def make_transport(kind: str, rtt_ms: float = 20.0, seed: int = 0):
    """'inproc' (zero delay), 'link' (emulated, virtual clock — fast and
    deterministic), 'link-sleep' (emulated, real wall-clock sleeps) or
    'socket' (loopback :class:`~repro.distributed.SocketTransport`: every
    message length-prefix framed through the kernel's TCP stack).

    Every conformance transport is wrapped in
    :class:`repro.analysis.CheckedTransport`: the whole matrix runs with
    the full-duplex protocol state machine validated per round id, so an
    out-of-order post/recv/discard fails the suite at the violating call,
    not as a downstream token mismatch."""
    if kind == "inproc":
        return CheckedTransport(InProcessTransport())
    if kind == "socket":
        # keep the conformance sweep fast: the socket column checks the
        # byte seam (frame → TCP → frame), not the delay model
        return CheckedTransport(SocketTransport.loopback(seed=seed))
    spec = LinkSpec(rtt_ms=rtt_ms, jitter_ms=max(0.5, rtt_ms * 0.08))
    if kind == "link":
        return CheckedTransport(EmulatedLinkTransport(spec, seed=seed,
                                                      sleep=False))
    if kind == "link-sleep":
        return CheckedTransport(EmulatedLinkTransport(spec, seed=seed,
                                                      sleep=True))
    raise ValueError(kind)


# ----------------------------------------------------------------- policies

def rtt_predictor(feats):
    """RTT-sensitive stand-in for the WC-DNN: γ large on a fast link,
    fused (γ ≤ 1) past 10 ms — the closed-loop fixture both the real and
    sim conformance runs share."""
    return 1.0 if feats[2] > 10.0 else 6.0


def make_policy(name: str, branches: int = 1):
    if name == "static":
        return StaticWindowPolicy(GAMMA, branches=branches)
    if name == "dynamic":
        return DynamicWindowPolicy(gamma0=GAMMA, gmax=6)
    if name == "awc-rtt":
        return AWCWindowPolicy(rtt_predictor)
    raise ValueError(name)


# ------------------------------------------------------------ scenario grid

@dataclass(frozen=True)
class Scenario:
    """One cell of the conformance grid: a model pair decoding a fixed
    prompt set over (transport RTT × γ policy × mode policy)."""
    family: str = "dense"
    rtt_ms: float = 0.0
    policy: str = "static"
    mode_policy: str = "auto"
    gamma_max: int = 6
    max_new: int = 10
    batch: int = 2
    seed: int = 3
    max_branches: int = 0     # > 0: tree-speculation session at this bound
    branches: int = 1         # per-round width the static policy requests

    @property
    def id(self) -> str:
        tree = f"-tree{self.max_branches}x{self.branches}" \
            if self.max_branches else ""
        return (f"{self.family}-rtt{self.rtt_ms:g}-{self.policy}-"
                f"{self.mode_policy}{tree}")


# RTT × γ-policy × mode-policy × model-pair. Half-duplex vs pipelined vs
# fused cells share (family, policy, rtt) so their committed tokens are
# directly comparable; the awc-rtt rows close the feature loop over the
# transport's measured RTT.
SCENARIOS = [
    Scenario(family="dense", rtt_ms=0.0, policy="static",
             mode_policy="auto"),
    Scenario(family="dense", rtt_ms=0.0, policy="static",
             mode_policy="pipeline"),
    Scenario(family="dense", rtt_ms=20.0, policy="static",
             mode_policy="pipeline"),
    Scenario(family="dense", rtt_ms=20.0, policy="static",
             mode_policy="fused"),
    Scenario(family="dense", rtt_ms=20.0, policy="dynamic",
             mode_policy="auto"),
    Scenario(family="dense", rtt_ms=0.0, policy="awc-rtt",
             mode_policy="auto"),
    Scenario(family="dense", rtt_ms=20.0, policy="awc-rtt",
             mode_policy="auto"),
    Scenario(family="dense", rtt_ms=20.0, policy="awc-rtt",
             mode_policy="pipeline"),
    Scenario(family="ssm", rtt_ms=20.0, policy="static",
             mode_policy="pipeline"),
    Scenario(family="hybrid", rtt_ms=20.0, policy="static",
             mode_policy="pipeline"),
    # tree speculation (attention-family, greedy, non-pipeline only):
    # the degenerate 1-branch cell anchors bit-identity with the linear
    # chain; the wide cell checks transport-invariance of real trees.
    Scenario(family="dense", rtt_ms=0.0, policy="static",
             mode_policy="distributed", max_branches=1, branches=1),
    Scenario(family="dense", rtt_ms=20.0, policy="static",
             mode_policy="distributed", max_branches=3, branches=3),
]


def scenario_prompts(scn: Scenario) -> np.ndarray:
    rng = np.random.default_rng(scn.seed)
    return rng.integers(0, 128, (scn.batch, 9)).astype(np.int32)


def run_real(engine: SpecDecodeEngine, scn: Scenario, transport_kind: str):
    """Drive one scenario through a DecodeSession over the given
    transport; returns (tokens, stats, session)."""
    tr = (None if transport_kind == "none"
          else make_transport(transport_kind, scn.rtt_ms, seed=scn.seed))
    mode = "auto" if tr is None and scn.mode_policy == "pipeline" \
        else scn.mode_policy
    sess = DecodeSession(engine, capacity=scn.batch, max_new_cap=scn.max_new,
                         gamma_max=scn.gamma_max, sync_every=2, transport=tr,
                         mode_policy=mode, key=jax.random.PRNGKey(scn.seed),
                         max_branches=scn.max_branches)
    sess.admit_batch(scenario_prompts(scn), scn.max_new)
    policy = make_policy(scn.policy, branches=scn.branches)
    max_iters = 2 * scn.max_new + 4          # fused tail: 1 token/iter
    while sess.unfinished and sess.iterations < max_iters:
        sess.run_chunk(policy)
    tokens, stats = sess.snapshot()
    if isinstance(tr, CheckedTransport):
        # chunk boundaries drain the wire: a miss discards its superseded
        # speculative window before the chunk returns, so nothing may be
        # left in flight here
        tr.assert_drained()
        inner = tr._inner
        if isinstance(inner, SocketTransport):
            inner.close()
    return tokens, stats, sess
