"""Sim↔real↔wire conformance harness.

One shared scenario table (:mod:`conformance.scenarios`) drives the same
decode workloads through DSD-Sim, the zero-delay ``InProcessTransport``
and the ``EmulatedLinkTransport``, asserting bit-identity of greedy
tokens real-vs-real (across transports AND mode policies, including the
cross-round pipelined mode) and qualitative agreement (γ trend, fused
fraction) sim-vs-real. The fixture definitions here replace the per-test
model-config/engine setups that used to be duplicated across
``test_distributed.py`` and ``test_session.py``.
"""
