"""Conformance tests: one scenario table driven through DSD-Sim, the
zero-delay ``InProcessTransport`` and the ``EmulatedLinkTransport``.

Real-vs-real: greedy committed tokens must be BIT-identical across
transports and mode policies (half-duplex, cross-round pipelined, fused)
for every scenario — delay models and overlap schedules may move time
around but never tokens. Sim-vs-real: the same RTT-sensitive AWC
predictor must adapt in the same DIRECTION (γ trend, fused fraction) on
both paths when the link slows down.
"""

import numpy as np
import pytest

from repro.core.window import AWCWindowPolicy
from repro.sim import (ClusterSpec, DSDSimulation, LinkSpec, PolicyStack,
                       TraceRecord)

from conformance.scenarios import (SCENARIOS, Scenario, make_engine,
                                   make_noised_engine, rtt_predictor,
                                   run_real)

_ENGINES: dict = {}


def _engine(family):
    if family not in _ENGINES:
        _ENGINES[family] = make_engine(family, gamma_max=6)
    return _ENGINES[family]


def _scn_params():
    out = []
    for s in SCENARIOS:
        marks = [pytest.mark.slow] if s.family != "dense" else []
        out.append(pytest.param(s, id=s.id, marks=marks))
    return out


@pytest.mark.parametrize("scn", _scn_params())
def test_real_vs_real_bit_identity(scn: Scenario):
    """Colocated == in-process transport == emulated link, token for
    token, for every (RTT, γ-policy, mode-policy, family) cell — the
    pipelined cells additionally prove optimistic drafting + rollback
    never perturbs the committed stream."""
    eng = _engine(scn.family)
    ref, ref_stats, _ = run_real(eng, scn, "none")
    got_ip, stats_ip, _ = run_real(eng, scn, "inproc")
    got_lk, stats_lk, _ = run_real(eng, scn, "link")
    got_sk, stats_sk, _ = run_real(eng, scn, "socket")
    np.testing.assert_array_equal(ref, got_ip)
    np.testing.assert_array_equal(ref, got_lk)
    # fourth column: the framed TCP loopback — greedy commits survive the
    # byte seam bit for bit
    np.testing.assert_array_equal(ref, got_sk)
    # tokens-per-request bookkeeping agrees too (not just the buffers)
    np.testing.assert_array_equal(ref_stats.produced, stats_ip.produced)
    np.testing.assert_array_equal(ref_stats.produced, stats_lk.produced)
    np.testing.assert_array_equal(ref_stats.produced, stats_sk.produced)


def test_degenerate_tree_matches_linear():
    """max_branches=1 compiles the grid-tree step, yet its committed
    greedy stream must be BIT-identical to the linear-chain engine on
    every transport — the tree accept rule collapses to the masked-window
    prefix rule when there is one branch."""
    import dataclasses
    eng = _engine("dense")
    lin = Scenario(policy="static", mode_policy="distributed", rtt_ms=0.0)
    tree = dataclasses.replace(lin, max_branches=1, branches=1)
    for kind in ("none", "inproc", "link"):
        ref, ref_stats, _ = run_real(eng, lin, kind)
        got, got_stats, _ = run_real(eng, tree, kind)
        np.testing.assert_array_equal(ref, got)
        np.testing.assert_array_equal(ref_stats.produced, got_stats.produced)


def test_wide_tree_commits_on_noised_pair():
    """A 3-branch tree on a noised-copy pair (α ≈ 0.8) must still match
    its own run across transports and actually accept draft tokens."""
    eng = make_noised_engine("dense", gamma_max=6)
    scn = Scenario(policy="static", mode_policy="distributed", rtt_ms=20.0,
                   max_new=16, max_branches=3, branches=3)
    ref, ref_stats, sess = run_real(eng, scn, "none")
    got, _, _ = run_real(eng, scn, "link")
    np.testing.assert_array_equal(ref, got)
    assert sum(map(sum, ref_stats.acceptance_seqs)) > 0, \
        "noised pair should accept tree tokens"


def test_pipeline_hits_preserve_tokens():
    """With a noised-copy draft (α ≈ 0.8) the pipelined path takes BOTH
    branches — kept optimistic windows and rollbacks — and still commits
    exactly the half-duplex stream."""
    eng = make_noised_engine("dense", gamma_max=6)
    scn_hd = Scenario(policy="static", mode_policy="distributed",
                      rtt_ms=20.0, max_new=16)
    scn_pl = Scenario(policy="static", mode_policy="pipeline",
                      rtt_ms=20.0, max_new=16)
    hd, _, _ = run_real(eng, scn_hd, "link")
    pl, _, sess = run_real(eng, scn_pl, "link")
    np.testing.assert_array_equal(hd, pl)
    assert sess.pipeline_hits > 0, "noised pair should hit sometimes"
    assert sess.pipeline_misses > 0, "and roll back sometimes"


def test_socket_pipeline_discard_preserves_tokens():
    """The pipelined path over the TCP loopback: a noised-copy draft
    (α ≈ 0.8) takes both the kept-optimistic-window and the
    rollback-discard branches, so superseded speculative windows are
    physically read off the socket and dropped — and the committed stream
    still equals the half-duplex in-process run."""
    eng = make_noised_engine("dense", gamma_max=6)
    scn_hd = Scenario(policy="static", mode_policy="distributed",
                      rtt_ms=0.0, max_new=16)
    scn_pl = Scenario(policy="static", mode_policy="pipeline",
                      rtt_ms=0.0, max_new=16)
    hd, _, _ = run_real(eng, scn_hd, "inproc")
    pl, _, sess = run_real(eng, scn_pl, "socket")
    np.testing.assert_array_equal(hd, pl)
    assert sess.pipeline_hits > 0, "noised pair should hit sometimes"
    assert sess.pipeline_misses > 0, "and roll back sometimes"


def test_awc_loop_closes_same_direction_sim_and_real():
    """Qualitative sim↔real agreement: the SAME rtt-sensitive predictor
    keeps γ large on a zero-delay link and flips toward fused mode at
    20 ms, both on real models (transport-measured RTT) and in DSD-Sim
    (link-measured RTT) replaying the real path's acceptance traces."""
    eng = _engine("dense")
    results = {}
    for rtt in (0.0, 20.0):
        scn = Scenario(policy="awc-rtt", mode_policy="auto", rtt_ms=rtt,
                       max_new=10)
        kind = "inproc" if rtt == 0 else "link"
        _, stats, sess = run_real(eng, scn, kind)
        results[rtt] = (sess.fused_iterations / max(1, sess.iterations),
                        float(np.mean(stats.gamma_seq)),
                        stats.acceptance_seqs)
    real_lo, real_hi = results[0.0], results[20.0]
    assert real_hi[0] > real_lo[0] or real_hi[1] < real_lo[1], \
        "real path must shrink γ / flip fused as the link slows"

    sim_stats = {}
    for rtt in (0.1, 20.0):
        records = [TraceRecord(request_id=i, prompt_length=9,
                               output_length=10,
                               acceptance_seq=seq or [0] * 10,
                               arrival_time_ms=0.0, drafter_id=i,
                               dataset="conformance")
                   for i, seq in enumerate(results[20.0][2])]
        sim = DSDSimulation(
            ClusterSpec(num_targets=1, num_drafters=len(records),
                        link=LinkSpec(rtt_ms=rtt, jitter_ms=0.5),
                        target_hw="A100", target_model="llama2-7b",
                        target_tp=1),
            PolicyStack(window=AWCWindowPolicy(rtt_predictor)),
            records, seed=0)
        an = sim.run()
        gam, modes = [], []
        for m in an.requests.values():
            gam.extend(m.gamma_sequence)
            modes.extend(m.mode_sequence)
        fused_frac = (sum(md == "fused" for md in modes) / len(modes)
                      if modes else 0.0)
        sim_stats[rtt] = (fused_frac, float(np.mean(gam)))
    sim_lo, sim_hi = sim_stats[0.1], sim_stats[20.0]
    assert sim_hi[0] > sim_lo[0] or sim_hi[1] < sim_lo[1], \
        "sim must adapt in the same direction as the real path"


def test_sim_pipeline_overlap_beats_half_duplex():
    """DSD-Sim's pipelined overlap model: with a high-acceptance trace on
    a slow link, pipeline=True finishes the same workload faster and
    records hits; on a zero-ish-RTT link the two models coincide."""
    def run(rtt, pipeline):
        records = [TraceRecord(request_id=i, prompt_length=16,
                               output_length=48,
                               acceptance_seq=([1] * 8 + [1, 1, 0, 1]) * 6,
                               arrival_time_ms=0.0, drafter_id=i,
                               dataset="conformance")
                   for i in range(4)]
        sim = DSDSimulation(
            ClusterSpec(num_targets=1, num_drafters=4,
                        link=LinkSpec(rtt_ms=rtt, jitter_ms=0.5),
                        target_hw="A100", target_model="llama2-7b",
                        target_tp=1),
            PolicyStack(), records, seed=0, pipeline=pipeline)
        an = sim.run()
        return an.summary()["token_throughput_tps"], an

    slow_hd, _ = run(40.0, False)
    slow_pl, an = run(40.0, True)
    assert slow_pl > slow_hd, (slow_pl, slow_hd)
    assert an.pipeline_hits > 0 and an.pipeline_misses > 0


def test_checked_transport_trips_on_injected_out_of_order_verdict():
    """The whole conformance matrix runs through CheckedTransport (see
    scenarios.make_transport) with zero protocol findings; this cell
    proves the detector is live by driving the protocol OUT of order on
    the same wrapped transport the matrix uses: a verdict posted for a
    round whose window the target never received must trip immediately."""
    from repro.analysis import ProtocolViolation
    from repro.distributed.wire import VerdictMsg, WindowMsg

    from conformance.scenarios import make_transport

    tr = make_transport("inproc")
    z = np.zeros(1, np.int32)

    def verdict(rid):
        return VerdictMsg(n_accepted=z, num_new=z, next_token=z,
                          last_token=z, done=np.zeros(1, bool), gamma=2,
                          n_active=1, round_id=rid)

    # round 0 flows correctly end to end
    tr.post_window(WindowMsg(tokens=np.zeros((1, 2), np.int32), gamma=2,
                             n_active=1, round_id=0))
    tr.recv_window()
    tr.post_verdict(verdict(0))
    tr.recv_verdict()
    # round 1's window was never posted or received — answering it is the
    # injected ordering violation
    with pytest.raises(ProtocolViolation, match="round 1.*before its window"):
        tr.post_verdict(verdict(1))
    # ...and a stale speculative window left on the wire at a chunk
    # boundary is the discard-protocol violation
    tr.post_window(WindowMsg(tokens=np.zeros((1, 2), np.int32), gamma=2,
                             n_active=1, round_id=2, speculative=True))
    with pytest.raises(ProtocolViolation, match="never discarded"):
        tr.assert_drained()
