"""Model-zoo correctness: incremental decode ≡ full forward; ring-buffer
sliding-window serving; ragged right-padded prefill; MoE no-drop equality."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model

B, S = 2, 16
FAMS = ["deepseek-7b", "qwen3-14b", "qwen2.5-3b", "mamba2-130m",
        "zamba2-1.2b"]


def _reduced(name, **over):
    cfg = ARCHS[name].reduced()
    return dataclasses.replace(cfg, **over) if over else cfg


@pytest.mark.parametrize("arch", FAMS)
@pytest.mark.slow
def test_incremental_equals_full(arch):
    cfg = _reduced(arch)
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = m.forward_train(params, {"tokens": toks})
    lg, cache = m.prefill(params, toks[:, :8], slots=S + 8)
    errs = [float(jnp.max(jnp.abs(full[:, 7] - lg[:, -1])))]
    for t in range(8, S):
        pos = jnp.full((B,), t, jnp.int32)
        lg1, cache = m.decode_step(params, toks[:, t], cache, pos)
        errs.append(float(jnp.max(jnp.abs(full[:, t] - lg1))))
    assert max(errs) < 1e-4, errs


@pytest.mark.parametrize("arch", ["llama4-maverick-400b-a17b", "arctic-480b"])
@pytest.mark.slow
def test_moe_incremental_equals_full_nodrop(arch):
    cfg = _reduced(arch, capacity_factor=8.0)   # no token drops
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = m.forward_train(params, {"tokens": toks})
    lg, cache = m.prefill(params, toks[:, :8], slots=S + 8)
    err = float(jnp.max(jnp.abs(full[:, 7] - lg[:, -1])))
    for t in range(8, S):
        pos = jnp.full((B,), t, jnp.int32)
        lg1, cache = m.decode_step(params, toks[:, t], cache, pos)
        err = max(err, float(jnp.max(jnp.abs(full[:, t] - lg1))))
    assert err < 1e-4


@pytest.mark.slow
def test_ring_buffer_equals_full_cache_within_window():
    """Sliding-window serving with a ring cache of exactly window slots must
    match full-cache attention restricted to the same window."""
    cfg = _reduced("deepseek-7b")
    W = 8
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 3 * W), 0, cfg.vocab)

    # full cache, windowed attention
    cache_f = m.init_cache(B, 3 * W + 4)
    pos0 = jnp.zeros((B,), jnp.int32)
    lf, cache_f = m.verify_step(params, toks, cache_f, pos0, window=W)

    # ring cache of W slots, decoding one token at a time
    cache_r = m.init_cache(B, W, ring=True)
    outs = []
    for t in range(3 * W):
        pos = jnp.full((B,), t, jnp.int32)
        lr, cache_r = m.decode_step(params, toks[:, t], cache_r, pos, window=W)
        outs.append(lr)
    ring = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(lf - ring)))
    assert err < 1e-4, err


@pytest.mark.slow
def test_ragged_right_padding_exact():
    """Right-padded prefill with prompt_lens must equal unpadded prefill."""
    for arch in ("deepseek-7b", "mamba2-130m", "zamba2-1.2b"):
        cfg = _reduced(arch)
        m = build_model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        lens = np.array([6, 11], np.int32)
        Smax = 12
        toks = np.zeros((2, Smax), np.int32)
        rows = [rng.integers(0, cfg.vocab, l).astype(np.int32) for l in lens]
        for i, r in enumerate(rows):
            toks[i, :len(r)] = r
        lg_pad, cache = m.prefill(params, jnp.asarray(toks), slots=32,
                                  prompt_lens=jnp.asarray(lens))
        for i, r in enumerate(rows):
            lg_solo, _ = m.prefill(params, jnp.asarray(r[None, :]), slots=32)
            a = lg_pad[i, lens[i] - 1]
            b = lg_solo[0, -1]
            assert float(jnp.max(jnp.abs(a - b))) < 1e-4, arch


def test_whisper_encdec_cross_attention_used():
    cfg = _reduced("whisper-tiny")
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    fe1 = jax.random.normal(jax.random.PRNGKey(2),
                            (B, cfg.n_frontend_tokens, cfg.d_model))
    fe2 = fe1 + 1.0
    l1, _ = m.forward_train(params, {"tokens": toks, "frontend": fe1})
    l2, _ = m.forward_train(params, {"tokens": toks, "frontend": fe2})
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3  # encoder affects decoder


def test_vlm_prefix_is_bidirectional_and_text_causal():
    cfg = _reduced("internvl2-76b")
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    P = cfg.n_frontend_tokens
    fe = jax.random.normal(jax.random.PRNGKey(2), (B, P, cfg.d_model))
    l1, _ = m.forward_train(params, {"tokens": toks, "frontend": fe})
    assert l1.shape == (B, S, cfg.vocab)
    # changing a LATE text token must not affect EARLY text logits (causal)
    toks2 = toks.at[:, -1].add(1)
    l2, _ = m.forward_train(params, {"tokens": toks2, "frontend": fe})
    assert float(jnp.max(jnp.abs(l1[:, :-1] - l2[:, :-1]))) < 1e-5
    # changing the image must affect text logits (prefix is attended)
    l3, _ = m.forward_train(params, {"tokens": toks, "frontend": fe + 1.0})
    assert float(jnp.max(jnp.abs(l1 - l3))) > 1e-3
