"""Training substrate: AdamW semantics, loss descent, data pipeline
determinism, checkpoint roundtrip, serving server integration."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.training import (AdamWConfig, DataConfig, SyntheticLM, TrainState,
                            adamw_init, adamw_update, checkpoint,
                            cosine_schedule, cross_entropy, init_train_state,
                            make_train_step)
from repro.core.engine import SpecDecodeEngine
from repro.core.window import StaticWindowPolicy
from repro.serving import ServeRequest, ServerConfig, SpecDecodeServer

TINY = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                   dtype="float32", remat=False)


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.array([5.0, -3.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||²
        params, state = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip_limits_update_norm():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    state = adamw_init(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    p2, _ = adamw_update(huge, state, params, cfg)
    assert float(jnp.abs(p2["w"]).max()) <= 1.5   # bounded step


def test_cosine_schedule_shape():
    sched = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(sched(jnp.array(0))) < 1e-4
    assert abs(float(sched(jnp.array(10))) - 1e-3) < 1e-9
    assert float(sched(jnp.array(100))) < 2e-4


def test_cross_entropy_ignores_masked_labels():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.array([[1, 2, -100, -100]])
    ce = cross_entropy(logits, labels)
    assert abs(float(ce) - float(jnp.log(8.0))) < 1e-5


def test_loss_decreases_on_synthetic_lm():
    model = build_model(TINY)
    opt = AdamWConfig(lr=3e-3)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt))
    data = SyntheticLM(DataConfig(vocab=256, seq_len=48, batch=8, seed=0))
    it = data.batches()
    losses = []
    for i in range(25):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step(state, b, jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_data_pipeline_deterministic():
    a = next(SyntheticLM(DataConfig(vocab=64, seq_len=16, batch=2,
                                    seed=7)).batches())
    b = next(SyntheticLM(DataConfig(vocab=64, seq_len=16, batch=2,
                                    seed=7)).batches())
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_checkpoint_roundtrip(tmp_path):
    model = build_model(TINY)
    params = model.init_params(jax.random.PRNGKey(0))
    path = str(tmp_path / "p.npz")
    checkpoint.save(params, path)
    restored = checkpoint.restore(params, path)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_server_wave_equals_unbatched():
    tcfg = dataclasses.replace(TINY, name="t", n_layers=3, n_kv_heads=4,
                               vocab=128)
    dcfg = dataclasses.replace(TINY, name="d", vocab=128)
    eng = SpecDecodeEngine(dcfg, tcfg, temperature=0.0,
                           key=jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(i, rng.integers(0, 128, int(rng.integers(5, 14)))
                         .astype(np.int32), 12) for i in range(4)]
    srv = SpecDecodeServer(eng, StaticWindowPolicy(3),
                           ServerConfig(max_batch=4, pad_to=4))
    for r in reqs:
        srv.submit(r)
    results = {r.request_id: r for r in srv.run()}
    for r in reqs:
        single, _ = eng.generate(r.prompt[None, :], 12, StaticWindowPolicy(3))
        np.testing.assert_array_equal(single[0, :12],
                                      results[r.request_id].tokens[:12])
        assert results[r.request_id].tpot_ms > 0
