"""AWC tests: WC-DNN architecture/training, stabilization semantics
(clamp / EMA / hysteresis), policy integration."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.awc import model as wcdnn
from repro.core.awc.stabilize import StabilizerConfig, WindowStabilizer
from repro.core.awc.train import TrainConfig, train
from repro.core.window import (AWCWindowPolicy, DynamicWindowPolicy,
                               FeatureSnapshot, StaticWindowPolicy)


def _feats(alpha=0.7, rtt=10.0, q=0.2, tpot=40.0, gp=4.0):
    return FeatureSnapshot(q_depth=q, alpha_recent=alpha, rtt_recent_ms=rtt,
                           tpot_recent_ms=tpot, gamma_prev=gp)


# ------------------------------------------------------------------ WC-DNN

def test_wcdnn_forward_shapes():
    p = wcdnn.init(jax.random.PRNGKey(0))
    x = jnp.ones((7, wcdnn.FEATURE_DIM))
    out = wcdnn.forward(p, x)
    assert out.shape == (7,)
    assert wcdnn.forward(p, jnp.ones(wcdnn.FEATURE_DIM)).shape == ()


def test_wcdnn_numpy_predictor_matches_jax():
    p = wcdnn.init(jax.random.PRNGKey(1))
    pred = wcdnn.numpy_predictor(p)
    x = np.random.default_rng(0).normal(
        size=(10, wcdnn.FEATURE_DIM)).astype(np.float32)
    jx = np.asarray(wcdnn.forward(p, jnp.asarray(x)))
    nx = np.array([pred(list(row)) for row in x])
    np.testing.assert_allclose(jx, nx, atol=1e-5)


def test_wcdnn_learns_synthetic_mapping():
    """Supervised regression (L1+AdamW) fits a nonlinear γ(features) map."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(2000, wcdnn.FEATURE_DIM)).astype(np.float32)
    y = (4 + 3 * np.tanh(X[:, 1]) - 2 * np.tanh(X[:, 2]) +
         np.clip(X[:, 0], -1, 1)).astype(np.float32)
    params, info = train(X, y, TrainConfig(epochs=40, lr=3e-3, seed=0))
    assert info["val_mae"] < 0.35, info


def test_wcdnn_save_load_roundtrip(tmp_path):
    p = wcdnn.init(jax.random.PRNGKey(2))
    path = str(tmp_path / "wc.npz")
    wcdnn.save(p, path)
    q = wcdnn.load(path)
    x = jnp.ones((3, wcdnn.FEATURE_DIM))
    np.testing.assert_allclose(np.asarray(wcdnn.forward(p, x)),
                               np.asarray(wcdnn.forward(q, x)))


# --------------------------------------------------------------- stabilizer

def test_clamping():
    st = WindowStabilizer(StabilizerConfig(clamp_lo=1, clamp_hi=12))
    g, _ = st.step(99.0)
    assert g <= 12
    st.reset()
    g, _ = st.step(-5.0)
    assert g >= 1


def test_ema_smooths_oscillation():
    st = WindowStabilizer(StabilizerConfig(ema_alpha=0.4))
    outs = [st.step(v)[0] for v in [2, 10, 2, 10, 2, 10, 2, 10]]
    # raw oscillation amplitude 8; EMA output must stay well inside
    assert max(outs) - min(outs) < 8


def test_hysteresis_requires_k_consecutive_low_steps():
    cfg = StabilizerConfig(hysteresis_k=2, ema_alpha=1.0)  # no smoothing
    st = WindowStabilizer(cfg)
    assert st.step(5.0)[1] == "distributed"
    assert st.step(1.0)[1] == "distributed"    # 1st low step: still sticky
    assert st.step(1.0)[1] == "fused"          # 2nd consecutive: switch
    # leaving fused also needs k consecutive high predictions
    assert st.step(8.0)[1] == "fused"
    assert st.step(8.0)[1] == "distributed"


def test_fused_mode_forces_gamma_one():
    st = WindowStabilizer(StabilizerConfig(hysteresis_k=1, ema_alpha=1.0))
    g, mode = st.step(0.5)
    assert mode == "fused" and g == 1


# ------------------------------------------------------------------ policies

def test_static_policy_constant():
    p = StaticWindowPolicy(6)
    for a in (0.1, 0.9):
        d = p.decide("x", _feats(alpha=a))
        assert d.gamma == 6 and d.mode == "distributed"


def test_dynamic_policy_thresholds():
    p = DynamicWindowPolicy(hi=0.75, lo=0.25, gamma0=4)
    assert p.decide("k", _feats(alpha=0.9)).gamma == 5    # grows
    assert p.decide("k", _feats(alpha=0.9)).gamma == 6
    assert p.decide("k", _feats(alpha=0.1)).gamma == 5    # shrinks
    assert p.decide("other", _feats(alpha=0.5)).gamma == 4  # per-pair state


def test_awc_policy_per_pair_state():
    calls = []

    def pred(f):
        calls.append(f)
        return 1.0 if f[1] < 0.3 else 8.0

    p = AWCWindowPolicy(pred)
    # low-acceptance pair trends to fused
    for _ in range(4):
        d_low = p.decide("low", _feats(alpha=0.1))
    d_high = p.decide("high", _feats(alpha=0.9))
    assert d_low.mode == "fused" and d_low.gamma == 1
    assert d_high.mode == "distributed" and d_high.gamma >= 4


def test_bootstrap_gamma_sane():
    # high acceptance + high RTT → large window; low acceptance → small
    hi = wcdnn.bootstrap_gamma([0.1, 0.9, 60.0, 40.0, 4.0])
    lo = wcdnn.bootstrap_gamma([0.1, 0.2, 5.0, 40.0, 4.0])
    assert hi >= 6
    assert lo <= 3


def test_bootstrap_gamma_overlapped_rtt_term():
    """The 6th feature (pipeline hit rate) discounts the RTT stall: a
    fully-hit pipeline on a slow link behaves like a fast link (no flight
    to fused mode), while 5-feature callers keep the legacy behavior."""
    slow = [0.1, 0.6, 120.0, 10.0, 4.0]
    assert wcdnn.bootstrap_gamma(slow) == 1.0            # fused sentinel
    assert wcdnn.bootstrap_gamma(slow + [0.0]) == 1.0    # pipe never hits
    piped = wcdnn.bootstrap_gamma(slow + [1.0])
    fast = wcdnn.bootstrap_gamma([0.1, 0.6, 0.0, 10.0, 4.0])
    assert piped > 1.0                                   # stays distributed
    assert piped == fast                                 # RTT fully hidden
    # higher hit rates leave less stall to amortize, so the pure
    # distributed-mode γ* shrinks monotonically toward the zero-RTT optimum
    gammas = [wcdnn.bootstrap_gamma(slow + [h], mode_aware=False)
              for h in (0.0, 0.5, 1.0)]
    assert gammas == sorted(gammas, reverse=True)
    assert gammas[-1] == wcdnn.bootstrap_gamma(
        [0.1, 0.6, 0.0, 10.0, 4.0], mode_aware=False)
