"""Per-kernel allclose tests vs the pure-jnp oracles, sweeping shapes and
dtypes (deliverable c: kernel validation in interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.verify import verify_window_fused, verify_reference
from repro.kernels.decode_attn import (decode_attention,
                                       decode_attention_reference)
from repro.kernels.ssd import (ssd_chunked_kernel, ssd_chunked_reference,
                               ssd_recurrent_reference)


# ------------------------------------------------------------------ verify

@pytest.mark.parametrize("B,G,V", [(4, 4, 1024), (2, 6, 2000), (3, 1, 512),
                                   (5, 12, 4096), (1, 8, 50304)])
@pytest.mark.slow
def test_verify_kernel_matches_oracle(B, G, V):
    key = jax.random.PRNGKey(B * 1000 + G)
    p = jax.nn.softmax(jax.random.normal(key, (B, G + 1, V)) * 2, -1)
    q = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(1), (B, G, V)) * 2, -1)
    q = q.at[: B // 2].set(p[: B // 2, :G])     # exercise accept path
    toks = jax.random.categorical(jax.random.PRNGKey(2), jnp.log(q),
                                  axis=-1).astype(jnp.int32)
    u = jax.random.uniform(jax.random.PRNGKey(3), (B, G))
    r = jax.random.uniform(jax.random.PRNGKey(4), (B,))
    ref = verify_reference(toks, q, p, u, r)
    out = verify_window_fused(toks, q, p, u, r)
    np.testing.assert_array_equal(np.asarray(ref.n_accepted),
                                  np.asarray(out.n_accepted))
    np.testing.assert_array_equal(np.asarray(ref.next_token),
                                  np.asarray(out.next_token))
    np.testing.assert_array_equal(np.asarray(ref.accept_mask),
                                  np.asarray(out.accept_mask))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.slow
def test_verify_kernel_dtypes(dtype):
    B, G, V = 3, 4, 1024
    p = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(0), (B, G + 1, V)), -1).astype(dtype)
    q = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(1), (B, G, V)), -1).astype(dtype)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, G), 0, V)
    u = jax.random.uniform(jax.random.PRNGKey(3), (B, G))
    r = jax.random.uniform(jax.random.PRNGKey(4), (B,))
    ref = verify_reference(toks, q.astype(jnp.float32),
                           p.astype(jnp.float32), u, r)
    out = verify_window_fused(toks, q, p, u, r)
    np.testing.assert_array_equal(np.asarray(ref.n_accepted),
                                  np.asarray(out.n_accepted))


# --------------------------------------------------------------------- tree

@pytest.mark.parametrize("d_max,b_max,gamma,branches,V",
                         [(3, 1, 3, 1, 1024), (4, 3, 4, 3, 2000),
                          (4, 3, 2, 2, 1024), (5, 2, 0, 1, 512),
                          (3, 4, 3, 4, 50304)])
@pytest.mark.slow
def test_tree_verify_kernel_matches_oracle(d_max, b_max, gamma, branches, V):
    from repro.core.tree import TreeSpec, verify_tree_greedy
    from repro.kernels.verify.ops import tree_verify_fused

    spec = TreeSpec(d_max, b_max)
    T = spec.n_entries
    B = 3
    rng = np.random.default_rng(d_max * 100 + b_max)
    toks = rng.integers(0, V, (B, T)).astype(np.int32)
    logits = rng.normal(size=(B, T, V)).astype(np.float32)
    # plant accepted edges: target argmax at parent == child's draft token
    for bi in range(B):
        for e in range(1, T):
            if rng.random() < 0.5:
                logits[bi, spec.parent_np[e], toks[bi, e]] = 50.0
    nv = spec.node_valid(jnp.asarray(gamma), jnp.asarray(branches))
    ref = verify_tree_greedy(jnp.asarray(toks), jnp.asarray(logits),
                             spec.parent_entry, spec.tree_pos, nv,
                             spec.win_mask, d_max)
    n_acc, winner, bonus = tree_verify_fused(
        jnp.asarray(toks), jnp.asarray(logits), spec.parent_entry,
        spec.tree_pos, nv, spec.win_mask)
    np.testing.assert_array_equal(np.asarray(n_acc),
                                  np.asarray(ref.n_accepted))
    np.testing.assert_array_equal(np.asarray(winner), np.asarray(ref.winner))
    np.testing.assert_array_equal(np.asarray(bonus),
                                  np.asarray(ref.next_token))


# -------------------------------------------------------------- decode_attn

@pytest.mark.parametrize(
    "B,T,H,Hkv,hd,S,window,ring",
    [(2, 1, 8, 2, 64, 1024, 0, False),
     (2, 5, 8, 8, 64, 1024, 0, False),
     (1, 4, 16, 4, 128, 2048, 256, False),
     (3, 1, 4, 1, 64, 512, 128, True),
     (2, 3, 6, 2, 32, 700, 0, False)])      # uneven S → pad path
@pytest.mark.slow
def test_decode_attn_matches_oracle(B, T, H, Hkv, hd, S, window, ring):
    rng = np.random.default_rng(B + T + S)
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, hd), jnp.float32)
    pos = rng.integers(S // 2, S - 8, B)
    if ring:
        pm = np.stack([(np.arange(S) + (p // S) * S) for p in pos])
        pm = np.where(pm <= pos[:, None], pm, pm - S)
        pm = np.where(pm >= 0, pm, -1)
    else:
        pm = np.stack([np.where(np.arange(S) < p, np.arange(S), -1)
                       for p in pos])
    q_pos = np.stack([p + np.arange(T) for p in pos]).astype(np.int32)
    ref = decode_attention_reference(q, k, v, jnp.asarray(pm, jnp.int32),
                                     jnp.asarray(q_pos), window)
    out = decode_attention(q, k, v, jnp.asarray(pm, jnp.int32),
                           jnp.asarray(q_pos), window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=1e-4)


@pytest.mark.slow
def test_decode_attn_bf16():
    B, T, H, Hkv, hd, S = 2, 2, 4, 2, 64, 512
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, hd)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, hd)).astype(jnp.bfloat16)
    pm = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
    q_pos = jnp.full((B, T), S, jnp.int32) + jnp.arange(T)[None, :]
    ref = decode_attention_reference(q.astype(jnp.float32),
                                     k.astype(jnp.float32),
                                     v.astype(jnp.float32), pm, q_pos, 0)
    out = decode_attention(q, k, v, pm, q_pos, 0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=3e-2, rtol=3e-2)


# ----------------------------------------------------------------------- ssd

@pytest.mark.parametrize("B,S,nh,hd,N,chunk",
                         [(2, 64, 3, 16, 32, 16), (1, 128, 2, 64, 128, 32),
                          (2, 50, 2, 32, 64, 16), (1, 256, 4, 32, 16, 128)])
@pytest.mark.slow
def test_ssd_kernel_matches_recurrence(B, S, nh, hd, N, chunk):
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, nh, hd))
    Bm = jax.random.normal(jax.random.PRNGKey(1), (B, S, N)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(2), (B, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (B, S, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (nh,)))
    h0 = jax.random.normal(jax.random.PRNGKey(5), (B, nh, hd, N))
    y_ref, h_ref = ssd_recurrent_reference(x, Bm, Cm, dt, A, h0)
    y_k, h_k = ssd_chunked_kernel(x, Bm, Cm, dt, A, h0, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               atol=5e-4, rtol=1e-3)


@pytest.mark.slow
def test_ssm_block_kernel_flag_equivalence():
    """ssm_block_train(use_kernel=True) must match the jnp path exactly."""
    from repro.configs.base import ModelConfig
    from repro.models.ssm import init_ssm_params, ssm_block_train
    cfg = ModelConfig(name="s", arch_type="ssm", n_layers=1, d_model=64,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab=64,
                      ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
                      dtype="float32", remat=False)
    p = init_ssm_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 64))
    y0, s0 = ssm_block_train(x, p, cfg, use_kernel=False)
    y1, s1 = ssm_block_train(x, p, cfg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s0.h), np.asarray(s1.h),
                               atol=1e-4, rtol=1e-4)
