"""repro.analysis: lint rules (each DSD0xx flags its seeded-bad fixture
and passes the minimally-fixed twin), the engine CLI/baseline contract,
the self-scan (src/repro stays clean or explicitly baselined), the
compile_guard sentry and the CheckedTransport protocol state machine."""

import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (CheckedTransport, CompileGuard, ProtocolViolation,
                            RecompileError, compile_guard)
from repro.analysis import lint

REPO = Path(__file__).resolve().parents[1]


def lint_snippet(tmp_path, source, name="snippet.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return lint.run_paths([p])


def codes(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------- DSD001

BAD_TRACED_IF = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(x):
        y = jnp.cumsum(x)
        if y > 0:
            return y
        return -y
"""

FIXED_TRACED_IF = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(x):
        y = jnp.cumsum(x)
        return jnp.where(y > 0, y, -y)
"""

BAD_HOST_LEAKS = """
    import jax
    import jax.numpy as jnp
    import numpy as np

    @jax.jit
    def step(x):
        return helper(jnp.cumsum(x))

    def helper(y):
        n = int(y)                  # host-forcing cast
        z = np.asarray(y)           # numpy on a traced array
        return y.item() + n + z
"""

FIXED_HOST_LEAKS = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(x):
        return helper(jnp.cumsum(x))

    def helper(y):
        return y + y.sum()
"""


def test_dsd001_flags_traced_if(tmp_path):
    findings = lint_snippet(tmp_path, BAD_TRACED_IF)
    assert codes(findings) == ["DSD001"]
    assert "control flow" in findings[0].message


def test_dsd001_fixed_twin_passes(tmp_path):
    assert lint_snippet(tmp_path, FIXED_TRACED_IF) == []


def test_dsd001_flags_host_leaks_in_reachable_helper(tmp_path):
    findings = lint_snippet(tmp_path, BAD_HOST_LEAKS)
    assert codes(findings) == ["DSD001"]
    msgs = " | ".join(f.message for f in findings)
    assert "int()" in msgs and ".item()" in msgs and "numpy" in msgs


def test_dsd001_fixed_helper_passes(tmp_path):
    assert lint_snippet(tmp_path, FIXED_HOST_LEAKS) == []


def test_dsd001_ignores_unreachable_host_code(tmp_path):
    # same leaks, but nothing jit-compiles this function: not a finding
    assert lint_snippet(tmp_path, """
        import numpy as np

        def postprocess(y):
            if y > 0:
                return int(y)
            return np.asarray(y)
    """) == []


# ---------------------------------------------------------------- DSD002

BAD_DONATION = """
    import jax

    def run(state):
        step = jax.jit(lambda s: s, donate_argnums=(0,))
        out = step(state)
        loss = state.sum()          # state's buffer was donated away
        return out, loss
"""

FIXED_DONATION = """
    import jax

    def run(state):
        step = jax.jit(lambda s: s, donate_argnums=(0,))
        state = step(state)
        loss = state.sum()
        return state, loss
"""


def test_dsd002_flags_donated_reuse(tmp_path):
    findings = lint_snippet(tmp_path, BAD_DONATION)
    assert codes(findings) == ["DSD002"]
    assert "`state`" in findings[0].message


def test_dsd002_fixed_twin_passes(tmp_path):
    assert lint_snippet(tmp_path, FIXED_DONATION) == []


# ---------------------------------------------------------------- DSD003

BAD_WIRE = """
    import dataclasses

    @dataclasses.dataclass
    class PingMsg:
        token: int
        round_id: int
        flags: int

    def encode_ping(msg):
        return bytes([msg.token, msg.round_id])     # drops flags

    def decode_ping(blob):
        return PingMsg(token=blob[0], round_id=blob[1], flags=0)
"""

FIXED_WIRE = """
    import dataclasses

    @dataclasses.dataclass
    class PingMsg:
        token: int
        round_id: int
        flags: int

    def encode_ping(msg):
        return bytes([msg.token, msg.round_id, msg.flags])

    def decode_ping(blob):
        return PingMsg(token=blob[0], round_id=blob[1], flags=blob[2])
"""

PASSTHROUGH_WIRE = """
    import dataclasses

    @dataclasses.dataclass
    class PingMsg:
        token: int
        device_blob: object = None   # wire-passthrough: stays on device

    def encode_ping(msg):
        return bytes([msg.token])

    def decode_ping(blob):
        return PingMsg(token=blob[0])
"""


def test_dsd003_flags_dropped_field(tmp_path):
    findings = lint_snippet(tmp_path, BAD_WIRE)
    assert codes(findings) == ["DSD003"]
    assert any("encode_ping" in f.message and "flags" in f.message
               for f in findings)


def test_dsd003_fixed_twin_passes(tmp_path):
    assert lint_snippet(tmp_path, FIXED_WIRE) == []


def test_dsd003_passthrough_comment_exempts(tmp_path):
    assert lint_snippet(tmp_path, PASSTHROUGH_WIRE) == []


BAD_FRAMING = """
    FRAME_WINDOW = 1
    FRAME_VERDICT = 2
    FRAME_CONTROL = 3

    def encode_window(msg):
        return b"w"

    def decode_window(blob):
        return None

    FRAME_ENCODERS = {FRAME_WINDOW: encode_window, FRAME_VERDICT: encode_window}
    FRAME_DECODERS = {FRAME_WINDOW: decode_window, FRAME_VERDICT: decode_window}
"""

FIXED_FRAMING = """
    FRAME_WINDOW = 1
    FRAME_VERDICT = 2
    FRAME_CONTROL = 3

    def enc(msg):
        return b"w"

    def dec(blob):
        return None

    FRAME_ENCODERS = {FRAME_WINDOW: enc, FRAME_VERDICT: enc,
                      FRAME_CONTROL: enc}
    FRAME_DECODERS = {FRAME_WINDOW: dec, FRAME_VERDICT: dec,
                      FRAME_CONTROL: dec}
"""


def test_dsd003_frame_kind_missing_from_codec_tables(tmp_path):
    """Length-prefix framing parity: every FRAME_* kind constant must be
    routed through BOTH codec tables."""
    findings = lint_snippet(tmp_path, BAD_FRAMING)
    assert codes(findings) == ["DSD003"]
    msgs = [f.message for f in findings]
    assert any("FRAME_ENCODERS" in m and "FRAME_CONTROL" in m for m in msgs)
    assert any("FRAME_DECODERS" in m and "FRAME_CONTROL" in m for m in msgs)


def test_dsd003_frame_tables_absent_entirely(tmp_path):
    findings = lint_snippet(tmp_path, """
        FRAME_PING = 9

        def anything():
            pass
    """)
    assert codes(findings) == ["DSD003"]
    assert any("no FRAME_ENCODERS" in f.message for f in findings)
    assert any("no FRAME_DECODERS" in f.message for f in findings)


def test_dsd003_complete_frame_tables_pass(tmp_path):
    assert lint_snippet(tmp_path, FIXED_FRAMING) == []


def test_dsd003_missing_decode_flagged(tmp_path):
    findings = lint_snippet(tmp_path, """
        import dataclasses

        @dataclasses.dataclass
        class PingMsg:
            token: int

        def encode_ping(msg):
            return bytes([msg.token])
    """)
    assert codes(findings) == ["DSD003"]
    assert "no decode_ping" in findings[0].message


# ---------------------------------------------------------------- DSD004

BAD_PALLAS_INTERPRET = """
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def my_call(x, interpret=None):
        return pl.pallas_call(kernel, grid=(4,))(x)
"""

FIXED_PALLAS_INTERPRET = """
    from jax.experimental import pallas as pl
    from repro.kernels import resolve_interpret

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def my_call(x, interpret=None):
        interpret = resolve_interpret(interpret)
        return pl.pallas_call(kernel, grid=(4,), interpret=interpret)(x)
"""


def test_dsd004_flags_unrouted_interpret(tmp_path):
    findings = lint_snippet(tmp_path, BAD_PALLAS_INTERPRET)
    assert codes(findings) == ["DSD004"]


def test_dsd004_fixed_twin_passes(tmp_path):
    assert lint_snippet(tmp_path, FIXED_PALLAS_INTERPRET) == []


def test_dsd004_interpret_without_resolve_flagged(tmp_path):
    findings = lint_snippet(tmp_path, """
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def my_call(x, interpret=None):
            return pl.pallas_call(kernel, grid=(4,),
                                  interpret=interpret)(x)
    """)
    assert codes(findings) == ["DSD004"]
    assert "resolve_interpret" in findings[0].message


# ---------------------------------------------------------------- DSD005

BAD_GRID = """
    from jax.experimental import pallas as pl
    from repro.kernels import resolve_interpret

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def my_call(x, tile, interpret=None):
        interpret = resolve_interpret(interpret)
        V = x.shape[0]
        grid = (V // tile,)
        return pl.pallas_call(kernel, grid=grid, interpret=interpret)(x)
"""

FIXED_GRID = """
    from jax.experimental import pallas as pl
    from repro.kernels import resolve_interpret

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def my_call(x, tile, interpret=None):
        interpret = resolve_interpret(interpret)
        V = x.shape[0]
        assert V % tile == 0, (V, tile)
        grid = (V // tile,)
        return pl.pallas_call(kernel, grid=grid, interpret=interpret)(x)
"""


def test_dsd005_flags_tiled_grid_without_assert(tmp_path):
    findings = lint_snippet(tmp_path, BAD_GRID)
    assert codes(findings) == ["DSD005"]


def test_dsd005_fixed_twin_passes(tmp_path):
    assert lint_snippet(tmp_path, FIXED_GRID) == []


def test_dsd005_untiled_grid_needs_no_assert(tmp_path):
    # grid with no // (e.g. one program per row) is exempt, matching
    # tree_accept_call / the paged decode kernel
    assert lint_snippet(tmp_path, """
        from jax.experimental import pallas as pl
        from repro.kernels import resolve_interpret

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def my_call(x, interpret=None):
            interpret = resolve_interpret(interpret)
            B = x.shape[0]
            return pl.pallas_call(kernel, grid=(B,),
                                  interpret=interpret)(x)
    """) == []


# ------------------------------------------------------- engine + baseline

def test_noqa_suppresses(tmp_path):
    src = BAD_TRACED_IF.replace("if y > 0:", "if y > 0:  # noqa: DSD001")
    assert lint_snippet(tmp_path, src) == []
    other = BAD_TRACED_IF.replace("if y > 0:", "if y > 0:  # noqa: DSD004")
    assert codes(lint_snippet(tmp_path, other)) == ["DSD001"]


def test_cli_exit_codes_and_baseline(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(BAD_TRACED_IF))
    baseline = tmp_path / "baseline.json"

    assert lint.main([str(bad), "--baseline", str(baseline)]) == 1
    out = capsys.readouterr().out
    assert "DSD001" in out and "bad.py" in out

    assert lint.main([str(bad), "--baseline", str(baseline),
                      "--write-baseline"]) == 0
    assert baseline.exists()
    # baselined findings no longer fail the run...
    assert lint.main([str(bad), "--baseline", str(baseline)]) == 0
    capsys.readouterr()
    # ...but a NEW finding in the same file still does
    bad.write_text(textwrap.dedent(BAD_TRACED_IF) + textwrap.dedent("""
        @jax.jit
        def step2(x):
            q = jnp.cumsum(x)
            if q < 0:
                return q
            return -q
    """))
    assert lint.main([str(bad), "--baseline", str(baseline)]) == 1


def test_select_filters_rules(tmp_path):
    bad = tmp_path / "both.py"
    bad.write_text(textwrap.dedent(BAD_TRACED_IF)
                   + textwrap.dedent(BAD_DONATION))
    all_codes = codes(lint.run_paths([bad]))
    assert all_codes == ["DSD001", "DSD002"]
    only = lint.run_paths([bad], select={"DSD002"})
    assert codes(only) == ["DSD002"]


def test_self_scan_repo_clean_or_baselined():
    """src/repro must stay lint-clean (or every finding explicitly
    baselined in .dsd-lint-baseline.json) — the CI lint step's contract."""
    project = lint.load_project([REPO / "src"])
    findings = lint.run_project(project)
    baseline = lint.load_baseline(REPO / ".dsd-lint-baseline.json")
    fps = lint._fingerprints(findings, project)
    fresh = [f.format() for f, fp in zip(findings, fps) if fp not in baseline]
    assert fresh == []


# ----------------------------------------------------------- compile_guard

def test_compile_guard_steady_state_clean():
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x * 2 + 1)
    f(jnp.ones(8)).block_until_ready()            # warm
    with compile_guard(allowed=0, what="steady") as g:
        for _ in range(3):
            f(jnp.ones(8)).block_until_ready()
    assert g.count == 0


def test_compile_guard_raises_on_recompile():
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x * 3)
    f(jnp.ones(4)).block_until_ready()
    with pytest.raises(RecompileError, match="compile-once"):
        with compile_guard(allowed=0):
            f(jnp.ones(16)).block_until_ready()   # new shape → recompile


def test_compile_guard_count_only_mode():
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x - 1)
    with compile_guard(allowed=None, what="warmup") as g:
        f(jnp.ones(32)).block_until_ready()
    assert g.count >= 1                            # counted, did not raise


def test_compile_guard_does_not_mask_exceptions():
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x)
    with pytest.raises(ValueError, match="inner"):
        with compile_guard(allowed=0):
            f(jnp.ones(64)).block_until_ready()   # would trip the guard...
            raise ValueError("inner")             # ...but this wins


def test_engine_compiled_programs_delegates():
    from repro.analysis.sanitize import jit_cache_programs
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x + 2)
    assert jit_cache_programs([f]) == 0
    f(jnp.ones(3))
    f(jnp.ones(5))
    assert jit_cache_programs([f]) == 2


# ------------------------------------------------------- CheckedTransport

def _win(rid, spec=False):
    from repro.distributed.wire import WindowMsg
    return WindowMsg(tokens=np.zeros((1, 2), np.int32), gamma=2, n_active=1,
                     round_id=rid, speculative=spec)


def _verd(rid):
    from repro.distributed.wire import VerdictMsg
    z = np.zeros(1, np.int32)
    return VerdictMsg(n_accepted=z, num_new=z, next_token=z, last_token=z,
                      done=np.zeros(1, bool), gamma=2, n_active=1,
                      round_id=rid)


def _checked():
    from repro.distributed.transport import InProcessTransport
    return CheckedTransport(InProcessTransport())


def test_checked_transport_happy_path_transparent():
    tr = _checked()
    tr.post_window(_win(0))
    msg, waited = tr.recv_window()
    assert msg.round_id == 0 and waited == 0.0
    tr.post_verdict(_verd(0))
    tr.recv_verdict()
    tr.send_window(_win(1))
    tr.send_verdict(_verd(1))
    tr.post_window(_win(2, spec=True))
    tr.discard_window()
    tr.assert_drained()
    assert tr.in_flight == 0                       # delegated accounting
    assert tr.messages_sent == 5
    assert tr.discarded_messages == 1


def test_checked_transport_verdict_before_window():
    tr = _checked()
    tr.post_window(_win(0))                        # posted but NOT received
    with pytest.raises(ProtocolViolation, match="before its window"):
        tr.post_verdict(_verd(0))


def test_checked_transport_double_recv():
    tr = _checked()
    tr.post_window(_win(0))
    tr.recv_window()
    with pytest.raises(ProtocolViolation, match="no window in flight"):
        tr.recv_window()


def test_checked_transport_double_verdict():
    tr = _checked()
    tr.send_window(_win(0))
    tr.send_verdict(_verd(0))
    with pytest.raises(ProtocolViolation, match="posted twice"):
        tr.post_verdict(_verd(0))


def test_checked_transport_discard_rules():
    tr = _checked()
    with pytest.raises(ProtocolViolation, match="no window in flight"):
        tr.discard_window()
    tr.post_window(_win(0))                        # non-speculative
    with pytest.raises(ProtocolViolation, match="NON-speculative"):
        tr.discard_window()


def test_checked_transport_undrained_speculative_window():
    tr = _checked()
    tr.post_window(_win(0, spec=True))
    with pytest.raises(ProtocolViolation, match="never discarded"):
        tr.assert_drained()


def test_checked_transport_duplicate_round_id():
    tr = _checked()
    tr.send_window(_win(0))
    with pytest.raises(ProtocolViolation, match="posted twice"):
        tr.post_window(_win(0))
