"""Cross-round pipelined speculation tests: rollback exactness, hit
promotion, compile-once invariance, and chunk-boundary hygiene.

The contract under test: after ANY verdict — partial accept, zero accept,
or a kept optimistic window — the pipelined ``DraftWorker`` state
(recurrent/SSM caches, attention KV, anchor token, position) is BITWISE
the state a freshly re-advanced half-duplex worker holds, so committed
greedy tokens are identical and no speculation artifact can leak forward.
Rollback reuses the same jitted ingest/re-advance programs the
half-duplex path compiles, so hits, rollbacks and fused/distributed mode
switches never add an XLA program after warmup.
"""

import jax
import numpy as np
import pytest

from repro.core.session import DecodeSession
from repro.core.window import StaticWindowPolicy, WindowDecision
from repro.distributed import EmulatedLinkTransport, InProcessTransport
from repro.sim.network import LinkSpec

from conformance.scenarios import GAMMA, make_engine, make_noised_engine

FAMILIES = ["dense",
            pytest.param("ssm", marks=pytest.mark.slow),
            pytest.param("hybrid", marks=pytest.mark.slow)]


def _session(eng, mode, max_new=12, sync_every=3, capacity=2, gamma_max=4,
             seed=1):
    return DecodeSession(eng, capacity=capacity, max_new_cap=max_new,
                         gamma_max=gamma_max, sync_every=sync_every,
                         transport=InProcessTransport(), mode_policy=mode,
                         key=jax.random.PRNGKey(seed))


def _trees_equal(a, b):
    la = [x for x in jax.tree.leaves(a) if hasattr(x, "shape")]
    lb = [x for x in jax.tree.leaves(b) if hasattr(x, "shape")]
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def _run_lockstep(eng, prompts, chunks=3, policy=None, **kw):
    """Run a half-duplex and a pipelined session in lockstep and return
    both (same engine, same prompts, same chunk count)."""
    policy = policy or StaticWindowPolicy(GAMMA)
    out = {}
    for mode in ("distributed", "pipeline"):
        sess = _session(eng, mode, **kw)
        sess.admit_batch(prompts, sess.max_new_cap)
        for _ in range(chunks):
            sess.run_chunk(policy)
        out[mode] = sess
    return out["distributed"], out["pipeline"]


# -------------------------------------------------------- rollback exactness

@pytest.mark.parametrize("family", FAMILIES)
def test_zero_accept_rollback_state_bitwise(family):
    """Independent random draft/target (α ≈ 0): every optimistic window
    is rolled back, and after each chunk the pipelined draft's
    recurrent/SSM/KV state equals a freshly re-advanced half-duplex
    worker bit for bit."""
    eng = make_engine(family, gamma_max=4)
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, 128, (2, 9)).astype(np.int32)
    hd, pl = _run_lockstep(eng, prompts)
    assert pl.pipeline_misses > 0 and pl.pipeline_hits == 0
    ta, _ = hd.snapshot()
    tb, _ = pl.snapshot()
    np.testing.assert_array_equal(ta, tb)
    assert _trees_equal(hd._state.draft_cache, pl._state.draft_cache)
    np.testing.assert_array_equal(np.asarray(hd._state.last_token),
                                  np.asarray(pl._state.last_token))
    np.testing.assert_array_equal(np.asarray(hd._state.pos),
                                  np.asarray(pl._state.pos))


@pytest.mark.parametrize("family", FAMILIES)
def test_partial_accept_rollback_and_hits_state_bitwise(family):
    """Noised-copy draft (α ≈ 0.8): the pipelined run takes both the hit
    (kept window) and miss (partial-accept rollback) branches; state and
    tokens still track the half-duplex worker exactly."""
    eng = make_noised_engine(family, gamma_max=4)
    rng = np.random.default_rng(5)
    prompts = rng.integers(0, 128, (2, 12)).astype(np.int32)
    hd, pl = _run_lockstep(eng, prompts, chunks=4, max_new=16, sync_every=4)
    assert pl.pipeline_hits > 0, "noised pair should keep some windows"
    assert pl.pipeline_misses > 0, "and roll back some"
    ta, sa = hd.snapshot()
    tb, sb = pl.snapshot()
    np.testing.assert_array_equal(ta, tb)
    # acceptance bookkeeping is identical round by round, not just tokens
    assert sa.accepted == sb.accepted and sa.proposed == sb.proposed
    assert _trees_equal(hd._state.draft_cache, pl._state.draft_cache)
    assert _trees_equal(hd._state.target_cache, pl._state.target_cache)


def test_budget_clamp_predicted_as_hit():
    """A request ending exactly on an all-accepted window is PREDICTED by
    the optimistic slot_stop_mask mirror (budget clamp + done flip), so
    the final window still counts as a hit, not a spurious rollback."""
    eng = make_noised_engine("dense", gamma_max=4)
    rng = np.random.default_rng(11)
    prompts = rng.integers(0, 128, (2, 12)).astype(np.int32)
    pol = StaticWindowPolicy(GAMMA)
    sess = _session(eng, "pipeline", max_new=9, sync_every=8)
    sess.admit_batch(prompts, 9)
    while sess.unfinished and sess.iterations < 32:
        sess.run_chunk(pol)
    ref, _ = eng.generate(prompts, 9, StaticWindowPolicy(GAMMA), gamma_max=4,
                          key=jax.random.PRNGKey(1))
    toks, _ = sess.snapshot()
    np.testing.assert_array_equal(ref, toks)


# ---------------------------------------------------------- compile hygiene

def test_zero_recompiles_across_hits_rollbacks_and_mode_switches():
    """After one warmup chunk, pipeline hits, rollbacks and fused ↔
    distributed mode switches add no XLA programs."""

    class Alternator:
        def __init__(self):
            self.i = 0

        def decide(self, pair_key, feats):
            self.i += 1
            if (self.i // 4) % 2 == 1:
                return WindowDecision(1, "fused")
            return WindowDecision(GAMMA, "distributed")

        def gamma_bound(self):
            return GAMMA + 1

        def name(self):
            return "alternator"

    eng = make_noised_engine("dense", gamma_max=4)
    rng = np.random.default_rng(7)
    prompts = rng.integers(0, 128, (2, 10)).astype(np.int32)
    pol = Alternator()
    sess = _session(eng, "pipeline", max_new=24, sync_every=4)
    sess.admit_batch(prompts, 24)
    sess.run_chunk(pol)                  # warmup: all programs compiled
    warm = eng.compiled_programs()
    while sess.unfinished and sess.iterations < 64:
        sess.run_chunk(pol)
    assert sess.pipeline_hits + sess.pipeline_misses > 0
    assert sess.fused_iterations > 0     # mode switches really happened
    assert eng.compiled_programs() == warm
    ref, _ = eng.generate(prompts, 24, StaticWindowPolicy(GAMMA), gamma_max=4,
                          key=jax.random.PRNGKey(1))
    toks, _ = sess.snapshot()
    np.testing.assert_array_equal(ref, toks)


# -------------------------------------------------------- transport hygiene

def test_no_inflight_messages_across_chunk_boundaries():
    """In-flight speculation never crosses a run_chunk boundary: after any
    chunk the transport queues are drained (admissions/retirements at the
    sync boundary can therefore never race a stale window), and invalidated
    windows are accounted as discarded."""
    eng = make_noised_engine("dense", gamma_max=4)
    rng = np.random.default_rng(9)
    prompts = rng.integers(0, 128, (2, 10)).astype(np.int32)
    tr = EmulatedLinkTransport(LinkSpec(rtt_ms=15.0, jitter_ms=1.0),
                               seed=2, sleep=False)
    sess = DecodeSession(eng, capacity=2, max_new_cap=16, gamma_max=4,
                         sync_every=3, transport=tr, mode_policy="pipeline",
                         key=jax.random.PRNGKey(1))
    sess.admit_batch(prompts, 16)
    pol = StaticWindowPolicy(GAMMA)
    while sess.unfinished and sess.iterations < 48:
        sess.run_chunk(pol)
        assert tr.in_flight == 0
    # every discard is a miss whose speculative window was already posted
    # (misses on a chunk's last round had nothing in flight to discard)
    assert 0 < tr.discarded_messages <= sess.pipeline_misses


def test_staggered_admission_under_pipeline_bit_identical():
    """In-flight admission/retirement + pipelining: the optimistic
    lifecycle mirror re-reads the device cursors/flags at each chunk
    start, so requests admitted into freed slots mid-stream still commit
    exactly their solo tokens."""
    eng = make_noised_engine("dense", gamma_max=4)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 128, int(rng.integers(6, 12)))
               .astype(np.int32) for _ in range(3)]
    pol = StaticWindowPolicy(GAMMA)
    sess = DecodeSession(eng, capacity=2, max_new_cap=8, max_prompt_len=16,
                         gamma_max=4, sync_every=2,
                         transport=InProcessTransport(),
                         mode_policy="pipeline")
    outs = {}
    sess.admit(prompts[0], 8, request_id=0)
    sess.run_chunk(pol)
    sess.admit(prompts[1], 6, request_id=1)
    for _ in range(64):
        if not sess.unfinished:
            break
        sess.run_chunk(pol)
        for j in sess.finished_slots():
            toks, rec = sess.retire(j)
            outs[rec.request_id] = toks
            if rec.request_id == 0 and 2 not in outs:
                sess.admit(prompts[2], 8, request_id=2)
                outs[2] = None
    assert not sess.unfinished
    for j in sess.finished_slots():
        toks, rec = sess.retire(j)
        outs[rec.request_id] = toks
    assert sess.pipeline_hits > 0
    budgets = {0: 8, 1: 6, 2: 8}
    for rid, p in enumerate(prompts):
        solo, _ = eng.generate(p[None, :], budgets[rid],
                               StaticWindowPolicy(GAMMA), gamma_max=4)
        np.testing.assert_array_equal(outs[rid], solo[0, :budgets[rid]])
