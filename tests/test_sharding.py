"""Sharding-spec and dry-run plumbing tests (no 512-device init needed:
fit_spec only reads mesh axis sizes, and the collective parser is pure)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import abstract_mesh, fit_spec
from repro.launch.dryrun import parse_collectives, _shape_bytes
from repro.launch.shapes import SHAPES


def _mesh(multi=False):
    if multi:
        return abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return abstract_mesh((16, 16), ("data", "model"))


# ----------------------------------------------------------------- fit_spec

def test_fit_spec_keeps_divisible():
    m = _mesh()
    assert fit_spec(m, P("data", "model"), (4096, 11008)) == P("data", "model")


def test_fit_spec_relocates_nondivisible_axis():
    m = _mesh()
    # 40 heads can't shard over model=16 → model moves to head_dim=128
    out = fit_spec(m, P("data", "model", None), (5120, 40, 128))
    assert out == P("data", None, "model")


def test_fit_spec_drops_unplaceable_axis():
    m = _mesh()
    # odd vocab: nothing divides 16 except d_model which is taken
    out = fit_spec(m, P("model", "data"), (51865, 384))
    assert "model" not in jax.tree.leaves(tuple(out)) or out[0] != "model"
    # d_model keeps its data sharding
    assert out[1] == "data" or out[-1] == "data"


def test_fit_spec_tuple_axis_degrades():
    m = _mesh(multi=True)
    # ('pod','data') = 32 doesn't divide 48 → largest dividing sub-axis (16)
    out = fit_spec(m, P(("pod", "data"), None), (48, 128))
    assert out[0] == "data"


def test_fit_spec_kv_heads_to_head_dim():
    m = _mesh()
    # (L-free) kv cache (B, S, Hkv=8, hd=128): model relocates off kv=8
    out = fit_spec(m, P("data", None, "model", None), (128, 32768, 8, 128))
    padded = list(out) + [None] * (4 - len(out))
    assert padded[2] != "model"                      # kv dim left unsharded
    assert "model" in [a for a in padded if isinstance(a, str)]
    assert padded[0] == "data"


# -------------------------------------------------------- collective parser

HLO_SAMPLE = """
  %all-gather = f32[4096,512]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %all-reduce.3 = bf16[512]{0} all-reduce(%y), channel_id=2, replica_groups=[16,16]<=[256]
  %reduce-scatter.1 = f32[32,64]{1,0} reduce-scatter(%z), replica_groups=[32,8]<=[256]
  %add = f32[128,128]{1,0} add(%a, %b)
"""


def test_parse_collectives_counts_and_bytes():
    out = parse_collectives(HLO_SAMPLE)
    kinds = out["per_kind"]
    assert kinds["all-gather"]["count"] == 1
    assert kinds["all-reduce"]["count"] == 1
    assert kinds["reduce-scatter"]["count"] == 1
    ag = 4096 * 512 * 4
    assert kinds["all-gather"]["buffer_bytes"] == ag
    # ring all-gather: (n-1)/n of the gathered buffer crosses each link
    assert abs(kinds["all-gather"]["moved_bytes"] - ag * 15 / 16) < 1
    # add op is not counted
    assert out["buffer_bytes"] < ag + 512 * 2 + 32 * 64 * 4 + 1


def test_shape_bytes_tuple():
    assert _shape_bytes("(bf16[2,4], f32[8])") == 2 * 4 * 2 + 8 * 4
    assert _shape_bytes("pred[16]") == 16


# -------------------------------------------------------------- input shapes

def test_input_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].sliding


def test_decode_specs_are_structs_only():
    """input_specs must not allocate device memory (ShapeDtypeStructs)."""
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.launch.shapes import input_specs
    cfg = get_config("qwen2.5-3b")
    model = build_model(cfg)
    spec = input_specs(cfg, SHAPES["decode_32k"], model)
    leaves = jax.tree.leaves(spec["cache"],
                             is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    assert leaves, "cache spec empty"
    for leaf in leaves:
        assert isinstance(leaf, (jax.ShapeDtypeStruct, bool)), type(leaf)
    assert spec["token"].shape == (128,)
    # ring cache for long_500k on a dense arch
    spec_l = input_specs(cfg, SHAPES["long_500k"], model)
    assert spec_l["ring"] is True
    assert spec_l["window"] == cfg.serve_sliding_window
    k_struct = spec_l["cache"].k
    assert k_struct.shape[2] == cfg.serve_sliding_window   # bounded slots


def test_ssm_long_context_state_is_o1():
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.launch.shapes import input_specs
    cfg = get_config("mamba2-130m")
    model = build_model(cfg)
    spec = input_specs(cfg, SHAPES["long_500k"], model)
    # recurrent state carries no sequence dimension at all
    assert spec["cache"].state.shape == (cfg.n_layers, 1, cfg.ssm_heads,
                                         cfg.ssm_head_dim, cfg.ssm_state)
