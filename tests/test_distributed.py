"""Distributed draft–target execution tests.

The invariants: routing speculation rounds through the zero-delay
:class:`InProcessTransport` commits greedy tokens BIT-identical to the
colocated ``DecodeSession`` path (dense, SSM and hybrid targets — the
regression anchor for the worker split); the
:class:`EmulatedLinkTransport` imposes measured wall-clock delays sampled
from the same ``LinkSpec`` model DSD-Sim uses and feeds the MEASURED RTT
into the window-policy features (so AWC flips to fused mode on a slow
link); and fused-mode rounds commit exactly the target's greedy
continuation while paying no per-window round trips.
"""

import time

import jax
import numpy as np
import pytest

from repro.core.engine import SpecDecodeEngine
from repro.core.session import DecodeSession
from repro.core.window import AWCWindowPolicy, StaticWindowPolicy
from repro.distributed import (EmulatedLinkTransport, InProcessTransport,
                               VerdictMsg, WindowMsg)
from repro.sim.network import (LinkSpec, verdict_payload_bytes,
                               window_payload_bytes)

# model pairs / γ / engine builder come from the shared conformance
# fixture module (one definition for every distributed/session test)
from conformance.scenarios import DRAFT, GAMMA, TARGETS, make_engine

_engine = make_engine


def _prompts(rng, n, lo=6, hi=12):
    return [rng.integers(0, 128, int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


# ------------------------------------------------------- bit-identity anchor

@pytest.mark.parametrize("family", [
    "dense",
    pytest.param("ssm", marks=pytest.mark.slow),
    pytest.param("hybrid", marks=pytest.mark.slow),
])
def test_inprocess_transport_bit_identical(family):
    """Greedy tokens through the split-worker + InProcessTransport path ==
    the colocated fused-step path, for attention AND recurrent targets."""
    eng = _engine(family)
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, 128, (2, 9)).astype(np.int32)
    ref, ref_stats = eng.generate(prompts, 12, StaticWindowPolicy(GAMMA))
    got, got_stats = eng.generate(prompts, 12, StaticWindowPolicy(GAMMA),
                                  transport=InProcessTransport())
    np.testing.assert_array_equal(ref, got)
    assert ref_stats.accepted == got_stats.accepted
    assert ref_stats.proposed == got_stats.proposed


def test_inprocess_transport_staggered_admission():
    """In-flight admission/retirement through the transport path commits
    the same greedy tokens as solo colocated runs."""
    eng = _engine("dense")
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, 3)
    pol = StaticWindowPolicy(GAMMA)
    sess = DecodeSession(eng, capacity=2, max_new_cap=8, max_prompt_len=16,
                         gamma_max=GAMMA, sync_every=2,
                         transport=InProcessTransport())
    outs = {}
    sess.admit(prompts[0], 8, request_id=0)
    sess.run_chunk(pol)
    sess.admit(prompts[1], 6, request_id=1)
    for _ in range(64):
        if not sess.unfinished:
            break
        sess.run_chunk(pol)
        for j in sess.finished_slots():
            toks, rec = sess.retire(j)
            outs[rec.request_id] = toks
            if rec.request_id == 0 and 2 not in outs:
                sess.admit(prompts[2], 8, request_id=2)
                outs[2] = None
    assert not sess.unfinished
    for j in sess.finished_slots():
        toks, rec = sess.retire(j)
        outs[rec.request_id] = toks
    budgets = {0: 8, 1: 6, 2: 8}
    for rid, p in enumerate(prompts):
        solo, _ = eng.generate(p[None, :], budgets[rid],
                               StaticWindowPolicy(GAMMA))
        np.testing.assert_array_equal(outs[rid], solo[0, :budgets[rid]])


def test_transport_zero_recompiles_across_churn():
    """The distributed programs (propose + verify/commit + insert) compile
    once; admissions, retirements and γ changes are data."""
    eng = _engine("dense")
    rng = np.random.default_rng(1)
    pol = StaticWindowPolicy(GAMMA)
    sess = DecodeSession(eng, capacity=2, max_new_cap=6, max_prompt_len=12,
                         gamma_max=GAMMA, sync_every=2,
                         transport=InProcessTransport())
    sess.admit(rng.integers(0, 128, 7).astype(np.int32), 6, request_id=0)
    sess.run_chunk(pol)
    warm = eng.compiled_programs()
    outs = {}
    for rid in range(1, 4):
        sess.admit(rng.integers(0, 128, int(rng.integers(2, 12)))
                   .astype(np.int32), int(rng.integers(2, 7)),
                   request_id=rid)
        while not sess.free:
            sess.run_chunk(pol)
            for j in sess.finished_slots():
                toks, rec = sess.retire(j)
                outs[rec.request_id] = toks
    while sess.unfinished:
        sess.run_chunk(pol)
    assert eng.compiled_programs() == warm


# ----------------------------------------------------------- fused execution

def test_fused_mode_commits_target_greedy():
    """Forced fused mode (cloud-only) produces exactly the target's greedy
    continuation — the same committed stream as greedy speculative
    decoding — through the transport, with zero window/verdict messages."""
    eng = _engine("dense")
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, 128, (2, 8)).astype(np.int32)
    ref, _ = eng.generate(prompts, 10, StaticWindowPolicy(GAMMA))
    tr = InProcessTransport()
    fus, stats = eng.generate(prompts, 10, StaticWindowPolicy(GAMMA),
                              transport=tr, mode_policy="fused")
    np.testing.assert_array_equal(ref, fus)
    assert stats.proposed == 0            # no speculation in fused mode
    # only per-chunk control flushes crossed the wire, never a window
    assert tr.bytes_sent < 64 * stats.iterations


def test_fused_mode_colocated_matches_greedy():
    """The colocated path honors fused decisions too (γ=0 masked step)."""
    eng = _engine("ssm")
    rng = np.random.default_rng(4)
    prompts = rng.integers(0, 128, (2, 8)).astype(np.int32)
    ref, _ = eng.generate(prompts, 10, StaticWindowPolicy(GAMMA))
    fus, stats = eng.generate(prompts, 10, StaticWindowPolicy(GAMMA),
                              mode_policy="fused")
    np.testing.assert_array_equal(ref, fus)
    assert stats.proposed == 0


def test_mixed_mode_switching_stays_greedy():
    """Alternating fused/distributed decisions mid-stream (the draft cache
    must stay coherent across fused rounds) still commits the target's
    greedy continuation."""

    class Alternator:
        def __init__(self):
            self.i = 0

        def decide(self, pair_key, feats):
            from repro.core.window import WindowDecision
            self.i += 1
            if (self.i // 3) % 2 == 1:
                return WindowDecision(1, "fused")
            return WindowDecision(GAMMA, "distributed")

        def gamma_bound(self):
            return GAMMA

        def name(self):
            return "alternator"

    eng = _engine("dense")
    rng = np.random.default_rng(6)
    prompts = rng.integers(0, 128, (2, 9)).astype(np.int32)
    ref, _ = eng.generate(prompts, 12, StaticWindowPolicy(GAMMA))
    got, stats = eng.generate(prompts, 12, Alternator(),
                              transport=InProcessTransport())
    np.testing.assert_array_equal(ref, got)
    assert stats.proposed > 0             # some distributed rounds ran


# ------------------------------------------------------------- emulated link

def _msgs(rid=0, gamma=4, speculative=False):
    w = WindowMsg(tokens=np.zeros((1, gamma), np.int32), gamma=gamma,
                  n_active=1, round_id=rid, speculative=speculative)
    v = VerdictMsg(n_accepted=np.zeros(1, np.int32),
                   num_new=np.ones(1, np.int32),
                   next_token=np.zeros(1, np.int32),
                   last_token=np.zeros(1, np.int32),
                   done=np.zeros(1, bool), gamma=gamma, n_active=1,
                   round_id=rid)
    return w, v


def test_emulated_link_records_sampled_delays():
    """The transport's RECORDED delay samples (not wall-clock sleeps — the
    deflaked contract) follow the LinkSpec model: per-direction logs, RTT
    pairs reconstructed from the sampled out+back sums, byte accounting
    per the paper's payload model. Seeded jitter makes this exact."""
    spec = LinkSpec(rtt_ms=20.0, jitter_ms=1.0)
    tr = EmulatedLinkTransport(spec, seed=0, sleep=False)
    for i in range(4):
        w, v = _msgs(rid=i)
        tr.send_window(w)
        tr.send_verdict(v)
    assert len(tr.delay_log["window"]) == 4
    assert len(tr.delay_log["verdict"]) == 4
    # sampled one-way delays respect the truncated-jitter bounds
    for d in tr.delay_log["window"] + tr.delay_log["verdict"]:
        assert 0.0 < d <= 0.5 * spec.rtt_ms + 4.0 * spec.jitter_ms + 1.0
    pairs = [o + b for o, b in zip(tr.delay_log["window"],
                                   tr.delay_log["verdict"])]
    assert tr.recent_rtt_ms == pytest.approx(sum(pairs) / len(pairs))
    assert tr.bytes_sent == 4 * (window_payload_bytes(4)
                                 + verdict_payload_bytes(4))
    assert tr.messages_sent == 8


def test_emulated_link_sleep_blocks_at_least_the_samples():
    """The sleeping transport really blocks: elapsed wall time is bounded
    below by the recorded samples (sleeps can only overshoot, so this
    direction is robust under scheduler noise)."""
    tr = EmulatedLinkTransport(LinkSpec(rtt_ms=20.0, jitter_ms=1.0), seed=0)
    w, v = _msgs(rid=0)
    t0 = time.perf_counter()
    tr.send_window(w)
    tr.send_verdict(v)
    wall_ms = (time.perf_counter() - t0) * 1e3
    sampled = tr.delay_log["window"][0] + tr.delay_log["verdict"][0]
    assert wall_ms >= 0.9 * sampled


def test_rtt_pairing_by_round_id_out_of_order():
    """Pipelined completion scrambles delivery order: a speculative window
    for round k+1 is posted before round k's verdict. RTT pairs must match
    by round id, and a discarded (invalidated) window must never pair."""
    spec = LinkSpec(rtt_ms=10.0, jitter_ms=0.5)
    tr = EmulatedLinkTransport(spec, seed=3, sleep=False)
    w1, v1 = _msgs(rid=1)
    w2, v2 = _msgs(rid=2, speculative=True)
    tr.post_window(w1)
    tr.post_window(w2)                 # in flight before verdict 1
    tr.recv_window()
    tr.post_verdict(v1)
    tr.recv_verdict()
    tr.post_verdict(v2)
    tr.recv_window()
    tr.recv_verdict()
    d = tr.delay_log
    expect = [(d["window"][0] + d["verdict"][0]),
              (d["window"][1] + d["verdict"][1])]
    assert tr.recent_rtt_ms == pytest.approx(sum(expect) / 2)
    # a discarded speculative window clears its half-pair: the next
    # verdict carrying a NEW round id cannot mismatch it
    w3, _ = _msgs(rid=3, speculative=True)
    tr.post_window(w3)
    dropped = tr.discard_window()
    assert dropped.round_id == 3 and tr.discarded_messages == 1
    w4, v4 = _msgs(rid=4)
    tr.post_window(w4)
    tr.recv_window()
    tr.post_verdict(v4)
    tr.recv_verdict()
    assert tr.recent_rtt_ms == pytest.approx(
        (expect[0] + expect[1] + d["window"][3] + d["verdict"][2]) / 3)


def test_emulated_link_rtt_feeds_policy_and_flips_fused():
    """The AWC feature loop closes over the transport: the SAME
    rtt-sensitive predictor keeps γ large through a zero-delay transport
    and flips to fused over a 20 ms emulated link, because
    ``rtt_recent_ms`` now comes from the transport's measurements."""
    def predictor(feats):
        return 1.0 if feats[2] > 10.0 else 6.0       # feats[2] = rtt_recent

    rng = np.random.default_rng(3)
    prompts = rng.integers(0, 128, (2, 9)).astype(np.int32)
    ref = None
    for name, make_tr in [
            ("inproc", InProcessTransport),
            ("rtt20", lambda: EmulatedLinkTransport(
                LinkSpec(rtt_ms=20.0, jitter_ms=1.0), seed=0))]:
        eng = _engine("dense")
        tr = make_tr()
        sess = DecodeSession(eng, capacity=2, max_new_cap=10, gamma_max=6,
                             sync_every=2, transport=tr)
        sess.admit_batch(prompts, 10)
        pol = AWCWindowPolicy(predictor)
        while sess.unfinished and sess.iterations < 40:
            sess.run_chunk(pol)
        toks, stats = sess.snapshot()
        if name == "inproc":
            assert sess.fused_iterations == 0
            assert max(stats.gamma_seq) == 6
            ref = toks
        else:
            assert sess.fused_iterations > 0          # flipped to fused
            assert tr.recent_rtt_ms > 10.0            # measured, not default
            # greedy commits are mode-invariant: same tokens either way
            np.testing.assert_array_equal(ref, toks)


def test_session_link_accounting():
    """Per-session link accounting: imposed delay accumulates in link_ms
    and the TPOT feature excludes it."""
    eng = _engine("dense")
    rng = np.random.default_rng(8)
    prompts = rng.integers(0, 128, (2, 8)).astype(np.int32)
    tr = EmulatedLinkTransport(LinkSpec(rtt_ms=10.0, jitter_ms=0.5), seed=1)
    sess = DecodeSession(eng, capacity=2, max_new_cap=6, gamma_max=GAMMA,
                         sync_every=2, transport=tr)
    sess.admit_batch(prompts, 6)
    while sess.unfinished and sess.iterations < 24:
        sess.run_chunk(StaticWindowPolicy(GAMMA))
    assert sess.link_ms > 0.0
    feats = sess._features(0.0)
    # tpot tracks target service time; the link delay (≥ rtt_ms per round)
    # stays out of it, so per-iteration tpot < per-iteration wall time
    assert feats.tpot_recent_ms < \
        sess.decode_wall_s * 1e3 / max(1, sess.iterations)
    assert feats.rtt_recent_ms == tr.recent_rtt_ms


def test_sampled_transport_distributed_and_fused_rounds():
    """Temperature > 0 exercises the q_probs-carrying verify signature
    (distributed rounds ship draft distributions; fused rounds use the
    cached zero placeholder) — the wire path must produce valid tokens
    and speculation stats in both modes."""
    eng = SpecDecodeEngine(DRAFT, TARGETS["dense"], temperature=1.0,
                           key=jax.random.PRNGKey(7))
    rng = np.random.default_rng(11)
    prompts = rng.integers(0, 128, (2, 8)).astype(np.int32)
    toks, stats = eng.generate(prompts, 8, StaticWindowPolicy(GAMMA),
                               transport=InProcessTransport())
    assert (toks[:, :8] >= 0).all() and stats.proposed > 0
    fus, fstats = eng.generate(prompts, 8, StaticWindowPolicy(GAMMA),
                               transport=InProcessTransport(),
                               mode_policy="fused")
    assert (fus[:, :8] >= 0).all() and fstats.proposed == 0


def test_non_sleeping_transport_keeps_tpot_honest():
    """With sleep=False the sampled delay never entered wall time, so it
    must NOT be subtracted from the TPOT feature (which would clamp it to
    ~0) — it lands on the virtual clock instead."""
    eng = _engine("dense")
    rng = np.random.default_rng(9)
    prompts = rng.integers(0, 128, (2, 8)).astype(np.int32)
    # warm the split-worker programs (same buffer geometry: max_new and
    # sync_every shape the stats buffers) so compile stays out of wall
    eng.generate(prompts, 6, StaticWindowPolicy(GAMMA), sync_every=2,
                 transport=InProcessTransport())
    tr = EmulatedLinkTransport(LinkSpec(rtt_ms=80.0, jitter_ms=0.5),
                               seed=1, sleep=False)
    sess = DecodeSession(eng, capacity=2, max_new_cap=6, gamma_max=GAMMA,
                         sync_every=2, transport=tr)
    sess.admit_batch(prompts, 6)
    t0 = time.perf_counter()
    while sess.unfinished and sess.iterations < 24:
        sess.run_chunk(StaticWindowPolicy(GAMMA))
    wall_ms = (time.perf_counter() - t0) * 1e3
    assert sess.link_ms > 80.0           # sampled delays were charged...
    assert wall_ms < sess.link_ms        # ...but never slept
    assert sess.virtual_ms >= sess.link_ms   # they hit the virtual clock
    feats = sess._features(0.0)
    assert feats.tpot_recent_ms > 0.0    # not clamped to zero by link_ms


# ------------------------------------------------- socket transport parity

def test_socket_loopback_bit_identical():
    """Greedy tokens through the TCP-loopback SocketTransport — every
    window/verdict length-prefix framed through the kernel — match the
    colocated path token for token."""
    from repro.distributed import SocketTransport
    eng = _engine("dense")
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, 128, (2, 9)).astype(np.int32)
    ref, ref_stats = eng.generate(prompts, 12, StaticWindowPolicy(GAMMA))
    tr = SocketTransport.loopback()
    try:
        got, got_stats = eng.generate(prompts, 12, StaticWindowPolicy(GAMMA),
                                      transport=tr)
        np.testing.assert_array_equal(ref, got)
        assert ref_stats.accepted == got_stats.accepted
        assert tr.wire_bytes > 0 and tr.in_flight == 0
    finally:
        tr.close()


@pytest.mark.slow
def test_process_hosts_match_in_process(tmp_path):
    """The full multi-process path: draft and target worker hosts in
    their own interpreters, windows/verdicts over two TCP streams — the
    committed greedy tokens must equal the same spec served in process."""
    import dataclasses

    from repro.serving import ServeRequest
    from repro.topology import (ClusterSpec, NodeSpec, PairSpec, ServingSpec,
                                WindowSpec, WorkloadSpec, build_deployment)
    cfgs = {"d": DRAFT, "t": TARGETS["dense"]}
    spec = ClusterSpec(
        nodes=[NodeSpec(id="edge0", role="draft", model="d"),
               NodeSpec(id="cloud0", role="target", model="t")],
        pairs=[PairSpec(id="pair0", draft="edge0", target="cloud0",
                        window=WindowSpec(kind="static", gamma=GAMMA),
                        mode_policy="distributed", process=True)],
        serving=ServingSpec(max_batch=2, sync_every=2, gamma_max=GAMMA,
                            temperature=0.0, server="continuous",
                            max_new_cap=8),
        workload=WorkloadSpec(num_requests=2, max_new=8),
        seed=11)
    rng = np.random.default_rng(0)
    reqs = [(rid, rng.integers(0, 128, 7).astype(np.int32))
            for rid in range(2)]

    def serve(s):
        dep = build_deployment(s, model_configs=cfgs)
        try:
            srv = dep.build_server()
            for rid, prompt in reqs:
                srv.submit(ServeRequest(rid, prompt, 8))
            res = {r.request_id: r.tokens for r in srv.run()}
            return res, srv.pair_summaries()
        finally:
            dep.shutdown()

    got, ps = serve(spec)
    ref, _ = serve(dataclasses.replace(
        spec, pairs=[dataclasses.replace(spec.pairs[0], process=False)]))
    assert set(got) == set(ref) == {0, 1}
    for rid in ref:
        np.testing.assert_array_equal(got[rid], ref[rid])
    row = ps["pair0"]
    assert row["process"] is True and row["wire_bytes"] > 0


def test_process_pair_spec_validation():
    """process: true is restricted to the cross-process-deterministic
    regime — greedy, distributed mode, static window, continuous server —
    and rejected loudly otherwise."""
    import dataclasses

    from repro.topology import (ClusterSpec, NodeSpec, PairSpec, ServingSpec,
                                TopologyError, WindowSpec, WorkloadSpec)
    base = ClusterSpec(
        nodes=[NodeSpec(id="e", role="draft", model="d"),
               NodeSpec(id="c", role="target", model="t")],
        pairs=[PairSpec(id="p", draft="e", target="c",
                        window=WindowSpec(kind="static", gamma=3),
                        mode_policy="distributed", process=True)],
        serving=ServingSpec(max_batch=1, server="continuous",
                            temperature=0.0),
        workload=WorkloadSpec(num_requests=1, max_new=4))
    base.validate()
    for mutate, msg in [
            (lambda s: setattr(s.serving, "temperature", 0.7), "temperature"),
            (lambda s: s.pairs.__setitem__(0, dataclasses.replace(
                s.pairs[0], mode_policy="auto")), "mode_policy"),
            (lambda s: s.pairs.__setitem__(0, dataclasses.replace(
                s.pairs[0], window=WindowSpec(kind="awc", gamma=3))),
             "window"),
            (lambda s: setattr(s.serving, "server", "legacy"), "continuous"),
            (lambda s: s.nodes.__setitem__(0, dataclasses.replace(
                s.nodes[0], port=99999)), "port")]:
        spec = ClusterSpec.from_dict(base.to_dict())
        mutate(spec)
        with pytest.raises(TopologyError, match=msg):
            spec.validate()
