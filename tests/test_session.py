"""Continuous-batching regression tests for the slot-based DecodeSession.

The invariants: a request decoded in a session with staggered co-tenants
commits greedy tokens BIT-identical to a solo ``generate()`` run (per-row
independence of the masked step, attention and SSM/hybrid families);
retiring a slot and re-admitting into it leaves no stale cache state; and
any admission/retirement pattern reuses the same two XLA programs (one
masked step + one prefill-insert) — continuous batching never recompiles.
"""

import jax
import numpy as np
import pytest

from repro.core.session import DecodeSession
from repro.core.window import StaticWindowPolicy
from repro.models import build_model
from repro.models.kvcache import init_attn_cache, insert_slot, reset_slot
from repro.serving import (ServeRequest, ServerConfig, SpecDecodeServer,
                           WaveSpecDecodeServer)

# model pairs / γ / engine builder come from the shared conformance
# fixture module (one definition for every distributed/session test)
from conformance.scenarios import DRAFT, GAMMA, TARGETS, make_engine

_engine = make_engine


def _drain(session, policy, outs, max_chunks=64):
    """Run chunks until every occupied slot finished, retiring as we go."""
    for _ in range(max_chunks):
        if not session.unfinished:
            break
        session.run_chunk(policy)
        for j in session.finished_slots():
            toks, rec = session.retire(j)
            outs[rec.request_id] = toks
    assert not session.unfinished


def _run_staggered(eng, prompts, budgets, scrub=False):
    """Admit request 0 alone, co-admit 1 and 2 mid-flight, retire 0 and
    re-admit request 3 into its freed slot; returns {request_id: tokens}
    and the compiled-program count delta across the in-flight churn."""
    pol = StaticWindowPolicy(GAMMA)
    sess = DecodeSession(eng, capacity=3, max_new_cap=max(budgets),
                         max_prompt_len=16, gamma_max=GAMMA, sync_every=2)
    outs = {}
    sess.admit(prompts[0], budgets[0], request_id=0)
    sess.run_chunk(pol)                      # slot 0 decodes solo first
    warm = eng.compiled_programs()           # step + insert both compiled
    sess.admit(prompts[1], budgets[1], request_id=1)
    sess.admit(prompts[2], budgets[2], request_id=2)
    while 0 not in outs:
        sess.run_chunk(pol)
        for j in sess.finished_slots():
            toks, rec = sess.retire(j, scrub=scrub)
            outs[rec.request_id] = toks
    assert sess.free, "request 0 should have freed a slot"
    sess.admit(prompts[3], budgets[3], request_id=3)   # re-admission
    _drain(sess, pol, outs)
    return outs, eng.compiled_programs() - warm


@pytest.mark.parametrize("family", sorted(TARGETS))
@pytest.mark.slow
def test_staggered_cotenants_bit_identical(family):
    """Greedy tokens under in-flight admission/retirement == solo generate,
    for attention AND recurrent-state targets, with zero recompiles across
    the churn."""
    eng = _engine(family)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 128, int(n)).astype(np.int32)
               for n in (9, 13, 6, 11)]
    budgets = [12, 8, 12, 10]
    outs, recompiles = _run_staggered(eng, prompts, budgets)
    assert recompiles == 0
    for rid in range(4):
        solo, _ = eng.generate(prompts[rid][None, :], budgets[rid],
                               StaticWindowPolicy(GAMMA))
        assert len(outs[rid]) == budgets[rid]
        np.testing.assert_array_equal(outs[rid], solo[0, :budgets[rid]])


def test_retire_readmit_no_stale_state():
    """The same prompt admitted into a recycled slot (with a live
    co-tenant) decodes identically on both visits, with and without
    explicit slot scrubbing."""
    eng = _engine("dense")
    rng = np.random.default_rng(5)
    p = rng.integers(0, 128, 9).astype(np.int32)
    co = rng.integers(0, 128, 12).astype(np.int32)
    pol = StaticWindowPolicy(GAMMA)
    for scrub in (False, True):
        sess = DecodeSession(eng, capacity=2, max_new_cap=8,
                             max_prompt_len=16, gamma_max=GAMMA,
                             sync_every=2)
        sess.admit(co, 8, request_id=99)         # long-lived co-tenant
        first = sess.admit(p, 6, request_id=0)
        outs = {}
        while 0 not in outs:
            sess.run_chunk(pol)
            for j in sess.finished_slots():
                toks, rec = sess.retire(j, scrub=scrub)
                outs[rec.request_id] = toks
        again = sess.admit(p, 6, request_id=1)   # recycled slot
        assert again == first
        _drain(sess, pol, outs)
        np.testing.assert_array_equal(outs[0], outs[1])


def test_paged_retire_readmit_reuses_blocks():
    """Paged sessions: retiring a request frees its KV blocks and the next
    admission reuses them (LIFO), with the recycled slot decoding the same
    prompt identically on both visits next to a live co-tenant."""
    eng = _engine("dense")
    rng = np.random.default_rng(6)
    p = rng.integers(0, 128, 9).astype(np.int32)
    co = rng.integers(0, 128, 12).astype(np.int32)
    pol = StaticWindowPolicy(GAMMA)
    sess = DecodeSession(eng, capacity=2, max_new_cap=8, max_prompt_len=16,
                         gamma_max=GAMMA, sync_every=2, paged=True,
                         kv_block_size=4)
    sess.admit(co, 8, request_id=99)
    first = sess.admit(p, 6, request_id=0)
    blocks_first = dict(sess._slot_blocks[first])
    outs = {}
    while 0 not in outs:
        sess.run_chunk(pol)
        for j in sess.finished_slots():
            toks, rec = sess.retire(j)
            outs[rec.request_id] = toks
    again = sess.admit(p, 6, request_id=1)
    assert again == first
    # the freed reservation is recycled (LIFO free list), id-for-id
    assert {s: sorted(ids) for s, ids in sess._slot_blocks[again].items()} \
        == {s: sorted(ids) for s, ids in blocks_first.items()}
    _drain(sess, pol, outs)
    np.testing.assert_array_equal(outs[0], outs[1])
    assert all(a is None or a.used_blocks == 0
               for a in sess._alloc.values())


def test_session_zero_recompiles_across_churn():
    """After the first admit + first chunk, the program count is frozen:
    admissions into any slot, retirements and re-admissions are data."""
    eng = _engine("dense")
    rng = np.random.default_rng(1)
    pol = StaticWindowPolicy(GAMMA)
    sess = DecodeSession(eng, capacity=2, max_new_cap=6, max_prompt_len=12,
                         gamma_max=GAMMA, sync_every=2)
    sess.admit(rng.integers(0, 128, 7).astype(np.int32), 6, request_id=0)
    sess.run_chunk(pol)
    warm = eng.compiled_programs()
    assert warm == 2         # one masked step + one prefill-insert
    outs = {}
    for rid in range(1, 5):  # churn: varying lengths/budgets/slots
        plen = int(rng.integers(2, 12))
        sess.admit(rng.integers(0, 128, plen).astype(np.int32),
                   int(rng.integers(2, 7)), request_id=rid)
        while not sess.free:
            sess.run_chunk(pol)
            for j in sess.finished_slots():
                toks, rec = sess.retire(j)
                outs[rec.request_id] = toks
    _drain(sess, pol, outs)
    assert eng.compiled_programs() == warm
    assert set(outs) == set(range(5))


def test_eos_stops_slot_early():
    """A committed eos_id truncates the request at the EOS token and frees
    its budget; other rows are unaffected."""
    eng = _engine("dense")
    rng = np.random.default_rng(2)
    p = rng.integers(0, 128, 8).astype(np.int32)
    ref, _ = eng.generate(p[None, :], 12, StaticWindowPolicy(GAMMA))
    eos = int(ref[0, 5])                      # 6th greedy token as EOS
    toks, stats = eng.generate(p[None, :], 12, StaticWindowPolicy(GAMMA),
                               eos_id=eos)
    k = int(np.argmax(ref[0, :12] == eos))    # first occurrence
    assert int(stats.produced[0]) == k + 1
    np.testing.assert_array_equal(toks[0, :k + 1], ref[0, :k + 1])
    assert (toks[0, k + 1:] == -1).all()


def test_insert_and_reset_slot_helpers():
    """kvcache slot recycling primitives: insert writes exactly one batch
    row; reset scrubs exactly one batch row back to init state."""
    c = init_attn_cache(n_layers=2, batch=3, slots=5, n_kv=2, head_dim=4,
                        dtype=np.float32)
    one = init_attn_cache(n_layers=2, batch=1, slots=5, n_kv=2, head_dim=4,
                          dtype=np.float32)
    one = one._replace(k=one.k + 1.0, v=one.v + 2.0,
                       pos_map=one.pos_map * 0 + 7)
    ins = insert_slot(c, one, 1)
    assert (np.asarray(ins.k[:, 1]) == 1.0).all()
    assert (np.asarray(ins.pos_map[:, 1]) == 7).all()
    assert (np.asarray(ins.k[:, 0]) == 0.0).all()       # neighbours intact
    assert (np.asarray(ins.pos_map[:, 2]) == -1).all()
    back = reset_slot(ins, 1)
    assert (np.asarray(back.k[:, 1]) == 0.0).all()
    assert (np.asarray(back.pos_map[:, 1]) == -1).all()
    assert (np.asarray(back.pos_map[:, 0]) == -1).all()


def test_continuous_server_metrics_schema():
    """Stream served end-to-end: cursor-true token payloads, and
    arrival-anchored timing (queue wait ≤ TTFT ≤ e2e)."""
    eng = _engine("dense")
    rng = np.random.default_rng(0)
    srv = SpecDecodeServer(eng, StaticWindowPolicy(GAMMA),
                           ServerConfig(max_batch=2, pad_to=4))
    budgets = {}
    for i in range(5):
        plen = int(rng.integers(5, 14))
        budgets[i] = int(rng.integers(4, 9))
        srv.submit(ServeRequest(i, rng.integers(0, 128, plen)
                                .astype(np.int32), budgets[i],
                                arrival_s=0.02 * i))
    results = {r.request_id: r for r in srv.run()}
    assert set(results) == set(range(5))
    for i, r in results.items():
        assert len(r.tokens) == budgets[i]
        assert (r.tokens >= 0).all()
        assert 0.0 <= r.queue_ms <= r.ttft_ms <= r.e2e_ms
        assert r.tpot_ms > 0


def test_wave_server_cursor_true_tokens():
    """The wave baseline also reports per-request payloads from the
    per-sequence cursor and arrival-anchored TTFT."""
    eng = _engine("dense")
    rng = np.random.default_rng(4)
    srv = WaveSpecDecodeServer(eng, StaticWindowPolicy(GAMMA),
                               ServerConfig(max_batch=2, pad_to=4))
    for i in range(4):
        srv.submit(ServeRequest(i, rng.integers(0, 128, int(rng.integers(
            5, 12))).astype(np.int32), 6 + 2 * (i % 2)))
    results = {r.request_id: r for r in srv.run()}
    assert set(results) == set(range(4))
    for i, r in results.items():
        assert len(r.tokens) == 6 + 2 * (i % 2)
        assert (r.tokens >= 0).all()
        assert r.ttft_ms >= r.queue_ms >= 0.0
