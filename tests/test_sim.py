"""DSD-Sim system behaviour tests: event core, scheduler dynamics, and the
paper's qualitative claims (RTT crossover, JSQ under light load, LAB TPOT)."""

import math

import pytest

from repro.sim import (ClusterSpec, DSDSimulation, Environment, JSQRouting,
                       LengthAwareBatching, LinkSpec, PolicyStack,
                       RandomRouting, BatchingConfig, Store, WorkloadGenerator,
                       simulate_from_yaml, loads)
from repro.core.window import StaticWindowPolicy, OracleStaticPolicy


# ------------------------------------------------------------- event core

def test_event_core_timeout_ordering():
    env = Environment()
    log = []

    def proc(name, delay):
        yield env.timeout(delay)
        log.append((name, env.now))

    env.process(proc("b", 2.0))
    env.process(proc("a", 1.0))
    env.process(proc("c", 2.0))   # same time as b: insertion order
    env.run()
    assert log == [("a", 1.0), ("b", 2.0), ("c", 2.0)]


def test_store_blocking_get():
    env = Environment()
    store = Store(env)
    got = []

    def consumer():
        item = yield store.get()
        got.append((item, env.now))

    def producer():
        yield env.timeout(5.0)
        store.put("x")

    env.process(consumer())
    env.process(producer())
    env.run()
    assert got == [("x", 5.0)]


def test_process_join():
    env = Environment()
    order = []

    def child():
        yield env.timeout(3.0)
        order.append("child")
        return 42

    def parent():
        result = yield env.process(child())
        order.append(("parent", result, env.now))

    env.process(parent())
    env.run()
    assert order == ["child", ("parent", 42, 3.0)]


def test_run_until():
    env = Environment()

    def proc():
        for _ in range(10):
            yield env.timeout(1.0)

    env.process(proc())
    env.run(until=4.5)
    assert env.now == 4.5


# --------------------------------------------------------------- scheduler

def _run(rtt=10.0, window=None, routing=None, batching=None, n=60,
         rate=30.0, seed=0, targets=2, drafters=64):
    cluster = ClusterSpec(num_targets=targets, num_drafters=drafters,
                          link=LinkSpec(rtt_ms=rtt, jitter_ms=1.0))
    pol = PolicyStack(
        routing=routing or RandomRouting(seed=seed),
        batching=batching or LengthAwareBatching(),
        batching_cfg=BatchingConfig(max_batch=16),
        window=window or StaticWindowPolicy(4))
    gen = WorkloadGenerator("gsm8k", rate, drafters, seed=seed)
    sim = DSDSimulation(cluster, pol, gen.generate(n), seed=seed)
    return sim.run().summary()


def test_all_requests_complete():
    s = _run()
    assert s["completed"] == 60
    assert s["throughput_rps"] > 0
    assert s["tpot_ms"]["mean"] > 0
    assert 0.0 < s["acceptance_rate"] <= 1.0


def test_throughput_degrades_with_rtt():
    lo = _run(rtt=5.0)["throughput_rps"]
    hi = _run(rtt=80.0)["throughput_rps"]
    assert lo > hi


def test_fused_insensitive_to_rtt():
    """Paper Fig. 6: fused (cloud-only) stays flat as RTT grows."""
    f10 = _run(rtt=10.0, window=OracleStaticPolicy(1, fused=True))
    f80 = _run(rtt=80.0, window=OracleStaticPolicy(1, fused=True))
    # fused pays RTT only twice per request chunk batch, not per window
    assert f80["tpot_ms"]["mean"] < f10["tpot_ms"]["mean"] * 1.6


def test_distributed_beats_fused_at_low_rtt():
    """Paper Fig. 6: the target-bound serving regime (many drafters per
    target) is where distributed SD pays off; fused catches up only once
    RTT dominates (crossover ≈40-60 ms under our calibration)."""
    d = _run(rtt=5.0, rate=40.0, n=80)
    f = _run(rtt=5.0, rate=40.0, n=80,
             window=OracleStaticPolicy(1, fused=True))
    assert d["throughput_rps"] > f["throughput_rps"]
    d_hi = _run(rtt=100.0, rate=40.0, n=80)
    f_hi = _run(rtt=100.0, rate=40.0, n=80,
                window=OracleStaticPolicy(1, fused=True))
    assert f_hi["throughput_rps"] > d_hi["throughput_rps"]


def test_jsq_beats_random_under_light_load():
    """Paper Fig. 8: JSQ lowers TPOT when resources are not saturated."""
    j = _run(routing=JSQRouting(), rate=20.0, n=80)
    r = _run(routing=RandomRouting(seed=1), rate=20.0, n=80)
    assert j["tpot_ms"]["mean"] <= r["tpot_ms"]["mean"] * 1.05


def test_deterministic_given_seed():
    a = _run(seed=3)
    b = _run(seed=3)
    assert a["throughput_rps"] == b["throughput_rps"]
    assert a["tpot_ms"]["mean"] == b["tpot_ms"]["mean"]


# ------------------------------------------------------------ yaml config

def test_miniyaml_parses_nested():
    doc = loads("""
# comment
cluster:
  targets: {count: 2, hw: A100, model: llama2-70b, tp: 4}
  link: {rtt_ms: 10.5, jitter_ms: 1}
policies:
  routing: jsq
  window: {kind: static, gamma: 6}
list_field:
  - 1
  - two
  - {a: 3}
flag: true
""")
    assert doc["cluster"]["targets"]["count"] == 2
    assert doc["cluster"]["link"]["rtt_ms"] == 10.5
    assert doc["policies"]["routing"] == "jsq"
    assert doc["list_field"] == [1, "two", {"a": 3}]
    assert doc["flag"] is True


def test_simulate_from_yaml_end_to_end():
    an = simulate_from_yaml("""
cluster:
  targets: {count: 2, hw: A100, model: llama2-70b, tp: 4}
  drafters: {count: 16, hw: A40, model: llama2-7b}
  link: {rtt_ms: 10}
policies:
  routing: jsq
  batching: {kind: lab, max_batch: 8}
  window: {kind: static, gamma: 4}
workload: {dataset: humaneval, rate_per_s: 10, num_requests: 20, seed: 1}
""")
    s = an.summary()
    assert s["completed"] == 20
    blob = an.to_json()
    assert "throughput_rps" in blob
