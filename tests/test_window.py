"""Window-policy unit tests: per-pair state isolation, gamma_bound
contracts, and fused-mode decisions surviving the stabilizer."""

from repro.core.awc.stabilize import StabilizerConfig
from repro.core.window import (AWCWindowPolicy, DynamicWindowPolicy,
                               FeatureSnapshot, OracleStaticPolicy,
                               StaticWindowPolicy)


def _feats(alpha=0.7, rtt=10.0, q=0.2, tpot=40.0, gp=4.0):
    return FeatureSnapshot(q_depth=q, alpha_recent=alpha, rtt_recent_ms=rtt,
                           tpot_recent_ms=tpot, gamma_prev=gp)


# ------------------------------------------------------ per-pair isolation

def test_dynamic_policy_pairs_do_not_share_gamma():
    """Two draft–target pairs adapt independently: driving one pair's γ up
    (high α) and the other's down (low α) never cross-contaminates."""
    p = DynamicWindowPolicy(hi=0.75, lo=0.25, gamma0=4, gmin=1, gmax=12)
    for _ in range(5):
        up = p.decide("edge0->cloud0", _feats(alpha=0.95))
        dn = p.decide("edge1->cloud1", _feats(alpha=0.05))
    assert up.gamma == 9          # 4 + 5
    assert dn.gamma == 1          # 4 - 3, clamped at gmin
    # a fresh pair still starts at gamma0, unaffected by either history
    assert p.decide("edge2->cloud2", _feats(alpha=0.5)).gamma == 4


def test_awc_policy_pairs_have_independent_stabilizers():
    """AWC keeps one stabilizer per pair: pushing one pair into fused mode
    leaves the other pair's EMA/hysteresis untouched."""
    p = AWCWindowPolicy(lambda f: 1.0 if f[1] < 0.3 else 8.0)
    for _ in range(4):
        low = p.decide("low", _feats(alpha=0.1))
    high = p.decide("high", _feats(alpha=0.9))
    assert low.mode == "fused" and low.gamma == 1
    assert high.mode == "distributed" and high.gamma == 8
    assert set(p._stab) == {"low", "high"}
    assert p._stab["low"].mode == "fused"
    assert p._stab["high"].mode == "distributed"


# ----------------------------------------------------- gamma_bound contract

def test_awc_gamma_bound_matches_stabilizer_clamp():
    """The policy's declared compile bound == the stabilizer's clamp_hi,
    and no decision ever exceeds it (the engine compiles ONE step at this
    width)."""
    cfg = StabilizerConfig(clamp_lo=1.0, clamp_hi=7.0)
    p = AWCWindowPolicy(lambda f: 1000.0, stab_cfg=cfg)
    assert p.gamma_bound() == int(cfg.clamp_hi) == 7
    for _ in range(10):
        d = p.decide("pair", _feats())
        assert 1 <= d.gamma <= p.gamma_bound()


def test_policy_gamma_bounds_cover_all_decisions():
    policies = [StaticWindowPolicy(5), DynamicWindowPolicy(gmax=9),
                OracleStaticPolicy(6), OracleStaticPolicy(6, fused=True),
                AWCWindowPolicy(lambda f: 99.0)]
    for pol in policies:
        bound = pol.gamma_bound()
        for a in (0.05, 0.5, 0.95):
            for _ in range(4):
                assert pol.decide("k", _feats(alpha=a)).gamma <= bound


# --------------------------------------------------- fused-mode stabilization

def test_fused_decisions_survive_stabilizer():
    """A predictor pinned at γ≤1 must reach fused mode through the
    clamp/EMA/hysteresis stack (not be smoothed or clamped away), and the
    resulting decisions carry γ=1."""
    p = AWCWindowPolicy(lambda f: 0.25)       # below clamp_lo
    modes = [p.decide("pair", _feats()).mode for _ in range(6)]
    assert modes[-1] == "fused"
    assert "distributed" in modes             # hysteresis delayed the flip
    d = p.decide("pair", _feats())
    assert d.mode == "fused" and d.gamma == 1


def test_fused_flip_requires_consecutive_low_predictions():
    """One transient γ=1 prediction between large ones never flips the
    mode (hysteresis_k=2 default)."""
    vals = iter([8.0, 1.0, 8.0, 8.0, 8.0, 8.0])
    p = AWCWindowPolicy(lambda f: next(vals),
                        stab_cfg=StabilizerConfig(ema_alpha=1.0))
    modes = [p.decide("pair", _feats()).mode for _ in range(6)]
    assert all(m == "distributed" for m in modes)
