"""Window-policy unit tests: per-pair state isolation, gamma_bound
contracts, and fused-mode decisions surviving the stabilizer."""

from repro.core.awc.stabilize import StabilizerConfig
from repro.core.window import (AWCWindowPolicy, DynamicWindowPolicy,
                               FeatureSnapshot, OracleStaticPolicy,
                               StaticWindowPolicy)


def _feats(alpha=0.7, rtt=10.0, q=0.2, tpot=40.0, gp=4.0):
    return FeatureSnapshot(q_depth=q, alpha_recent=alpha, rtt_recent_ms=rtt,
                           tpot_recent_ms=tpot, gamma_prev=gp)


# ------------------------------------------------------ per-pair isolation

def test_dynamic_policy_pairs_do_not_share_gamma():
    """Two draft–target pairs adapt independently: driving one pair's γ up
    (high α) and the other's down (low α) never cross-contaminates."""
    p = DynamicWindowPolicy(hi=0.75, lo=0.25, gamma0=4, gmin=1, gmax=12)
    for _ in range(5):
        up = p.decide("edge0->cloud0", _feats(alpha=0.95))
        dn = p.decide("edge1->cloud1", _feats(alpha=0.05))
    assert up.gamma == 9          # 4 + 5
    assert dn.gamma == 1          # 4 - 3, clamped at gmin
    # a fresh pair still starts at gamma0, unaffected by either history
    assert p.decide("edge2->cloud2", _feats(alpha=0.5)).gamma == 4


def test_awc_policy_pairs_have_independent_stabilizers():
    """AWC keeps one stabilizer per pair: pushing one pair into fused mode
    leaves the other pair's EMA/hysteresis untouched."""
    p = AWCWindowPolicy(lambda f: 1.0 if f[1] < 0.3 else 8.0)
    for _ in range(4):
        low = p.decide("low", _feats(alpha=0.1))
    high = p.decide("high", _feats(alpha=0.9))
    assert low.mode == "fused" and low.gamma == 1
    assert high.mode == "distributed" and high.gamma == 8
    assert set(p._stab) == {"low", "high"}
    assert p._stab["low"].mode == "fused"
    assert p._stab["high"].mode == "distributed"


# ----------------------------------------------------- gamma_bound contract

def test_awc_gamma_bound_matches_stabilizer_clamp():
    """The policy's declared compile bound == the stabilizer's clamp_hi,
    and no decision ever exceeds it (the engine compiles ONE step at this
    width)."""
    cfg = StabilizerConfig(clamp_lo=1.0, clamp_hi=7.0)
    p = AWCWindowPolicy(lambda f: 1000.0, stab_cfg=cfg)
    assert p.gamma_bound() == int(cfg.clamp_hi) == 7
    for _ in range(10):
        d = p.decide("pair", _feats())
        assert 1 <= d.gamma <= p.gamma_bound()


def test_policy_gamma_bounds_cover_all_decisions():
    policies = [StaticWindowPolicy(5), DynamicWindowPolicy(gmax=9),
                OracleStaticPolicy(6), OracleStaticPolicy(6, fused=True),
                AWCWindowPolicy(lambda f: 99.0)]
    for pol in policies:
        bound = pol.gamma_bound()
        for a in (0.05, 0.5, 0.95):
            for _ in range(4):
                assert pol.decide("k", _feats(alpha=a)).gamma <= bound


# --------------------------------------------------- fused-mode stabilization

def test_fused_decisions_survive_stabilizer():
    """A predictor pinned at γ≤1 must reach fused mode through the
    clamp/EMA/hysteresis stack (not be smoothed or clamped away), and the
    resulting decisions carry γ=1."""
    p = AWCWindowPolicy(lambda f: 0.25)       # below clamp_lo
    modes = [p.decide("pair", _feats()).mode for _ in range(6)]
    assert modes[-1] == "fused"
    assert "distributed" in modes             # hysteresis delayed the flip
    d = p.decide("pair", _feats())
    assert d.mode == "fused" and d.gamma == 1


def test_fused_flip_requires_consecutive_low_predictions():
    """One transient γ=1 prediction between large ones never flips the
    mode (hysteresis_k=2 default)."""
    vals = iter([8.0, 1.0, 8.0, 8.0, 8.0, 8.0])
    p = AWCWindowPolicy(lambda f: next(vals),
                        stab_cfg=StabilizerConfig(ema_alpha=1.0))
    modes = [p.decide("pair", _feats()).mode for _ in range(6)]
    assert all(m == "distributed" for m in modes)


# -------------------------------------------- factory (one construction path)

def test_make_window_policy_kinds_and_freshness():
    from repro.core.window import make_window_policy
    import pytest
    s = make_window_policy("static", gamma=6)
    assert isinstance(s, StaticWindowPolicy) and s.gamma == 6
    d = make_window_policy("dynamic", gamma=5, hi=0.8, lo=0.1, gmax=9)
    assert isinstance(d, DynamicWindowPolicy)
    assert (d.gamma0, d.hi, d.lo, d.gmax) == (5, 0.8, 0.1, 9)
    a1 = make_window_policy("awc", predictor=lambda f: 4.0)
    a2 = make_window_policy("awc", predictor=lambda f: 4.0)
    assert isinstance(a1, AWCWindowPolicy) and a1 is not a2
    a1.decide("k", _feats())
    assert not a2._stab, "factory instances must not share stabilizers"
    with pytest.raises(ValueError):
        make_window_policy("prophet")


# -------------------- per-pair stabilizer isolation under multi-pair routing

def test_pair_stabilizers_stay_isolated_under_routed_serving():
    """Two draft–target pairs with different LinkSpecs served CONCURRENTLY
    by one SpecDecodeServer must not share γ hysteresis state: a shared
    AWC policy whose predictor keys on the measured-RTT feature converges
    the fast pair to a large γ and the slow pair into fused mode, with one
    WindowStabilizer per pair id."""
    import numpy as np
    import jax
    from repro.configs.base import ModelConfig
    from repro.core.engine import SpecDecodeEngine
    from repro.distributed import EmulatedLinkTransport, InProcessTransport
    from repro.serving import (ServeRequest, ServerConfig, ServingPair,
                               SpecDecodeServer)
    from repro.sim.network import LinkSpec

    tiny = ModelConfig(name="wt", arch_type="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                       dtype="float32", remat=False)
    engine = SpecDecodeEngine(tiny, tiny, temperature=0.0, gamma_max=8,
                              sync_every=2, key=jax.random.PRNGKey(0))
    # ONE shared policy object across both pairs — isolation must come
    # from per-pair-key stabilizers, not from separate policy instances
    policy = AWCWindowPolicy(lambda f: 8.0 if f[2] < 10.0 else 0.5)
    pairs = [
        ServingPair("fast", engine, policy,
                    transport=InProcessTransport()),
        ServingPair("slow", engine, policy,
                    transport=EmulatedLinkTransport(
                        LinkSpec(rtt_ms=40.0, jitter_ms=1.0), seed=0,
                        sleep=False)),
    ]
    srv = SpecDecodeServer(cfg=ServerConfig(max_batch=2), pairs=pairs)
    rng = np.random.default_rng(0)
    for i in range(8):
        srv.submit(ServeRequest(
            i, rng.integers(0, tiny.vocab, 8).astype(np.int32), 16))
    results = srv.run()
    assert len(results) == 8
    assert {r.pair_id for r in results} == {"fast", "slow"}
    # one stabilizer per PAIR, keyed by pair id, with distinct converged
    # operating points: large-γ distributed on the fast link, fused on
    # the slow one
    assert set(policy._stab) == {"fast", "slow"}
    fast, slow = policy._stab["fast"], policy._stab["slow"]
    assert fast.mode == "distributed"
    assert slow.mode == "fused"
    assert fast._ema > slow._ema
    ps = srv.pair_summaries()
    assert ps["fast"]["mean_gamma"] > ps["slow"]["mean_gamma"]
    assert ps["slow"]["fused_fraction"] > ps["fast"]["fused_fraction"]
