"""Compile-once decode-loop regression tests.

The engine jits ONE masked-window step at gamma_max; the per-iteration γ is
a traced scalar, so AWC-style adaptive-γ generation must never recompile.
Committed tokens must stay bit-identical to the classic per-γ speculative
step (`spec_decode_step` with a dedicated static γ each iteration — the
seed engine's execution model).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.engine import SpecDecodeEngine
from repro.core.specdec import SpecDecodeState, spec_decode_step
from repro.core.window import FeatureSnapshot, WindowDecision

DRAFT = ModelConfig(name="d", arch_type="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                    dtype="float32", remat=False)
TARGET_DENSE = dataclasses.replace(DRAFT, name="t", n_layers=3, n_kv_heads=4)
TARGET_SSM = ModelConfig(name="ts", arch_type="ssm", n_layers=2, d_model=64,
                         n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
                         ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                         dtype="float32", remat=False, tie_embeddings=True)

GAMMA_MAX = 6


class CyclingWindowPolicy:
    """AWC-style adversarial workload: a different γ every iteration."""

    def __init__(self, gmax: int = GAMMA_MAX):
        self.gmax = gmax
        self._i = 0

    def decide(self, pair_key: str, feats: FeatureSnapshot) -> WindowDecision:
        g = 1 + (self._i % self.gmax)
        self._i += 1
        return WindowDecision(g, "distributed")

    def gamma_bound(self) -> int:
        return self.gmax

    def name(self) -> str:
        return f"cycling-{self.gmax}"


def _reference_greedy(engine, prompts, n):
    """Target-only greedy decoding — the ground truth any speculative
    schedule must reproduce exactly at temperature 0."""
    tm = engine.target
    B, S = prompts.shape
    lg, cache = tm.prefill(engine.target_params, jnp.asarray(prompts),
                           S + n + 16)
    cur = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
    ref = [np.asarray(cur)]
    pos = jnp.full((B,), S, jnp.int32)
    for _ in range(n - 1):
        lg1, cache = tm.decode_step(engine.target_params, cur, cache, pos)
        cur = jnp.argmax(lg1, -1).astype(jnp.int32)
        ref.append(np.asarray(cur))
        pos = pos + 1
    return np.stack(ref, 1)


@pytest.mark.parametrize("target_cfg", [TARGET_DENSE, TARGET_SSM],
                         ids=["dense", "ssm"])
def test_adaptive_gamma_compiles_one_program(target_cfg):
    """γ varying every iteration over [1, gamma_max] ⇒ exactly one jit-cache
    entry AND exactly one lowered/compiled XLA program."""
    eng = SpecDecodeEngine(DRAFT, target_cfg, temperature=0.0,
                           key=jax.random.PRNGKey(7))
    B, S, N = 2, 10, 24
    prompts = np.random.default_rng(0).integers(0, 128, (B, S)).astype(np.int32)
    toks, stats = eng.generate(prompts, N, CyclingWindowPolicy(),
                               sync_every=4)
    assert len(eng._jit_cache) == 1, eng._jit_cache.keys()
    assert eng.compiled_programs() == 1
    # γ really did vary across the run
    assert len(set(stats.gamma_seq)) > 1
    # adaptive-γ output is still exactly the target's greedy continuation
    ref = _reference_greedy(eng, prompts, N)
    np.testing.assert_array_equal(toks[:, :N], ref)

    # a second same-shape generate reuses the program (different max_new or
    # batch shapes legitimately compile new entries)
    eng.generate(prompts, N, CyclingWindowPolicy(), sync_every=4)
    assert eng.compiled_programs() == 1


@pytest.mark.slow
def test_masked_step_bit_identical_to_per_gamma_step():
    """The masked-window engine's committed tokens == driving the classic
    per-γ `spec_decode_step` (a dedicated static-γ program per iteration,
    the seed engine's model) with the same γ schedule, token for token."""
    eng = SpecDecodeEngine(DRAFT, TARGET_DENSE, temperature=0.0,
                           key=jax.random.PRNGKey(3))
    B, S, N = 2, 8, 16
    prompts = np.random.default_rng(1).integers(0, 128, (B, S)).astype(np.int32)
    toks, stats = eng.generate(prompts, N, CyclingWindowPolicy(),
                               sync_every=4)

    # reference: the per-γ execution model, eager, one window at a time
    draft_decode = lambda p, t, c, pos: eng.draft.decode_step(p, t, c, pos)
    target_verify = lambda p, w, c, pos: eng.target.verify_step(p, w, c, pos)
    state = eng._prefill(jnp.asarray(prompts, jnp.int32), S + N + 32,
                         jax.random.PRNGKey(0))
    out = [[int(state.last_token[b])] for b in range(B)]
    gammas = iter(stats.gamma_seq)
    produced = np.ones(B, np.int64)
    while produced.min() < N:
        gamma = next(gammas)
        res = spec_decode_step(draft_decode, target_verify,
                               eng.draft_params, eng.target_params,
                               state, gamma, jax.random.PRNGKey(9),
                               temperature=0.0)
        state = res.state
        new = np.asarray(res.new_tokens)
        nn = np.asarray(res.num_new)
        for b in range(B):
            out[b].extend(int(t) for t in new[b, :nn[b]])
        produced += nn
    ref = np.stack([np.asarray(seq[:N]) for seq in out])
    np.testing.assert_array_equal(toks[:, :N], ref)


def test_stats_schema_and_prefill_timing():
    eng = SpecDecodeEngine(DRAFT, TARGET_DENSE, temperature=0.0,
                           key=jax.random.PRNGKey(5))
    prompts = np.random.default_rng(2).integers(0, 128, (2, 8)).astype(np.int32)
    toks, stats = eng.generate(prompts, 12, CyclingWindowPolicy())
    assert stats.prefill_s > 0.0
    assert stats.prefill_s < stats.wall_s
    assert stats.tokens >= 2 * 11
    assert stats.iterations == len(stats.gamma_seq)
    assert len(stats.acceptance_seqs) == 2
    assert all(b in (0, 1) for s in stats.acceptance_seqs for b in s)
    assert (toks[:, :12] >= 0).all()
