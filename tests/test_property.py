"""Hypothesis property-based tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st

from repro.core.awc.stabilize import StabilizerConfig, WindowStabilizer
from repro.core.specdec import expected_accepted, expected_speedup
from repro.kernels.verify import verify_reference
from repro.sim.trace import AcceptanceCursor, markov_acceptance_seq
from repro.sim import loads as yaml_loads
from repro.sim.hwmodel import HardwareModel, OpShape
import random


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(2, 6), st.integers(16, 64),
       st.integers(0, 2 ** 31 - 1))
def test_verify_invariants(B, G, V, seed):
    """0 ≤ n_accepted ≤ γ; next_token ∈ [0, V); num_new = n_accepted + 1;
    accepted prefix is contiguous."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    p = jax.nn.softmax(jax.random.normal(ks[0], (B, G + 1, V)) * 3, -1)
    q = jax.nn.softmax(jax.random.normal(ks[1], (B, G, V)) * 3, -1)
    toks = jax.random.categorical(ks[2], jnp.log(q), axis=-1).astype(jnp.int32)
    u = jax.random.uniform(ks[3], (B, G))
    r = jax.random.uniform(ks[4], (B,))
    out = verify_reference(toks, q, p, u, r)
    n = np.asarray(out.n_accepted)
    t = np.asarray(out.next_token)
    m = np.asarray(out.accept_mask)
    assert ((0 <= n) & (n <= G)).all()
    assert ((0 <= t) & (t < V)).all()
    # contiguous prefix: mask[:, :n] all True, mask[:, n] False (if n < G)
    for b in range(B):
        assert m[b, : n[b]].all()
        if n[b] < G:
            assert not m[b, n[b]]


@settings(max_examples=50, deadline=None)
@given(st.floats(0.01, 0.99), st.integers(1, 16))
def test_eq1_bounds(alpha, gamma):
    """1 ≤ E[τ] ≤ γ+1 and monotone in α."""
    e = float(expected_accepted(alpha, gamma))
    assert 1.0 - 1e-5 <= e <= gamma + 1 + 1e-5
    e2 = float(expected_accepted(min(0.999, alpha + 0.2), gamma))
    assert e2 >= e - 1e-5


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-50, 50), min_size=1, max_size=40),
       st.integers(1, 4))
def test_stabilizer_output_always_in_range(raws, k):
    stab = WindowStabilizer(StabilizerConfig(hysteresis_k=k))
    for r in raws:
        g, mode = stab.step(r)
        assert 1 <= g <= 12
        assert mode in ("distributed", "fused")
        if mode == "fused":
            assert g == 1


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.05, 0.95), st.floats(0.0, 0.9),
       st.integers(10, 400))
def test_markov_acceptance_stationary_rate(seed, alpha, rho, n):
    rng = random.Random(seed)
    seq = markov_acceptance_seq(rng, n, alpha, rho)
    assert len(seq) == n
    assert set(seq) <= {0, 1}


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1), min_size=1, max_size=64),
       st.integers(1, 12))
def test_acceptance_cursor_consume(seq, gamma):
    cur = AcceptanceCursor(seq)
    n, all_acc = cur.consume(gamma)
    assert 0 <= n <= gamma
    assert all_acc == (n == gamma)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(1, 32), st.integers(0, 4096),
       st.sampled_from(["A40", "A100", "H100", "TPUv5e"]),
       st.sampled_from(["llama2-7b", "llama2-70b"]))
def test_hwmodel_latency_positive_and_monotone_in_batch(
        batch, tokens, ctx, hw, model):
    hm = HardwareModel()
    shp1 = OpShape(context_lens=[ctx] * batch, new_tokens=[tokens] * batch)
    shp2 = OpShape(context_lens=[ctx] * (batch + 1),
                   new_tokens=[tokens] * (batch + 1))
    t1 = hm.predict("decode", shp1, hw, model)
    t2 = hm.predict("decode", shp2, hw, model)
    assert t1 > 0
    assert t2 >= t1 - 1e-12          # more work never takes less time


@settings(max_examples=25, deadline=None)
@given(st.dictionaries(
    st.text(alphabet="abcdefgh", min_size=1, max_size=6),
    st.one_of(st.integers(-1000, 1000), st.booleans(),
              st.text(alphabet="xyz", min_size=0, max_size=5)),
    min_size=0, max_size=6))
def test_miniyaml_roundtrip_flat_dicts(d):
    text = "\n".join(
        f"{k}: {repr(v) if isinstance(v, str) else v}" for k, v in d.items())
    parsed = yaml_loads(text)
    if not d:
        assert parsed is None
        return
    for k, v in d.items():
        assert parsed[k] == v


# ----------------------------------------------------------- wire round trip

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(1, 12),
       st.integers(0, 2 ** 31 - 1), st.integers(0, 2 ** 40),
       st.integers(0, 64), st.booleans())
def test_wire_window_roundtrip(B, G, seed, round_id, n_active, speculative):
    """Arbitrary WindowMsg payloads survive encode→decode bit for bit
    (q_probs excluded — the documented device pass-through)."""
    from repro.distributed import WindowMsg, decode_window, encode_window
    rng = np.random.default_rng(seed)
    msg = WindowMsg(tokens=rng.integers(0, 2 ** 31 - 1, (B, G),
                                        dtype=np.int32),
                    gamma=min(G, 4), n_active=n_active, round_id=round_id,
                    speculative=speculative)
    out = decode_window(encode_window(msg))
    np.testing.assert_array_equal(out.tokens, msg.tokens)
    assert (out.gamma, out.n_active, out.round_id, out.speculative) == \
        (msg.gamma, msg.n_active, msg.round_id, msg.speculative)
    assert out.payload_bytes == msg.payload_bytes
    assert out.q_probs is None


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(0, 2 ** 31 - 1),
       st.integers(0, 2 ** 40), st.integers(1, 12), st.integers(0, 64))
def test_wire_verdict_roundtrip(B, seed, round_id, gamma, n_active):
    from repro.distributed import VerdictMsg, decode_verdict, encode_verdict
    rng = np.random.default_rng(seed)
    i32 = lambda: rng.integers(0, 2 ** 31 - 1, (B,), dtype=np.int32)
    msg = VerdictMsg(n_accepted=i32(), num_new=i32(), next_token=i32(),
                     last_token=i32(), done=rng.integers(0, 2, (B,)) > 0,
                     gamma=gamma, n_active=n_active, round_id=round_id)
    out = decode_verdict(encode_verdict(msg))
    for f in ("n_accepted", "num_new", "next_token", "last_token", "done"):
        np.testing.assert_array_equal(getattr(out, f), getattr(msg, f))
    assert (out.gamma, out.n_active, out.round_id) == \
        (msg.gamma, msg.n_active, msg.round_id)
    assert out.payload_bytes == msg.payload_bytes


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(1, 5), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1), st.integers(0, 2 ** 40),
       st.integers(0, 64))
def test_wire_tree_window_roundtrip(B, d_max, b_max, seed, round_id,
                                    n_active):
    """Tree WindowMsg payloads (token grid + parent table + branch count)
    survive encode→decode bit for bit, and the framed size matches the
    node-count-priced analytic payload model exactly."""
    from repro.distributed import WindowMsg, decode_window, encode_window
    from repro.sim.network import window_payload_bytes
    rng = np.random.default_rng(seed)
    T = 1 + d_max * b_max
    parent = np.zeros((T,), np.int32)
    for d in range(d_max):
        for k in range(b_max):
            e = 1 + d * b_max + k
            parent[e] = 0 if d == 0 else 1 + (d - 1) * b_max + k
    msg = WindowMsg(tokens=rng.integers(0, 2 ** 31 - 1, (B, T),
                                        dtype=np.int32),
                    gamma=d_max, n_active=n_active, round_id=round_id,
                    n_nodes=T, branches=b_max, parent=parent)
    out = decode_window(encode_window(msg))
    np.testing.assert_array_equal(out.tokens, msg.tokens)
    np.testing.assert_array_equal(out.parent, msg.parent)
    assert (out.gamma, out.n_active, out.round_id, out.n_nodes,
            out.branches) == (msg.gamma, msg.n_active, msg.round_id,
                              msg.n_nodes, msg.branches)
    assert out.payload_bytes == msg.payload_bytes == \
        max(1, n_active) * window_payload_bytes(d_max, n_nodes=T)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(1, 6), st.integers(0, 2 ** 31 - 1),
       st.integers(0, 2 ** 40), st.integers(1, 12), st.integers(0, 64))
def test_wire_tree_verdict_roundtrip(B, D, seed, round_id, gamma, n_active):
    """Verdicts carrying the winning tree path round-trip exactly."""
    from repro.distributed import VerdictMsg, decode_verdict, encode_verdict
    rng = np.random.default_rng(seed)
    i32 = lambda: rng.integers(0, 2 ** 31 - 1, (B,), dtype=np.int32)
    msg = VerdictMsg(n_accepted=i32(), num_new=i32(), next_token=i32(),
                     last_token=i32(), done=rng.integers(0, 2, (B,)) > 0,
                     gamma=gamma, n_active=n_active, round_id=round_id,
                     path=rng.integers(0, 2 ** 31 - 1, (B, D),
                                       dtype=np.int32))
    out = decode_verdict(encode_verdict(msg))
    for f in ("n_accepted", "num_new", "next_token", "last_token", "done",
              "path"):
        np.testing.assert_array_equal(getattr(out, f), getattr(msg, f))
    assert (out.gamma, out.n_active, out.round_id) == \
        (msg.gamma, msg.n_active, msg.round_id)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 64), st.integers(0, 256), st.integers(1, 64))
def test_payload_bytes_monotone_in_nodes(g, n, dn):
    """Node-count-priced windows grow strictly with the tree size at any
    γ — the link charges for every grid entry plus its parent-table row."""
    from repro.sim.network import window_payload_bytes
    assert window_payload_bytes(g, n_nodes=n + dn) > \
        window_payload_bytes(g, n_nodes=n) > 0


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 64), st.integers(1, 64))
def test_payload_bytes_monotone_in_gamma(g, dg):
    """The modeled wire costs grow strictly with γ (ids + per-token probs
    out, per-position logprobs back) — the LinkSpec serialization term
    must never shrink when the window widens."""
    from repro.sim.network import verdict_payload_bytes, window_payload_bytes
    assert window_payload_bytes(g + dg) > window_payload_bytes(g) > 0
    assert verdict_payload_bytes(g + dg) > verdict_payload_bytes(g) > 0


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 2 ** 31 - 1),
       st.booleans(), st.data())
def test_wire_window_every_prefix_raises(B, G, seed, tree, data):
    """Hardened framing: EVERY strict prefix of a valid encoded window is
    rejected with ValueError (never struct.error / short frombuffer), and
    so is the blob with one flipped byte in the length-bearing header."""
    from repro.distributed import WindowMsg, decode_window, encode_window
    rng = np.random.default_rng(seed)
    T = 1 + G if tree else G
    msg = WindowMsg(tokens=rng.integers(0, 2 ** 31 - 1, (B, T),
                                        dtype=np.int32),
                    gamma=G, n_active=B,
                    n_nodes=T if tree else 0, branches=1,
                    parent=(np.maximum(np.arange(T, dtype=np.int32) - 1, 0)
                            if tree else None))
    blob = encode_window(msg)
    cut = data.draw(st.integers(0, len(blob) - 1), label="cut")
    with pytest.raises(ValueError):
        decode_window(blob[:cut])
    with pytest.raises(ValueError):
        decode_window(blob + b"\x00")


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(0, 4), st.integers(0, 2 ** 31 - 1),
       st.data())
def test_wire_verdict_every_prefix_raises(B, D, seed, data):
    from repro.distributed import VerdictMsg, decode_verdict, encode_verdict
    rng = np.random.default_rng(seed)
    i32 = lambda: rng.integers(0, 2 ** 31 - 1, (B,), dtype=np.int32)
    msg = VerdictMsg(n_accepted=i32(), num_new=i32(), next_token=i32(),
                     last_token=i32(), done=rng.integers(0, 2, (B,)) > 0,
                     gamma=3, n_active=B,
                     path=(rng.integers(0, 2 ** 31 - 1, (B, D),
                                        dtype=np.int32) if D else None))
    blob = encode_verdict(msg)
    cut = data.draw(st.integers(0, len(blob) - 1), label="cut")
    with pytest.raises(ValueError):
        decode_verdict(blob[:cut])
    with pytest.raises(ValueError):
        decode_verdict(blob + b"\xff")
