"""Topology spec layer: JSON round trip (hypothesis property), validate()
rejections, deployment structure, legacy-flag shim equivalence, and the
one-pair bit-identity regression (topology-built serving == the
pre-topology hand-wired server)."""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig
from repro.core.engine import SpecDecodeEngine
from repro.core.window import StaticWindowPolicy
from repro.distributed import InProcessTransport
from repro.serving import (LeastLoadedPairRouter, ServeRequest, ServerConfig,
                           SpecDecodeServer)
from repro.sim.network import LinkSpec
from repro.topology import (ClusterSpec, NodeSpec, PairSpec, ServingSpec,
                            TopologyError, WindowSpec, WorkloadSpec,
                            build_deployment, build_simulation,
                            one_pair_spec)

TINY_T = ModelConfig(name="topo-t", arch_type="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                     vocab=128, dtype="float32", remat=False)
TINY_D = dataclasses.replace(TINY_T, name="topo-d", n_layers=1)
TINY = {"topo-t": TINY_T, "topo-d": TINY_D}


def two_pair_spec(rtt_fast=0.0, rtt_slow=40.0, window=None,
                  max_batch=2) -> ClusterSpec:
    window = window or WindowSpec("static", 3)
    return ClusterSpec(
        nodes=[NodeSpec("e0", "draft", "topo-d"),
               NodeSpec("e1", "draft", "topo-d"),
               NodeSpec("c0", "target", "topo-t")],
        pairs=[PairSpec("fast", "e0", "c0",
                        link=LinkSpec(rtt_ms=rtt_fast, jitter_ms=0.0),
                        window=window),
               PairSpec("slow", "e1", "c0",
                        link=LinkSpec(rtt_ms=rtt_slow, jitter_ms=1.0),
                        window=window)],
        serving=ServingSpec(max_batch=max_batch, gamma_max=6, sync_every=4),
        workload=WorkloadSpec(num_requests=4, max_new=8))


# ----------------------------------------------------------- JSON round trip

def test_round_trip_explicit():
    spec = two_pair_spec()
    again = ClusterSpec.from_json(spec.to_json())
    assert again == spec
    # and None links / defaults survive too
    spec2 = one_pair_spec()
    assert ClusterSpec.from_json(spec2.to_json()) == spec2
    assert spec2.pairs[0].link is None


def test_from_dict_rejects_unknown_fields():
    d = two_pair_spec().to_dict()
    d["nodes"][0]["gpu_count"] = 9
    with pytest.raises(TopologyError):
        ClusterSpec.from_dict(d)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:             # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _name = st.text(alphabet="abcdef012", min_size=1, max_size=6)
    _pos = st.floats(min_value=0.0, max_value=500.0, allow_nan=False)

    @st.composite
    def cluster_specs(draw):
        n_d = draw(st.integers(1, 3))
        n_t = draw(st.integers(1, 2))
        nodes = [NodeSpec(id=f"d{i}", role="draft", model=draw(_name),
                          device=draw(_name), hw=draw(_name),
                          sim_model=draw(_name),
                          tp=draw(st.integers(0, 8)))
                 for i in range(n_d)]
        nodes += [NodeSpec(id=f"t{i}", role="target", model=draw(_name))
                  for i in range(n_t)]
        pairs = []
        for i in range(draw(st.integers(1, 4))):
            has_link = draw(st.booleans())
            link = None
            if has_link:
                link = LinkSpec(rtt_ms=draw(_pos), jitter_ms=draw(_pos),
                                bandwidth_gbps=draw(st.floats(
                                    min_value=0.01, max_value=100.0,
                                    allow_nan=False)),
                                name=draw(_name))
            mode = draw(st.sampled_from(
                ("auto", "distributed", "fused", "pipeline") if has_link
                else ("auto", "distributed", "fused")))
            window = WindowSpec(
                kind=draw(st.sampled_from(("static", "dynamic", "awc"))),
                gamma=draw(st.integers(1, 12)), hi=draw(_pos),
                lo=draw(_pos), gmax=draw(st.integers(1, 16)))
            pairs.append(PairSpec(
                id=f"p{i}", draft=f"d{draw(st.integers(0, n_d - 1))}",
                target=f"t{draw(st.integers(0, n_t - 1))}", link=link,
                window=window, mode_policy=mode))
        serving = ServingSpec(max_batch=draw(st.integers(1, 16)),
                              length_aware=draw(st.booleans()),
                              sync_every=draw(st.integers(1, 16)),
                              gamma_max=draw(st.integers(2, 16)),
                              temperature=draw(st.floats(
                                  min_value=0.0, max_value=2.0,
                                  allow_nan=False)),
                              rtt_ms=draw(_pos),
                              router=draw(st.sampled_from(
                                  ("least-loaded", "round-robin"))))
        workload = WorkloadSpec(dataset=draw(_name),
                                num_requests=draw(st.integers(0, 64)),
                                max_new=draw(st.integers(1, 128)),
                                rate_per_s=draw(_pos),
                                prompt_lo=draw(st.integers(1, 8)),
                                prompt_hi=draw(st.integers(9, 64)))
        return ClusterSpec(nodes=nodes, pairs=pairs, serving=serving,
                           workload=workload,
                           seed=draw(st.integers(0, 2**31 - 1)))

    @settings(max_examples=60, deadline=None)
    @given(cluster_specs())
    def test_round_trip_property(spec):
        """spec == decode(encode(spec)) — exact, including floats, None
        links, and every nested dataclass — and generated specs pass
        validate()."""
        spec.validate()
        assert ClusterSpec.from_json(spec.to_json()) == spec
        # dict round trip too (the path the launcher file-loading uses)
        assert ClusterSpec.from_dict(spec.to_dict()) == spec


# --------------------------------------------------------------- validate()

def _valid() -> ClusterSpec:
    return two_pair_spec()


def test_validate_accepts_valid_spec():
    _valid().validate()


@pytest.mark.parametrize("mutate,msg", [
    (lambda s: s.pairs.__setitem__(
        0, dataclasses.replace(s.pairs[0], draft="ghost")),
     "unknown node ref"),
    (lambda s: s.pairs.__setitem__(
        1, dataclasses.replace(s.pairs[1], id="fast")),
     "duplicate pair id"),
    (lambda s: s.nodes.append(NodeSpec("e0", "draft", "topo-d")),
     "duplicate node id"),
    (lambda s: s.pairs.__setitem__(
        0, dataclasses.replace(s.pairs[0],
                               link=LinkSpec(rtt_ms=-5.0))),
     "negative rtt_ms"),
    (lambda s: s.pairs.__setitem__(
        0, dataclasses.replace(s.pairs[0],
                               link=LinkSpec(rtt_ms=1.0, jitter_ms=-1.0))),
     "negative jitter_ms"),
    (lambda s: s.pairs.__setitem__(
        0, dataclasses.replace(
            s.pairs[0], link=LinkSpec(bandwidth_gbps=0.0))),
     "bandwidth_gbps"),
    (lambda s: s.nodes.__setitem__(
        2, dataclasses.replace(s.nodes[2], role="oracle")),
     "role"),
    (lambda s: s.pairs.__setitem__(
        0, dataclasses.replace(s.pairs[0], target="e1")),
     "role"),   # wrong-role reference: a draft node used as target
    (lambda s: s.pairs.__setitem__(
        0, dataclasses.replace(s.pairs[0], mode_policy="warp")),
     "mode_policy"),
    (lambda s: s.pairs.__setitem__(
        0, dataclasses.replace(s.pairs[0], link=None,
                               mode_policy="pipeline")),
     "pipeline"),
    (lambda s: s.pairs.__setitem__(
        0, dataclasses.replace(s.pairs[0],
                               window=WindowSpec(kind="prophet"))),
     "window kind"),
    (lambda s: s.pairs.__setitem__(
        0, dataclasses.replace(s.pairs[0],
                               window=WindowSpec(gamma=0))),
     "gamma"),
    (lambda s: setattr(s.serving, "max_batch", 0), "max_batch"),
    (lambda s: setattr(s.serving, "router", "psychic"), "router"),
    (lambda s: setattr(s.serving, "server", "wave"), "wave"),
    (lambda s: setattr(s.workload, "max_new", 0), "max_new"),
    # prompt_hi is an EXCLUSIVE bound (numpy integers semantics): an
    # empty range must be rejected at validate(), not crash the launcher
    (lambda s: (setattr(s.workload, "prompt_lo", 32),
                setattr(s.workload, "prompt_hi", 32)), "prompt_lo"),
    (lambda s: s.pairs.clear(), "at least one pair"),
])
def test_validate_rejections(mutate, msg):
    spec = _valid()
    mutate(spec)
    with pytest.raises(TopologyError, match=msg.split()[0]):
        spec.validate()


# ------------------------------------------------------- legacy-flag shim

def test_legacy_flags_compile_to_equivalent_one_pair_spec():
    """Every pre-existing launch.serve flag combination maps to a one-pair
    ClusterSpec — including --link-rtt-ms 0 (zero-delay in-process link)
    and --mode-policy pipeline."""
    spec = one_pair_spec(target="qwen3-14b", draft="qwen2.5-3b",
                         policy="awc", gamma=6, gamma_max=10, max_batch=3,
                         sync_every=4, temperature=0.5, rtt_ms=7.0,
                         link_rtt_ms=0.0, link_jitter_ms=2.0,
                         link_bw_gbps=0.5, mode_policy="pipeline",
                         requests=5, max_new=17, arrival_rate=3.0, seed=9)
    spec.validate()
    assert spec == ClusterSpec(
        nodes=[NodeSpec("edge0", "draft", "qwen2.5-3b"),
               NodeSpec("cloud0", "target", "qwen3-14b")],
        pairs=[PairSpec("pair0", "edge0", "cloud0",
                        link=LinkSpec(rtt_ms=0.0, jitter_ms=2.0,
                                      bandwidth_gbps=0.5),
                        window=WindowSpec(kind="awc", gamma=6),
                        mode_policy="pipeline")],
        serving=ServingSpec(max_batch=3, sync_every=4, gamma_max=10,
                            temperature=0.5, rtt_ms=7.0),
        workload=WorkloadSpec(num_requests=5, max_new=17, rate_per_s=3.0),
        seed=9)
    # no link flags -> colocated pair, no transport
    colocated = one_pair_spec(mode_policy="auto")
    assert colocated.pairs[0].link is None
    deployment = build_deployment(
        dataclasses.replace(colocated, nodes=[
            NodeSpec("edge0", "draft", "topo-d"),
            NodeSpec("cloud0", "target", "topo-t")]),
        model_configs=TINY)
    assert deployment.pairs[0].transport is None


# -------------------------------------------------- deployment structure

def test_build_deployment_shares_node_params_and_isolates_pairs():
    spec = two_pair_spec()
    dep = build_deployment(spec, model_configs=TINY, sleep_links=False)
    assert [p.pair_id for p in dep.pairs] == ["fast", "slow"]
    e_fast, e_slow = dep.pairs[0].engine, dep.pairs[1].engine
    # distinct draft nodes -> distinct engines, but ONE set of target
    # params built for the shared cloud node
    assert e_fast is not e_slow
    assert e_fast.target_params is e_slow.target_params
    assert e_fast.draft_params is not e_slow.draft_params
    # one transport and one policy instance per pair
    assert dep.pairs[0].transport is not dep.pairs[1].transport
    assert isinstance(dep.pairs[0].transport, InProcessTransport)
    assert type(dep.pairs[1].transport).__name__ == "EmulatedLinkTransport"
    assert dep.pairs[0].policy is not dep.pairs[1].policy
    assert isinstance(dep.router, LeastLoadedPairRouter)
    assert dep.vocab == TINY_T.vocab


def test_build_deployment_validates():
    spec = two_pair_spec()
    spec.pairs[1] = dataclasses.replace(spec.pairs[1], draft="ghost")
    with pytest.raises(TopologyError):
        build_deployment(spec, model_configs=TINY)


# ------------------------------------------------ one-pair bit identity

def test_topology_server_bit_identical_to_legacy_path():
    """A one-pair spec with a zero-delay link, built through
    build_deployment, must commit greedy tokens BIT-identical to the
    hand-wired engine + ServerConfig(transport=...) surface the launcher
    used before the topology API existed."""
    spec = one_pair_spec(target="topo-t", draft="topo-d", policy="static",
                         gamma=3, gamma_max=6, max_batch=2, sync_every=4,
                         temperature=0.0, link_rtt_ms=0.0, seed=3)
    dep = build_deployment(spec, model_configs=TINY)
    srv_topo = dep.build_server()

    # the legacy construction, byte for byte what launch.serve did pre-PR5
    engine = SpecDecodeEngine(TINY_D, TINY_T, temperature=0.0, rtt_ms=10.0,
                              gamma_max=6, sync_every=4,
                              key=jax.random.PRNGKey(3))
    srv_legacy = SpecDecodeServer(
        engine, StaticWindowPolicy(3),
        ServerConfig(max_batch=2, transport=InProcessTransport()))

    rng = np.random.default_rng(0)
    reqs = [(i, rng.integers(0, TINY_T.vocab, int(rng.integers(4, 12)))
             .astype(np.int32)) for i in range(4)]
    for srv in (srv_topo, srv_legacy):
        for i, prompt in reqs:
            srv.submit(ServeRequest(i, prompt, 8))
    got = {r.request_id: r.tokens for r in srv_topo.run()}
    ref = {r.request_id: r.tokens for r in srv_legacy.run()}
    assert set(got) == set(ref) == {0, 1, 2, 3}
    for rid in ref:
        assert np.array_equal(got[rid], ref[rid]), rid
    # per-pair summary exists and carries the flat link stats per pair id
    ps = srv_topo.pair_summaries()
    assert set(ps) == {"pair0"}
    assert ps["pair0"]["requests"] == 4
    assert ps["pair0"]["messages"] > 0


# ------------------------------------------------------------ sim factory

def test_build_simulation_pins_pairs_to_links_and_targets():
    spec = two_pair_spec(rtt_fast=2.0, rtt_slow=80.0)
    spec.workload = WorkloadSpec(num_requests=6, max_new=24, rate_per_s=50.0)
    sim = build_simulation(spec)
    # one sim drafter per pair with ITS pair's link
    assert sim.drafter_links is not None and len(sim.drafter_links) == 2
    assert sim.drafter_links[0].spec.rtt_ms == 2.0
    assert sim.drafter_links[1].spec.rtt_ms == 80.0
    an = sim.run()
    assert an.requests, "simulation served nothing"
    for m in an.requests.values():
        # pinned routing: both pairs share the single target node
        assert m.target_id == 0
        assert m.tokens_generated > 0


# ------------------------------------------- process-backed pair spec fields

def test_process_pair_fields_round_trip_and_validate():
    """NodeSpec.address/port and PairSpec.process survive the JSON round
    trip with defaults intact, and a fully-specified process pair
    validates under the restricted regime (greedy + static + distributed
    + continuous)."""
    spec = ClusterSpec(
        nodes=[NodeSpec(id="edge0", role="draft", model="topo-d",
                        address="10.0.0.2", port=7101),
               NodeSpec(id="cloud0", role="target", model="topo-t",
                        address="10.0.0.9", port=7100)],
        pairs=[PairSpec(id="p0", draft="edge0", target="cloud0",
                        window=WindowSpec(kind="static", gamma=4),
                        mode_policy="distributed", process=True)],
        serving=ServingSpec(max_batch=2, temperature=0.0,
                            server="continuous"),
        workload=WorkloadSpec(num_requests=2, max_new=8))
    spec.validate()
    again = ClusterSpec.from_json(spec.to_json())
    assert again == spec
    assert again.nodes[0].address == "10.0.0.2"
    assert again.nodes[1].port == 7100
    assert again.pairs[0].process is True
    # defaults stay default (and keep old topology JSONs loadable)
    legacy = two_pair_spec()
    rt = ClusterSpec.from_dict(legacy.to_dict())
    assert rt.nodes[0].address == "" and rt.nodes[0].port == 0
    assert rt.pairs[0].process is False


def test_build_deployment_rejects_explicit_key_with_process_pairs():
    """Worker hosts rebuild params from spec.seed; an explicit PRNG key
    cannot cross the process boundary and must be rejected up front."""
    spec = ClusterSpec(
        nodes=[NodeSpec(id="edge0", role="draft", model="topo-d"),
               NodeSpec(id="cloud0", role="target", model="topo-t")],
        pairs=[PairSpec(id="p0", draft="edge0", target="cloud0",
                        window=WindowSpec(kind="static", gamma=3),
                        mode_policy="distributed", process=True)],
        serving=ServingSpec(max_batch=1, temperature=0.0,
                            server="continuous"),
        workload=WorkloadSpec(num_requests=1, max_new=4))
    with pytest.raises(TopologyError, match="seed"):
        build_deployment(spec, model_configs=TINY,
                         key=jax.random.PRNGKey(0))
