"""Suite-wide fixtures.

The full suite compiles several hundred XLA programs (every model arch
in test_smoke_archs, every engine/session/transport configuration).  On
CPU, letting all of those executables accumulate in one process
eventually segfaults jaxlib's native compiler partway through the run —
deterministically, and only after ~190 tests — so each module drops the
jit/pjit executable caches it filled once its tests finish.  Re-running
a module recompiles from scratch; within-module compile-count tests
(compile-once gates, zero-recompile invariants) are unaffected because
the caches are only cleared at module teardown.
"""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
