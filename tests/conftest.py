"""Suite-wide fixtures.

The full suite compiles several hundred XLA programs (every model arch
in test_smoke_archs, every engine/session/transport configuration).  On
CPU, letting all of those executables accumulate in one process
eventually segfaults jaxlib's native compiler partway through the run —
deterministically, and only after ~190 tests — so each module drops the
jit/pjit executable caches it filled once its tests finish.  Re-running
a module recompiles from scratch; within-module compile-count tests
(compile-once gates, zero-recompile invariants) are unaffected because
the caches are only cleared at module teardown.

Set ``DSD_CLEAR_JIT_CACHES=0`` to disable the workaround (e.g. to check
whether an upstream jaxlib fixed the crash, or to profile cache reuse
across modules).  With the workaround off, a warning reports the
accumulated backend-compile count once it enters the known segfault
regime so the crash stays diagnosable rather than mysterious.
"""

import os
import warnings

import jax
import pytest

from repro.analysis.sanitize import (install_compile_listener,
                                     total_backend_compiles)

_CLEAR_CACHES = os.environ.get("DSD_CLEAR_JIT_CACHES", "1") != "0"
# the deterministic jaxlib CPU segfault lands around ~190 accumulated
# programs; start warning below that so the report precedes the crash
_SEGFAULT_REGIME = 150

install_compile_listener()


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    if _CLEAR_CACHES:
        jax.clear_caches()
        return
    accumulated = total_backend_compiles()
    if accumulated >= _SEGFAULT_REGIME:
        warnings.warn(
            f"DSD_CLEAR_JIT_CACHES=0: {accumulated} XLA programs have "
            f"accumulated in this process — jaxlib's CPU compiler is known "
            f"to segfault around ~190; a crash past this point is the "
            f"known executable-cache bug, not the test that was running",
            ResourceWarning, stacklevel=0)
