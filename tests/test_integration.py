"""Cross-layer integration tests: MoE grouping, AWC-in-the-engine,
chunked prefill equivalence, trace capture → simulator replay."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ModelConfig
from repro.core.engine import SpecDecodeEngine
from repro.core.window import AWCWindowPolicy, StaticWindowPolicy
from repro.core.awc.model import bootstrap_gamma, default_predictor
from repro.models import build_model
from repro.sim import (ClusterSpec, DSDSimulation, LinkSpec, PolicyStack,
                       TraceRecord)
from repro.sim.policies import BatchingConfig, LengthAwareBatching, JSQRouting


def test_moe_grouping_matches_ungrouped():
    """GShard grouping for long sequences must equal the ungrouped block
    when capacity is non-binding."""
    cfg = dataclasses.replace(ARCHS["llama4-maverick-400b-a17b"].reduced(),
                              capacity_factor=8.0, moe_group=16)
    from repro.models.moe import init_moe_params, moe_block
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y_grouped, _ = moe_block(x, p, cfg)   # 64 > moe_group=16 → grouped
    cfg2 = dataclasses.replace(cfg, moe_group=4096)
    y_plain, _ = moe_block(x, p, cfg2)    # ungrouped
    np.testing.assert_allclose(np.asarray(y_grouped), np.asarray(y_plain),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.slow
def test_chunked_prefill_cache_matches_full():
    cfg = ARCHS["qwen3-14b"].reduced()
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    lg_full, cache_full = m.prefill(params, toks, slots=48)
    lg_chunk, cache_chunk = m.prefill(params, toks, slots=48, chunk=8)
    # chunked path returns last-chunk logits only
    np.testing.assert_allclose(np.asarray(lg_full[:, -8:]),
                               np.asarray(lg_chunk), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(cache_full.k),
                               np.asarray(cache_chunk.k), atol=1e-5)
    # decode continues identically from either cache
    pos = jnp.full((2,), 32, jnp.int32)
    tok = jnp.argmax(lg_chunk[:, -1], -1).astype(jnp.int32)
    a, _ = m.decode_step(params, tok, cache_full, pos)
    b, _ = m.decode_step(params, tok, cache_chunk, pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.slow
def test_awc_policy_runs_in_engine():
    dcfg = ModelConfig(name="d", arch_type="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                       dtype="float32", remat=False)
    tcfg = dataclasses.replace(dcfg, name="t", n_layers=3, n_kv_heads=4)
    eng = SpecDecodeEngine(dcfg, tcfg, temperature=0.0,
                           key=jax.random.PRNGKey(2))
    prompts = np.random.default_rng(0).integers(0, 128, (2, 10)).astype(np.int32)
    for predictor in (default_predictor(), bootstrap_gamma):
        toks, stats = eng.generate(prompts, 16, AWCWindowPolicy(predictor))
        assert stats.tokens >= 2 * 15
        assert all(1 <= g <= 12 for g in stats.gamma_seq)
    # AWC output must STILL be exactly the target's greedy continuation
    ref, _ = eng.generate(prompts, 16, StaticWindowPolicy(4))
    awc, _ = eng.generate(prompts, 16, AWCWindowPolicy(bootstrap_gamma))
    np.testing.assert_array_equal(ref[:, :16], awc[:, :16])


@pytest.mark.slow
def test_captured_traces_replay_through_sim():
    dcfg = ModelConfig(name="d", arch_type="dense", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                       dtype="float32", remat=False)
    tcfg = dataclasses.replace(dcfg, name="t", n_layers=2)
    eng = SpecDecodeEngine(dcfg, tcfg, temperature=1.0,
                           key=jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, 64, (4, 8)).astype(np.int32)
    seqs = eng.capture_traces(prompts, 12, gamma=4)
    records = [TraceRecord(request_id=i, prompt_length=8, output_length=12,
                           acceptance_seq=bits, arrival_time_ms=i * 40.0,
                           drafter_id=i, dataset="captured")
               for i, bits in enumerate(seqs)]
    sim = DSDSimulation(
        ClusterSpec(num_targets=1, num_drafters=4, link=LinkSpec(rtt_ms=5.0)),
        PolicyStack(routing=JSQRouting(), batching=LengthAwareBatching(),
                    batching_cfg=BatchingConfig(max_batch=4),
                    window=StaticWindowPolicy(4)),
        records)
    s = sim.run().summary()
    assert s["completed"] == 4
    assert 0.0 <= s["acceptance_rate"] <= 1.0


def test_heterogeneous_cluster_pools():
    from repro.sim.scheduler import PAPER_DRAFT_POOL, PAPER_TARGET_POOL
    cl = ClusterSpec(num_targets=3, num_drafters=6,
                     target_pool=PAPER_TARGET_POOL,
                     draft_pool=PAPER_DRAFT_POOL)
    assert cl.target_at(0)[0] == "A100"
    assert cl.target_at(1)[1] == "qwen-72b"
    assert cl.target_at(3) == cl.target_at(0)     # round-robin
    assert cl.draft_at(1) == ("V100", "qwen-7b")
    homo = ClusterSpec()
    assert homo.target_at(7) == ("A100", "llama2-70b", 4)
