"""Fleet workload subsystem: TraceSpec validation + seeded determinism,
rolling quantile windows, α/link-aware pair costing, router churn
(sticky/drain/ties), SLO-aware admission, sim pair routing, and the
elastic pair pool's control law."""

import dataclasses
import math

import numpy as np
import pytest

from repro.fleet import (ElasticPairPool, RequestClass, RollingQuantile,
                         SmartPairRouter, TraceSpec, WorkloadError,
                         fleet_serve_requests, fleet_trace_records,
                         generate_requests, pair_cost, slo_report)
from repro.configs.base import ModelConfig
from repro.serving import (LeastLoadedPairRouter, ServeRequest, ServeResult,
                           ServingPair, SpecDecodeServer)
from repro.sim.network import LinkSpec
from repro.topology import (ClusterSpec, NodeSpec, PairSpec, ServingSpec,
                            TopologyError, WindowSpec, WorkloadSpec,
                            build_deployment, build_simulation)

TINY_T = ModelConfig(name="fleet-t", arch_type="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                     vocab=128, dtype="float32", remat=False)
TINY_D = dataclasses.replace(TINY_T, name="fleet-d", n_layers=1)
TINY = {"fleet-t": TINY_T, "fleet-d": TINY_D}


def two_pair_spec(rtt_fast=0.0, rtt_slow=40.0, max_batch=2,
                  router="least-loaded") -> ClusterSpec:
    return ClusterSpec(
        nodes=[NodeSpec("e0", "draft", "fleet-d"),
               NodeSpec("e1", "draft", "fleet-d"),
               NodeSpec("c0", "target", "fleet-t")],
        pairs=[PairSpec("fast", "e0", "c0",
                        link=LinkSpec(rtt_ms=rtt_fast, jitter_ms=0.0),
                        window=WindowSpec("static", 3)),
               PairSpec("slow", "e1", "c0",
                        link=LinkSpec(rtt_ms=rtt_slow, jitter_ms=0.0),
                        window=WindowSpec("static", 3))],
        serving=ServingSpec(max_batch=max_batch, gamma_max=6, sync_every=4,
                            router=router),
        workload=WorkloadSpec(num_requests=4, max_new=8))


def tiny_trace(**kw) -> TraceSpec:
    kw.setdefault("num_requests", 10)
    kw.setdefault("rate_per_s", 200.0)
    return TraceSpec(**kw)


# ------------------------------------------------------ TraceSpec validation

@pytest.mark.parametrize("mutate, match", [
    (lambda t: setattr(t, "rate_per_s", -1.0), "rate_per_s"),
    (lambda t: setattr(t, "rate_per_s", 0.0), "rate_per_s"),
    (lambda t: setattr(t, "num_requests", -1), "num_requests"),
    (lambda t: setattr(t, "shape", "weekly"), "shape"),
    (lambda t: setattr(t, "classes", []), "at least one"),
    (lambda t: setattr(t, "diurnal_amplitude", 1.5), "amplitude"),
    (lambda t: setattr(t.classes[0], "prompt_mean", -3.0), "negative"),
    (lambda t: setattr(t.classes[0], "output_mean", 0.0), "> 0"),
    (lambda t: setattr(t.classes[0], "prompt_min", 0), "prompt_min"),
    (lambda t: setattr(t.classes[0], "prompt_max", 1), "prompt_min"),
    (lambda t: setattr(t.classes[0], "slo_ttft_ms", -1.0), "SLO"),
    (lambda t: setattr(t.classes[0], "alpha", 1.5), "alpha"),
    (lambda t: setattr(t.classes[0], "weight", -0.1), "weight"),
    (lambda t: setattr(t.classes[1], "name", t.classes[0].name), "duplicate"),
])
def test_trace_validation_rejects(mutate, match):
    t = tiny_trace()
    if "diurnal" in match or "amplitude" in match:
        t.shape = "diurnal"
    mutate(t)
    with pytest.raises(WorkloadError, match=match):
        t.validate()


def test_trace_validation_replay():
    t = tiny_trace(shape="replay")
    with pytest.raises(WorkloadError, match="replay_arrivals_s"):
        t.validate()
    t.replay_arrivals_s = [0.0, 0.5, 0.2]
    with pytest.raises(WorkloadError, match="nondecreasing"):
        t.validate()
    t.replay_arrivals_s = [0.0, 0.2, 0.5]
    t.replay_classes = ["chat", "nope", "chat"]
    with pytest.raises(WorkloadError, match="not declared"):
        t.validate()
    t.replay_classes = ["chat", "chat"]
    with pytest.raises(WorkloadError, match="length"):
        t.validate()
    t.replay_classes = []
    t.validate()
    reqs = generate_requests(t)
    assert [r.arrival_s for r in reqs[:3]] == [0.0, 0.2, 0.5]


def test_trace_unknown_fields_rejected():
    with pytest.raises(WorkloadError, match="unknown field"):
        TraceSpec.from_dict({"burst_hz": 3})
    with pytest.raises(WorkloadError, match="unknown field"):
        TraceSpec.from_dict({"classes": [{"name": "x", "color": "red"}]})


def test_cluster_spec_trace_round_trip_and_validation():
    spec = two_pair_spec()
    spec.workload.trace = tiny_trace(shape="burst", burst_every_s=1.0,
                                     burst_len_s=0.2, burst_multiplier=3.0)
    again = ClusterSpec.from_json(spec.to_json())
    assert again == spec
    assert again.workload.trace.shape == "burst"
    spec.workload.trace.rate_per_s = -2.0
    with pytest.raises(TopologyError, match="workload.trace"):
        spec.validate()


# ------------------------------------------------------- seeded determinism

def test_identical_specs_replay_identical_streams():
    t = tiny_trace(shape="diurnal", diurnal_period_s=5.0, seed=7)
    a = generate_requests(t)
    b = generate_requests(TraceSpec.from_json(t.to_json()))
    assert [dataclasses.astuple(r) for r in a] == \
           [dataclasses.astuple(r) for r in b]
    c = generate_requests(dataclasses.replace(t, seed=8))
    assert [dataclasses.astuple(r) for r in a] != \
           [dataclasses.astuple(r) for r in c]
    # arrivals are nondecreasing and class-sampled from declared names
    names = {cl.name for cl in t.classes}
    assert all(x.arrival_s <= y.arrival_s for x, y in zip(a, a[1:]))
    assert all(r.request_class in names for r in a)


def test_sim_and_real_adapters_share_one_stream():
    reqs = generate_requests(tiny_trace(seed=3))
    serve = fleet_serve_requests(reqs, vocab=128, seed=3)
    recs = fleet_trace_records(reqs, seed=3)
    assert len(serve) == len(recs) == len(reqs)
    for r, s, rec in zip(reqs, serve, recs):
        assert s.request_id == rec.request_id == r.request_id
        assert len(s.prompt) == rec.prompt_length == r.prompt_len
        assert s.max_new_tokens == rec.output_length == r.output_len
        assert s.arrival_s * 1e3 == pytest.approx(rec.arrival_time_ms)
        assert s.slo_ttft_ms == rec.slo_ttft_ms == r.slo_ttft_ms
        assert rec.drafter_id < 0       # unpinned: routed at arrival
    # adapters are themselves deterministic
    serve2 = fleet_serve_requests(reqs, vocab=128, seed=3)
    assert all(np.array_equal(x.prompt, y.prompt)
               for x, y in zip(serve, serve2))
    recs2 = fleet_trace_records(reqs, seed=3)
    assert [r.acceptance_seq for r in recs] == \
           [r.acceptance_seq for r in recs2]


# ---------------------------------------------------------- rolling quantile

def test_rolling_quantile_matches_numpy_and_evicts():
    q = RollingQuantile(size=64)
    assert math.isnan(q.p50()) and len(q) == 0
    rng = np.random.default_rng(0)
    vals = rng.uniform(0, 100, 200)
    for v in vals:
        q.push(v)
    assert len(q) == 64
    window = vals[-64:]
    assert q.p50() == pytest.approx(np.percentile(window, 50))
    assert q.p95() == pytest.approx(np.percentile(window, 95))
    assert q.mean() == pytest.approx(window.mean())
    q.push(float("nan"))        # non-finite samples are ignored
    assert len(q) == 64


# ------------------------------------------------------------- pair costing

def test_pair_cost_orders_sanely():
    # closer link, better acceptance, emptier queue → cheaper
    assert pair_cost(2.0, 0.8, 0.0) < pair_cost(150.0, 0.8, 0.0)
    assert pair_cost(10.0, 0.9, 0.0) < pair_cost(10.0, 0.3, 0.0)
    assert pair_cost(10.0, 0.8, 0.0) < pair_cost(10.0, 0.8, 0.9)
    # long-context amplifies the link term only
    lan = pair_cost(2.0, 0.8, 0.0, long_context=True) \
        / pair_cost(2.0, 0.8, 0.0)
    wan = pair_cost(150.0, 0.8, 0.0, long_context=True) \
        / pair_cost(150.0, 0.8, 0.0)
    assert wan > lan


class _FakeTransport:
    def __init__(self, rtt):
        self.recent_rtt_ms = rtt


class _FakeSession:
    def __init__(self, capacity=4, accepted=0, proposed=0):
        self.capacity = capacity
        self.accepted = accepted
        self.proposed = proposed


def _fake_pair(pid, rtt, capacity=4, accepted=0, proposed=0):
    return ServingPair(pair_id=pid, engine=None, policy=None,
                       transport=_FakeTransport(rtt),
                       session=_FakeSession(capacity, accepted, proposed))


def test_smart_router_prefers_lan_and_respects_capacity():
    router = SmartPairRouter(long_prompt_tokens=64)
    pairs = [_fake_pair("lan", 2.0), _fake_pair("wan", 150.0)]
    chat = ServeRequest(0, np.zeros(8, np.int32), 8)
    long_ctx = ServeRequest(1, np.zeros(128, np.int32), 8)
    assert router.route(chat, pairs, [4, 4]) == 0
    assert router.route(long_ctx, pairs, [4, 4]) == 0
    # LAN full → chat spills to WAN
    assert router.route(chat, pairs, [0, 4]) == 1
    # α-aware: a WAN pair with far better acceptance can win a long queue
    good_wan = [_fake_pair("lan", 30.0, accepted=5, proposed=100),
                _fake_pair("wan", 30.0, accepted=95, proposed=100)]
    assert router.route(chat, good_wan, [4, 4]) == 1


def test_least_loaded_ties_break_deterministically():
    router = LeastLoadedPairRouter()
    pairs = [_fake_pair("a", 0.0), _fake_pair("b", 0.0)]
    req = ServeRequest(0, np.zeros(4, np.int32), 4)
    for _ in range(5):
        assert router.route(req, pairs, [2, 2]) == 0
    assert router.route(req, pairs, [1, 2]) == 1


# ------------------------------------------------- router churn (real server)

def _serve(spec, reqs):
    dep = build_deployment(spec, model_configs=TINY, sleep_links=False)
    server = dep.build_server()
    for r in reqs:
        server.submit(r)
    return server, server.run()


def _requests(n, vocab=128, plen=8, max_new=4):
    rng = np.random.default_rng(0)
    return [ServeRequest(i, rng.integers(0, vocab, plen).astype(np.int32),
                         max_new) for i in range(n)]


def test_sticky_routing_survives_retirement_and_readmission():
    # 6 requests through 2 pairs × 1 slot: every slot retires and
    # re-admits; each request finishes wholly on the pair that admitted it
    spec = two_pair_spec(max_batch=1)
    server, results = _serve(spec, _requests(6))
    assert sorted(r.request_id for r in results) == list(range(6))
    by_pair = server.pair_summaries()
    assert by_pair["fast"]["requests"] + by_pair["slow"]["requests"] == 6
    assert by_pair["fast"]["requests"] >= 1     # re-admission exercised
    for r in results:
        assert r.pair_id in ("fast", "slow")


def test_drained_pair_receives_no_new_requests():
    spec = two_pair_spec()
    dep = build_deployment(spec, model_configs=TINY, sleep_links=False)
    server = dep.build_server()
    server.drain("slow")
    for r in _requests(4):
        server.submit(r)
    results = server.run()
    assert len(results) == 4
    assert all(r.pair_id == "fast" for r in results)
    assert server.pair_summaries()["slow"]["requests"] == 0
    # re-admission: undrained pair serves again on the next run
    server.undrain("slow")
    server2 = dep.build_server()
    for r in _requests(6):
        server2.submit(r)
    results2 = server2.run()
    assert {r.pair_id for r in results2} == {"fast", "slow"}


def test_all_pairs_draining_raises():
    spec = two_pair_spec()
    dep = build_deployment(spec, model_configs=TINY, sleep_links=False)
    server = dep.build_server()
    server.drain("fast")
    server.drain("slow")
    server.submit(_requests(1)[0])
    with pytest.raises(RuntimeError, match="draining"):
        server.run()


def test_pair_summaries_report_rolling_percentiles():
    spec = two_pair_spec()
    server, results = _serve(spec, _requests(4))
    for row in server.pair_summaries().values():
        for k in ("ttft_p50_ms", "ttft_p95_ms", "tpot_p50_ms",
                  "tpot_p95_ms", "shed"):
            assert k in row
        if row["requests"]:
            assert row["ttft_p50_ms"] <= row["ttft_p95_ms"]
            assert row["ttft_p95_ms"] > 0


# ------------------------------------------------------- SLO-aware admission

def _slo_server(mode):
    spec = two_pair_spec()
    dep = build_deployment(spec, model_configs=TINY, sleep_links=False)
    server = dep.build_server()
    server.cfg.slo_admission = mode
    server.cfg.slo_min_samples = 2
    return server


class _Clock:
    def now(self):
        return 1.0


def test_slo_admission_reroutes_off_drifting_pair():
    server = _slo_server("reroute")
    for _ in range(4):
        server._ttft_q[0].push(500.0)    # pair fast: p95 ≈ 500ms, drifted
        server._ttft_q[1].push(10.0)     # pair slow: healthy
    req = ServeRequest(0, np.zeros(8, np.int32), 4, slo_ttft_ms=100.0)
    arrived, pending = [req], [req]
    assert server._apply_slo_admission(arrived, pending, 0, [2, 2],
                                       _Clock()) == 1
    # no SLO on the request → gate is the identity
    free = ServeRequest(1, np.zeros(8, np.int32), 4)
    arrived, pending = [free], [free]
    assert server._apply_slo_admission(arrived, pending, 0, [2, 2],
                                       _Clock()) == 0


def test_slo_admission_sheds_when_no_pair_is_healthy():
    server = _slo_server("shed")
    for _ in range(4):
        server._ttft_q[0].push(500.0)
        server._ttft_q[1].push(800.0)
    req = ServeRequest(7, np.zeros(8, np.int32), 4, request_class="chat",
                       slo_ttft_ms=100.0)
    arrived, pending = [req], [req]
    assert server._apply_slo_admission(arrived, pending, 0, [2, 2],
                                       _Clock()) is None
    assert arrived == [] and pending == []
    assert len(server.results) == 1 and server.results[0].shed
    assert server.results[0].request_class == "chat"
    # reroute mode admits anyway instead of shedding
    server2 = _slo_server("reroute")
    for _ in range(4):
        server2._ttft_q[0].push(500.0)
        server2._ttft_q[1].push(800.0)
    req2 = ServeRequest(8, np.zeros(8, np.int32), 4, slo_ttft_ms=100.0)
    arrived, pending = [req2], [req2]
    assert server2._apply_slo_admission(arrived, pending, 0, [2, 2],
                                        _Clock()) == 0
    assert pending == [req2]


def test_slo_report_grades_only_slo_carrying_requests():
    rows = [
        {"request_class": "chat", "slo_ttft_ms": 100.0, "slo_tpot_ms": 0.0,
         "ttft_ms": 50.0, "tpot_ms": 5.0},
        {"request_class": "chat", "slo_ttft_ms": 100.0, "slo_tpot_ms": 0.0,
         "ttft_ms": 150.0, "tpot_ms": 5.0},
        {"request_class": "batch", "slo_ttft_ms": 0.0, "slo_tpot_ms": 0.0,
         "ttft_ms": 9999.0, "tpot_ms": 999.0},
        {"request_class": "chat", "slo_ttft_ms": 100.0, "slo_tpot_ms": 0.0,
         "ttft_ms": 10.0, "tpot_ms": 1.0, "shed": True},
    ]
    rep = slo_report(rows)
    assert rep["graded"] == 3           # batch-offline excluded
    assert rep["attained"] == 1         # one miss, one shed
    assert rep["attainment"] == pytest.approx(1 / 3)
    assert rep["per_class"]["chat"]["shed"] == 1
    assert rep["per_class"]["batch"]["graded"] == 0


# ------------------------------------------------------------ sim pair routing

def test_sim_pair_router_orders_lanes_like_the_cost_model():
    spec = two_pair_spec(rtt_fast=2.0, rtt_slow=150.0)
    spec.workload.trace = tiny_trace(num_requests=12, rate_per_s=100.0)

    def lane_counts(router):
        sim = build_simulation(spec, pair_router=router)
        an = sim.run()
        counts = [0, 0]
        for m in an.requests.values():
            counts[m.drafter_id] += 1
        return counts, an.summary()

    smart, smart_summ = lane_counts("smart")
    ll, ll_summ = lane_counts("least-loaded")
    assert sum(smart) == sum(ll) == 12
    # the cost model concentrates load on the cheap LAN lane; least-loaded
    # balances lanes blindly
    assert smart[0] > ll[0]
    # both summaries carry comparable SLO attainment blocks
    for summ in (smart_summ, ll_summ):
        assert 0.0 <= summ["slo"]["attainment"] <= 1.0
        assert summ["slo"]["graded"] > 0
        assert "per_class" in summ["slo"]


def test_sim_records_carry_class_and_slo():
    spec = two_pair_spec()
    spec.workload.trace = tiny_trace(num_requests=6, rate_per_s=100.0)
    sim = build_simulation(spec)
    an = sim.run()
    classes = {m.request_class for m in an.requests.values()}
    assert classes <= {"chat", "long-context", "batch-offline"}
    assert any(m.slo_ttft_ms > 0 for m in an.requests.values())


# ------------------------------------------------------------- elastic pool

class _FakeHandle:
    def __init__(self, pair_id, log):
        self.pair_id = pair_id
        self.capacity = 2
        self.log = log
        self.alive = True

    def serve(self, reqs):
        import time
        assert self.alive, "drained/reaped pair must receive no new waves"
        self.log.append((self.pair_id, [r.request_id for r in reqs]))
        time.sleep(0.02)
        return [ServeResult(request_id=r.request_id,
                            tokens=np.zeros(1, np.int32), ttft_ms=1.0,
                            tpot_ms=1.0, e2e_ms=2.0, acceptance_rate=0.5,
                            pair_id=self.pair_id) for r in reqs]

    def shutdown(self):
        self.alive = False


def _elastic_pool(**kw):
    spec = two_pair_spec()
    spec.pairs[0].process = False   # template cloning only needs the spec
    log = []
    pool = ElasticPairPool(spec, "fast",
                           spawn_fn=lambda p: _FakeHandle(p.id, log),
                           tick_s=0.005, **kw)
    return pool, log


def test_elastic_scales_up_under_backlog_and_serves_everything():
    pool, log = _elastic_pool(min_pairs=1, max_pairs=3, scale_up_depth=0.5)
    reqs = _requests(10)
    results = pool.run(reqs)
    assert sorted(r.request_id for r in results) == list(range(10))
    summ = pool.summary()
    assert 2 <= summ["pairs_spawned"] <= 3          # backlog forced growth
    assert summ["max_concurrent_pairs"] <= 3        # bound respected
    assert sum(len(ids) for _, ids in log) == 10
    pool.shutdown()


def test_elastic_control_law_reaps_idle_pairs():
    pool, _ = _elastic_pool(min_pairs=1, max_pairs=4,
                            scale_up_depth=0.5, scale_down_depth=0.5)
    pool.scale_up()
    pool.scale_up()
    pool.scale_up()
    assert pool.summary()["pairs_spawned"] == 3
    assert pool.evaluate_scaling(backlog=0) == "down"
    kinds = [k for _, k, _ in pool.events]
    assert kinds.count("reap") == 1
    # draining pair is excluded from the active set; floor is respected
    assert pool.evaluate_scaling(backlog=0) == "down"
    assert pool.evaluate_scaling(backlog=0) is None     # at min_pairs
    # heavy backlog on the remaining pair scales back up
    assert pool.evaluate_scaling(backlog=50) == "up"
    pool.shutdown()


def test_elastic_spawned_pairs_get_fresh_ids():
    pool, _ = _elastic_pool()
    a = pool.scale_up()
    b = pool.scale_up()
    assert a != b and a.startswith("fast-e") and b.startswith("fast-e")
    assert set(pool.handles) == {a, b}
    pool.shutdown()
