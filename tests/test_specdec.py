"""Speculative-decoding algorithm tests: acceptance semantics, Eq. (1)/(2),
and engine-level greedy equivalence with target-only decoding."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import (expected_accepted, expected_speedup, optimal_gamma,
                        verify_window, verify_window_greedy)
from repro.core.engine import SpecDecodeEngine
from repro.core.window import StaticWindowPolicy


def test_identical_distributions_accept_everything():
    key = jax.random.PRNGKey(0)
    B, G, V = 8, 5, 64
    p = jax.nn.softmax(jax.random.normal(key, (B, G + 1, V)), -1)
    q = p[:, :G, :]
    toks = jax.random.categorical(jax.random.PRNGKey(1), jnp.log(q),
                                  axis=-1).astype(jnp.int32)
    res = verify_window(jax.random.PRNGKey(2), toks, q, p)
    assert bool((res.n_accepted == G).all())
    assert bool((res.num_new == G + 1).all())


def test_disjoint_supports_reject_immediately():
    B, G, V = 4, 4, 32
    # q concentrated on token 0, p on token V-1 → ratio ≈ 0 → reject at 0
    q = jnp.full((B, G, V), 1e-9).at[:, :, 0].set(1.0)
    p = jnp.full((B, G + 1, V), 1e-9).at[:, :, V - 1].set(1.0)
    toks = jnp.zeros((B, G), jnp.int32)
    res = verify_window(jax.random.PRNGKey(0), toks, q, p)
    assert bool((res.n_accepted == 0).all())
    assert bool((res.next_token == V - 1).all())


def test_empirical_acceptance_matches_eq1():
    """Monte-carlo acceptance with alpha-controlled p/q ≈ Eq. (1)."""
    alpha, G, V, N = 0.7, 6, 128, 2000
    key = jax.random.PRNGKey(0)
    # q uniform over V; p = alpha at drafted token + (1-alpha) spread
    q = jnp.full((N, G, V), 1.0 / V)
    toks = jax.random.randint(key, (N, G), 0, V)
    onehot = jax.nn.one_hot(toks, V)
    # acceptance prob = min(1, p/q) at token = alpha/ (1/V) ... construct
    # p so p(token)/q(token) = alpha exactly: p(token) = alpha/V
    p_g = (jnp.ones((N, G, V)) - onehot * 1.0) * ((1 - alpha / V) / (V - 1)) \
        + onehot * (alpha / V)
    p = jnp.concatenate([p_g, jnp.full((N, 1, V), 1.0 / V)], axis=1)
    res = verify_window(jax.random.PRNGKey(1), toks, q, p)
    emp = float(res.num_new.mean())
    theory = float(expected_accepted(alpha, G))
    assert abs(emp - theory) / theory < 0.05, (emp, theory)


def test_eq2_speedup_and_optimum():
    s1 = float(expected_speedup(0.8, 4, 0.05))
    assert s1 > 1.0
    g = optimal_gamma(0.9, 0.02)
    assert 4 <= g <= 12
    assert optimal_gamma(0.3, 0.5) <= 2


def test_greedy_verify_prefix_semantics():
    B, G, V = 2, 4, 16
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, G + 1, V))
    tgt = jnp.argmax(logits, -1)
    draft = tgt[:, :G].at[0, 2].add(1)   # seq 0 mismatches at position 2
    res = verify_window_greedy(draft.astype(jnp.int32), logits)
    assert int(res.n_accepted[0]) == 2
    assert int(res.n_accepted[1]) == G
    assert int(res.next_token[0]) == int(tgt[0, 2])
    assert int(res.next_token[1]) == int(tgt[1, G])


# ------------------------------------------------------- engine equivalence

DRAFT = ModelConfig(name="d", arch_type="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                    dtype="float32", remat=False)
TARGETS = {
    "dense": dataclasses.replace(DRAFT, name="t", n_layers=3, n_kv_heads=4),
    "ssm": ModelConfig(name="ts", arch_type="ssm", n_layers=2, d_model=64,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
                       ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                       dtype="float32", remat=False, tie_embeddings=True),
    "hybrid": ModelConfig(name="th", arch_type="hybrid", n_layers=4,
                          d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                          head_dim=16, vocab=128, ssm_state=16,
                          ssm_head_dim=16, ssm_chunk=8, attn_every=2,
                          dtype="float32", remat=False),
}


def _reference_greedy(engine, prompts, n):
    tm = engine.target
    B, S = prompts.shape
    lg, cache = tm.prefill(engine.target_params, jnp.asarray(prompts),
                           S + n + 16)
    cur = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
    ref = [np.asarray(cur)]
    pos = jnp.full((B,), S, jnp.int32)
    for _ in range(n - 1):
        lg1, cache = tm.decode_step(engine.target_params, cur, cache, pos)
        cur = jnp.argmax(lg1, -1).astype(jnp.int32)
        ref.append(np.asarray(cur))
        pos = pos + 1
    return np.stack(ref, 1)


@pytest.mark.parametrize("family", sorted(TARGETS))
@pytest.mark.slow
def test_engine_greedy_equals_target_decoding(family):
    eng = SpecDecodeEngine(DRAFT, TARGETS[family], temperature=0.0,
                           key=jax.random.PRNGKey(7))
    B, S, N = 2, 10, 24
    prompts = np.random.default_rng(0).integers(0, 128, (B, S)).astype(np.int32)
    toks, stats = eng.generate(prompts, N, StaticWindowPolicy(3))
    ref = _reference_greedy(eng, prompts, N)
    assert (toks[:, :N] == ref).all()
    # stats.tokens excludes the prefill-sampled anchor token (1 per seq)
    assert stats.tokens >= B * (N - 1)
    assert len(stats.acceptance_seqs) == B


def test_engine_acceptance_traces_schema():
    eng = SpecDecodeEngine(DRAFT, TARGETS["dense"], temperature=0.0,
                           key=jax.random.PRNGKey(1))
    prompts = np.random.default_rng(1).integers(0, 128, (2, 8)).astype(np.int32)
    seqs = eng.capture_traces(prompts, 12, gamma=4)
    assert len(seqs) == 2
    for s in seqs:
        assert all(b in (0, 1) for b in s)
        assert len(s) >= 1
