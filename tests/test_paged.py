"""Paged + quantized KV slot pool regression tests (models/kvcache.py,
kernels/decode_attn/paged.py, core/session.py).

The contract under test: an fp paged pool driven through block tables is
BIT-identical to the dense layout at every level — primitive write/gather,
the attention layer, and whole sessions under admission/retirement churn
(including rejected speculation windows rolling back through the block
table) — while admission reserves only each request's own block footprint.
Overflow writes DROP (never clamp), the allocator never double-assigns a
block, and the Pallas paged kernel matches the reference oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.session import DecodeSession
from repro.core.window import StaticWindowPolicy
from repro.models.attention import (attention_decode, attention_decode_paged,
                                    init_attn_params)
from repro.models.kvcache import (AttnCache, BlockAllocator,
                                  gather_layer_paged, init_paged_attn_cache,
                                  logical_blocks, paged_insert_row,
                                  paged_release_slot, paged_update_layer,
                                  quantize_kv, update_layer_cache)
from repro.kernels.decode_attn.paged import paged_decode_attention
from repro.kernels.decode_attn.ref import decode_attention_reference

from conformance.scenarios import GAMMA, make_engine, make_noised_engine

B, T, HKV, G, HD = 2, 3, 2, 2, 8


def _dense_and_paged(length, bs, n_blocks, steps=4, ring=False, seed=0):
    """Drive identical windows through a dense layer cache and a paged
    pool; returns the dense triple and the paged pool pieces."""
    rng = np.random.default_rng(seed)
    kd = jnp.zeros((B, length, HKV, HD), jnp.float32)
    vd = jnp.zeros_like(kd)
    pmd = jnp.full((B, length), -1, jnp.int32)
    kp = jnp.zeros((n_blocks, bs, HKV, HD), jnp.float32)
    vp = jnp.zeros_like(kp)
    pmp = jnp.full((n_blocks, bs), -1, jnp.int32)
    alloc = BlockAllocator(n_blocks)
    n_log = logical_blocks(length, bs)
    tbl = jnp.array([alloc.alloc(n_log) for _ in range(B)], jnp.int32)
    pos = jnp.array([0, 2], jnp.int32)
    for _ in range(steps):
        k_new = jnp.asarray(rng.normal(size=(B, T, HKV, HD)), jnp.float32)
        v_new = jnp.asarray(rng.normal(size=(B, T, HKV, HD)), jnp.float32)
        kd, vd, pmd = update_layer_cache(kd, vd, pmd, k_new, v_new, pos, ring)
        kp, vp, _, _, pmp = paged_update_layer(
            kp, vp, None, None, pmp, tbl, k_new, v_new, pos, ring, length)
        pos = pos + T
    return (kd, vd, pmd), (kp, vp, pmp, tbl), pos


# ------------------------------------------------------------------ kvcache

@pytest.mark.parametrize("length,bs", [(20, 4), (18, 4), (16, 7)])
def test_paged_write_gather_bit_identical_dense(length, bs):
    """Paged write → position-ordered gather reproduces the dense cache
    bit-for-bit, including lengths that are not a block multiple."""
    dense, paged, _ = _dense_and_paged(length, bs, n_blocks=16)
    k_g, v_g, pm_g = gather_layer_paged(paged[0], paged[1], None, None,
                                        paged[2], paged[3], length,
                                        jnp.float32)
    assert (np.asarray(k_g) == np.asarray(dense[0])).all()
    assert (np.asarray(v_g) == np.asarray(dense[1])).all()
    assert (np.asarray(pm_g) == np.asarray(dense[2])).all()


def test_paged_ring_wraps_like_dense():
    """Ring mode: logical slot = pos % length in both layouts (T=1 windows;
    a window never straddles the ring seam in serving)."""
    rng = np.random.default_rng(1)
    length, bs = 8, 4
    kd = jnp.zeros((B, length, HKV, HD), jnp.float32)
    vd = jnp.zeros_like(kd)
    pmd = jnp.full((B, length), -1, jnp.int32)
    pool = init_paged_attn_cache(1, B, length, 8, bs, HKV, HD, jnp.float32,
                                 ring=True)
    alloc = BlockAllocator(8)
    tbl = jnp.array([alloc.alloc(2) for _ in range(B)], jnp.int32)
    kp, vp, pmp = pool.k[0], pool.v[0], pool.pos_map[0]
    for step in range(13):                      # wraps past length
        k_new = jnp.asarray(rng.normal(size=(B, 1, HKV, HD)), jnp.float32)
        v_new = jnp.asarray(rng.normal(size=(B, 1, HKV, HD)), jnp.float32)
        pos = jnp.full((B,), step, jnp.int32)
        kd, vd, pmd = update_layer_cache(kd, vd, pmd, k_new, v_new, pos, True)
        kp, vp, _, _, pmp = paged_update_layer(
            kp, vp, None, None, pmp, tbl, k_new, v_new, pos, True, length)
    k_g, _, pm_g = gather_layer_paged(kp, vp, None, None, pmp, tbl, length,
                                      jnp.float32)
    assert (np.asarray(k_g) == np.asarray(kd)).all()
    assert (np.asarray(pm_g) == np.asarray(pmd)).all()


def test_uniform_overflow_write_drops_whole_window():
    """Non-ring uniform writes past the cache edge DROP atomically — the
    old ``min(pos, S-1)`` clamp silently overwrote the newest slot."""
    S = 8
    k = jnp.zeros((B, S, HKV, HD), jnp.float32)
    v, pm = jnp.zeros_like(k), jnp.full((B, S), -1, jnp.int32)
    k_new = jnp.ones((B, T, HKV, HD), jnp.float32)
    # sentinel in the last slot: a clamp would overwrite it
    k = k.at[:, S - 1].set(7.0)
    pm = pm.at[:, S - 1].set(S - 1)
    pos = jnp.full((B,), S, jnp.int32)          # entirely past the edge
    k2, v2, pm2 = update_layer_cache(k, v, pm, k_new, k_new, pos, False,
                                     uniform_pos=True)
    assert (np.asarray(k2) == np.asarray(k)).all()
    assert (np.asarray(pm2) == np.asarray(pm)).all()
    pos = jnp.full((B,), S - T + 1, jnp.int32)  # straddles the edge
    k3, _, pm3 = update_layer_cache(k, v, pm, k_new, k_new, pos, False,
                                    uniform_pos=True)
    assert (np.asarray(k3) == np.asarray(k)).all()
    assert (np.asarray(pm3) == np.asarray(pm)).all()


def test_uniform_boundary_write_lands():
    """The last fully-in-range uniform window (pos = S − T) writes through
    the guard untouched."""
    S = 8
    k = jnp.zeros((B, S, HKV, HD), jnp.float32)
    v, pm = jnp.zeros_like(k), jnp.full((B, S), -1, jnp.int32)
    k_new = jnp.ones((B, T, HKV, HD), jnp.float32)
    pos = jnp.full((B,), S - T, jnp.int32)
    k2, _, pm2 = update_layer_cache(k, v, pm, k_new, k_new, pos, False,
                                    uniform_pos=True)
    assert (np.asarray(k2)[:, S - T:] == 1.0).all()
    assert (np.asarray(k2)[:, :S - T] == 0.0).all()
    assert (np.asarray(pm2)[:, S - T:]
            == np.arange(S - T, S)[None, :]).all()


def test_scatter_overflow_drops_per_position():
    """Ragged (per-sequence) writes drop exactly the out-of-range
    positions; in-range neighbours still land."""
    S = 8
    k = jnp.zeros((B, S, HKV, HD), jnp.float32)
    v, pm = jnp.zeros_like(k), jnp.full((B, S), -1, jnp.int32)
    k = k.at[:, S - 1].set(7.0)                 # clamp victim sentinel
    k_new = jnp.ones((B, T, HKV, HD), jnp.float32)
    pos = jnp.array([S - 1, S + 2], jnp.int32)  # row 0: 1 of 3 in range
    k2, _, pm2 = update_layer_cache(k, v, pm, k_new, k_new, pos, False)
    assert (np.asarray(k2)[0, S - 1] == 1.0).all()   # in-range write landed
    assert (np.asarray(k2)[1, S - 1] == 7.0).all()   # OOB row dropped
    assert np.asarray(pm2)[0, S - 1] == S - 1
    assert (np.asarray(pm2)[1] == -1).all()


def test_paged_insert_release_roundtrip():
    """Insert scrubs every mapped block (stale tenants cannot leak) and
    release unmaps so later writes drop."""
    rng = np.random.default_rng(3)
    length, bs, NB = 12, 4, 8
    row = AttnCache(
        k=jnp.asarray(rng.normal(size=(1, 1, length, HKV, HD)), jnp.float32),
        v=jnp.asarray(rng.normal(size=(1, 1, length, HKV, HD)), jnp.float32),
        pos_map=jnp.arange(length, dtype=jnp.int32)[None, None])
    pool = init_paged_attn_cache(1, 2, length, NB, bs, HKV, HD, jnp.float32)
    # dirty the pool first: the insert must fully rewrite its blocks
    pool = pool.replace(pos_map=jnp.full_like(pool.pos_map, 99))
    ids = jnp.array([5, 1, 3], jnp.int32)
    pool = paged_insert_row(pool, row, ids, 1)
    k_g, _, pm_g = gather_layer_paged(pool.k[0], pool.v[0], None, None,
                                      pool.pos_map[0], pool.block_table,
                                      length, jnp.float32)
    assert (np.asarray(k_g[1]) == np.asarray(row.k[0, 0])).all()
    assert (np.asarray(pm_g[1]) == np.arange(length)).all()
    assert (np.asarray(pm_g[0]) == -1).all()         # unmapped slot masks
    pool = paged_release_slot(pool, 1)
    assert (np.asarray(pool.block_table[1]) == -1).all()
    k2, *_ = paged_update_layer(
        pool.k[0], pool.v[0], None, None, pool.pos_map[0], pool.block_table,
        jnp.full((2, 1, HKV, HD), 5.0), jnp.full((2, 1, HKV, HD), 5.0),
        jnp.zeros((2,), jnp.int32), False, length)
    assert not (np.asarray(k2) == 5.0).any()         # released ⇒ writes drop


def test_int8_quantization_error_bounded():
    """Per-entry symmetric int8: roundtrip error ≤ scale/2 per element."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(16, HKV, HD)) * 3.0, jnp.float32)
    q, s = quantize_kv(x)
    err = np.abs(np.asarray(q).astype(np.float32)
                 * np.asarray(s)[..., None] - np.asarray(x))
    assert (err <= np.asarray(s)[..., None] * 0.5 + 1e-6).all()


# ---------------------------------------------------------------- allocator

def test_block_allocator_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.integers(4, 24),
           st.lists(st.tuples(st.booleans(), st.integers(1, 6)),
                    min_size=1, max_size=30))
    def run(n_blocks, ops):
        """Random alloc/free interleavings: no block is ever live twice,
        free+used always partition [0, n_blocks), exhaustion raises."""
        a = BlockAllocator(n_blocks)
        live: list[list[int]] = []
        for is_alloc, n in ops:
            if is_alloc:
                if n > a.free_blocks:
                    with pytest.raises(RuntimeError):
                        a.alloc(n)
                else:
                    ids = a.alloc(n)
                    flat = [i for grp in live for i in grp]
                    assert not set(ids) & set(flat)
                    assert len(set(ids)) == n
                    live.append(ids)
            elif live:
                a.free(live.pop(0))
            assert a.free_blocks + a.used_blocks == n_blocks
            assert a.used_blocks == sum(len(g) for g in live)
        for g in live:
            a.free(g)
        assert a.free_blocks == n_blocks and a.used_blocks == 0

    run()


# ------------------------------------------------------------ kernel + attn

def test_paged_kernel_matches_reference():
    """The Pallas paged-decode kernel (scalar-prefetch block-table grid)
    matches the dense reference oracle on the gathered view, full and
    sliding-window, eagerly and under jit."""
    length, bs = 20, 4
    dense, paged, pos = _dense_and_paged(length, bs, n_blocks=16)
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(B, T, HKV, G, HD)), jnp.float32)
    q_pos = pos[:, None] + jnp.arange(T)[None, :]
    for window in (0, 7):
        ref = decode_attention_reference(
            q.reshape(B, T, HKV * G, HD), dense[0], dense[1], dense[2],
            q_pos, window=window)
        out = paged_decode_attention(q, paged[0], paged[1], None, None,
                                     paged[2], paged[3], q_pos,
                                     length=length, window=window)
        np.testing.assert_allclose(np.asarray(out).reshape(ref.shape),
                                   np.asarray(ref), atol=2e-6, rtol=2e-6)
    jit_out = jax.jit(lambda *a: paged_decode_attention(
        *a, length=length, interpret=True))(
        q, paged[0], paged[1], None, None, paged[2], paged[3], q_pos)
    np.testing.assert_allclose(np.asarray(jit_out),
                               np.asarray(paged_decode_attention(
                                   q, paged[0], paged[1], None, None,
                                   paged[2], paged[3], q_pos,
                                   length=length)), atol=1e-6)


def test_paged_kernel_quantized_matches_dequant_reference():
    """Int8 pool: the kernel's in-register dequant equals attending over
    the dequantized gather."""
    length, bs, NB = 16, 4, 12
    rng = np.random.default_rng(11)
    pool = init_paged_attn_cache(1, B, length, NB, bs, HKV, HD, jnp.float32,
                                 quantize=True)
    alloc = BlockAllocator(NB)
    tbl = jnp.array([alloc.alloc(4) for _ in range(B)], jnp.int32)
    kp, vp, ks, vs, pmp = pool.k[0], pool.v[0], pool.k_scale[0], \
        pool.v_scale[0], pool.pos_map[0]
    pos = jnp.zeros((B,), jnp.int32)
    for _ in range(3):
        k_new = jnp.asarray(rng.normal(size=(B, T, HKV, HD)), jnp.float32)
        v_new = jnp.asarray(rng.normal(size=(B, T, HKV, HD)), jnp.float32)
        kp, vp, ks, vs, pmp = paged_update_layer(
            kp, vp, ks, vs, pmp, tbl, k_new, v_new, pos, False, length)
        pos = pos + T
    q = jnp.asarray(rng.normal(size=(B, T, HKV, G, HD)), jnp.float32)
    q_pos = pos[:, None] + jnp.arange(T)[None, :]
    k_d, v_d, pm_d = gather_layer_paged(kp, vp, ks, vs, pmp, tbl, length,
                                        jnp.float32)
    ref = decode_attention_reference(q.reshape(B, T, HKV * G, HD), k_d, v_d,
                                     pm_d, q_pos)
    out = paged_decode_attention(q, kp, vp, ks, vs, pmp, tbl, q_pos,
                                 length=length)
    np.testing.assert_allclose(np.asarray(out).reshape(ref.shape),
                               np.asarray(ref), atol=2e-6, rtol=2e-6)


def test_attention_decode_paged_bitwise_dense():
    """The full attention layer — rope, projections, cache write, gather,
    grouped attend — is bitwise identical between layouts (fp pool, XLA
    gather path, the one serving uses off-TPU)."""
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=HKV, d_ff=64, vocab=64,
                      dtype="float32", remat=False)
    p = init_attn_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    length, bs, NB = 20, 4, 16
    rng = np.random.default_rng(6)
    kd = jnp.zeros((B, length, HKV, cfg.head_dim), jnp.float32)
    vd, pmd = jnp.zeros_like(kd), jnp.full((B, length), -1, jnp.int32)
    pool = init_paged_attn_cache(1, B, length, NB, bs, HKV, cfg.head_dim,
                                 jnp.float32)
    alloc = BlockAllocator(NB)
    tbl = jnp.array([alloc.alloc(5) for _ in range(B)], jnp.int32)
    kp, vp, pmp = pool.k[0], pool.v[0], pool.pos_map[0]
    pos = jnp.array([0, 3], jnp.int32)
    for _ in range(4):
        x = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)), jnp.float32)
        out_d, kd, vd, pmd = attention_decode(x, p, cfg, kd, vd, pmd, pos,
                                              ring=False)
        out_p, kp, vp, _, _, pmp = attention_decode_paged(
            x, p, cfg, kp, vp, None, None, pmp, tbl, pos, ring=False,
            length=length, use_kernel=False)
        assert (np.asarray(out_p) == np.asarray(out_d)).all()
        pos = pos + T
    k_g, v_g, pm_g = gather_layer_paged(kp, vp, None, None, pmp, tbl,
                                        length, jnp.float32)
    assert (np.asarray(k_g) == np.asarray(kd)).all()
    assert (np.asarray(pm_g) == np.asarray(pmd)).all()


# ------------------------------------------------------------------ session

def _run_session(eng, prompts, max_new, paged, pool=None, quant=False,
                 churn=None):
    sess = DecodeSession(eng, capacity=2, max_new_cap=max_new,
                         max_prompt_len=10, gamma_max=GAMMA, sync_every=2,
                         key=jax.random.PRNGKey(0), paged=paged,
                         kv_block_size=4, kv_pool_blocks=pool,
                         kv_quantize=quant)
    pol = StaticWindowPolicy(GAMMA)
    outs = {}
    pending = list(range(len(prompts)))
    while pending or sess.unfinished:
        while pending and sess.can_admit(len(prompts[pending[0]]), max_new):
            rid = pending.pop(0)
            sess.admit(prompts[rid], max_new, request_id=rid)
        sess.run_chunk(pol)
        for j in sess.finished_slots():
            toks, rec = sess.retire(j)
            outs[rec.request_id] = toks.tolist()
        if churn is not None:
            churn(sess)
    return outs, sess


def test_paged_session_churn_bit_identical():
    """Staggered admissions + retirements through a shared engine: paged
    greedy tokens == dense, program count frozen across further churn,
    every block freed at drain; a pool sized below full concurrency
    throttles admission but commits the same stream; the quantized pool
    completes with plausible output."""
    eng = make_engine("dense", temperature=0.0, seed=7)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 128, rng.integers(4, 10)).astype(np.int32)
               for _ in range(5)]
    dense, _ = _run_session(eng, prompts, 10, paged=False)
    paged, psess = _run_session(eng, prompts, 10, paged=True)
    assert dense == paged
    progs = eng.compiled_programs()
    extra = [rng.integers(0, 128, rng.integers(4, 10)).astype(np.int32)
             for _ in range(3)]
    again, psess2 = _run_session(eng, extra, 10, paged=True)
    assert eng.compiled_programs() == progs, \
        "paged admission/retirement churn must not recompile"
    assert all(a is None or a.used_blocks == 0
               for s in (psess, psess2) for a in s._alloc.values())
    small, _ = _run_session(eng, prompts, 10, paged=True,
                            pool=dict(draft=12, target=12))
    assert small == dense
    quant, _ = _run_session(eng, prompts, 10, paged=True, quant=True)
    assert sorted(quant) == sorted(dense)
    assert all(len(t) == 10 for t in quant.values())


def test_paged_rollback_bit_identical_dense():
    """A noised-copy draft (α ≈ 0.8) makes the target reject windows, so
    speculative entries roll back through the block table via pos_map
    masking — committed tokens still match the dense layout exactly."""
    eng = make_noised_engine("dense")
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, 128, rng.integers(5, 10)).astype(np.int32)
               for _ in range(3)]
    dense, dsess = _run_session(eng, prompts, 12, paged=False)
    paged, _ = _run_session(eng, prompts, 12, paged=True)
    assert dense == paged
    assert dsess.accepted < dsess.proposed, \
        "the noised pair should reject some windows (rollback exercised)"


def test_paged_pool_exhaustion():
    """can_admit turns False when blocks run out; a forced admit raises
    without leaking a half-reservation; retirement restores admission."""
    eng = make_engine("dense", temperature=0.0, seed=7)
    rng = np.random.default_rng(2)
    p = rng.integers(0, 128, 8).astype(np.int32)
    sess = DecodeSession(eng, capacity=2, max_new_cap=10, max_prompt_len=10,
                         gamma_max=GAMMA, sync_every=2, paged=True,
                         kv_block_size=4,
                         kv_pool_blocks=dict(draft=8, target=8))
    assert sess.can_admit(len(p), 10)
    sess.admit(p, 10, request_id=0)
    assert not sess.can_admit(len(p), 10)       # slot free, blocks are not
    free_before = {s: a.free_blocks for s, a in sess._alloc.items()}
    with pytest.raises(RuntimeError, match="insufficient free KV blocks"):
        sess.admit(p, 10, request_id=1)
    assert {s: a.free_blocks for s, a in sess._alloc.items()} == free_before
    pol = StaticWindowPolicy(GAMMA)
    while not sess.finished_slots():
        sess.run_chunk(pol)
    sess.retire(sess.finished_slots()[0])
    assert sess.can_admit(len(p), 10)


def test_prefill_rejects_undersized_cache():
    """Satellite of the overflow-drop change: the prefill call site refuses
    a cache too small for the prompt instead of silently dropping KV."""
    eng = make_engine("dense")
    toks = jnp.zeros((1, 12), jnp.int32)
    with pytest.raises(ValueError, match="exceeds cache slots"):
        eng.target.prefill(eng.target_params, toks, slots=8)
