"""Serving benchmark: continuous slot-based scheduling vs wave batching on
the SAME Poisson arrival stream, plus the DSD-Sim prediction for the same
workload — the sim↔real scheduler-parity artifact.

A staggered stream with mixed output budgets is exactly where wave batching
loses: a long sequence holds every slot in its wave hostage and new
arrivals wait for the whole wave to drain, while the continuous
DecodeSession retires each request at its own boundary and admits the next
arrival into the freed slot. The continuous server must achieve strictly
higher tokens/s and lower mean TTFT than the wave server on the same
stream, with ZERO recompiles after warmup across admissions/retirements.

Both servers run the stream twice: the first pass pays XLA compiles, the
second is measured. The DSD-Sim column replays the engine's ground-truth
acceptance traces through the simulator's continuous-batching target
(hwmodel latencies are datacenter-GPU predictions, so sim↔real deltas are
calibration ratios, not errors — same caveat as benchmarks/fig4).

    PYTHONPATH=src python benchmarks/bench_serving.py [--smoke] \
        [--requests 16] [--rate 16] [--max-batch 4] [--out ...]

Writes BENCH_serving.json (repo root by default).
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from repro.analysis import compile_guard
from repro.configs.base import ModelConfig
from repro.core.engine import SpecDecodeEngine
from repro.core.window import StaticWindowPolicy
from repro.serving import (ServeRequest, ServerConfig, SpecDecodeServer,
                           WaveSpecDecodeServer)
from repro.sim import (ClusterSpec, DSDSimulation, LinkSpec, PolicyStack,
                       TraceRecord)
from repro.sim.policies import (BatchingConfig, FIFOBatching,
                                LengthAwareBatching)

DRAFT = ModelConfig(name="bench-draft", arch_type="dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                    vocab=512, dtype="float32", remat=False)
TARGET = ModelConfig(name="bench-target", arch_type="dense", n_layers=4,
                     d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                     vocab=512, dtype="float32", remat=False)


def build_stream(rng, n_requests: int, rate: float, plen_lo: int,
                 plen_hi: int, budgets: list[int]) -> list[ServeRequest]:
    """Poisson arrivals, uniform prompt lengths, cycled output budgets."""
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.integers(plen_lo, plen_hi))
        reqs.append(ServeRequest(
            i, rng.integers(0, TARGET.vocab, plen).astype(np.int32),
            budgets[i % len(budgets)], arrival_s=t))
    return reqs


def serve_stream(server_cls, engine, policy, cfg: ServerConfig,
                 stream: list[ServeRequest]) -> dict:
    srv = server_cls(engine, policy, cfg)
    for r in stream:
        srv.submit(ServeRequest(r.request_id, r.prompt, r.max_new_tokens,
                                arrival_s=r.arrival_s))
    t0 = time.perf_counter()
    with compile_guard(allowed=None, track=[engine],
                       what=f"{server_cls.__name__} stream") as guard:
        results = srv.run()
    wall_s = time.perf_counter() - t0
    tokens = int(sum(len(r.tokens) for r in results))
    ttfts = [r.ttft_ms for r in results]
    e2es = [r.e2e_ms for r in results]
    return {
        "completed": len(results),
        "wall_s": round(wall_s, 4),
        "tokens": tokens,
        "tokens_per_s": round(tokens / max(1e-9, wall_s), 2),
        "mean_ttft_ms": round(float(np.mean(ttfts)), 2),
        "p95_ttft_ms": round(float(np.percentile(ttfts, 95)), 2),
        "mean_e2e_ms": round(float(np.mean(e2es)), 2),
        "mean_queue_ms": round(float(np.mean([r.queue_ms
                                              for r in results])), 2),
        "mean_acceptance": round(float(np.mean([r.acceptance_rate
                                                for r in results])), 4),
        "compiles_during_run": guard.count,
    }


def capture_acceptance(engine, stream: list[ServeRequest],
                       gamma: int) -> list[list[int]]:
    """Ground-truth acceptance bits per request (padded batch, true
    lengths) for the simulator replay."""
    maxlen = max(len(r.prompt) for r in stream)
    prompts = np.zeros((len(stream), maxlen), np.int32)
    lens = np.zeros((len(stream),), np.int32)
    for i, r in enumerate(stream):
        prompts[i, :len(r.prompt)] = r.prompt
        lens[i] = len(r.prompt)
    max_new = max(r.max_new_tokens for r in stream)
    _, stats = engine.generate(prompts, max_new, StaticWindowPolicy(gamma),
                               prompt_lens=lens)
    return stats.acceptance_seqs


def simulate_stream(stream: list[ServeRequest], seqs: list[list[int]],
                    gamma: int, max_batch: int, length_aware: bool) -> dict:
    records = [TraceRecord(request_id=r.request_id,
                           prompt_length=len(r.prompt),
                           output_length=r.max_new_tokens,
                           acceptance_seq=seqs[i],
                           arrival_time_ms=r.arrival_s * 1e3,
                           drafter_id=i, dataset="bench_serving")
               for i, r in enumerate(stream)]
    batching = LengthAwareBatching() if length_aware else FIFOBatching()
    sim = DSDSimulation(
        ClusterSpec(num_targets=1, num_drafters=len(stream),
                    link=LinkSpec(rtt_ms=1.0)),
        PolicyStack(batching=batching,
                    batching_cfg=BatchingConfig(max_batch=max_batch,
                                                continuous=True),
                    window=StaticWindowPolicy(gamma)),
        records)
    s = sim.run().summary()
    return {
        "completed": s["completed"],
        "tokens_per_s": round(s["token_throughput_tps"], 2),
        "mean_ttft_ms": round(s["ttft_ms"]["mean"], 2),
        "mean_e2e_ms": round(s["e2e_ms"]["mean"], 2),
        "acceptance_rate": round(s["acceptance_rate"], 4),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=16.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-lane variant (fewer/shorter requests)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_serving.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        n_req, budgets, plen = 6, [6, 12], (6, 16)
        args.max_batch, args.rate = 2, 50.0
    else:
        n_req, budgets, plen = args.requests, [16, 32, 48], (8, 33)

    rng = np.random.default_rng(args.seed)
    stream = build_stream(rng, n_req, args.rate, plen[0], plen[1], budgets)
    cfg = ServerConfig(
        max_batch=args.max_batch, length_aware=True, pad_to=8,
        max_prompt_len=((plen[1] + 7) // 8) * 8,
        max_new_cap=max(budgets), sync_every=args.sync_every)

    def make_engine():
        return SpecDecodeEngine(DRAFT, TARGET, temperature=0.0,
                                gamma_max=args.gamma,
                                sync_every=args.sync_every,
                                key=jax.random.PRNGKey(args.seed))

    def policy():
        return StaticWindowPolicy(args.gamma)

    results = {}
    engines = {}
    for name, cls in [("wave", WaveSpecDecodeServer),
                      ("continuous", SpecDecodeServer)]:
        engine = engines[name] = make_engine()
        serve_stream(cls, engine, policy(), cfg, stream)     # warmup pass
        # a measured pass that still paid an XLA compile (wave geometry is
        # timing-dependent) would inflate wall time with compile time —
        # retry so the recorded numbers are pure serving. For the
        # continuous server any retry would MASK a recompile regression,
        # so its first measured pass is the recorded one.
        for _ in range(3):
            results[name] = serve_stream(cls, engine, policy(), cfg, stream)
            if (name == "continuous"
                    or results[name]["compiles_during_run"] == 0):
                break

    seqs = capture_acceptance(engines["wave"], stream, args.gamma)
    sim = simulate_stream(stream, seqs, args.gamma, args.max_batch,
                          cfg.length_aware)

    real = results["continuous"]
    out = {
        "bench": "serving_continuous_vs_wave",
        "config": {"requests": n_req, "rate_rps": args.rate,
                   "max_batch": args.max_batch, "budgets": budgets,
                   "prompt_len": list(plen), "gamma": args.gamma,
                   "sync_every": args.sync_every, "smoke": args.smoke,
                   "draft": DRAFT.name, "target": TARGET.name,
                   "backend": jax.default_backend(),
                   "jax": jax.__version__,
                   "platform": platform.platform()},
        "wave": results["wave"],
        "continuous": results["continuous"],
        "sim_continuous": sim,
        "continuous_over_wave_tokens_per_s": round(
            real["tokens_per_s"] / max(1e-9,
                                       results["wave"]["tokens_per_s"]), 4),
        "continuous_over_wave_mean_ttft": round(
            real["mean_ttft_ms"] / max(1e-9,
                                       results["wave"]["mean_ttft_ms"]), 4),
        # calibration ratios, not errors: hwmodel predicts datacenter GPUs
        "sim_over_real_tokens_per_s": round(
            sim["tokens_per_s"] / max(1e-9, real["tokens_per_s"]), 4),
        "sim_over_real_mean_ttft": round(
            sim["mean_ttft_ms"] / max(1e-9, real["mean_ttft_ms"]), 4),
        "continuous_wins": bool(
            real["tokens_per_s"] > results["wave"]["tokens_per_s"]
            and real["mean_ttft_ms"] < results["wave"]["mean_ttft_ms"]),
        "zero_recompiles_after_warmup":
            results["continuous"]["compiles_during_run"] == 0,
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    print(f"\ncontinuous/wave tokens_per_s = "
          f"{out['continuous_over_wave_tokens_per_s']:.3f}  "
          f"ttft ratio = {out['continuous_over_wave_mean_ttft']:.3f}  "
          f"wins = {out['continuous_wins']}  "
          f"zero recompiles = {out['zero_recompiles_after_warmup']}")
    # the bench doubles as a regression gate (CI runs --smoke): losing to
    # the wave baseline or recompiling across admissions is a failure
    return 0 if (out["continuous_wins"]
                 and out["zero_recompiles_after_warmup"]) else 1


if __name__ == "__main__":
    raise SystemExit(main())
