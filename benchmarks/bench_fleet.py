"""Fleet serving benchmark: SLO attainment vs offered load under smart
(α/link-aware) pair routing, against the least-loaded baseline, on a
heterogeneous 2-pair topology (LAN edge + WAN edge sharing one cloud
target) — with a DSD-Sim column built from the IDENTICAL ClusterSpec.

The workload is a fleet :class:`~repro.fleet.TraceSpec`: chat /
long-context traffic carrying TTFT+TPOT SLOs plus batch-offline filler
that carries none. SLO thresholds are SELF-CALIBRATED, not hard-coded:
the bench first serves a probe wave through each pair alone (the other
drained) and places the chat TPOT SLO midway between the measured LAN and
WAN per-token times — so by construction a request served on the LAN pair
attains and one served on the WAN pair misses, on ANY host speed. The sim
column calibrates its own midpoint the same way (records pinned per
lane), because sim and real clocks need not agree — only the ROUTING
ORDERING must.

What the paper's fleet story predicts and this bench gates:

- the α/link-aware router (``pair_cost``: RTT × recent acceptance × queue
  occupancy) routes SLO-bearing traffic onto the LAN pair and spills to
  the WAN pair only when the LAN slots are full, so its SLO attainment at
  the calibrated operating load is STRICTLY higher than least-loaded's
  (which happily parks half the stream on the WAN pair whenever the LAN
  pair has one request in flight);
- the attainment gap holds across the offered-load curve (smart ≥
  least-loaded at every load);
- DSD-Sim, fed the same spec and the same unpinned trace through
  ``SIM_PAIR_ROUTERS``, agrees on the policy ordering.

    PYTHONPATH=src python benchmarks/bench_fleet.py [--smoke] \
        [--requests 16] [--seed 0] [--out BENCH_fleet.json]

``--smoke`` is the CI fast-lane variant: one load point, fewer requests,
and the gates relax to smart ≥ least-loaded plus the report-schema check.
Writes BENCH_fleet.json (repo root by default).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.window import StaticWindowPolicy
from repro.distributed import InProcessTransport
from repro.fleet import (RequestClass, TraceSpec, fleet_serve_requests,
                         fleet_trace_records, generate_requests, slo_report)
from repro.fleet.workload import serve_results_rows
from repro.serving import PAIR_ROUTERS, ServeRequest
from repro.sim.network import LinkSpec
from repro import topology as topo

TARGET = ModelConfig(name="bench-fleet-model", arch_type="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                     vocab=128, dtype="float32", remat=False)
GAMMA = 4
GAMMA_MAX = 8
LAN_RTT_MS = 2.0
WAN_RTT_MS = 80.0
ROUTERS = ("least-loaded", "smart")


def noised_draft_params(target_params, scale: float, seed: int = 42):
    """Draft = target + N(0, (scale·std)²) per tensor → controlled α."""
    import jax.numpy as jnp
    leaves, treedef = jax.tree.flatten(target_params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        if isinstance(leaf, jax.Array) and leaf.ndim > 0:
            leaf = leaf + scale * jnp.std(leaf) * jax.random.normal(
                k, leaf.shape, leaf.dtype)
        out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def fleet_spec(max_batch: int, max_new: int, seed: int) -> topo.ClusterSpec:
    """Heterogeneous 2-pair topology: LAN edge + WAN edge, one cloud
    target. Links sleep for real, so a WAN round costs wall-clock time
    the single-threaded chunk scheduler cannot hide — exactly the cost
    smart routing is paid to avoid."""
    return topo.ClusterSpec(
        nodes=[
            topo.NodeSpec("edge-lan", "draft", "bench-fleet-model",
                          device="edge-nic", sim_model="llama2-7b"),
            topo.NodeSpec("edge-wan", "draft", "bench-fleet-model",
                          device="edge-lte", sim_model="llama2-7b"),
            topo.NodeSpec("cloud", "target", "bench-fleet-model",
                          hw="A100", sim_model="llama2-7b", tp=1),
        ],
        pairs=[
            topo.PairSpec("lan", "edge-lan", "cloud",
                          link=LinkSpec(rtt_ms=LAN_RTT_MS, jitter_ms=0.2),
                          window=topo.WindowSpec("static", GAMMA)),
            topo.PairSpec("wan", "edge-wan", "cloud",
                          link=LinkSpec(rtt_ms=WAN_RTT_MS, jitter_ms=2.0),
                          window=topo.WindowSpec("static", GAMMA)),
        ],
        serving=topo.ServingSpec(max_batch=max_batch, gamma_max=GAMMA_MAX,
                                 sync_every=4, temperature=0.0,
                                 router="smart"),
        workload=topo.WorkloadSpec(num_requests=8, max_new=max_new),
        seed=seed)


def fleet_trace(n: int, rate: float, slo_ttft_ms: float, slo_tpot_ms: float,
                seed: int) -> TraceSpec:
    """The bench workload: SLO-bearing chat + long-context traffic and
    batch-offline filler, bursty arrivals at mean ``rate`` req/s. Length
    distributions are sized to the tiny bench model (short prompts, short
    outputs with enough tokens for a stable TPOT sample)."""
    return TraceSpec(
        classes=[
            RequestClass(name="chat", weight=0.6, prompt_mean=12,
                         prompt_sigma=0.3, prompt_min=6, prompt_max=24,
                         output_mean=12, output_sigma=0.2, output_min=8,
                         output_max=16, slo_ttft_ms=slo_ttft_ms,
                         slo_tpot_ms=slo_tpot_ms, alpha=0.85, rho=0.5),
            RequestClass(name="long-context", weight=0.25, prompt_mean=32,
                         prompt_sigma=0.3, prompt_min=16, prompt_max=64,
                         output_mean=12, output_sigma=0.2, output_min=8,
                         output_max=16, slo_ttft_ms=slo_ttft_ms * 1.5,
                         slo_tpot_ms=slo_tpot_ms, alpha=0.8, rho=0.5),
            RequestClass(name="batch-offline", weight=0.15, prompt_mean=16,
                         prompt_sigma=0.4, prompt_min=6, prompt_max=48,
                         output_mean=12, output_sigma=0.3, output_min=8,
                         output_max=16, slo_ttft_ms=0.0, slo_tpot_ms=0.0,
                         alpha=0.8, rho=0.5),
        ],
        num_requests=n, rate_per_s=rate, shape="burst",
        burst_every_s=max(0.4, 4.0 / rate), burst_len_s=0.15,
        burst_multiplier=3.0, seed=seed)


# --------------------------------------------------------------------------
# real path
# --------------------------------------------------------------------------

def warm_engines(dep, prompt_len: int, max_new: int, seed: int) -> None:
    """Compile every split-worker program at the serving geometry before
    any measured (or calibration) serve."""
    rng = np.random.default_rng(seed)
    B = dep.spec.serving.max_batch
    prompts = rng.integers(0, TARGET.vocab,
                           (B, prompt_len)).astype(np.int32)
    for eng in {id(p.engine): p.engine for p in dep.pairs}.values():
        eng.generate(prompts, max_new, StaticWindowPolicy(GAMMA),
                     gamma_max=GAMMA_MAX, sync_every=4,
                     key=jax.random.PRNGKey(seed),
                     transport=InProcessTransport())


def calibrate_pair(dep, pair_id: str, max_new: int, seed: int) -> dict:
    """Serve one probe wave through ONE pair (the other drained) and
    report its per-token and end-to-end times at the serving batch
    geometry — the empirical basis for the SLO thresholds."""
    server = dep.build_server()
    for p in dep.pairs:
        if p.pair_id != pair_id:
            server.drain(p.pair_id)
    rng = np.random.default_rng(seed)
    n = dep.spec.serving.max_batch * 2
    for i in range(n):
        server.submit(ServeRequest(
            i, rng.integers(0, TARGET.vocab, 12).astype(np.int32), max_new))
    results = server.run()
    for p in dep.pairs:
        server.undrain(p.pair_id)
    tpots = sorted(r.tpot_ms for r in results)
    e2es = sorted(r.e2e_ms for r in results)
    return {
        "pair": pair_id,
        "tpot_p50_ms": round(float(np.median(tpots)), 3),
        "e2e_max_ms": round(float(e2es[-1]), 3),
    }


def run_real(dep, trace: TraceSpec, router: str) -> dict:
    """Serve the trace's stream through the deployment under one routing
    policy; grade SLO attainment with the shared ``slo_report`` rule."""
    dep.router = PAIR_ROUTERS[router]()
    server = dep.build_server()
    reqs = generate_requests(trace)
    for r in fleet_serve_requests(reqs, dep.vocab, seed=trace.seed):
        server.submit(r)
    t0 = time.perf_counter()
    results = server.run()
    wall_s = time.perf_counter() - t0
    rep = slo_report(serve_results_rows(results))
    pairs = server.pair_summaries()
    tokens = int(sum(len(r.tokens) for r in results))
    return {
        "router": router,
        "rate_rps": trace.rate_per_s,
        "requests": len(results),
        "tokens": tokens,
        "wall_s": round(wall_s, 3),
        "tokens_per_s": round(tokens / max(1e-9, wall_s), 2),
        "attainment": round(rep["attainment"], 4),
        "graded": rep["graded"],
        "attained": rep["attained"],
        "shed": int(sum(d.get("shed", 0) for d in pairs.values())),
        "per_class": rep["per_class"],
        "pair_requests": {pid: d["requests"] for pid, d in pairs.items()},
        "pair_ttft_p95_ms": {pid: d["ttft_p95_ms"]
                             for pid, d in pairs.items()},
    }


# --------------------------------------------------------------------------
# sim column (identical spec, identical unpinned stream)
# --------------------------------------------------------------------------

def sim_lane_tpot(spec, trace: TraceSpec, lane: int) -> float:
    """Sim calibration: a small probe of the trace pinned to one lane."""
    probe = dataclasses.replace(trace, num_requests=4)
    records = [dataclasses.replace(r, drafter_id=lane)
               for r in fleet_trace_records(generate_requests(probe),
                                            seed=probe.seed)]
    an = topo.build_simulation(spec, records).run()
    tpots = [m.tpot_ms for m in an.requests.values()
             if m.tokens_generated > 1]
    return float(np.median(tpots))


def run_sim(spec, trace: TraceSpec, router: str,
            slo_ttft_ms: float, slo_tpot_ms: float) -> dict:
    """DSD-Sim on the identical spec: the same unpinned stream, lanes
    assigned at arrival by the sim pair router, graded against the
    SIM-calibrated SLO midpoint."""
    records = fleet_trace_records(generate_requests(trace), seed=trace.seed)
    for rec in records:
        if rec.slo_tpot_ms > 0:        # re-scale graded classes to sim time
            rec.slo_tpot_ms = slo_tpot_ms
        if rec.slo_ttft_ms > 0:
            rec.slo_ttft_ms = slo_ttft_ms
    an = topo.build_simulation(spec, records, pair_router=router).run()
    lanes = [0] * len(spec.pairs)
    for m in an.requests.values():
        lanes[m.drafter_id] += 1
    slo = an.summary()["slo"]
    return {
        "router": router,
        "rate_rps": trace.rate_per_s,
        "attainment": round(slo["attainment"], 4),
        "graded": slo["graded"],
        "lane_requests": {spec.pairs[i].id: n for i, n in enumerate(lanes)},
    }


# --------------------------------------------------------------------------

REPORT_KEYS = ("bench", "config", "calibration", "real", "sim", "checks")
ROW_KEYS = ("router", "rate_rps", "attainment", "graded", "tokens_per_s")


def schema_ok(out: dict) -> bool:
    """The SLO-attainment report shape CI consumes."""
    if not all(k in out for k in REPORT_KEYS):
        return False
    rows = out["real"]
    if not rows or not all(all(k in r for k in ROW_KEYS) for r in rows):
        return False
    if not all(0.0 <= r["attainment"] <= 1.0 for r in rows + out["sim"]):
        return False
    return {r["router"] for r in rows} == set(ROUTERS)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16,
                    help="requests per (router, load) serve run")
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast-lane variant: one load point, fewer "
                         "requests; gates smart >= least-loaded plus the "
                         "report schema")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent
                                         .parent / "BENCH_fleet.json"))
    args = ap.parse_args(argv)

    n_req = 8 if args.smoke else args.requests
    max_new = args.max_new
    spec = fleet_spec(max_batch=2, max_new=max_new, seed=args.seed)

    from repro.models.model import build_model
    tparams = build_model(TARGET).init_params(jax.random.PRNGKey(args.seed))
    dparams = noised_draft_params(tparams, 0.004)
    dep = topo.build_deployment(
        spec, model_configs={"bench-fleet-model": TARGET},
        node_params={"edge-lan": dparams, "edge-wan": dparams,
                     "cloud": tparams})
    warm_engines(dep, prompt_len=16, max_new=max_new, seed=args.seed)

    # -- self-calibrated SLOs: midpoint of the measured per-pair TPOTs ----
    lan_cal = calibrate_pair(dep, "lan", max_new, args.seed)
    wan_cal = calibrate_pair(dep, "wan", max_new, args.seed)
    slo_tpot = 0.5 * (lan_cal["tpot_p50_ms"] + wan_cal["tpot_p50_ms"])
    slo_ttft = 8.0 * wan_cal["e2e_max_ms"]
    # operating loads relative to the LAN pair's measured capacity: at
    # ~1× LAN capacity the LAN pair is busy often enough that
    # least-loaded regularly diverts SLO traffic to the WAN pair while
    # smart still (mostly) fits the stream on the LAN slots
    lan_cap_rps = (1e3 * spec.serving.max_batch
                   / max(1.0, lan_cal["e2e_max_ms"]))
    loads = ([round(lan_cap_rps, 2)] if args.smoke else
             [round(lan_cap_rps * f, 2) for f in (0.5, 1.0, 1.5)])
    primary = loads[0] if args.smoke else loads[1]

    # -- sim-side calibration (sim clocks differ from the host's) ---------
    cal_trace = fleet_trace(4, 4.0, 1.0, 1.0, args.seed)
    sim_lan_t = sim_lane_tpot(spec, cal_trace, 0)
    sim_wan_t = sim_lane_tpot(spec, cal_trace, 1)
    sim_slo_tpot = 0.5 * (sim_lan_t + sim_wan_t)
    sim_slo_ttft = 8.0 * sim_wan_t * max_new

    real_rows, sim_rows = [], []
    for rate in loads:
        trace = fleet_trace(n_req, rate, slo_ttft, slo_tpot, args.seed)
        for router in ROUTERS:
            real_rows.append(run_real(dep, trace, router))
            sim_rows.append(run_sim(spec, trace, router,
                                    sim_slo_ttft, sim_slo_tpot))

    def att(rows, router, rate):
        return next(r["attainment"] for r in rows
                    if r["router"] == router and r["rate_rps"] == rate)

    smart_primary = att(real_rows, "smart", primary)
    ll_primary = att(real_rows, "least-loaded", primary)
    curve_ok = all(att(real_rows, "smart", r)
                   >= att(real_rows, "least-loaded", r) for r in loads)
    sim_smart = att(sim_rows, "smart", primary)
    sim_ll = att(sim_rows, "least-loaded", primary)

    # keep the spec's committed form carrying the primary-load trace, so
    # the report's spec is replayable through launch.serve / sim as-is
    spec.workload.trace = fleet_trace(n_req, primary, round(slo_ttft, 3),
                                      round(slo_tpot, 3), args.seed)
    spec.workload.num_requests = n_req

    out = {
        "bench": "fleet_slo_routing",
        "config": {"requests": n_req, "max_new": max_new,
                   "gamma": GAMMA, "max_batch": spec.serving.max_batch,
                   "lan_rtt_ms": LAN_RTT_MS, "wan_rtt_ms": WAN_RTT_MS,
                   "loads_rps": loads, "primary_load_rps": primary,
                   "routers": list(ROUTERS), "smoke": args.smoke,
                   "seed": args.seed, "model": TARGET.name,
                   "backend": jax.default_backend(),
                   "jax": jax.__version__,
                   "platform": platform.platform()},
        "calibration": {
            "lan": lan_cal, "wan": wan_cal,
            "slo_tpot_ms": round(slo_tpot, 3),
            "slo_ttft_ms": round(slo_ttft, 3),
            "lan_capacity_rps": round(lan_cap_rps, 2),
            "sim": {"lan_tpot_ms": round(sim_lan_t, 3),
                    "wan_tpot_ms": round(sim_wan_t, 3),
                    "slo_tpot_ms": round(sim_slo_tpot, 3),
                    "slo_ttft_ms": round(sim_slo_ttft, 3)},
        },
        "spec": spec.to_dict(),
        "real": real_rows,
        "sim": sim_rows,
        "checks": {},
    }
    checks = {
        "schema_ok": schema_ok(out),
        "smart_attainment_primary": smart_primary,
        "least_loaded_attainment_primary": ll_primary,
        "smart_beats_least_loaded": smart_primary > ll_primary,
        "smart_geq_least_loaded_all_loads": curve_ok,
        "sim_smart_attainment": sim_smart,
        "sim_least_loaded_attainment": sim_ll,
        "sim_same_policy_ordering": sim_smart > sim_ll,
    }
    out["checks"] = checks
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))

    if args.smoke:
        ok = (checks["schema_ok"]
              and smart_primary >= ll_primary
              and sim_smart >= sim_ll)
    else:
        ok = (checks["schema_ok"]
              and checks["smart_beats_least_loaded"]
              and checks["smart_geq_least_loaded_all_loads"]
              and checks["sim_same_policy_ordering"])
    print(f"\nsmart={smart_primary}  least-loaded={ll_primary}  "
          f"sim: smart={sim_smart} least-loaded={sim_ll}  "
          f"schema_ok={checks['schema_ok']}  ok={ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
