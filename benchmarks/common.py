"""Shared scenario plumbing for the paper-figure benchmarks."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

from repro.sim import (BatchingConfig, ClusterSpec, DSDSimulation,
                       FIFOBatching, JSQRouting, LengthAwareBatching,
                       LinkSpec, PolicyStack, RandomRouting,
                       RoundRobinRouting, WorkloadGenerator)
from repro.core.window import (AWCWindowPolicy, DynamicWindowPolicy,
                               OracleStaticPolicy, StaticWindowPolicy)
from repro.core.awc.model import default_predictor

DATASETS = ("gsm8k", "humaneval", "cnndm")


def window_policy(kind: str, gamma: int = 4, branches: int = 1):
    if kind == "static":
        return StaticWindowPolicy(gamma, branches=branches)
    if kind == "dynamic":
        return DynamicWindowPolicy(gamma0=gamma)
    if kind == "awc":
        return AWCWindowPolicy(default_predictor())
    if kind == "fused":
        return OracleStaticPolicy(1, fused=True)
    raise ValueError(kind)


def routing_policy(kind: str, seed: int = 0):
    return {"random": lambda: RandomRouting(seed=seed),
            "rr": RoundRobinRouting,
            "jsq": JSQRouting}[kind]()


def batching_policy(kind: str):
    return {"fifo": FIFOBatching, "lab": LengthAwareBatching}[kind]()


def run_scenario(dataset: str = "gsm8k", *, targets: int = 2,
                 drafters: int = 64, rtt_ms: float = 10.0,
                 rate: float = 40.0, n_requests: int = 80,
                 routing: str = "jsq", batching: str = "lab",
                 window: str = "static", gamma: int = 4, branches: int = 1,
                 max_batch: int = 16, seed: int = 0,
                 target_hw: str = "A100", target_model: str = "llama2-70b",
                 target_tp: int = 4, draft_hw: str = "A40",
                 draft_model: str = "llama2-7b",
                 heterogeneous: bool = False) -> dict:
    from repro.sim.scheduler import PAPER_DRAFT_POOL, PAPER_TARGET_POOL
    cluster = ClusterSpec(
        num_targets=targets, target_hw=target_hw, target_model=target_model,
        target_tp=target_tp, num_drafters=drafters, draft_hw=draft_hw,
        draft_model=draft_model,
        target_pool=PAPER_TARGET_POOL if heterogeneous else None,
        draft_pool=PAPER_DRAFT_POOL if heterogeneous else None,
        link=LinkSpec(rtt_ms=rtt_ms, jitter_ms=max(0.5, rtt_ms * 0.08)))
    pol = PolicyStack(routing=routing_policy(routing, seed),
                      batching=batching_policy(batching),
                      batching_cfg=BatchingConfig(max_batch=max_batch),
                      window=window_policy(window, gamma, branches))
    gen = WorkloadGenerator(dataset, rate, drafters, seed=seed)
    sim = DSDSimulation(cluster, pol, gen.generate(n_requests), seed=seed)
    t0 = time.time()
    summary = sim.run().summary()
    summary["_sim_wall_s"] = time.time() - t0
    return summary


def mean_over_seeds(fn, seeds=(0, 1, 2)) -> dict:
    """Paper: 'each measurement is repeated across multiple random seeds and
    the reported results represent the mean values'."""
    outs = [fn(seed) for seed in seeds]

    def avg(path):
        vals = []
        for o in outs:
            v = o
            for k in path:
                v = v[k]
            vals.append(v)
        return sum(vals) / len(vals)

    return {
        "throughput_rps": avg(["throughput_rps"]),
        "token_throughput_tps": avg(["token_throughput_tps"]),
        "ttft_ms": avg(["ttft_ms", "mean"]),
        "tpot_ms": avg(["tpot_ms", "mean"]),
        "acceptance_rate": avg(["acceptance_rate"]),
        "target_utilization": avg(["target_utilization"]),
        "mean_gamma": avg(["mean_gamma"]),
    }
