"""Fig. 4 analogue — hardware-modeling-engine calibration.

The paper validates VIDUR's prefill/decode latency predictions against real
GPU measurements (MAE 7.4% prefill / 5.2% decode). We cannot measure
A40/A100/H100 here, so we reproduce the *methodology* on the hardware we do
have: run real reduced JAX models on this host across a grid of
(batch, prompt/context) shapes, fit the analytic predictor's per-(hw, op)
calibration factors on half the grid, and report held-out MAE — the same
predictor+calibration machinery the simulator uses for its GPU catalog.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.sim.hwmodel import HardwareModel, ModelDesc, OpShape, register_model


def _measure(model, params, op, batch, length, ctx, reps=7) -> float:
    """min-of-reps wall time — robust to scheduler noise on a shared host."""
    key = jax.random.PRNGKey(0)
    if op == "prefill":
        toks = jax.random.randint(key, (batch, length), 0, model.cfg.vocab)
        fn = jax.jit(lambda p, t: model.prefill(p, t, length + 8)[0])
        fn(params, toks)[0].block_until_ready()          # compile + warm
        fn(params, toks)[0].block_until_ready()
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn(params, toks)[0].block_until_ready()
            times.append(time.perf_counter() - t0)
        return min(times)
    # decode
    toks = jax.random.randint(key, (batch, ctx), 0, model.cfg.vocab)
    _, cache = jax.jit(lambda p, t: model.prefill(p, t, ctx + 16))(params, toks)
    tok = jnp.zeros((batch,), jnp.int32)
    pos = jnp.full((batch,), ctx, jnp.int32)
    fn = jax.jit(lambda p, t, c, ps: model.decode_step(p, t, c, ps)[0])
    fn(params, tok, cache, pos).block_until_ready()
    fn(params, tok, cache, pos).block_until_ready()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(params, tok, cache, pos).block_until_ready()
        times.append(time.perf_counter() - t0)
    return min(times)


def run(quick: bool = True):
    cfg = dataclasses.replace(
        get_config("qwen2.5-3b").reduced(), n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, head_dim=64, d_ff=1024, vocab=2048)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    register_model(ModelDesc(
        name="cal-model", n_layers=cfg.n_layers, d_model=cfg.d_model,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff,
        vocab=cfg.vocab, dtype_bytes=4))

    grid = [("prefill", 1, 64, 0), ("prefill", 2, 128, 0),
            ("prefill", 4, 64, 0), ("prefill", 1, 256, 0),
            ("decode", 1, 1, 64), ("decode", 2, 1, 128),
            ("decode", 4, 1, 64), ("decode", 8, 1, 128)]
    if not quick:
        grid += [("prefill", 8, 128, 0), ("decode", 16, 1, 256),
                 ("prefill", 2, 512, 0), ("decode", 2, 1, 512)]

    samples = []
    for op, b, ln, ctx in grid:
        wall = _measure(model, params, op, b, ln, ctx)
        shp = (OpShape([0] * b, [ln] * b) if op == "prefill"
               else OpShape([ctx] * b, [1] * b))
        samples.append((op, "CPU", shp, "cal-model", wall))

    hm = HardwareModel()
    train, test = samples[::2], samples[1::2]
    hm.fit_calibration(train)
    mae_pre = hm.mean_abs_pct_error(
        [s for s in test if s[0] == "prefill"])
    mae_dec = hm.mean_abs_pct_error(
        [s for s in test if s[0] == "decode"])
    rows = [("fig4_prefill_mae_pct", mae_pre,
             "paper reports 7.4% (VIDUR vs GPUs)"),
            ("fig4_decode_mae_pct", mae_dec,
             "paper reports 5.2% (VIDUR vs GPUs)")]
    for op, b, ln, ctx in grid[:4]:
        pass
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.2f},{note}")
