"""Roofline analysis (deliverable g).

Reads the dry-run JSONL rows (launch/dryrun.py) and derives, per
(arch × shape) on the single-pod mesh:

    compute term    = HLO_FLOPs  / (chips × 197e12 FLOP/s)
    memory term     = HLO_bytes  / (chips × 819e9 B/s)
    collective term = coll_bytes / (chips × 50e9 B/s per ICI link)

HLO numbers come from ``compiled.cost_analysis()`` — which counts
while-loop bodies ONCE (verified experimentally; see EXPERIMENTS.md) — so
each row is rescaled by its analytic loop-trip product recorded by the
dry-run (``loop_trips`` / ``hlo_body_copies``). Collective bytes are parsed
from the partitioned HLO (ring-algorithm per-link bytes) and rescaled the
same way. MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference);
the ratio MODEL_FLOPS / HLO_FLOPs flags remat/padding/dispatch waste.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Any, Optional

PEAK_FLOPS = 197e12       # TPU v5e bf16, per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

RESULT_GLOB = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun_*.jsonl")


def load_rows(pattern: str = RESULT_GLOB) -> list[dict]:
    rows: list[dict] = []
    for path in sorted(glob.glob(pattern)):
        with open(path) as f:
            for line in f:
                if line.strip():
                    rows.append(json.loads(line))
    # keep the LAST row per (arch, shape, mesh) — reruns supersede
    dedup: dict[tuple, dict] = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def analyze_row(r: dict) -> Optional[dict]:
    if not r.get("ok"):
        return None
    scale = r.get("loop_trips", 1) / max(1, r.get("hlo_body_copies", 1))
    flops_dev = r["flops_per_device"] * scale
    bytes_dev = r["bytes_per_device"] * scale
    coll_dev = r["collectives"]["moved_bytes"] * scale
    n = r["devices"]

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    hlo_total = flops_dev * n
    ratio = r["model_flops"] / hlo_total if hlo_total else float("nan")
    mfu_bound = (r["model_flops"] / (n * PEAK_FLOPS)) / bound_s \
        if bound_s > 0 else float("nan")
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "devices": n,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": r["model_flops"],
        "hlo_flops_total": hlo_total,
        "useful_ratio": ratio,
        "roofline_mfu_bound": mfu_bound,
        "peak_gib": r["memory"]["peak_estimate_bytes"] / 2 ** 30,
    }


def recommendation(a: dict) -> str:
    d = a["dominant"]
    if d == "collective":
        return ("reduce cross-device traffic: coarser FSDP all-gathers, "
                "overlap collectives with compute, or trade TP for DP")
    if d == "memory":
        if a["shape"].startswith("decode") or a["shape"] == "long_500k":
            return ("decode is weight/KV-bandwidth-bound: larger serving "
                    "batch, KV in bf16/int8, flash-decode kernel tiling")
        return "fuse elementwise chains; avoid re-materialized activations"
    if a["useful_ratio"] < 0.5:
        return ("compute-bound but <50% useful flops: cut padded-head/"
                "rect-attention waste (causal flash kernel, exact-divisor "
                "head sharding)")
    return "compute-bound near useful peak: tune MXU tiling / dtype"


def run(quick: bool = True):
    rows = load_rows()
    singles = sorted((analyze_row(r) for r in rows
                      if r["mesh"] == "single"),
                     key=lambda a: (a is None, a and (a["arch"], a["shape"])))
    out = []
    for a in singles:
        if a is None:
            continue
        out.append((f"roofline_{a['arch']}_{a['shape']}_{a['dominant']}_s",
                    max(a["compute_s"], a["memory_s"], a["collective_s"]),
                    f"c={a['compute_s']:.2e} m={a['memory_s']:.2e} "
                    f"x={a['collective_s']:.2e} useful={a['useful_ratio']:.2f}"))
    ok = sum(1 for r in rows if r.get("ok"))
    out.append(("dryrun_rows_ok", float(ok), f"of {len(rows)}"))
    return out


def markdown_table(mesh: str = "single") -> str:
    rows = load_rows()
    lines = ["| arch | shape | dominant | compute (s) | memory (s) | "
             "collective (s) | useful | peak GiB/dev | next move |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        a = analyze_row(r)
        if a is None:
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | "
                         f"{r.get('error', '')[:60]} |")
            continue
        lines.append(
            f"| {a['arch']} | {a['shape']} | **{a['dominant']}** | "
            f"{a['compute_s']:.3e} | {a['memory_s']:.3e} | "
            f"{a['collective_s']:.3e} | {a['useful_ratio']:.2f} | "
            f"{a['peak_gib']:.2f} | {recommendation(a)} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    if "--markdown" in sys.argv:
        print(markdown_table())
    else:
        for name, val, note in run():
            print(f"{name},{val:.4e},{note}")
