"""Table 2 — AWC vs Static(γ=4) vs Dynamic window policies.

Paper: 4 system configs (20 targets × {600, 1000} drafts × {10, 30} ms RTT)
× 3 datasets; AWC wins throughput in 12/12 (up to +9.7% GSM8K), TPOT drops
6–10%, TTFT within 0.5–4% of best.

Quick mode scales the cluster 1:10 (2T/60D|100D) keeping the drafter:target
ratio and load point; full mode runs the paper's 20T/600D|1000D with the
paper's request counts (400/400/100).
"""

from __future__ import annotations

from .common import DATASETS, mean_over_seeds, run_scenario

N_REQ = {"gsm8k": 400, "cnndm": 400, "humaneval": 100}


def run(quick: bool = True):
    # the paper's Table-2 clusters are HETEROGENEOUS (mixed draft/target
    # pools, §5.2) — that heterogeneity is what a learned per-pair window
    # controller exploits
    if quick:
        configs = [("cfg1", dict(targets=3, drafters=60, rtt_ms=10.0,
                                 rate=40.0, heterogeneous=True)),
                   ("cfg2", dict(targets=3, drafters=102, rtt_ms=10.0,
                                 rate=55.0, heterogeneous=True))]
        datasets = ("gsm8k", "humaneval")
        seeds = (0, 1, 2)
        n_scale = 0.25
    else:
        configs = [
            ("cfg1_600d_10ms", dict(targets=21, drafters=600, rtt_ms=10.0,
                                    rate=400.0, heterogeneous=True)),
            ("cfg2_1000d_10ms", dict(targets=21, drafters=1000, rtt_ms=10.0,
                                     rate=550.0, heterogeneous=True)),
            ("cfg3_600d_30ms", dict(targets=21, drafters=600, rtt_ms=30.0,
                                    rate=400.0, heterogeneous=True)),
            ("cfg4_1000d_30ms", dict(targets=21, drafters=1000, rtt_ms=30.0,
                                     rate=550.0, heterogeneous=True)),
        ]
        datasets = DATASETS
        seeds = (0, 1, 2)
        n_scale = 1.0

    rows = []
    awc_wins = 0
    cells = 0
    for cname, ckw in configs:
        for ds in datasets:
            n = max(90, int(N_REQ[ds] * n_scale))
            out = {}
            for pol in ("static", "dynamic", "awc"):
                out[pol] = mean_over_seeds(
                    lambda seed: run_scenario(ds, n_requests=n, window=pol,
                                              seed=seed, **ckw), seeds)
            st, dy, aw = out["static"], out["dynamic"], out["awc"]
            thpt_gain = 100 * (aw["throughput_rps"] / st["throughput_rps"] - 1)
            tpot_gain = 100 * (aw["tpot_ms"] / st["tpot_ms"] - 1)
            ttft_gain = 100 * (aw["ttft_ms"] / st["ttft_ms"] - 1)
            cells += 1
            if (aw["throughput_rps"] >= st["throughput_rps"]
                    and aw["throughput_rps"] >= dy["throughput_rps"]):
                awc_wins += 1
            rows.append((f"table2_{cname}_{ds}_static_thpt",
                         st["throughput_rps"], f"gamma={st['mean_gamma']:.1f}"))
            rows.append((f"table2_{cname}_{ds}_dynamic_thpt",
                         dy["throughput_rps"], f"gamma={dy['mean_gamma']:.1f}"))
            rows.append((f"table2_{cname}_{ds}_awc_thpt",
                         aw["throughput_rps"],
                         f"{thpt_gain:+.1f}% vs static; gamma={aw['mean_gamma']:.1f}"))
            rows.append((f"table2_{cname}_{ds}_awc_tpot_ms", aw["tpot_ms"],
                         f"{tpot_gain:+.1f}% vs static "
                         f"(static={st['tpot_ms']:.1f})"))
            rows.append((f"table2_{cname}_{ds}_awc_ttft_ms", aw["ttft_ms"],
                         f"{ttft_gain:+.1f}% vs static"))
    rows.append(("table2_awc_best_throughput_cells", float(awc_wins),
                 f"of {cells} (paper: 12/12)"))
    return rows


if __name__ == "__main__":
    for name, val, note in run(quick=False):
        print(f"{name},{val:.3f},{note}")
