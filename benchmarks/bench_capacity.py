"""Capacity benchmark: paged + quantized KV slot pool vs dense per-slot
rows at a FIXED KV-memory budget — the serving-capacity artifact for the
paged cache (models/kvcache.py, core/session.py).

A dense session must reserve ``slots_len`` positions per slot — prompt
bound + worst-case decode budget + speculative headroom — for every
admitted request, even one that asks for a handful of tokens. The paged
pool reserves only the blocks covering the request's OWN footprint
(prompt + its clamped budget + 2γ + 2), so at one HBM budget the pool
admits ~slots_len / footprint× more concurrent requests. With the bench
geometry (prompt 16, typical budget 16, worst-case cap 480, γ 4, block
16) that is ≥10×. The per-token decode latency at EQUAL occupancy must
stay within 5% of dense (on CPU the paged gather is at parity or better),
and greedy committed tokens must be bit-identical to the dense layout.
The int8-quantized pool is reported as a second capacity curve (≈4× the
fp32 block count at the same budget) but not gated — quantized attention
is approximate.

Gates (exit non-zero on failure):
  full  : capacity_x >= 10, latency ratio <= 1.05, bit-identical tokens
  smoke : capacity_x > 1, bit-identical tokens (CI fast lane)

    PYTHONPATH=src python benchmarks/bench_capacity.py [--smoke] \
        [--budget-slots 7] [--occupancy 4] [--out ...]

Writes BENCH_capacity.json (repo root by default; smoke does not write).
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from repro.analysis import compile_guard
from repro.configs.base import ModelConfig
from repro.core.engine import SpecDecodeEngine
from repro.core.session import DecodeSession
from repro.core.window import StaticWindowPolicy

DRAFT = ModelConfig(name="bench-draft", arch_type="dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                    vocab=512, dtype="float32", remat=False)
TARGET = ModelConfig(name="bench-target", arch_type="dense", n_layers=4,
                     d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                     vocab=512, dtype="float32", remat=False)


def kv_bytes(cache) -> int:
    """K + V (+ scale) bytes of one cache pytree — pos_map/block-table
    bookkeeping excluded (it is negligible and exists in both layouts)."""
    total = cache.k.nbytes + cache.v.nbytes
    ks = getattr(cache, "k_scale", None)
    if ks is not None:
        total += ks.nbytes + cache.v_scale.nbytes
    return int(total)


def make_session(engine, capacity, geo, paged, pool=None, quantize=False):
    return DecodeSession(engine, capacity=capacity,
                         max_new_cap=geo["max_new_cap"],
                         max_prompt_len=geo["prompt_len"],
                         gamma_max=geo["gamma"], sync_every=geo["sync_every"],
                         key=jax.random.PRNGKey(1), log_gamma=False,
                         paged=paged, kv_block_size=geo["block"],
                         kv_pool_blocks=pool, kv_quantize=quantize)


def run_stream(engine, prompts, geo, paged, quantize=False) -> dict:
    """Admit ``occupancy`` requests, decode to completion, retire — the
    equal-occupancy latency workload (dense-parity pool when paged)."""
    sess = make_session(engine, len(prompts), geo, paged, quantize=quantize)
    pol = StaticWindowPolicy(geo["gamma"])
    for i, p in enumerate(prompts):
        sess.admit(p, geo["max_new"], request_id=i)
    outs = {}
    while sess.unfinished:
        sess.run_chunk(pol)
        for j in sess.finished_slots():
            toks, rec = sess.retire(j)
            outs[rec.request_id] = toks.tolist()
    tokens = sum(len(t) for t in outs.values()) - len(outs)
    return {"tokens": outs,
            "ms_per_token": sess.decode_wall_s * 1e3 / max(1, tokens)}


def paged_admission_capacity(engine, geo, pool: dict, cap_bound: int) -> int:
    """Empirical capacity: admit typical requests into a paged session
    whose pool holds the HBM budget until the block allocator refuses."""
    sess = make_session(engine, cap_bound, geo, True, pool=pool)
    rng = np.random.default_rng(7)
    admitted = 0
    while (admitted < cap_bound
           and sess.can_admit(geo["prompt_len"], geo["max_new"])):
        prompt = rng.integers(0, TARGET.vocab,
                              geo["prompt_len"]).astype(np.int32)
        sess.admit(prompt, geo["max_new"], request_id=admitted)
        admitted += 1
    return admitted


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget-slots", type=int, default=7,
                    help="HBM budget expressed in dense slots (per side)")
    ap.add_argument("--occupancy", type=int, default=4,
                    help="equal-occupancy batch for the latency gate")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-lane variant (capacity>1 + bit-identity)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_capacity.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        geo = dict(prompt_len=8, max_new=8, max_new_cap=64, gamma=4,
                   block=8, sync_every=4)
        args.budget_slots, args.occupancy, args.repeats = 3, 2, 1
    else:
        geo = dict(prompt_len=16, max_new=16, max_new_cap=480, gamma=4,
                   block=16, sync_every=8)

    engine = SpecDecodeEngine(DRAFT, TARGET, temperature=0.0,
                              gamma_max=geo["gamma"],
                              key=jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, TARGET.vocab,
                            geo["prompt_len"]).astype(np.int32)
               for _ in range(args.occupancy)]

    # ---- memory accounting from REAL arrays (one-slot dense, one-block
    # pools), not an analytic formula -----------------------------------
    probe = make_session(engine, 1, geo, paged=False)
    probe._ensure_state()
    slots_len = probe.slots_len
    dense_slot_bytes = {
        "draft": kv_bytes(probe._state.draft_cache),
        "target": kv_bytes(probe._state.target_cache)}
    qprobe = make_session(engine, 1, geo, paged=True, pool=1, quantize=True)
    qprobe._ensure_state()
    fprobe = make_session(engine, 1, geo, paged=True, pool=1)
    fprobe._ensure_state()
    block_bytes = {s: kv_bytes(getattr(fprobe._state, f"{s}_cache"))
                   for s in ("draft", "target")}
    qblock_bytes = {s: kv_bytes(getattr(qprobe._state, f"{s}_cache"))
                    for s in ("draft", "target")}

    budget = {s: args.budget_slots * dense_slot_bytes[s]
              for s in ("draft", "target")}
    pool = {s: budget[s] // block_bytes[s] for s in ("draft", "target")}
    qpool = {s: budget[s] // qblock_bytes[s] for s in ("draft", "target")}
    need = make_session(engine, 1, geo, paged=True, pool=1).blocks_needed(
        geo["prompt_len"], geo["max_new"])

    # ---- capacity: dense by construction, paged by admitting until the
    # allocator refuses ---------------------------------------------------
    dense_capacity = args.budget_slots
    cap_bound = min(pool.values()) // need + 4
    paged_capacity = paged_admission_capacity(engine, geo, pool, cap_bound)
    int8_capacity = min(qpool.values()) // need      # analytic second curve
    capacity_x = paged_capacity / max(1, dense_capacity)

    # ---- latency + bit-identity at equal occupancy ----------------------
    run_stream(engine, prompts, geo, False)          # warmup (compiles)
    run_stream(engine, prompts, geo, True)
    run_stream(engine, prompts, geo, True, quantize=True)
    # every variant is warm: the measured repeats must not compile again
    with compile_guard(allowed=None, what="measured capacity repeats",
                       track=[engine]) as cg:
        dense = min((run_stream(engine, prompts, geo, False)
                     for _ in range(args.repeats)),
                    key=lambda r: r["ms_per_token"])
        paged = min((run_stream(engine, prompts, geo, True)
                     for _ in range(args.repeats)),
                    key=lambda r: r["ms_per_token"])
        int8 = run_stream(engine, prompts, geo, True, quantize=True)
    bit_identical = dense["tokens"] == paged["tokens"]
    latency_ratio = paged["ms_per_token"] / max(1e-9, dense["ms_per_token"])

    out = {
        "bench": "kv_capacity_paged_vs_dense",
        "config": {**geo, "budget_slots": args.budget_slots,
                   "occupancy": args.occupancy, "slots_len": slots_len,
                   "smoke": args.smoke,
                   "draft": DRAFT.name, "target": TARGET.name,
                   "backend": jax.default_backend(),
                   "jax": jax.__version__, "platform": platform.platform()},
        "memory": {
            "dense_slot_bytes": dense_slot_bytes,
            "block_bytes": block_bytes,
            "int8_block_bytes": qblock_bytes,
            "budget_bytes": budget,
            "pool_blocks": pool,
            "int8_pool_blocks": qpool,
            "blocks_per_request": need,
        },
        "capacity": {
            "dense": dense_capacity,
            "paged": paged_capacity,
            "paged_int8": int8_capacity,
            "paged_over_dense": round(capacity_x, 3),
            "int8_over_dense": round(int8_capacity
                                     / max(1, dense_capacity), 3),
        },
        "latency": {
            "dense_ms_per_token": round(dense["ms_per_token"], 4),
            "paged_ms_per_token": round(paged["ms_per_token"], 4),
            "int8_ms_per_token": round(int8["ms_per_token"], 4),
            "paged_over_dense": round(latency_ratio, 4),
        },
        "bit_identical_tokens": bool(bit_identical),
        "recompiles_after_warmup": cg.count,
        "zero_recompiles_after_warmup": cg.count == 0,
    }
    if args.smoke:
        ok = bit_identical and paged_capacity > dense_capacity
    else:
        ok = (bit_identical and capacity_x >= 10.0 and latency_ratio <= 1.05)
        Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    out["pass"] = bool(ok)
    print(json.dumps(out, indent=2))
    print(f"\ncapacity paged/dense = {capacity_x:.2f}x "
          f"(int8 {out['capacity']['int8_over_dense']:.2f}x)  "
          f"latency ratio = {latency_ratio:.3f}  "
          f"bit-identical = {bit_identical}  pass = {ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
