"""Distributed-execution benchmark: the paper's RTT–γ crossover on the
REAL model path (Fig. 6 analogue), plus the sim↔real parity column.

Sweeps RTT ∈ {0, 5, 20, 80, 150} ms × window policies {static-4, dynamic,
awc}
(plus a forced-fused static-4 row — the cloud-only baseline — and a
PIPELINED static-4 row that overlaps window k+1's drafting with window
k's verification) through the split-worker transport path: every
speculation round is a real draft→verify→verdict exchange whose
window/verdict payloads pay measured wall-clock delays sampled from the
SAME ``LinkSpec`` model DSD-Sim uses.
The draft is a noise-perturbed copy of the target (``--draft-noise``), so
the acceptance rate is a controlled ≈0.9 instead of the ≈0 a random
unrelated pair gives — high enough that distributed execution genuinely
wins at low RTT, the crossover is observable, AND the pipelined arm's
all-accept windows land often enough (the batch stalls together, so the
hit rate is the BATCH all-accept rate) for the overlap to show.

What the paper predicts and this benchmark checks on real models:

- distributed throughput falls with RTT while forced-fused stays flat →
  they cross (fig. 6);
- AWC reacts to the transport's MEASURED ``rtt_recent_ms``: γ stays large
  through the zero-delay transport and shrinks / flips to fused mode on a
  20 ms link (the closed loop);
- cross-round pipelining beats the half-duplex distributed arm once the
  RTT clears the compute time (RTT ≥ 20 ms here) by hiding the draft scan
  + one link direction behind verification on every pipeline hit;
- DSD-Sim, replaying the engine's captured acceptance traces through the
  same ``LinkSpec`` (with the same overlap model for the pipelined rows),
  shows the same qualitative crossover and ordering (parity columns);
- TOPOLOGY ARM: a heterogeneous 2-pair deployment (fast LAN pair + slow
  WAN pair sharing one cloud target) built from ONE declarative
  ``repro.topology.ClusterSpec`` shows the per-pair AWC stabilizers
  converging to DIFFERENT γ/fused operating points in a single serve run,
  and ``build_simulation`` on the IDENTICAL spec agrees on the per-pair
  ordering.

The benchmark doubles as the CI regression gate (``--smoke``): it exits
nonzero if either the zero-delay ``InProcessTransport`` or the PIPELINED
mode over it is not bit-identical to the colocated ``DecodeSession``
path.

    PYTHONPATH=src python benchmarks/bench_distributed.py [--smoke] \
        [--requests 4] [--max-new 24] [--draft-noise 0.004] [--out ...]

Writes BENCH_distributed.json (repo root by default).
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import compile_guard
from repro.configs.base import ModelConfig
from repro.core.awc.model import default_predictor
from repro.core.engine import SpecDecodeEngine
from repro.core.session import DecodeSession
from repro.core.window import (AWCWindowPolicy, DynamicWindowPolicy,
                               StaticWindowPolicy)
from repro.distributed import EmulatedLinkTransport, InProcessTransport
from repro.models.model import build_model
from repro.sim import (ClusterSpec, DSDSimulation, LinkSpec, PolicyStack,
                       TraceRecord)
from repro.sim.policies import BatchingConfig, LengthAwareBatching
from repro.core.window import OracleStaticPolicy
from repro import topology as topo

TARGET = ModelConfig(name="bench-dist-target", arch_type="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                     vocab=128, dtype="float32", remat=False)
# 150 ms tops the grid so the closed-loop AWC check has an operating
# point where the paper's prediction is unambiguous for ANY host speed:
# at α ≈ 0.9 the WC-DNN keeps γ large until RTT clears several multiples
# of the measured TPOT, and a slow/contended host measures TPOT high
# enough that 80 ms sits inside that saturation band.
RTTS = (0.0, 5.0, 20.0, 80.0, 150.0)
GAMMA_MAX = 12


def noised_draft_params(target_params, scale: float, seed: int = 42):
    """Draft = target + N(0, (scale·std)²) per tensor: same architecture,
    controllably-degraded predictions → tunable acceptance rate."""
    leaves, treedef = jax.tree.flatten(target_params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        if isinstance(leaf, jax.Array) and leaf.ndim > 0:
            leaf = leaf + scale * jnp.std(leaf) * jax.random.normal(
                k, leaf.shape, leaf.dtype)
        out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def make_policy(name: str):
    if name == "static-4":
        return StaticWindowPolicy(4), "auto"
    if name == "dynamic":
        return DynamicWindowPolicy(gamma0=4, gmax=GAMMA_MAX), "auto"
    if name == "awc":
        return AWCWindowPolicy(default_predictor()), "auto"
    if name == "fused":
        return StaticWindowPolicy(4), "fused"
    if name == "pipeline":
        # same γ policy as the half-duplex static-4 arm — the delta is
        # purely the cross-round overlap
        return StaticWindowPolicy(4), "pipeline"
    raise ValueError(name)


def make_transport(rtt_ms: float, seed: int):
    if rtt_ms <= 0:
        return InProcessTransport()
    return EmulatedLinkTransport(
        LinkSpec(rtt_ms=rtt_ms, jitter_ms=max(0.5, rtt_ms * 0.08)),
        seed=seed)


def run_cell(engine, prompts, max_new: int, sync_every: int,
             policy_name: str, rtt_ms: float, seed: int,
             waves: int = 2) -> dict:
    """Decode ``waves`` consecutive request waves through ONE policy and
    ONE transport (serving-style: the window policy's per-pair stabilizer
    state persists across requests, so wave 2+ shows the controller's
    CONVERGED behavior on this link — one short wave alone mostly measures
    its warmup transient). The reported stats aggregate all waves."""
    policy, mode_policy = make_policy(policy_name)
    tr = make_transport(rtt_ms, seed)
    B = prompts.shape[0]
    tokens = iters = fused_iters = accepted = proposed = 0
    pipe_hits = pipe_misses = 0
    wall_s = link_ms = 0.0
    gammas: list[int] = []
    for w in range(waves):
        sess = DecodeSession(engine, capacity=B, max_new_cap=max_new,
                             gamma_max=GAMMA_MAX, sync_every=sync_every,
                             key=jax.random.PRNGKey(seed + w), transport=tr,
                             mode_policy=mode_policy)
        sess.admit_batch(prompts, max_new)
        max_iters = 2 * max_new + sync_every   # fused tail: 1 token/iter
        while sess.unfinished and sess.iterations < max_iters:
            sess.run_chunk(policy)
        _, stats = sess.snapshot()
        tokens += stats.tokens
        iters += sess.iterations
        fused_iters += sess.fused_iterations
        accepted += stats.accepted
        proposed += stats.proposed
        wall_s += sess.decode_wall_s
        link_ms += sess.link_ms
        pipe_hits += sess.pipeline_hits
        pipe_misses += sess.pipeline_misses
        gammas.extend(stats.gamma_seq)
    return {
        "policy": policy_name,
        "rtt_ms": rtt_ms,
        "waves": waves,
        "tokens": tokens,
        "iterations": iters,
        "decode_wall_s": round(wall_s, 4),
        "tokens_per_s": round(tokens / max(1e-9, wall_s), 2),
        "acceptance_rate": round(accepted / max(1, proposed), 4),
        "mean_gamma": round(float(np.mean(gammas)), 3) if gammas else 0.0,
        "fused_fraction": round(fused_iters / max(1, iters), 4),
        "distributed_iterations": iters - fused_iters,
        "link_ms": round(link_ms, 2),
        "link_bytes": tr.bytes_sent,
        "measured_rtt_ms": round(tr.recent_rtt_ms, 3),
        "pipeline_hits": pipe_hits,
        "pipeline_misses": pipe_misses,
    }


def bit_identity_gate(engine, prompts, max_new: int, sync_every: int) -> bool:
    """Zero-delay transport — half-duplex AND pipelined — must commit
    exactly the colocated tokens (the pipelined/half-duplex bit-identity
    gate CI fails on)."""
    ref, _ = engine.generate(prompts, max_new, StaticWindowPolicy(4),
                             gamma_max=GAMMA_MAX, sync_every=sync_every,
                             key=jax.random.PRNGKey(0))
    got, _ = engine.generate(prompts, max_new, StaticWindowPolicy(4),
                             gamma_max=GAMMA_MAX, sync_every=sync_every,
                             key=jax.random.PRNGKey(0),
                             transport=InProcessTransport())
    piped, pstats = engine.generate(prompts, max_new, StaticWindowPolicy(4),
                                    gamma_max=GAMMA_MAX,
                                    sync_every=sync_every,
                                    key=jax.random.PRNGKey(0),
                                    transport=InProcessTransport(),
                                    mode_policy="pipeline")
    speculated = pstats.pipeline_hits + pstats.pipeline_misses > 0
    return bool(np.array_equal(ref, got) and np.array_equal(ref, piped)
                and speculated)


def sim_parity(prompts, seqs, max_new: int, rtts, seed: int) -> list[dict]:
    """DSD-Sim replaying the engine's captured acceptance traces over the
    same LinkSpec: per-RTT AWC γ/mode behavior + static-vs-fused
    throughput, for the qualitative crossover comparison."""
    rows = []
    B = prompts.shape[0]

    def run(rtt, window, pipeline=False):
        # two waves per drafter (mirroring run_cell): the per-pair
        # stabilizer state persists across a drafter's requests, so the
        # second request shows the converged window behavior
        records = [TraceRecord(request_id=i, prompt_length=prompts.shape[1],
                               output_length=max_new,
                               acceptance_seq=seqs[i % B],
                               arrival_time_ms=float(i // B),
                               drafter_id=i % B,
                               dataset="bench_distributed")
                   for i in range(2 * B)]
        spec = LinkSpec(rtt_ms=rtt, jitter_ms=max(0.5, rtt * 0.08))
        # llama2-7b@A100/tp1 gives the sim target a per-step service time
        # (~10 ms) in the same regime as the bench's real tiny-model TPOT,
        # so the SAME LinkSpec sweep probes the same RTT/TPOT ratios on
        # both paths — that ratio, not absolute hardware speed, is what
        # positions the crossover.
        sim = DSDSimulation(
            ClusterSpec(num_targets=1, num_drafters=B, link=spec,
                        target_hw="A100", target_model="llama2-7b",
                        target_tp=1),
            PolicyStack(batching=LengthAwareBatching(),
                        batching_cfg=BatchingConfig(max_batch=B,
                                                    continuous=True),
                        window=window),
            records, seed=seed, pipeline=pipeline)
        an = sim.run()
        gam, modes = [], []
        for m in an.requests.values():
            gam.extend(m.gamma_sequence)
            modes.extend(m.mode_sequence)
        s = an.summary()
        return s, gam, modes

    for rtt in rtts:
        s_awc, gam, modes = run(rtt, AWCWindowPolicy(default_predictor()))
        s_dist, _, _ = run(rtt, StaticWindowPolicy(4))
        s_pipe, _, _ = run(rtt, StaticWindowPolicy(4), pipeline=True)
        s_fused, _, _ = run(rtt, OracleStaticPolicy(1, fused=True))
        fused_frac = (sum(m == "fused" for m in modes) / len(modes)
                      if modes else 0.0)
        rows.append({
            "rtt_ms": rtt,
            "awc_mean_gamma": round(float(np.mean(gam)), 3) if gam else 0.0,
            "awc_fused_fraction": round(fused_frac, 4),
            "static4_tokens_per_s": round(s_dist["token_throughput_tps"], 2),
            "static4_pipelined_tokens_per_s":
                round(s_pipe["token_throughput_tps"], 2),
            "fused_tokens_per_s": round(s_fused["token_throughput_tps"], 2),
        })
    return rows


def two_pair_spec(B: int, max_new: int, sync_every: int,
                  seed: int) -> "topo.ClusterSpec":
    """The heterogeneous 2-pair topology: one cloud target serving a fast
    LAN edge draft AND a slow WAN edge draft, AWC window control per pair.
    ONE spec drives both the real deployment and the sim parity column."""
    return topo.ClusterSpec(
        nodes=[
            topo.NodeSpec("edge-lan", "draft", "bench-dist-target",
                          device="edge-nic", sim_model="llama2-7b"),
            topo.NodeSpec("edge-wan", "draft", "bench-dist-target",
                          device="edge-lte", sim_model="llama2-7b"),
            # llama2-7b@A100/tp1 keeps the sim target's per-step service
            # time in the same regime as the real tiny model's TPOT (the
            # RTT/TPOT ratio positions the operating point, not absolute
            # hardware speed) — the same calibration sim_parity uses
            topo.NodeSpec("cloud", "target", "bench-dist-target",
                          hw="A100", sim_model="llama2-7b", tp=1),
        ],
        pairs=[
            topo.PairSpec("lan", "edge-lan", "cloud",
                          link=LinkSpec(rtt_ms=2.0, jitter_ms=0.3),
                          window=topo.WindowSpec("awc")),
            # WAN at 150 ms: once the RTT/TPOT ratio is this lopsided the
            # WC-DNN prefers fused across the whole α band the arm's
            # draft operates in, so per-pair divergence is robust to
            # host-speed noise in the measured-TPOT feature
            topo.PairSpec("wan", "edge-wan", "cloud",
                          link=LinkSpec(rtt_ms=150.0, jitter_ms=5.0),
                          window=topo.WindowSpec("awc")),
        ],
        serving=topo.ServingSpec(max_batch=B, gamma_max=GAMMA_MAX,
                                 sync_every=sync_every, temperature=0.0),
        workload=topo.WorkloadSpec(num_requests=4 * B, max_new=max_new),
        seed=seed)


def run_two_pair_arm(tparams, B: int, max_new: int,
                     prompt_len: int, sync_every: int, seed: int) -> dict:
    """Serve one request stream through a heterogeneous 2-pair deployment
    (fast LAN pair + slow WAN pair, one shared cloud target) built from a
    single ClusterSpec, and replay the arm's own captured acceptance
    traces through ``build_simulation`` on the IDENTICAL spec.

    What the redesign promises and this arm checks: per-pair AWC
    stabilizers converge to DIFFERENT γ/fused operating points in one
    serve run (the WAN pair collapses toward fused / small γ while the
    LAN pair keeps speculating), and the sim column agrees on the
    per-pair ordering.

    The arm's draft uses noise 0.012 (α ≈ 0.75): the WC-DNN's decisions
    are RTT-sensitive across that whole acceptance band, whereas at
    α ≳ 0.9 it saturates to γ_max regardless of moderate RTT — the arm
    must probe link heterogeneity, not acceptance saturation."""
    from repro.serving import ServeRequest

    spec = two_pair_spec(B, max_new, sync_every, seed)
    dparams = noised_draft_params(tparams, 0.012, seed=43)
    dep = topo.build_deployment(
        spec, model_configs={"bench-dist-target": TARGET},
        node_params={"edge-lan": dparams, "edge-wan": dparams,
                     "cloud": tparams})
    rng = np.random.default_rng(seed)
    warm_prompts = rng.integers(0, TARGET.vocab,
                                (B, prompt_len)).astype(np.int32)
    # warm every split-worker program at the SERVING session geometry
    # before the measured run: a compile landing inside a served chunk
    # would pollute the AWC TPOT feature for most of the short stream.
    # The warmup doubles as the trace capture for the sim parity column
    # (same params, zero-delay transport).
    seqs = None
    for eng in {id(p.engine): p.engine for p in dep.pairs}.values():
        _, wstats = eng.generate(
            warm_prompts, max_new, StaticWindowPolicy(4),
            gamma_max=GAMMA_MAX, sync_every=sync_every,
            key=jax.random.PRNGKey(seed), transport=InProcessTransport())
        seqs = wstats.acceptance_seqs
        eng.generate(warm_prompts, max_new, StaticWindowPolicy(4),
                     gamma_max=GAMMA_MAX, sync_every=sync_every,
                     transport=InProcessTransport(), mode_policy="fused")
    server = dep.build_server()
    wl = spec.workload
    for i in range(wl.num_requests):
        prompt = rng.integers(0, TARGET.vocab, prompt_len).astype(np.int32)
        server.submit(ServeRequest(i, prompt, wl.max_new))
    t0 = time.perf_counter()
    results = server.run()
    wall_s = time.perf_counter() - t0
    pairs = server.pair_summaries()

    # -- sim parity from the IDENTICAL spec -------------------------------
    records = []
    rid = 0
    for pair_idx in range(len(spec.pairs)):
        for wave in range(2):
            for b in range(B):
                records.append(TraceRecord(
                    request_id=rid, prompt_length=prompt_len,
                    output_length=wl.max_new,
                    acceptance_seq=seqs[b % B],
                    arrival_time_ms=float(wave),
                    drafter_id=pair_idx,
                    dataset="bench_two_pair"))
                rid += 1
    an = topo.build_simulation(spec, records).run()
    sim_pairs = {}
    for pid_idx, p in enumerate(spec.pairs):
        gam, modes = [], []
        for m in an.requests.values():
            if m.drafter_id == pid_idx:
                gam.extend(m.gamma_sequence)
                modes.extend(m.mode_sequence)
        sim_pairs[p.id] = {
            "mean_gamma": round(float(np.mean(gam)), 3) if gam else 0.0,
            "fused_fraction": round(
                sum(md == "fused" for md in modes) / len(modes), 4)
            if modes else 0.0,
        }

    def diverges(d: dict) -> bool:
        return (d["wan"]["fused_fraction"] > d["lan"]["fused_fraction"]
                or d["wan"]["mean_gamma"] < d["lan"]["mean_gamma"])

    lan_tr = next(p.transport for p in dep.pairs if p.pair_id == "lan")
    wan_tr = next(p.transport for p in dep.pairs if p.pair_id == "wan")
    return {
        "spec": spec.to_dict(),
        "requests": len(results),
        "wall_s": round(wall_s, 3),
        "pairs": pairs,
        "sim_pairs": sim_pairs,
        "checks": {
            "both_pairs_served": (pairs["lan"]["requests"] > 0
                                  and pairs["wan"]["requests"] > 0),
            "measured_rtt_ordering": (wan_tr.recent_rtt_ms
                                      > lan_tr.recent_rtt_ms),
            "awc_pairs_diverge": diverges(pairs),
            "sim_same_pair_ordering": diverges(sim_pairs),
        },
    }


def two_pair_procs_spec(B: int, max_new: int, sync_every: int,
                        seed: int, rtt_ms: float = 60.0) -> "topo.ClusterSpec":
    """Homogeneous 2-pair PROCESS topology: two edge drafts sharing one
    cloud target model, equal links, static γ, every pair in its own
    draft+target process pair over SocketTransports. Equal links make the
    parallelism win unambiguous: a single-threaded interleaved server
    must serialize both pairs' link waits, processes overlap them."""
    return topo.ClusterSpec(
        nodes=[
            topo.NodeSpec("edge-a", "draft", "bench-dist-target"),
            topo.NodeSpec("edge-b", "draft", "bench-dist-target"),
            topo.NodeSpec("cloud", "target", "bench-dist-target"),
        ],
        pairs=[
            topo.PairSpec("proc-a", "edge-a", "cloud",
                          link=LinkSpec(rtt_ms=rtt_ms, jitter_ms=0.0),
                          window=topo.WindowSpec("static", 4),
                          mode_policy="distributed", process=True),
            topo.PairSpec("proc-b", "edge-b", "cloud",
                          link=LinkSpec(rtt_ms=rtt_ms, jitter_ms=0.0),
                          window=topo.WindowSpec("static", 4),
                          mode_policy="distributed", process=True),
        ],
        serving=topo.ServingSpec(max_batch=B, gamma_max=4,
                                 sync_every=sync_every, temperature=0.0,
                                 server="continuous", max_new_cap=max_new),
        workload=topo.WorkloadSpec(num_requests=2 * B, max_new=max_new),
        seed=seed)


def run_two_pair_procs_arm(B: int, max_new: int, prompt_len: int,
                           sync_every: int, seed: int) -> dict:
    """The truly-parallel arm: serve one request stream through two
    process-backed pairs (4 worker processes, every round a framed
    window/verdict exchange over TCP), then the IDENTICAL topology with
    ``process: false`` through the single-threaded interleaved server.

    Checks: committed greedy tokens are bit-identical across the process
    boundary (the hosts rebuild params from the spec seed), and the
    aggregate tokens/s of the parallel arm clears 1.5× the interleaved
    baseline — the two pairs' link waits overlap instead of serializing.
    Each arm serves the stream twice and measures the second pass, so
    compiles (guarded to wave 0 inside the hosts by the recompile sentry)
    stay out of the measured window."""
    import dataclasses

    from repro.serving import ServeRequest

    spec = two_pair_procs_spec(B, max_new, sync_every, seed)
    rng = np.random.default_rng(seed)
    reqs = [(i, rng.integers(0, TARGET.vocab, prompt_len).astype(np.int32))
            for i in range(spec.workload.num_requests)]

    def serve(s):
        dep = topo.build_deployment(
            s, model_configs={"bench-dist-target": TARGET})
        try:
            results, wall = [], 0.0
            for _ in range(2):          # warm pass, then the measured pass
                srv = dep.build_server()
                for i, p in reqs:
                    srv.submit(ServeRequest(i, p, max_new))
                t0 = time.perf_counter()
                results = srv.run()
                wall = time.perf_counter() - t0
            return results, wall, srv.pair_summaries()
        finally:
            dep.shutdown()

    procs_res, procs_wall, procs_pairs = serve(spec)
    base_spec = dataclasses.replace(
        spec, pairs=[dataclasses.replace(p, process=False)
                     for p in spec.pairs])
    base_res, base_wall, _ = serve(base_spec)

    got = {r.request_id: r.tokens for r in procs_res}
    ref = {r.request_id: r.tokens for r in base_res}
    tokens_match = (set(got) == set(ref)
                    and all(np.array_equal(got[k], ref[k]) for k in ref))
    procs_tps = sum(len(t) for t in got.values()) / max(1e-9, procs_wall)
    base_tps = sum(len(t) for t in ref.values()) / max(1e-9, base_wall)
    speedup = procs_tps / max(1e-9, base_tps)
    return {
        "spec": spec.to_dict(),
        "requests": len(procs_res),
        "procs_wall_s": round(procs_wall, 3),
        "interleaved_wall_s": round(base_wall, 3),
        "procs_tokens_per_s": round(procs_tps, 2),
        "interleaved_tokens_per_s": round(base_tps, 2),
        "aggregate_speedup": round(speedup, 3),
        "pairs": procs_pairs,
        "checks": {
            "tokens_match_across_arms": bool(tokens_match),
            "both_pairs_served": all(
                procs_pairs[p]["requests"] > 0 for p in ("proc-a", "proc-b")),
            "aggregate_speedup_ok": bool(speedup >= 1.5),
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4,
                    help="batch rows decoded per cell")
    ap.add_argument("--max-new", type=int, default=96,
                    help="tokens per request — long enough for the AWC "
                         "stabilizer (EMA + hysteresis) to converge on the "
                         "link it observes")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--sync-every", type=int, default=4,
                    help="feature-update granularity; small so AWC sees "
                         "measured rtt/tpot early in each session, but ≥ 4 "
                         "so the pipelined arm can overlap most rounds "
                         "(in-flight speculation never crosses a chunk "
                         "boundary, so a chunk's last round is unpipelined)")
    ap.add_argument("--draft-noise", type=float, default=0.004,
                    help="draft = target + noise·std per tensor (0.004 → "
                         "α ≈ 0.9: the regime where both the low-RTT "
                         "distributed win and the pipelined overlap are "
                         "observable)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-lane variant (RTT {0,20}, fewer tokens); "
                         "exit nonzero iff the zero-delay transport is not "
                         "bit-identical to the colocated path")
    ap.add_argument("--no-procs", dest="procs", action="store_false",
                    default=True,
                    help="skip the process-backed 2-pair arm (4 worker "
                         "subprocesses)")
    ap.add_argument("--procs-only", action="store_true",
                    help="run ONLY the process-backed 2-pair arm: draft + "
                         "target hosts as subprocesses over socket pairs, "
                         "gated on cross-process bit-identity and the "
                         "≥1.5× aggregate-throughput win")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_distributed.json"))
    args = ap.parse_args(argv)

    if args.procs_only:
        B, mn = (2, 16) if args.smoke else (args.requests,
                                           min(args.max_new, 32))
        procs = run_two_pair_procs_arm(B, mn, args.prompt_len,
                                       args.sync_every, args.seed)
        out = {"bench": "distributed_two_pair_procs",
               "config": {"max_batch": B, "max_new": mn,
                          "prompt_len": args.prompt_len,
                          "sync_every": args.sync_every, "smoke": args.smoke,
                          "backend": jax.default_backend(),
                          "jax": jax.__version__,
                          "platform": platform.platform()},
               "two_pair_procs": procs}
        Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
        print(json.dumps(out, indent=2))
        ok = all(procs["checks"].values())
        print(f"\ntwo_pair_procs={procs['checks']}  ok={ok}")
        return 0 if ok else 1

    if args.smoke:
        rtts = (0.0, 20.0)
        policies = ("static-4", "awc", "fused", "pipeline")
        n_req, max_new = 2, 8
    else:
        rtts = RTTS
        policies = ("static-4", "dynamic", "awc", "fused", "pipeline")
        n_req, max_new = args.requests, args.max_new

    tm = build_model(TARGET)
    tparams = tm.init_params(jax.random.PRNGKey(args.seed))
    dparams = noised_draft_params(tparams, args.draft_noise)
    engine = SpecDecodeEngine(TARGET, TARGET, draft_params=dparams,
                              target_params=tparams, temperature=0.0,
                              gamma_max=GAMMA_MAX,
                              sync_every=args.sync_every,
                              key=jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, TARGET.vocab,
                           (n_req, args.prompt_len)).astype(np.int32)

    # warmup: compile every program (colocated step + split workers +
    # fused-round ingest) before any measured cell
    engine.generate(prompts, max_new, StaticWindowPolicy(4),
                    gamma_max=GAMMA_MAX, sync_every=args.sync_every,
                    transport=InProcessTransport())
    # fused warmup must use the CELL geometry: dw_ingest's trace depends
    # on the chunk shape, so a shorter warmup would leave one program to
    # compile inside the guarded grid
    engine.generate(prompts, max_new, StaticWindowPolicy(4),
                    gamma_max=GAMMA_MAX, sync_every=args.sync_every,
                    transport=InProcessTransport(), mode_policy="fused")
    bit_identical = bit_identity_gate(engine, prompts, max_new,
                                      args.sync_every)

    # every program was warmed above: the whole measured RTT×policy grid
    # must run compile-free (adaptive γ / mode flips are traced, not
    # recompiled)
    cells = []
    with compile_guard(allowed=None, what="measured RTT×policy cells",
                       track=[engine]) as cg:
        for rtt in rtts:
            for pol in policies:
                cells.append(run_cell(engine, prompts, max_new,
                                      args.sync_every, pol, rtt, args.seed))

    def cell(pol, rtt):
        return next(c for c in cells
                    if c["policy"] == pol and c["rtt_ms"] == rtt)

    # acceptance traces from the colocated run feed the sim parity column
    _, tr_stats = engine.generate(prompts, max_new, StaticWindowPolicy(4),
                                  gamma_max=GAMMA_MAX,
                                  sync_every=args.sync_every,
                                  key=jax.random.PRNGKey(args.seed))
    sim_rows = sim_parity(prompts, tr_stats.acceptance_seqs, max_new, rtts,
                          args.seed)

    # heterogeneous 2-pair topology arm: fast LAN + slow WAN pair under
    # one server, real deployment and sim built from ONE ClusterSpec
    two_pair = run_two_pair_arm(tparams, n_req, max_new,
                                args.prompt_len, args.sync_every,
                                args.seed)

    # truly-parallel arm: the same 2-pair shape with every pair in its own
    # draft+target process pair over framed TCP streams, vs the identical
    # topology interleaved on one thread
    two_pair_procs = None
    if args.procs:
        B_p, mn_p = (2, 16) if args.smoke else (n_req, min(max_new, 32))
        two_pair_procs = run_two_pair_procs_arm(
            B_p, mn_p, args.prompt_len, args.sync_every, args.seed)

    lo, hi = rtts[0], rtts[-1]
    mid = 20.0 if 20.0 in rtts else hi
    awc_lo, awc_hi = cell("awc", lo), cell("awc", hi)
    # the closed loop: AWC on the real path reacts to the link. Judged at
    # the TOP of the RTT grid — mid-grid operating points are legitimately
    # host-speed-dependent (the controller weighs the measured RTT against
    # the measured TPOT), but at the grid top the RTT dominates any
    # plausible host's step time.
    awc_adapts = (awc_hi["fused_fraction"] > awc_lo["fused_fraction"]
                  or awc_hi["mean_gamma"] < awc_lo["mean_gamma"])
    dist_falls = (cell("static-4", hi)["tokens_per_s"]
                  < cell("static-4", lo)["tokens_per_s"])
    # fused is RTT-insensitive in comparison (paper fig. 6)
    fused_ratio = (cell("fused", hi)["tokens_per_s"]
                   / max(1e-9, cell("fused", lo)["tokens_per_s"]))
    # cross-round pipelining must win wherever the RTT clears compute.
    # "Clears compute" is MACHINE-RELATIVE (pipelining pays off when RTT
    # ≳ the target step time — README §pipelined speculation): gate at
    # RTTs ≥ 2× the measured colocated per-iteration time, floored at the
    # 20 ms the reference machine crossed at, so a slower host doesn't
    # fail the bench at an RTT its own compute time still hides.
    c0 = cell("static-4", lo)
    per_iter_ms = 1e3 * c0["decode_wall_s"] / max(1, c0["iterations"])
    pipeline_gate_rtt = max(20.0, 2.0 * per_iter_ms)
    pipeline_beats_hd = all(
        cell("pipeline", rtt)["tokens_per_s"]
        > cell("static-4", rtt)["tokens_per_s"]
        for rtt in rtts if rtt >= pipeline_gate_rtt)
    sim_lo = next(r for r in sim_rows if r["rtt_ms"] == lo)
    sim_hi = next(r for r in sim_rows if r["rtt_ms"] == hi)
    sim_pipeline_ordering = all(
        r["static4_pipelined_tokens_per_s"] > r["static4_tokens_per_s"]
        for r in sim_rows if r["rtt_ms"] >= 20.0)
    sim_awc_adapts = (sim_hi["awc_fused_fraction"]
                      > sim_lo["awc_fused_fraction"]
                      or sim_hi["awc_mean_gamma"] < sim_lo["awc_mean_gamma"])
    sim_crossover = (sim_lo["static4_tokens_per_s"]
                     > sim_lo["fused_tokens_per_s"]
                     and sim_hi["fused_tokens_per_s"]
                     > sim_hi["static4_tokens_per_s"])

    out = {
        "bench": "distributed_rtt_gamma_crossover",
        "config": {"requests": n_req, "max_new": max_new,
                   "prompt_len": args.prompt_len, "gamma_max": GAMMA_MAX,
                   "sync_every": args.sync_every,
                   "draft_noise": args.draft_noise, "rtts_ms": list(rtts),
                   "policies": list(policies), "smoke": args.smoke,
                   "model": TARGET.name,
                   "backend": jax.default_backend(),
                   "jax": jax.__version__,
                   "platform": platform.platform()},
        "bit_identical_zero_delay": bit_identical,
        "cells": cells,
        "sim_parity": sim_rows,
        "two_pair": two_pair,
        "two_pair_procs": two_pair_procs,
        "checks": {
            "recompiles_during_cells": cg.count,
            "zero_recompiles_during_cells": cg.count == 0,
            "awc_adapts_to_link": awc_adapts,
            "distributed_throughput_falls_with_rtt": dist_falls,
            "fused_rtt_insensitive_ratio": round(fused_ratio, 3),
            "pipeline_gate_rtt_ms": round(pipeline_gate_rtt, 1),
            "pipeline_beats_half_duplex_at_gate_rtts": pipeline_beats_hd,
            "sim_pipeline_same_ordering": sim_pipeline_ordering,
            "sim_awc_adapts": sim_awc_adapts,
            "sim_shows_crossover": sim_crossover,
            "sim_real_qualitative_match": bool(awc_adapts
                                               and sim_awc_adapts),
            "two_pair_awc_diverges": two_pair["checks"]["awc_pairs_diverge"],
            "two_pair_sim_same_ordering":
                two_pair["checks"]["sim_same_pair_ordering"],
            "two_pair_procs": (two_pair_procs["checks"]
                               if two_pair_procs else "skipped"),
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    # smoke: too few tokens for operating points to converge — gate on the
    # bit-identity anchors plus the 2-pair arm running end to end with
    # physically-ordered measured RTTs. Full runs additionally gate the
    # per-pair AWC divergence and the sim's per-pair ordering agreement.
    two_ok_smoke = (two_pair["checks"]["both_pairs_served"]
                    and two_pair["checks"]["measured_rtt_ordering"])
    two_ok = (two_ok_smoke
              and two_pair["checks"]["awc_pairs_diverge"]
              and two_pair["checks"]["sim_same_pair_ordering"])
    no_recompiles = cg.count == 0
    procs_ok = (all(two_pair_procs["checks"].values())
                if two_pair_procs else True)
    ok = ((bit_identical and two_ok_smoke and no_recompiles and procs_ok)
          if args.smoke
          else (bit_identical and awc_adapts and dist_falls
                and pipeline_beats_hd and two_ok and no_recompiles
                and procs_ok))
    print(f"\nbit_identical={bit_identical}  awc_adapts={awc_adapts}  "
          f"dist_falls={dist_falls}  pipeline_beats_hd={pipeline_beats_hd}  "
          f"sim_match={sim_awc_adapts}  "
          f"two_pair={two_pair['checks']}  "
          f"procs={two_pair_procs['checks'] if two_pair_procs else 'skipped'}"
          f"  ok={ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
