"""Benchmark runner — one module per paper table/figure (deliverable d).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig5,table2]

Prints ``name,value,derived`` CSV rows. Quick mode (default) uses scaled
clusters/seed counts so the whole suite finishes in minutes on CPU; --full
runs the paper-scale 20-target/600-2000-drafter configurations.
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (eq12_analytic, fig4_calibration, fig5_policy_stacks,
               fig6_rtt_crossover, fig7_8_routing, fig9_10_batching,
               roofline, table2_awc)

MODULES = {
    "eq12": eq12_analytic,
    "fig4": fig4_calibration,
    "fig5": fig5_policy_stacks,
    "fig6": fig6_rtt_crossover,
    "table2": table2_awc,
    "fig7_8": fig7_8_routing,
    "fig9_10": fig9_10_batching,
    "roofline": roofline,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    names = args.only.split(",") if args.only else list(MODULES)
    print("name,value,derived")
    rc = 0
    for name in names:
        mod = MODULES[name]
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
        except Exception as e:  # pragma: no cover
            print(f"{name}_ERROR,nan,{type(e).__name__}: {e}")
            rc = 1
            continue
        for rname, val, note in rows:
            note = str(note).replace(",", ";")
            print(f"{rname},{val},{note}")
        print(f"{name}_wall_s,{time.time()-t0:.1f},", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
