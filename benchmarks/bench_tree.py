"""Tree-speculation benchmark: multi-branch grid drafts vs the linear
chain on the REAL engine, at equal α and equal target passes.

The draft is a noise-perturbed copy of the target (``--draft-noise``,
default 0.05 → α ≈ 0.45): low enough that the greedy chain breaks early
often, which is exactly the regime tree speculation buys back — when the
primary root is rejected, an alternative top-k root (plus its chain) can
still commit. Every cell decodes the same prompts for the same budget, so
the comparison is committed tokens PER TARGET PASS (each speculation
round is one verify pass on either path) at the same acceptance rate.

Gates (CI runs ``--smoke``; all three must hold or the run exits 1):

- **speedup** — the (γ=4, b=3) tree commits ≥ 1.15× the linear chain's
  tokens per target pass;
- **zero recompiles** — after the (γ_max, b_max) tree program compiles,
  per-round (γ, branches) decisions sweep the whole grid family without
  adding a single XLA program (``engine.compiled_programs()`` flat);
- **degenerate bit-identity** — a max_branches=1 tree session commits
  EXACTLY the linear engine's greedy tokens.

The sim-parity column reports the analytic
:func:`repro.core.tree.tree_expected_accepted` prediction (fed the
linear run's measured α) next to each cell's measured tokens/pass — the
same model DSD-Sim's tree acceptance replay and the AWC joint {γ, b}
policy use, so the column shows the controller sees the ordering the
real path realizes.

    PYTHONPATH=src python benchmarks/bench_tree.py [--smoke] \
        [--max-new 48] [--batch 4] [--draft-noise 0.05] [--out ...]

Writes BENCH_tree.json (repo root by default).
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import compile_guard
from repro.configs.base import ModelConfig
from repro.core.engine import SpecDecodeEngine
from repro.core.session import DecodeSession
from repro.core.tree import tree_expected_accepted
from repro.core.window import StaticWindowPolicy
from repro.models.model import build_model

CFG = ModelConfig(name="bench-tree", arch_type="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  dtype="float32", remat=False)
GAMMA = 4
GAMMA_MAX = 6
B_MAX = 4


def noised_draft_params(target_params, scale: float, seed: int = 42):
    """Draft = target + N(0, (scale·std)²) per tensor: same architecture,
    controllably-degraded predictions → tunable acceptance rate."""
    leaves, treedef = jax.tree.flatten(target_params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        if isinstance(leaf, jax.Array) and leaf.ndim > 0:
            leaf = leaf + scale * jnp.std(leaf) * jax.random.normal(
                k, leaf.shape, leaf.dtype)
        out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def make_engine(noise: float, seed: int = 0) -> SpecDecodeEngine:
    tparams = build_model(CFG).init_params(jax.random.PRNGKey(seed))
    return SpecDecodeEngine(CFG, CFG,
                            draft_params=noised_draft_params(tparams, noise),
                            target_params=tparams, temperature=0.0,
                            key=jax.random.PRNGKey(seed))


def run_cell(engine, prompts, max_new: int, max_branches: int,
             policies) -> dict:
    """One decode of ``prompts`` through a session at the given tree
    bound, cycling ``policies`` chunk by chunk (a single StaticWindowPolicy
    for the plain cells; the recompile gate passes the whole (γ, b) sweep).
    Returns tokens, passes (= speculation rounds = target passes) and the
    committed token matrix."""
    sess = DecodeSession(engine, capacity=prompts.shape[0],
                         max_new_cap=max_new, gamma_max=GAMMA_MAX,
                         sync_every=4, mode_policy="distributed",
                         max_branches=max_branches,
                         key=jax.random.PRNGKey(0))
    sess.admit_batch(prompts, max_new)
    t0 = time.perf_counter()
    i = 0
    while sess.unfinished:
        sess.run_chunk(policies[i % len(policies)])
        i += 1
    wall = time.perf_counter() - t0
    tokens, stats = sess.snapshot()
    # per-REQUEST tokens per pass (every pass serves the whole batch), so
    # the number is directly comparable to the per-request analytic model
    tpp = stats.tokens / max(1, sess.iterations) / prompts.shape[0]
    return {"tokens": tokens, "n_tokens": int(stats.tokens),
            "passes": int(sess.iterations), "tokens_per_pass": tpp,
            "alpha": stats.acceptance_rate, "wall_s": wall}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--draft-noise", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; exit nonzero if any gate fails")
    args = ap.parse_args(argv)
    if args.smoke:
        args.max_new = min(args.max_new, 32)
        args.batch = min(args.batch, 4)

    engine = make_engine(args.draft_noise, args.seed)
    prompts = np.random.default_rng(args.seed + 1).integers(
        0, CFG.vocab, (args.batch, 9)).astype(np.int32)

    # -- linear baseline + tree cells at equal α / equal passes ------------
    lin = run_cell(engine, prompts, args.max_new, 0,
                   [StaticWindowPolicy(GAMMA)])
    alpha = lin["alpha"]
    cells = []
    for b in range(2, B_MAX + 1):
        cell = run_cell(engine, prompts, args.max_new, b,
                        [StaticWindowPolicy(GAMMA, branches=b)])
        cells.append({
            "gamma": GAMMA, "branches": b,
            "tokens_per_pass": round(cell["tokens_per_pass"], 3),
            "passes": cell["passes"],
            "speedup_vs_linear":
                round(cell["tokens_per_pass"] / lin["tokens_per_pass"], 3),
            # sim parity: analytic committed/pass at the LINEAR run's α —
            # what the AWC {γ, b} policy and DSD-Sim's replay predict
            "sim_tokens_per_pass":
                round(1.0 + tree_expected_accepted(alpha, GAMMA, b), 3),
        })
    sim_lin = 1.0 + tree_expected_accepted(alpha, GAMMA, 1)

    # -- gate 1: tree ≥ 1.15× linear tokens/target pass at b=3 -------------
    gate_cell = next(c for c in cells if c["branches"] == 3)
    speedup_ok = gate_cell["speedup_vs_linear"] >= 1.15

    # -- gate 2: zero recompiles across per-round tree shapes --------------
    # warm the (GAMMA_MAX, B_MAX) program, then sweep every (γ, b) shape
    # in ONE session, chunk by chunk: the program count must not move.
    warm = run_cell(engine, prompts, args.max_new, B_MAX,
                    [StaticWindowPolicy(GAMMA, branches=B_MAX)])
    sweep = [StaticWindowPolicy(g, branches=b)
             for g in range(1, GAMMA_MAX + 1)
             for b in range(1, B_MAX + 1)]
    with compile_guard(allowed=None, what="(γ, b) shape sweep",
                       track=[engine]) as guard:
        run_cell(engine, prompts, args.max_new, B_MAX, sweep)
    recompiles = guard.count
    recompile_ok = recompiles == 0

    # -- gate 3: degenerate 1-branch tree ≡ linear engine ------------------
    degen = run_cell(engine, prompts, args.max_new, 1,
                     [StaticWindowPolicy(GAMMA, branches=1)])
    degenerate_ok = bool(np.array_equal(lin["tokens"], degen["tokens"]))

    report = {
        "bench": "tree", "smoke": args.smoke,
        "host": platform.node(), "backend": jax.default_backend(),
        "config": {"max_new": args.max_new, "batch": args.batch,
                   "draft_noise": args.draft_noise, "gamma": GAMMA,
                   "gamma_max": GAMMA_MAX, "b_max": B_MAX,
                   "vocab": CFG.vocab},
        "alpha_measured": round(alpha, 4),
        "linear": {"tokens_per_pass": round(lin["tokens_per_pass"], 3),
                   "passes": lin["passes"],
                   "sim_tokens_per_pass": round(sim_lin, 3)},
        "tree_cells": cells,
        "checks": {
            "tree_speedup_b3": gate_cell["speedup_vs_linear"],
            "tree_speedup_ok": bool(speedup_ok),
            "recompiles_across_shapes": int(recompiles),
            "zero_recompile_ok": bool(recompile_ok),
            "degenerate_bit_identical": degenerate_ok,
        },
    }
    out = Path(args.out) if args.out else \
        Path(__file__).resolve().parent.parent / "BENCH_tree.json"
    out.write_text(json.dumps(report, indent=1) + "\n")
    print(json.dumps(report["checks"], indent=1))
    print(f"wrote {out}")

    ok = speedup_ok and recompile_ok and degenerate_ok
    if not ok:
        print("TREE BENCH GATE FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
