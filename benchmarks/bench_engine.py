"""Decode-loop benchmark: compile count, tokens/s and per-iteration wall
time for static-γ vs adaptive-γ (AWC-style per-iteration varying) workloads
on the real-model engine — the first point in the repo's perf trajectory.

The engine compiles ONE masked-window step at gamma_max; an adaptive
workload that changes γ every iteration must hold tokens/s within a few
percent of the static workload (the seed engine instead paid a full XLA
compile for every new γ).

    PYTHONPATH=src python benchmarks/bench_engine.py \
        [--batch 4] [--max-new 48] [--gamma-max 8] [--repeats 3] [--out ...]

Writes BENCH_engine.json (repo root by default).
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from repro.analysis import compile_guard
from repro.configs.base import ModelConfig
from repro.core.engine import SpecDecodeEngine
from repro.core.session import DecodeSession
from repro.core.window import FeatureSnapshot, StaticWindowPolicy, WindowDecision

DRAFT = ModelConfig(name="bench-draft", arch_type="dense", n_layers=2,
                    d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                    vocab=512, dtype="float32", remat=False)
TARGET = ModelConfig(name="bench-target", arch_type="dense", n_layers=4,
                     d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                     vocab=512, dtype="float32", remat=False)


class CyclingWindowPolicy:
    """Adaptive-γ workload: a different γ every iteration (AWC-style)."""

    def __init__(self, gmax: int):
        self.gmax = gmax
        self._i = 0

    def decide(self, pair_key: str, feats: FeatureSnapshot) -> WindowDecision:
        g = 1 + (self._i % self.gmax)
        self._i += 1
        return WindowDecision(g, "distributed")

    def gamma_bound(self) -> int:
        return self.gmax

    def name(self) -> str:
        return f"cycling-{self.gmax}"


def run_workload(engine: SpecDecodeEngine, prompts, max_new: int,
                 make_policy, gamma_max: int, repeats: int) -> dict:
    # warmup: pays the (single) compile
    c0 = engine.compiled_programs()
    t0 = time.perf_counter()
    engine.generate(prompts, max_new, make_policy(), gamma_max=gamma_max)
    warmup_s = time.perf_counter() - t0
    compiles = engine.compiled_programs() - c0

    decode_s, tokens, iters, per_iter_ms = [], 0, 0, []
    with compile_guard(allowed=None, what="post-warmup repeats",
                       track=[engine]) as guard:
        for _ in range(repeats):
            _, stats = engine.generate(prompts, max_new, make_policy(),
                                       gamma_max=gamma_max)
            d = stats.wall_s - stats.prefill_s
            decode_s.append(d)
            tokens += stats.tokens
            iters += stats.iterations
            per_iter_ms.append(d * 1e3 / max(1, stats.iterations))
    recompiles = guard.count
    total_decode = sum(decode_s)
    return {
        "warmup_s": round(warmup_s, 4),
        "compiles": compiles,
        "recompiles_after_warmup": recompiles,
        "repeats": repeats,
        "decode_s": round(total_decode, 4),
        "tokens": tokens,
        "iterations": iters,
        "tokens_per_s": round(tokens / max(1e-9, total_decode), 2),
        "per_iteration_ms": round(float(np.mean(per_iter_ms)), 4),
    }


def run_session_workload(engine: SpecDecodeEngine, prompts, max_new: int,
                         gamma: int, repeats: int, paged: bool) -> dict:
    """Static-γ decode through a DecodeSession slot pool — dense per-slot
    rows vs the paged KV block pool at identical occupancy, so the paged
    arm's tokens/s is directly comparable to the dense arm's."""
    B, P = prompts.shape

    def one_pass():
        sess = DecodeSession(engine, capacity=B, max_new_cap=max_new,
                             max_prompt_len=P, gamma_max=gamma,
                             key=jax.random.PRNGKey(0), log_gamma=False,
                             paged=paged)
        pol = StaticWindowPolicy(gamma)
        for i in range(B):
            sess.admit(prompts[i], max_new, request_id=i)
        while sess.unfinished:
            sess.run_chunk(pol)
        tokens, _ = sess.snapshot()
        produced = sum(len(t[t >= 0]) for t in tokens) - B
        return produced, sess.decode_wall_s

    c0 = engine.compiled_programs()
    one_pass()                               # warmup: pays the compiles
    compiles = engine.compiled_programs() - c0
    tokens = 0
    decode_s = 0.0
    with compile_guard(allowed=None, what="post-warmup session repeats",
                       track=[engine]) as g:
        for _ in range(repeats):
            t, d = one_pass()
            tokens += t
            decode_s += d
    return {
        "compiles": compiles,
        "recompiles_after_warmup": g.count,
        "repeats": repeats,
        "decode_s": round(decode_s, 4),
        "tokens": tokens,
        "tokens_per_s": round(tokens / max(1e-9, decode_s), 2),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--gamma-max", type=int, default=8)
    ap.add_argument("--static-gamma", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_engine.json"))
    args = ap.parse_args(argv)

    engine = SpecDecodeEngine(DRAFT, TARGET, temperature=0.0,
                              gamma_max=args.gamma_max,
                              key=jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, TARGET.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)

    results = {
        "static": run_workload(
            engine, prompts, args.max_new,
            lambda: StaticWindowPolicy(args.static_gamma),
            args.gamma_max, args.repeats),
        "adaptive": run_workload(
            engine, prompts, args.max_new,
            lambda: CyclingWindowPolicy(args.gamma_max),
            args.gamma_max, args.repeats),
        "session_dense": run_session_workload(
            engine, prompts, args.max_new, args.static_gamma, args.repeats,
            paged=False),
        "paged": run_session_workload(
            engine, prompts, args.max_new, args.static_gamma, args.repeats,
            paged=True),
    }
    ratio = (results["adaptive"]["tokens_per_s"] /
             max(1e-9, results["static"]["tokens_per_s"]))
    paged_ratio = (results["paged"]["tokens_per_s"] /
                   max(1e-9, results["session_dense"]["tokens_per_s"]))
    out = {
        "bench": "engine_decode_loop",
        "config": {"batch": args.batch, "prompt_len": args.prompt_len,
                   "max_new": args.max_new, "gamma_max": args.gamma_max,
                   "static_gamma": args.static_gamma,
                   "draft": DRAFT.name, "target": TARGET.name,
                   "backend": jax.default_backend(),
                   "jax": jax.__version__,
                   "platform": platform.platform()},
        "workloads": results,
        "adaptive_over_static_tokens_per_s": round(ratio, 4),
        "paged_over_dense_tokens_per_s": round(paged_ratio, 4),
        "compile_once": (results["adaptive"]["compiles"] <= 1 and
                         results["adaptive"]["recompiles_after_warmup"] == 0
                         and results["paged"]["recompiles_after_warmup"]
                         == 0),
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    print(f"\nadaptive/static tokens/s = {ratio:.3f}  "
          f"(adaptive compiles: {results['adaptive']['compiles']}, "
          f"recompiles after warmup: "
          f"{results['adaptive']['recompiles_after_warmup']})")
    print(f"paged/dense session tokens/s = {paged_ratio:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
