"""Figs. 9–10 — FIFO vs Length-Aware Batching (LAB).

Paper: LAB lowers TPOT by 1–2 ms across workloads (less padding /
head-of-line blocking); both hit the same throughput ceiling once compute
saturates.
"""

from __future__ import annotations

from .common import DATASETS, mean_over_seeds, run_scenario


def run(quick: bool = True):
    datasets = ("gsm8k",) if quick else DATASETS
    counts = (64, 128) if quick else (400, 800, 1200, 1600)
    targets = 2 if quick else 20
    seeds = (0,) if quick else (0, 1)
    rows = []
    for ds in datasets:
        for nd in counts:
            rate = nd * 0.6
            n = min(250, nd)
            f = mean_over_seeds(lambda s: run_scenario(
                ds, targets=targets, drafters=nd, rate=rate, n_requests=n,
                batching="fifo", seed=s), seeds)
            l = mean_over_seeds(lambda s: run_scenario(
                ds, targets=targets, drafters=nd, rate=rate, n_requests=n,
                batching="lab", seed=s), seeds)
            rows.append((f"fig9_{ds}_{nd}d_fifo_tpot_ms", f["tpot_ms"], ""))
            rows.append((f"fig9_{ds}_{nd}d_lab_tpot_ms", l["tpot_ms"],
                         f"{l['tpot_ms']-f['tpot_ms']:+.2f}ms vs fifo"))
            rows.append((f"fig10_{ds}_{nd}d_fifo_thpt", f["throughput_rps"], ""))
            rows.append((f"fig10_{ds}_{nd}d_lab_thpt", l["throughput_rps"], ""))
    return rows


if __name__ == "__main__":
    for name, val, note in run(quick=False):
        print(f"{name},{val:.3f},{note}")
