"""Fig. 6 — distributed vs fused (cloud-only) execution as RTT grows.

Paper: distributed wins at low RTT (edge drafting runs concurrently with
cloud verification); fused is RTT-insensitive; crossover ≈ 50–60 ms.

The TREE arm runs the same static γ with 3-branch grid trees: its
windows are priced by NODE COUNT (``window_payload_bytes(γ, n_nodes=1 +
γ·b)`` — every grid entry plus its parent-table row crosses the link),
so the tree pays more serialization per round than the chain but commits
more tokens per verify pass at the same α. The crossover therefore moves
in two directions at once — better compute amortization, worse payload —
and the benchmark reports both crossovers so the net effect is visible.

Run as a module (``python -m benchmarks.fig6_rtt_crossover``) to refresh
the committed ``FIG6_rtt_crossover.json`` at the repo root.
"""

from __future__ import annotations

import json
from pathlib import Path

from .common import mean_over_seeds, run_scenario

RTTS = (5.0, 10.0, 20.0, 30.0, 40.0, 60.0, 80.0, 100.0)
TREE_BRANCHES = 3


def run(quick: bool = True):
    n = 60 if quick else 150
    seeds = (0,) if quick else (0, 1, 2)
    rtts = RTTS[::2] if quick else RTTS
    rows = []
    crossover = {"dist": None, "tree": None}
    prev = {"dist": None, "tree": None}
    for rtt in rtts:
        d = mean_over_seeds(lambda s: run_scenario(
            "gsm8k", rtt_ms=rtt, window="static", n_requests=n, seed=s), seeds)
        t = mean_over_seeds(lambda s: run_scenario(
            "gsm8k", rtt_ms=rtt, window="static", branches=TREE_BRANCHES,
            n_requests=n, seed=s), seeds)
        f = mean_over_seeds(lambda s: run_scenario(
            "gsm8k", rtt_ms=rtt, window="fused", n_requests=n, seed=s), seeds)
        rows.append((f"fig6_rtt{int(rtt)}_dist_thpt", d["throughput_rps"],
                     f"tpot={d['tpot_ms']:.1f}ms"))
        rows.append((f"fig6_rtt{int(rtt)}_tree_thpt", t["throughput_rps"],
                     f"tpot={t['tpot_ms']:.1f}ms; b={TREE_BRANCHES}; "
                     f"node-count-priced payloads"))
        rows.append((f"fig6_rtt{int(rtt)}_fused_thpt", f["throughput_rps"],
                     f"tpot={f['tpot_ms']:.1f}ms"))
        for arm, summary in (("dist", d), ("tree", t)):
            gap = summary["throughput_rps"] - f["throughput_rps"]
            if prev[arm] is not None and crossover[arm] is None \
                    and gap < 0 <= prev[arm]:
                crossover[arm] = rtt
            prev[arm] = gap
    rows.append(("fig6_crossover_rtt_ms", float(crossover["dist"] or -1),
                 "paper observes 50-60ms"))
    rows.append(("fig6_tree_crossover_rtt_ms", float(crossover["tree"] or -1),
                 "tree arm: more tokens/pass vs bigger payloads"))
    return rows


def main() -> int:
    rows = run(quick=False)
    out = Path(__file__).resolve().parent.parent / "FIG6_rtt_crossover.json"
    out.write_text(json.dumps(
        {"bench": "fig6_rtt_crossover", "tree_branches": TREE_BRANCHES,
         "rows": [{"name": n, "value": v, "note": note}
                  for n, v, note in rows]}, indent=1) + "\n")
    for name, val, note in rows:
        print(f"{name},{val:.3f},{note}")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
