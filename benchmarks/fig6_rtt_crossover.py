"""Fig. 6 — distributed vs fused (cloud-only) execution as RTT grows.

Paper: distributed wins at low RTT (edge drafting runs concurrently with
cloud verification); fused is RTT-insensitive; crossover ≈ 50–60 ms.
"""

from __future__ import annotations

from .common import mean_over_seeds, run_scenario

RTTS = (5.0, 10.0, 20.0, 30.0, 40.0, 60.0, 80.0, 100.0)


def run(quick: bool = True):
    n = 60 if quick else 150
    seeds = (0,) if quick else (0, 1, 2)
    rtts = RTTS[::2] if quick else RTTS
    rows = []
    crossover = None
    prev = None
    for rtt in rtts:
        d = mean_over_seeds(lambda s: run_scenario(
            "gsm8k", rtt_ms=rtt, window="static", n_requests=n, seed=s), seeds)
        f = mean_over_seeds(lambda s: run_scenario(
            "gsm8k", rtt_ms=rtt, window="fused", n_requests=n, seed=s), seeds)
        rows.append((f"fig6_rtt{int(rtt)}_dist_thpt", d["throughput_rps"],
                     f"tpot={d['tpot_ms']:.1f}ms"))
        rows.append((f"fig6_rtt{int(rtt)}_fused_thpt", f["throughput_rps"],
                     f"tpot={f['tpot_ms']:.1f}ms"))
        gap = d["throughput_rps"] - f["throughput_rps"]
        if prev is not None and crossover is None and gap < 0 <= prev:
            crossover = rtt
        prev = gap
    rows.append(("fig6_crossover_rtt_ms", float(crossover or -1),
                 "paper observes 50-60ms"))
    return rows


if __name__ == "__main__":
    for name, val, note in run(quick=False):
        print(f"{name},{val:.3f},{note}")
