"""Fig. 5 — end-to-end SLOs/throughput for accumulating policy stacks.

Default: Random + FIFO + Static γ
Setting 1: JSQ + FIFO + Static γ
Setting 2: JSQ + LAB + Static γ
Setting 3: JSQ + LAB + Dynamic γ
Setting 4: JSQ + LAB + AWC

Paper: accumulating policies steadily improves throughput and latency (GSM8K
throughput 25.1 → 28.1 r/s; TPOT 45 → 37 ms), with AWC the main latency win.
"""

from __future__ import annotations

from .common import DATASETS, mean_over_seeds, run_scenario

STACKS = [
    ("default", dict(routing="random", batching="fifo", window="static")),
    ("setting1", dict(routing="jsq", batching="fifo", window="static")),
    ("setting2", dict(routing="jsq", batching="lab", window="static")),
    ("setting3", dict(routing="jsq", batching="lab", window="dynamic")),
    ("setting4", dict(routing="jsq", batching="lab", window="awc")),
]


def run(quick: bool = True):
    # the paper's Fig-5 cluster is the §5.2 heterogeneous deployment — the
    # adaptive-γ stages only differentiate when pairs differ
    n = 60 if quick else 200
    seeds = (0, 1) if quick else (0, 1, 2)
    rows = []
    for ds in (DATASETS if not quick else ("gsm8k",)):
        base = None
        for name, kw in STACKS:
            s = mean_over_seeds(
                lambda seed: run_scenario(ds, n_requests=n, seed=seed,
                                          targets=3, heterogeneous=True,
                                          **kw),
                seeds)
            if base is None:
                base = s
            rows.append((f"fig5_{ds}_{name}_thpt_rps", s["throughput_rps"],
                         f"+{100*(s['throughput_rps']/base['throughput_rps']-1):.1f}% vs default"))
            rows.append((f"fig5_{ds}_{name}_tpot_ms", s["tpot_ms"],
                         f"{100*(s['tpot_ms']/base['tpot_ms']-1):+.1f}% vs default"))
            rows.append((f"fig5_{ds}_{name}_ttft_ms", s["ttft_ms"], ""))
    return rows


if __name__ == "__main__":
    for name, val, note in run(quick=False):
        print(f"{name},{val:.3f},{note}")
