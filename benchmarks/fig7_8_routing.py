"""Figs. 7–8 — routing ablation: Random vs Round-Robin vs JSQ while scaling
the number of draft clients.

Paper: JSQ keeps TPOT 5–20 ms lower until saturation (~1k drafts), then RR
catches up (head-of-line blocking at the fastest server).
"""

from __future__ import annotations

from .common import mean_over_seeds, run_scenario

DRAFT_COUNTS = (40, 80, 160, 320)          # 1:10 scale of the paper's 0.4k-2k
FULL_COUNTS = (400, 800, 1200, 1600, 2000)


def run(quick: bool = True):
    counts = DRAFT_COUNTS[:3] if quick else FULL_COUNTS
    targets = 2 if quick else 20
    seeds = (0,) if quick else (0, 1)
    rows = []
    for nd in counts:
        rate = nd * 0.6     # keep per-drafter load constant as we scale
        n = min(300, nd)
        for r in ("random", "rr", "jsq"):
            s = mean_over_seeds(lambda seed: run_scenario(
                "gsm8k", targets=targets, drafters=nd, rate=rate,
                n_requests=n, routing=r, seed=seed), seeds)
            rows.append((f"fig7_{nd}d_{r}_thpt_rps", s["throughput_rps"],
                         f"util={s['target_utilization']:.2f}"))
            rows.append((f"fig8_{nd}d_{r}_tpot_ms", s["tpot_ms"], ""))
    return rows


if __name__ == "__main__":
    for name, val, note in run(quick=False):
        print(f"{name},{val:.3f},{note}")
