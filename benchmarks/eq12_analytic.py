"""Eqs. (1)–(2) — analytic E[τ] and speedup S vs Monte-Carlo measurement of
the actual accept/resample implementation (repro.core.specdec)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.specdec import expected_accepted, expected_speedup, verify_window


def _empirical_tau(alpha: float, gamma: int, n: int = 4000, v: int = 128):
    key = jax.random.PRNGKey(0)
    q = jnp.full((n, gamma, v), 1.0 / v)
    toks = jax.random.randint(key, (n, gamma), 0, v)
    onehot = jax.nn.one_hot(toks, v)
    p_g = (jnp.ones((n, gamma, v)) - onehot) * ((1 - alpha / v) / (v - 1)) \
        + onehot * (alpha / v)
    p = jnp.concatenate([p_g, jnp.full((n, 1, v), 1.0 / v)], axis=1)
    res = verify_window(jax.random.PRNGKey(1), toks, q, p)
    return float(res.num_new.mean())


def run(quick: bool = True):
    rows = []
    grid = [(0.6, 2), (0.8, 4)] if quick else \
        [(0.5, 2), (0.6, 4), (0.7, 4), (0.8, 4), (0.8, 8), (0.9, 8), (0.9, 12)]
    for alpha, gamma in grid:
        theory = float(expected_accepted(alpha, gamma))
        emp = _empirical_tau(alpha, gamma)
        err = 100 * abs(emp - theory) / theory
        rows.append((f"eq1_alpha{alpha}_g{gamma}_etau", emp,
                     f"theory={theory:.3f} err={err:.1f}%"))
    s = float(expected_speedup(0.8, 4, 0.05))
    rows.append(("eq2_speedup_a0.8_g4_c0.05", s, "analytic"))
    return rows


if __name__ == "__main__":
    for name, val, note in run(quick=False):
        print(f"{name},{val:.3f},{note}")
