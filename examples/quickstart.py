"""Quickstart: distributed speculative decoding in ~60 lines.

Builds a reduced draft/target pair, serves a batch of prompts through the
DSD engine under three window policies (static γ / dynamic / AWC), then
runs the same policy comparison at cluster scale in DSD-Sim.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import SpecDecodeEngine
from repro.core.window import (AWCWindowPolicy, DynamicWindowPolicy,
                               StaticWindowPolicy)
from repro.core.awc.model import default_predictor
from repro.sim import simulate_from_yaml


def main():
    # --- real-model engine (reduced configs; full configs go via dry-run) --
    target_cfg = get_config("qwen3-14b").reduced()
    draft_cfg = dataclasses.replace(get_config("qwen2.5-3b").reduced(),
                                    vocab=target_cfg.vocab)
    engine = SpecDecodeEngine(draft_cfg, target_cfg, temperature=1.0,
                              rtt_ms=10.0, key=jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, target_cfg.vocab, (4, 16)).astype(np.int32)

    print("=== real-model engine (temp 1.0; 4 sequences, 32 new tokens) ===")
    # gamma_max=12 compiles ONE masked-window step program; all three
    # policies (static γ=4, the dynamic heuristic, AWC's per-iteration
    # adaptive γ) reuse it — varying γ never triggers a recompile.
    for policy in (StaticWindowPolicy(4), DynamicWindowPolicy(),
                   AWCWindowPolicy(default_predictor())):
        tokens, stats = engine.generate(prompts, 32, policy,
                                        key=jax.random.PRNGKey(1),
                                        gamma_max=12)
        print(f"  {policy.name():10s} acceptance={stats.acceptance_rate:.3f} "
              f"tokens/iter={stats.tokens_per_iteration:.2f} "
              f"iters={stats.iterations} "
              f"programs={engine.compiled_programs()}")

    # --- cluster-scale simulation (DSD-Sim) -------------------------------
    print("=== DSD-Sim: 2 cloud targets, 64 edge drafters, GSM8K ===")
    for window in ("static, gamma: 4", "dynamic", "awc"):
        summary = simulate_from_yaml(f"""
cluster:
  targets: {{count: 2, hw: A100, model: llama2-70b, tp: 4}}
  drafters: {{count: 64, hw: A40, model: llama2-7b}}
  link: {{rtt_ms: 10, jitter_ms: 1}}
policies:
  routing: jsq
  batching: {{kind: lab, max_batch: 16}}
  window: {{kind: {window.split(',')[0]}, gamma: 4}}
workload: {{dataset: gsm8k, rate_per_s: 40, num_requests: 80, seed: 0}}
""").summary()
        print(f"  {window.split(',')[0]:10s} "
              f"thpt={summary['throughput_rps']:.2f} r/s  "
              f"tpot={summary['tpot_ms']['mean']:.1f} ms  "
              f"gamma={summary['mean_gamma']:.1f}")


if __name__ == "__main__":
    main()
