"""Close the paper's trace loop: capture ground-truth acceptance sequences
from REAL draft/target JAX models, write them in the Table-1 trace schema,
and replay them through DSD-Sim (the paper captures these from GPU profiling
runs; DSD-Sim replays them instead of assuming a probabilistic acceptance
model).

    PYTHONPATH=src python examples/capture_traces.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import SpecDecodeEngine
from repro.sim import (ClusterSpec, DSDSimulation, LinkSpec, PolicyStack,
                       TraceRecord, save_trace)
from repro.sim.policies import BatchingConfig, LengthAwareBatching, JSQRouting
from repro.core.window import StaticWindowPolicy


def main():
    target_cfg = get_config("deepseek-7b").reduced()
    # the draft shares the target family (distilled-style pairing)
    draft_cfg = dataclasses.replace(target_cfg, n_layers=2, d_model=128,
                                    n_heads=2, n_kv_heads=2, head_dim=64,
                                    d_ff=256, name="deepseek-draft")
    engine = SpecDecodeEngine(draft_cfg, target_cfg, temperature=1.0,
                              key=jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    n_req = 8
    prompts = rng.integers(0, target_cfg.vocab, (n_req, 12)).astype(np.int32)
    print("capturing acceptance traces from real models...")
    seqs = engine.capture_traces(prompts, max_new_tokens=24, gamma=6)

    records = []
    t = 0.0
    for i, bits in enumerate(seqs):
        t += float(rng.exponential(50.0))
        records.append(TraceRecord(
            request_id=i, prompt_length=12, output_length=24,
            acceptance_seq=bits, arrival_time_ms=t,
            drafter_id=i % 8, dataset="captured"))
        print(f"  req {i}: alpha={np.mean(bits):.3f} bits={len(bits)}")
    save_trace(records, "/tmp/captured_traces.jsonl")
    print("saved /tmp/captured_traces.jsonl (Table-1 schema)")

    cluster = ClusterSpec(num_targets=2, num_drafters=8,
                          link=LinkSpec(rtt_ms=10.0))
    sim = DSDSimulation(cluster, PolicyStack(
        routing=JSQRouting(), batching=LengthAwareBatching(),
        batching_cfg=BatchingConfig(max_batch=8),
        window=StaticWindowPolicy(4)), records)
    s = sim.run().summary()
    print(f"replayed through DSD-Sim: thpt={s['throughput_rps']:.2f} r/s "
          f"tpot={s['tpot_ms']['mean']:.1f} ms "
          f"acceptance={s['acceptance_rate']:.3f}")


if __name__ == "__main__":
    main()
