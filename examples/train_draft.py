"""Train a draft model, then measure how training improves speculative
acceptance against a fixed target — the draft-quality knob the paper's α
(acceptance rate) abstracts.

Trains a small llama-family draft on the synthetic LM for a few hundred
steps (use --d-model 640 --layers 16 for a ~100M configuration if you have
the patience on CPU; the launcher scales to the full configs on TPU).

    PYTHONPATH=src python examples/train_draft.py [--steps 200]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import SpecDecodeEngine
from repro.core.window import StaticWindowPolicy
from repro.models import build_model
from repro.training import (AdamWConfig, DataConfig, SyntheticLM,
                            cosine_schedule, init_train_state,
                            make_train_step)


def train_lm(cfg, steps, data_cfg, lr=3e-3, seed=0):
    model = build_model(cfg)
    opt = AdamWConfig(lr=lr, schedule=cosine_schedule(lr, 20, steps))
    state = init_train_state(model, jax.random.PRNGKey(seed), opt)
    step = jax.jit(make_train_step(model, opt))
    it = SyntheticLM(data_cfg).batches()
    first = last = None
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step(state, batch, jax.random.PRNGKey(i))
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    return state.params, first, last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    vocab = 512
    data = DataConfig(vocab=vocab, seq_len=96, batch=8, seed=0)

    target_cfg = ModelConfig(
        name="target", arch_type="dense", n_layers=6, d_model=256,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab=vocab, dtype="float32",
        remat=False)
    draft_cfg = ModelConfig(
        name="draft", arch_type="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=4, n_kv_heads=2,
        head_dim=args.d_model // 4, d_ff=args.d_model * 4, vocab=vocab,
        dtype="float32", remat=False)
    print(f"target params: {target_cfg.param_count()/1e6:.1f}M, "
          f"draft params: {draft_cfg.param_count()/1e6:.1f}M")

    print("training target on synthetic LM...")
    tparams, f0, f1 = train_lm(target_cfg, args.steps, data, seed=1)
    print(f"  target loss {f0:.3f} -> {f1:.3f}")
    print("training draft on the same distribution...")
    dparams, g0, g1 = train_lm(draft_cfg, args.steps, data, seed=2)
    print(f"  draft  loss {g0:.3f} -> {g1:.3f}")

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, vocab, (4, 16)).astype(np.int32)

    untrained = SpecDecodeEngine(draft_cfg, target_cfg,
                                 target_params=tparams, temperature=1.0,
                                 key=jax.random.PRNGKey(3))
    _, s0 = untrained.generate(prompts, 32, StaticWindowPolicy(4),
                               key=jax.random.PRNGKey(4))
    trained = SpecDecodeEngine(draft_cfg, target_cfg,
                               draft_params=dparams, target_params=tparams,
                               temperature=1.0, key=jax.random.PRNGKey(3))
    _, s1 = trained.generate(prompts, 32, StaticWindowPolicy(4),
                             key=jax.random.PRNGKey(4))
    print(f"acceptance untrained draft: {s0.acceptance_rate:.3f} "
          f"({s0.tokens_per_iteration:.2f} tok/iter)")
    print(f"acceptance trained draft:   {s1.acceptance_rate:.3f} "
          f"({s1.tokens_per_iteration:.2f} tok/iter)")
    assert s1.acceptance_rate > s0.acceptance_rate, \
        "training the draft on the target's distribution must raise alpha"


if __name__ == "__main__":
    main()
