"""End-to-end serving driver (deliverable b), topology-first: ONE
declarative ClusterSpec — 2 edge drafts behind heterogeneous links (fast
LAN, slow WAN) sharing 1 cloud target — builds BOTH the real multi-pair
deployment (`build_deployment` → SpecDecodeServer with per-pair
transports and per-pair AWC stabilizers) and the matching DSD-Sim run
(`build_simulation`), then validates the fused-verification Pallas kernel
against the engine's jnp path on the same inputs.

    PYTHONPATH=src python examples/edge_cloud_serving.py [--requests 8]
    PYTHONPATH=src python examples/edge_cloud_serving.py \
        --topology examples/cluster_2pair.json
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.verify import verify_reference, verify_window_fused
from repro.serving import ServeRequest
from repro.sim.network import LinkSpec
from repro.topology import (ClusterSpec, NodeSpec, PairSpec, ServingSpec,
                            WindowSpec, WorkloadSpec, build_deployment,
                            build_simulation)


def default_spec() -> ClusterSpec:
    """2 edge drafts → 1 cloud target over heterogeneous links, AWC window
    control per pair (the worked example of README §Deployment topology)."""
    return ClusterSpec(
        nodes=[
            NodeSpec("edge-lan", "draft", "qwen2.5-3b", device="edge-nic"),
            NodeSpec("edge-wan", "draft", "qwen2.5-3b", device="edge-lte"),
            NodeSpec("cloud", "target", "deepseek-7b", device="cloud-pool"),
        ],
        pairs=[
            PairSpec("lan", "edge-lan", "cloud",
                     link=LinkSpec(rtt_ms=2.0, jitter_ms=0.3,
                                   name="campus-lan"),
                     window=WindowSpec("awc")),
            PairSpec("wan", "edge-wan", "cloud",
                     link=LinkSpec(rtt_ms=40.0, jitter_ms=3.0,
                                   bandwidth_gbps=0.1, name="metro-wan"),
                     window=WindowSpec("awc")),
        ],
        serving=ServingSpec(max_batch=2, gamma_max=8, sync_every=4),
        workload=WorkloadSpec(num_requests=8, max_new=16))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default=None,
                    help="ClusterSpec JSON (default: the built-in 2-pair "
                         "edge-cloud example)")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()

    spec = (ClusterSpec.load(args.topology) if args.topology
            else default_spec())
    if args.requests is not None:
        spec.workload.num_requests = args.requests
    spec.validate()

    # -- real path: one spec -> engines, transports, policies, server -----
    deployment = build_deployment(spec)
    server = deployment.build_server()
    wl = spec.workload
    rng = np.random.default_rng(spec.seed)
    for i in range(wl.num_requests):
        plen = int(rng.integers(wl.prompt_lo, wl.prompt_hi))
        server.submit(ServeRequest(
            i, rng.integers(0, deployment.vocab, plen).astype(np.int32),
            wl.max_new))
    results = server.run()
    ttft = np.mean([r.ttft_ms for r in results])
    tpot = np.mean([r.tpot_ms for r in results])
    print(f"served={len(results)} pairs={len(deployment.pairs)} "
          f"ttft={ttft:.1f}ms tpot={tpot:.1f}ms")
    for pid, d in server.pair_summaries().items():
        print(f"  pair={pid:4s} requests={d['requests']} "
              f"mean_gamma={d['mean_gamma']:.2f} "
              f"fused_fraction={d['fused_fraction']:.2f} "
              f"measured_rtt={d.get('recent_rtt_ms', 0.0):.1f}ms")

    # -- sim path: the IDENTICAL spec drives DSD-Sim ----------------------
    analyzer = build_simulation(spec).run()
    per_pair: dict[int, list[int]] = {}
    for m in analyzer.requests.values():
        per_pair.setdefault(m.drafter_id, []).extend(m.gamma_sequence)
    for i, p in enumerate(spec.pairs):
        g = per_pair.get(i, [])
        mean_g = float(np.mean(g)) if g else 0.0
        print(f"  sim pair={p.id:4s} mean_gamma={mean_g:.2f}")

    # fused Pallas verification kernel == engine verification semantics
    V = get_config("deepseek-7b").reduced().vocab
    B, G = 4, 4
    p = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2),
                                         (B, G + 1, V)), -1)
    q = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(3),
                                         (B, G, V)), -1)
    toks = jax.random.categorical(jax.random.PRNGKey(4), jnp.log(q),
                                  -1).astype(jnp.int32)
    u = jax.random.uniform(jax.random.PRNGKey(5), (B, G))
    r = jax.random.uniform(jax.random.PRNGKey(6), (B,))
    ref = verify_reference(toks, q, p, u, r)
    out = verify_window_fused(toks, q, p, u, r)
    same = (np.asarray(ref.n_accepted) == np.asarray(out.n_accepted)).all() \
        and (np.asarray(ref.next_token) == np.asarray(out.next_token)).all()
    print(f"pallas verify kernel == jnp oracle: {bool(same)}")


if __name__ == "__main__":
    main()
