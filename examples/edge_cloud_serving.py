"""End-to-end serving driver (deliverable b): serve a stream of requests
through the continuous slot-based SpecDecodeServer on real JAX models,
comparing the paper's window policies, and validate the fused-verification
Pallas kernel against the engine's jnp path on the same inputs.

    PYTHONPATH=src python examples/edge_cloud_serving.py [--requests 12]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.engine import SpecDecodeEngine
from repro.core.window import AWCWindowPolicy, StaticWindowPolicy
from repro.core.awc.model import default_predictor
from repro.kernels.verify import verify_reference, verify_window_fused
from repro.serving import ServeRequest, ServerConfig, SpecDecodeServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    target_cfg = get_config("deepseek-7b").reduced()
    draft_cfg = dataclasses.replace(get_config("qwen2.5-3b").reduced(),
                                    vocab=target_cfg.vocab)
    # gamma_max bounds every policy's window; the engine compiles one
    # masked-window step per wave shape and reuses it across policies
    engine = SpecDecodeEngine(draft_cfg, target_cfg, temperature=1.0,
                              rtt_ms=10.0, gamma_max=12, sync_every=8,
                              key=jax.random.PRNGKey(0))

    rng = np.random.default_rng(1)
    for policy_name, policy in [("static-4", StaticWindowPolicy(4)),
                                ("awc", AWCWindowPolicy(default_predictor()))]:
        server = SpecDecodeServer(engine, policy,
                                  ServerConfig(max_batch=4, length_aware=True))
        for i in range(args.requests):
            plen = int(rng.integers(8, 40))
            server.submit(ServeRequest(
                i, rng.integers(0, target_cfg.vocab, plen).astype(np.int32),
                args.max_new))
        results = server.run()
        acc = np.mean([r.acceptance_rate for r in results])
        ttft = np.mean([r.ttft_ms for r in results])
        tpot = np.mean([r.tpot_ms for r in results])
        print(f"policy={policy_name:9s} served={len(results):3d} "
              f"acceptance={acc:.3f} ttft={ttft:.1f}ms tpot={tpot:.1f}ms")

    # fused Pallas verification kernel == engine verification semantics
    B, G, V = 4, 4, target_cfg.vocab
    p = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (B, G + 1, V)), -1)
    q = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(3), (B, G, V)), -1)
    toks = jax.random.categorical(jax.random.PRNGKey(4), jnp.log(q), -1).astype(jnp.int32)
    u = jax.random.uniform(jax.random.PRNGKey(5), (B, G))
    r = jax.random.uniform(jax.random.PRNGKey(6), (B,))
    ref = verify_reference(toks, q, p, u, r)
    out = verify_window_fused(toks, q, p, u, r)
    same = (np.asarray(ref.n_accepted) == np.asarray(out.n_accepted)).all() \
        and (np.asarray(ref.next_token) == np.asarray(out.next_token)).all()
    print(f"pallas verify kernel == jnp oracle: {bool(same)}")


if __name__ == "__main__":
    main()
