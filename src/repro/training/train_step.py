"""Training step: cross-entropy LM loss + AdamW update, remat-aware.

``make_train_step`` builds a pure ``(state, batch, key) -> (state, metrics)``
function closed over the model — the object the launcher jits with
in/out shardings for the production mesh.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import Model
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE over valid positions. labels == -100 are ignored."""
    valid = (labels != -100)
    if mask is not None:
        valid = valid & (mask > 0)
    safe_labels = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, nll, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def make_loss_fn(model: Model, aux_weight: float = 0.01):
    def loss_fn(params, batch):
        logits, aux = model.forward_train(params, batch)
        ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
        return ce + aux_weight * aux, (ce, aux)
    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    aux_weight: float = 0.01, micro_steps: int = 1):
    """``micro_steps`` > 1 enables gradient accumulation: the global batch
    splits into micro-batches scanned sequentially, bounding live activation
    memory (the production 256-seq × 4k-token batches need this on
    16 GB chips); gradients accumulate in the parameter dtype."""
    loss_fn = make_loss_fn(model, aux_weight)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict, key: jax.Array
                   ) -> tuple[TrainState, dict]:
        if micro_steps == 1:
            (loss, (ce, aux)), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                return x.reshape(micro_steps, x.shape[0] // micro_steps,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)
            zero = jax.tree.map(jnp.zeros_like, state.params)

            def acc(carry, mb):
                g_acc, l_acc, c_acc, a_acc = carry
                (l, (c, a)), g = grad_fn(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, c_acc + c, a_acc + a), None

            (grads, loss, ce, aux), _ = jax.lax.scan(
                acc, (zero, 0.0, 0.0, 0.0), micro)
            inv = 1.0 / micro_steps
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss, ce, aux = loss * inv, ce * inv, aux * inv
        params, opt = adamw_update(grads, state.opt, state.params, opt_cfg)
        metrics = {"loss": loss, "ce": ce, "aux": aux,
                   "step": state.step + 1}
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    return train_step


def init_train_state(model: Model, key: jax.Array,
                     opt_cfg: AdamWConfig) -> TrainState:
    params = model.init_params(key)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))
