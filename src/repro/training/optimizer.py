"""Optimizers — AdamW with decoupled weight decay, gradient clipping, and
learning-rate schedules. Pure-JAX pytree implementation (no optax)."""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # dtype of the moment accumulators; bf16 halves optimizer memory for the
    # 400B-class archs (see DESIGN.md §5 / EXPERIMENTS.md §Dry-run)
    state_dtype: Optional[Any] = None
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, step / max(1, warmup))
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * warm * cos
    return fn


def adamw_init(params: Any, cfg: AdamWConfig) -> AdamWState:
    def zeros_like(p):
        dt = cfg.state_dtype or p.dtype
        return jnp.zeros(p.shape, dtype=dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros_like, params),
                      nu=jax.tree.map(zeros_like, params))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 cfg: AdamWConfig) -> tuple[Any, AdamWState]:
    step = state.step + 1
    lr = cfg.schedule(step) if cfg.schedule is not None else cfg.lr

    if cfg.grad_clip and cfg.grad_clip > 0:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g32
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g32 * g32
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return (new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
