"""Training substrate: optimizer, train step, data pipeline, checkpoints."""

from .optimizer import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                        cosine_schedule, global_norm)
from .train_step import (TrainState, cross_entropy, init_train_state,
                         make_loss_fn, make_train_step)
from .data import DataConfig, SyntheticLM
from . import checkpoint
