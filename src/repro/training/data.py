"""Synthetic token data pipeline.

Deterministic, seedable, infinite stream of LM batches with a structured
synthetic language (Zipfian unigrams + a first-order Markov kernel + copy
spans) — enough signal that a ~100M model's loss visibly drops within a few
hundred steps (examples/train_draft.py), unlike uniform-random tokens.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_states: int = 64
    copy_prob: float = 0.15
    frontend_tokens: int = 0      # encdec/vlm: stub embedding length
    frontend_dim: int = 0


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # Zipf unigram over vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = ranks ** (-cfg.zipf_a)
        self.unigram /= self.unigram.sum()
        # low-rank Markov structure: state -> next-token distribution tilt
        k = min(cfg.markov_states, v)
        self.state_of = rng.integers(0, k, size=v)
        self.tilt = rng.dirichlet(np.ones(k) * 0.3, size=k)  # (k, k)
        self.rng = rng

    def _sample_seq(self, rng: np.random.Generator, n: int) -> np.ndarray:
        cfg = self.cfg
        v = cfg.vocab
        out = np.empty(n, dtype=np.int32)
        out[0] = rng.choice(v, p=self.unigram)
        i = 1
        while i < n:
            if i > 8 and rng.random() < cfg.copy_prob:
                # copy a recent span (teaches induction-style structure)
                span = rng.integers(2, min(8, i))
                start = rng.integers(0, i - span)
                ln = min(span, n - i)
                out[i:i + ln] = out[start:start + ln]
                i += ln
                continue
            s = self.state_of[out[i - 1]]
            # mix unigram with the state tilt projected back onto vocab
            p = 0.7 * self.unigram
            boost_states = self.tilt[s]
            p = p + 0.3 * boost_states[self.state_of] * self.unigram * len(boost_states)
            p = p / p.sum()
            out[i] = rng.choice(v, p=p)
            i += 1
        return out

    def batches(self) -> Iterator[dict]:
        cfg = self.cfg
        step = 0
        while True:
            rng = np.random.default_rng((cfg.seed, step))
            toks = np.stack([self._sample_seq(rng, cfg.seq_len + 1)
                             for _ in range(cfg.batch)])
            batch = {"tokens": toks[:, :-1].astype(np.int32),
                     "labels": toks[:, 1:].astype(np.int32)}
            if cfg.frontend_tokens:
                dim = cfg.frontend_dim
                batch["frontend"] = rng.standard_normal(
                    (cfg.batch, cfg.frontend_tokens, dim)).astype(np.float32)
            yield batch
            step += 1
