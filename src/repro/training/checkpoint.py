"""Checkpointing: flatten a pytree of arrays to an .npz with path-encoded
keys; restore onto an existing structure (shape/dtype checked)."""

from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


_SEP = "|"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(tree: Any, path: str) -> None:
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:        # file object: numpy won't append .npz
        np.savez(f, **_flatten(tree))
    os.replace(tmp, path)


def restore(template: Any, path: str) -> Any:
    """Restore into the structure of ``template`` (a pytree of arrays)."""
    z = np.load(path)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for pth, leaf in leaves_with_paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in pth)
        if key not in z:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = z[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        new_leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
