"""arctic-480b — MoE 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base].
35L, d_model 7168, 56 heads (GQA kv=8), expert d_ff 4864, vocab 32000."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", arch_type="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, head_dim=128,
    n_experts=128, experts_per_tok=2, moe_dense_residual=True,
    capacity_factor=1.25)
