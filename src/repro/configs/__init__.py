"""Assigned architecture configs (+ the paper's own draft/target pair).

Each module cites its source; ``get_config(arch_id)`` is the ``--arch``
lookup used by the launchers.
"""

from .base import ModelConfig
from . import (arctic_480b, command_r_plus_104b, deepseek_7b,
               internvl2_76b, llama4_maverick_400b_a17b, mamba2_130m,
               paper_pair, qwen2_5_3b, qwen3_14b, whisper_tiny, zamba2_1_2b)

ARCHS: dict[str, ModelConfig] = {
    "deepseek-7b": deepseek_7b.CONFIG,
    "mamba2-130m": mamba2_130m.CONFIG,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b.CONFIG,
    "qwen3-14b": qwen3_14b.CONFIG,
    "qwen2.5-3b": qwen2_5_3b.CONFIG,
    "command-r-plus-104b": command_r_plus_104b.CONFIG,
    "whisper-tiny": whisper_tiny.CONFIG,
    "internvl2-76b": internvl2_76b.CONFIG,
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
    "arctic-480b": arctic_480b.CONFIG,
    # paper pair
    "llama2-7b": paper_pair.DRAFT,
    "llama2-70b": paper_pair.TARGET,
}

ASSIGNED = [k for k in ARCHS if k not in ("llama2-7b", "llama2-70b")]


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
