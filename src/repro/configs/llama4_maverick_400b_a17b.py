"""llama4-maverick-400b-a17b — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].
48L, d_model 5120, 40 heads (GQA kv=8), d_ff 8192, vocab 202048,
MoE 128 experts top-1."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", arch_type="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    n_experts=128, experts_per_tok=1, capacity_factor=1.25,
    rope_theta=500000.0)
