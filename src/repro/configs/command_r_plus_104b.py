"""command-r-plus-104b — dense, GQA, no-bias
[hf:CohereForAI/c4ai-command-r-v01].
64L, d_model 12288, 96 heads (GQA kv=8), d_ff 33792, vocab 256000."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", arch_type="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256000, head_dim=128, rope_theta=75000000.0)
