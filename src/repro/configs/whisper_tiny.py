"""whisper-tiny — audio enc-dec, conv frontend STUB [arXiv:2212.04356].
4L decoder (+4L encoder), d_model 384, 6 heads, d_ff 1536, vocab 51865.
The mel-spectrogram + conv feature extractor is stubbed per assignment:
input_specs() provides precomputed frame embeddings (B, 1500, 384)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", arch_type="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, head_dim=64,
    encoder_layers=4, n_frontend_tokens=1500, cross_attention=True)
