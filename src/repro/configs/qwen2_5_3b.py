"""qwen2.5-3b — dense, GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B].
36L, d_model 2048, 16 heads (GQA kv=2), d_ff 11008, vocab 151936."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", arch_type="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab=151936, head_dim=128, qkv_bias=True,
    rope_theta=1000000.0)
