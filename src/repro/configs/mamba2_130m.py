"""mamba2-130m — SSD state-space duality [arXiv:2405.21060].
24L, d_model 768, attention-free, vocab 50280, ssm_state 128."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", arch_type="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    ssm_conv=4, ssm_chunk=128, tie_embeddings=True)
