"""internvl2-76b — VLM: InternViT (STUB) + InternLM2 backbone
[arXiv:2404.16821].
80L, d_model 8192, 64 heads (GQA kv=8), d_ff 28672, vocab 128256.
The vision encoder + projector is stubbed per assignment: input_specs()
provides precomputed patch embeddings (B, 256, 8192) as a bidirectional
prefix ahead of the text tokens."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", arch_type="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    n_frontend_tokens=256, rope_theta=1000000.0)
