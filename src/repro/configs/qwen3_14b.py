"""qwen3-14b — dense, qk_norm + GQA [hf:Qwen/Qwen3-8B].
40L, d_model 5120, 40 heads (GQA kv=8), d_ff 17408, vocab 151936."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", arch_type="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, head_dim=128, qk_norm=True,
    rope_theta=1000000.0)
