"""zamba2-1.2b — hybrid: Mamba2 backbone + SHARED attention block
[arXiv:2411.15242].
38 Mamba2 layers, d_model 2048, shared attn block (32 heads, kv=32,
d_ff 8192) invoked every 6 layers, vocab 32000, ssm_state 64."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", arch_type="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    ssm_chunk=128, attn_every=6)
