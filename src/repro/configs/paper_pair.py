"""The paper's own draft/target pair (§5): llama2-7b edge draft +
llama2-70b cloud target [arXiv:2307.09288]."""
from .base import ModelConfig

DRAFT = ModelConfig(
    name="llama2-7b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=32000, head_dim=128)

TARGET = ModelConfig(
    name="llama2-70b", arch_type="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=32000, head_dim=128)

CONFIG = TARGET
