"""Model configuration schema for the whole zoo.

One frozen dataclass covers all six architecture families (dense, moe, ssm,
hybrid, encdec-audio, vlm); family-specific fields default off. Every
assigned architecture file in this package instantiates it with the exact
published numbers and cites its source in the module docstring.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 → d_model // n_heads

    # attention variants
    qk_norm: bool = False          # qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False         # qwen2.5-style bias on qkv projections
    attn_out_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 = full attention; >0 = window size
    # serving variant: use sliding window only for long-context serving
    serve_sliding_window: int = 8192

    # MoE
    n_experts: int = 0
    experts_per_tok: int = 0
    moe_dense_residual: bool = False   # arctic: dense MLP residual beside MoE
    capacity_factor: float = 1.25
    moe_group: int = 4096              # GShard group size for long sequences

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128           # SSD chunk length
    attn_every: int = 0            # hybrid: shared attn block cadence

    # encoder-decoder (audio) / vlm
    encoder_layers: int = 0
    n_frontend_tokens: int = 0     # whisper frames (post-conv) / vit patches
    frontend_dim: int = 0          # stub embedding dim (0 → d_model)
    cross_attention: bool = False

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    remat: bool = True

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.n_heads))
        if self.frontend_dim == 0:
            object.__setattr__(self, "frontend_dim", self.d_model)

    # -- derived ------------------------------------------------------------

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameters (excludes stub frontends, which carry none)."""
        d, hd = self.d_model, self.head_dim
        total = 2 * self.vocab * d if not self.tie_embeddings else self.vocab * d

        def attn_params() -> int:
            p = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
            if self.qkv_bias:
                p += (self.n_heads + 2 * self.n_kv_heads) * hd
            if self.qk_norm:
                p += 2 * hd
            return p + 2 * d  # two norms

        def mlp_params() -> int:
            return 3 * d * self.d_ff

        def moe_params() -> int:
            p = d * self.n_experts + self.n_experts * 3 * d * self.d_ff
            if self.moe_dense_residual:
                p += 3 * d * self.d_ff
            return p + 2 * d

        def ssm_params() -> int:
            din, st, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            proj_in = d * (2 * din + 2 * st + nh)
            conv = (din + 2 * st) * self.ssm_conv
            return proj_in + conv + 2 * nh + din + din * d + d

        if self.arch_type == "dense" or self.arch_type == "vlm":
            total += self.n_layers * (attn_params() + mlp_params())
        elif self.arch_type == "moe":
            total += self.n_layers * (attn_params() + moe_params())
        elif self.arch_type == "ssm":
            total += self.n_layers * ssm_params()
        elif self.arch_type == "hybrid":
            total += self.n_layers * ssm_params()
            total += attn_params() + mlp_params()   # one shared block
        elif self.arch_type == "encdec":
            total += self.encoder_layers * (attn_params() + mlp_params())
            # decoder blocks: self-attn + cross-attn + mlp
            total += self.n_layers * (2 * attn_params() + mlp_params())
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        if self.n_experts == 0:
            return self.param_count()
        dense_ffn = self.experts_per_tok * 3 * self.d_model * self.d_ff
        if self.moe_dense_residual:
            dense_ffn += 3 * self.d_model * self.d_ff
        per_layer = (self.d_model * (self.n_heads + 2 * self.n_kv_heads)
                     * self.head_dim + self.n_heads * self.head_dim
                     * self.d_model + dense_ffn + self.d_model * self.n_experts)
        emb = (1 if self.tie_embeddings else 2) * self.vocab * self.d_model
        return emb + self.n_layers * per_layer

    # -- smoke-test reduction -------------------------------------------------

    def reduced(self) -> "ModelConfig":
        """The REDUCED same-family variant used by CPU smoke tests:
        2 layers, d_model ≤ 512, ≤ 4 experts, small vocab."""
        d = min(self.d_model, 256)
        heads = max(1, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        hd = max(8, d // heads)
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) or 512,
            vocab=min(self.vocab, 512),
            dtype="float32",
            remat=False,
        )
        if self.n_experts:
            kw["n_experts"] = min(4, self.n_experts)
            kw["experts_per_tok"] = min(self.experts_per_tok, 2)
        if self.ssm_state:
            kw["ssm_state"] = min(self.ssm_state, 16)
            kw["ssm_head_dim"] = 16
            kw["ssm_chunk"] = 16
        if self.attn_every:
            kw["attn_every"] = 1
        if self.encoder_layers:
            kw["encoder_layers"] = 2
        if self.n_frontend_tokens:
            kw["n_frontend_tokens"] = min(self.n_frontend_tokens, 16)
        if self.sliding_window:
            kw["sliding_window"] = min(self.sliding_window, 64)
        kw["serve_sliding_window"] = min(self.serve_sliding_window, 64)
        return replace(self, **kw)
