"""In-process batched serving loop on real JAX models.

Wave-based batched serving: requests are admitted from a queue into waves of
up to ``max_batch`` sequences (FIFO or length-aware grouping — the same
policies DSD-Sim models), each wave runs the distributed speculative
decoding engine with the configured window policy, and per-request
TTFT/TPOT/e2e metrics are recorded in the same schema as DSD-Sim's analyzer
(so simulator predictions and real execution are directly comparable —
that comparison is benchmarks/fig4's decode-path calibration).

Continuous (iteration-level) batching is modeled in DSD-Sim; the real-model
server uses wave batching, which keeps the engine state dense. Sequences
that finish early in a wave simply stop contributing tokens (their slots pad
until the wave completes).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..core.engine import SpecDecodeEngine
from ..core.window import StaticWindowPolicy, WindowPolicy


@dataclass
class ServeRequest:
    request_id: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    arrival_s: float = 0.0


@dataclass
class ServeResult:
    request_id: int
    tokens: np.ndarray
    ttft_ms: float
    tpot_ms: float
    e2e_ms: float
    acceptance_rate: float


@dataclass
class ServerConfig:
    max_batch: int = 8
    length_aware: bool = True    # LAB wave formation
    pad_to: int = 16             # prompt padding quantum


class SpecDecodeServer:
    def __init__(self, engine: SpecDecodeEngine,
                 window_policy: Optional[WindowPolicy] = None,
                 cfg: Optional[ServerConfig] = None):
        self.engine = engine
        self.policy = window_policy or StaticWindowPolicy(4)
        self.cfg = cfg or ServerConfig()
        self.queue: list[ServeRequest] = []
        self.results: list[ServeResult] = []

    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)

    # -- wave formation (FIFO vs LAB, mirroring sim/policies.py) -------------

    def _next_wave(self) -> list[ServeRequest]:
        if not self.queue:
            return []
        head = self.queue.pop(0)
        wave = [head]
        if self.cfg.length_aware:
            rest = sorted(self.queue,
                          key=lambda r: abs(len(r.prompt) - len(head.prompt)))
            chosen = rest[: self.cfg.max_batch - 1]
            ids = {id(c) for c in chosen}
            self.queue = [r for r in self.queue if id(r) not in ids]
            wave.extend(chosen)
        else:
            while self.queue and len(wave) < self.cfg.max_batch:
                wave.append(self.queue.pop(0))
        return wave

    def _pad_prompts(self, wave: list[ServeRequest]
                     ) -> tuple[np.ndarray, np.ndarray]:
        """RIGHT-pad to the wave max (rounded to pad_to). Right padding is
        exact here: attention pads are overwritten before any query can see
        them (kvcache pos_map induction) and SSM state is identity-masked
        past each sequence's true length."""
        q = self.cfg.pad_to
        maxlen = max(len(r.prompt) for r in wave)
        maxlen = ((maxlen + q - 1) // q) * q
        out = np.zeros((len(wave), maxlen), np.int32)
        lens = np.zeros(len(wave), np.int32)
        for i, r in enumerate(wave):
            out[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        return out, lens

    def run(self) -> list[ServeResult]:
        """Drain the queue; returns per-request results."""
        while self.queue:
            wave = self._next_wave()
            prompts, lens = self._pad_prompts(wave)
            max_new = max(r.max_new_tokens for r in wave)
            t0 = time.perf_counter()
            tokens, stats = self.engine.generate(prompts, max_new,
                                                 window_policy=self.policy,
                                                 prompt_lens=lens)
            wall_ms = (time.perf_counter() - t0) * 1e3
            # wave-level timing attribution: the measured prefill wall time
            # IS the TTFT for every wave member (the anchor token is sampled
            # at the end of prefill); decode time spread per produced token
            ttft_ms = stats.prefill_ms
            decode_ms = max(0.0, wall_ms - ttft_ms)
            for i, r in enumerate(wave):
                n = r.max_new_tokens
                seq_bits = stats.acceptance_seqs[i]
                acc = (sum(seq_bits) / len(seq_bits)) if seq_bits else 0.0
                self.results.append(ServeResult(
                    request_id=r.request_id,
                    tokens=tokens[i, :n],
                    ttft_ms=ttft_ms,
                    tpot_ms=decode_ms / max(1, n - 1),
                    e2e_ms=wall_ms,
                    acceptance_rate=acc))
        return self.results
