"""In-process serving on real JAX models — continuous (iteration-level)
batching over a persistent :class:`repro.core.session.DecodeSession`.

:class:`SpecDecodeServer` is a slot-based continuous scheduler: requests
are admitted into free slots of a live decode session the moment they have
arrived and a slot is open (admission policy mirroring
``sim/policies.py`` — FIFO or length-aware LAB), decode proceeds in
``sync_every``-iteration chunks shared by all co-resident requests, and
finished requests retire at chunk boundaries, freeing their slot for the
next arrival without stalling neighbours. This is the execution model
DSD-Sim assumes (``BatchingConfig.continuous=True``), so simulator
predictions and real execution are directly comparable — that comparison
is ``benchmarks/bench_serving.py``'s sim↔real delta.

Per-request metrics include queue wait: TTFT runs from the request's own
``arrival_s`` to the end of its own prefill-insert (its anchor token), and
e2e to its retirement; token payloads come from the per-sequence cursor,
never from an assumed ``max_new_tokens``.

:class:`WaveSpecDecodeServer` keeps the previous wave-batched execution
model (admit a wave, drain it fully, admit the next) as the measured
baseline: a long sequence holds every slot in its wave hostage, which is
exactly the sim↔real gap the continuous scheduler closes.

``ServerConfig.transport`` routes every speculation round through a
:class:`repro.distributed.Transport` (draft on the edge, target in the
cloud, window/verdict payloads paying measured link delays);
``ServerConfig.mode_policy`` forces or frees the fused/distributed mode
decision. The default (no transport) keeps the colocated fast path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..core.engine import SpecDecodeEngine
from ..core.session import DecodeSession
from ..core.window import StaticWindowPolicy, WindowPolicy


@dataclass
class ServeRequest:
    request_id: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    arrival_s: float = 0.0       # relative to the serve-loop start


@dataclass
class ServeResult:
    request_id: int
    tokens: np.ndarray           # exactly the tokens produced (cursor-true)
    ttft_ms: float               # arrival → own first token (queue incl.)
    tpot_ms: float               # first token → finish, per later token
    e2e_ms: float                # arrival → retirement
    acceptance_rate: float
    queue_ms: float = 0.0        # arrival → admission start


@dataclass
class ServerConfig:
    max_batch: int = 8           # slot-pool capacity
    length_aware: bool = True    # LAB admission (vs FIFO), as in sim
    pad_to: int = 16             # prompt padding quantum
    max_prompt_len: Optional[int] = None   # continuous pad bound
                                           # (default: queue max, rounded)
    max_new_cap: Optional[int] = None      # output width (default: queue max)
    eos_id: int = -1
    sync_every: Optional[int] = None       # admission/retirement granularity
    transport: Optional[object] = None     # repro.distributed.Transport:
                                           # route rounds over a (emulated)
                                           # edge-cloud link
    mode_policy: str = "auto"              # auto | distributed | fused
                                           # | pipeline (overlap rounds)


class _ArrivalClock:
    """Wall clock for the serve loop; ``wait_until`` idles to an arrival."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait_until(self, t_s: float) -> None:
        d = t_s - self.now()
        if d > 0:
            time.sleep(d)


class SpecDecodeServer:
    """Continuous slot-based scheduler over one decode session."""

    def __init__(self, engine: SpecDecodeEngine,
                 window_policy: Optional[WindowPolicy] = None,
                 cfg: Optional[ServerConfig] = None):
        self.engine = engine
        self.policy = window_policy or StaticWindowPolicy(4)
        self.cfg = cfg or ServerConfig()
        self.queue: list[ServeRequest] = []
        self.results: list[ServeResult] = []

    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)

    # -- admission (FIFO vs LAB, mirroring sim/policies.py) ------------------

    def _select_admissions(self, arrived: list[ServeRequest],
                           k: int) -> list[ServeRequest]:
        """Pick ≤ k arrived requests: head-of-line always goes; LAB fills
        the remaining free slots with the requests whose prompt lengths are
        closest to the head's (minimum intra-pool padding waste), FIFO in
        arrival order — the same rule ``sim.policies.LengthAwareBatching``
        applies to a wave."""
        if not arrived or k <= 0:
            return []
        head = arrived[0]
        if not self.cfg.length_aware:
            return arrived[:k]
        rest = sorted(arrived[1:],
                      key=lambda r: abs(len(r.prompt) - len(head.prompt)))
        return [head] + rest[:k - 1]

    # -- serve loop ----------------------------------------------------------

    def _make_session(self, pending: list[ServeRequest]) -> DecodeSession:
        q = self.cfg.pad_to
        mp = self.cfg.max_prompt_len or max(len(r.prompt) for r in pending)
        mp = ((mp + q - 1) // q) * q
        cap = self.cfg.max_new_cap or max(r.max_new_tokens for r in pending)
        gmax = (self.engine.gamma_max or
                self.engine._policy_gamma_bound(self.policy))
        return DecodeSession(self.engine, capacity=self.cfg.max_batch,
                             max_new_cap=cap, max_prompt_len=mp,
                             gamma_max=gmax,
                             sync_every=self.cfg.sync_every,
                             eos_id=self.cfg.eos_id, log_gamma=False,
                             transport=self.cfg.transport,
                             mode_policy=self.cfg.mode_policy)

    def run(self) -> list[ServeResult]:
        """Drain the submitted stream; returns per-request results.

        Loop invariant per cycle: admit arrived requests into free slots →
        run one decode chunk → retire finished slots. When no request is
        in flight the loop idles to the next arrival instead of spinning.
        """
        if not self.queue:
            return self.results
        pending = sorted(self.queue, key=lambda r: r.arrival_s)
        self.queue = []
        session = self._make_session(pending)
        clock = _ArrivalClock()
        in_flight: dict[int, tuple[ServeRequest, float, float]] = {}

        while pending or session.occupied:
            now = clock.now()
            arrived = [r for r in pending if r.arrival_s <= now]
            free = session.free
            if free and arrived:
                for r in self._select_admissions(arrived, len(free)):
                    admit_start = clock.now()
                    session.admit(r.prompt, r.max_new_tokens,
                                  request_id=r.request_id)
                    in_flight[r.request_id] = (r, admit_start, clock.now())
                    pending.remove(r)
                    arrived.remove(r)
            if not session.occupied:
                clock.wait_until(min(r.arrival_s for r in pending))
                continue
            # q_depth: requests that have ARRIVED and wait for a slot —
            # future arrivals must not leak into policy features
            session.run_chunk(
                self.policy,
                q_depth=len(arrived) / max(1, 4 * self.cfg.max_batch))
            for j in session.finished_slots():
                tokens, rec = session.retire(j)
                r, admit_s, first_tok_s = in_flight.pop(rec.request_id)
                end_s = clock.now()
                n = len(tokens)
                bits = rec.bits
                self.results.append(ServeResult(
                    request_id=r.request_id,
                    tokens=tokens,
                    ttft_ms=(first_tok_s - r.arrival_s) * 1e3,
                    tpot_ms=(end_s - first_tok_s) * 1e3 / max(1, n - 1),
                    e2e_ms=(end_s - r.arrival_s) * 1e3,
                    acceptance_rate=(sum(bits) / len(bits)) if bits else 0.0,
                    queue_ms=(admit_s - r.arrival_s) * 1e3))
        return self.results


class WaveSpecDecodeServer:
    """Wave-batched baseline: requests are admitted in waves of up to
    ``max_batch`` sequences (FIFO or LAB grouping), each wave runs
    ``engine.generate`` to the wave-max token budget, and the next wave
    starts only when the whole wave has drained. Kept as the measured
    baseline for ``benchmarks/bench_serving.py``; new code should use the
    continuous :class:`SpecDecodeServer`."""

    def __init__(self, engine: SpecDecodeEngine,
                 window_policy: Optional[WindowPolicy] = None,
                 cfg: Optional[ServerConfig] = None):
        self.engine = engine
        self.policy = window_policy or StaticWindowPolicy(4)
        self.cfg = cfg or ServerConfig()
        self.queue: list[ServeRequest] = []
        self.results: list[ServeResult] = []

    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)

    def _next_wave(self, arrived: list[ServeRequest]) -> list[ServeRequest]:
        head = arrived.pop(0)
        wave = [head]
        if self.cfg.length_aware:
            rest = sorted(arrived,
                          key=lambda r: abs(len(r.prompt) - len(head.prompt)))
            chosen = rest[: self.cfg.max_batch - 1]
            ids = {id(c) for c in chosen}
            arrived[:] = [r for r in arrived if id(r) not in ids]
            wave.extend(chosen)
        else:
            while arrived and len(wave) < self.cfg.max_batch:
                wave.append(arrived.pop(0))
        return wave

    def _pad_prompts(self, wave: list[ServeRequest]
                     ) -> tuple[np.ndarray, np.ndarray]:
        """RIGHT-pad to the wave max (rounded to pad_to). Right padding is
        exact here: attention pads are overwritten before any query can see
        them (kvcache pos_map induction) and SSM state is identity-masked
        past each sequence's true length."""
        q = self.cfg.pad_to
        maxlen = max(len(r.prompt) for r in wave)
        maxlen = ((maxlen + q - 1) // q) * q
        out = np.zeros((len(wave), maxlen), np.int32)
        lens = np.zeros(len(wave), np.int32)
        for i, r in enumerate(wave):
            out[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        return out, lens

    def run(self) -> list[ServeResult]:
        """Drain the queue wave by wave; returns per-request results."""
        pending = sorted(self.queue, key=lambda r: r.arrival_s)
        self.queue = []
        clock = _ArrivalClock()
        while pending:
            now = clock.now()
            arrived = [r for r in pending if r.arrival_s <= now]
            if not arrived:
                clock.wait_until(min(r.arrival_s for r in pending))
                continue
            wave = self._next_wave(arrived)
            for r in wave:
                pending.remove(r)
            prompts, lens = self._pad_prompts(wave)
            max_new = max(r.max_new_tokens for r in wave)
            wave_start = clock.now()
            assert self.cfg.transport is None, \
                "transports need the continuous server"
            tokens, stats = self.engine.generate(
                prompts, max_new, window_policy=self.policy,
                prompt_lens=lens, eos_id=self.cfg.eos_id,
                mode_policy=self.cfg.mode_policy)
            wave_end = clock.now()
            # wave-level timing attribution: the measured prefill wall time
            # IS the first-token time for every wave member (the anchor
            # token is sampled at the end of the batched prefill); decode
            # time spreads per produced token. Queue wait — arrival to the
            # wave's prefill — is part of every member's TTFT.
            first_tok_s = wave_start + stats.prefill_s
            for i, r in enumerate(wave):
                n = min(r.max_new_tokens, int(stats.produced[i]))
                seq_bits = stats.acceptance_seqs[i]
                acc = (sum(seq_bits) / len(seq_bits)) if seq_bits else 0.0
                self.results.append(ServeResult(
                    request_id=r.request_id,
                    tokens=tokens[i, :n],
                    ttft_ms=(first_tok_s - r.arrival_s) * 1e3,
                    tpot_ms=(wave_end - first_tok_s) * 1e3 / max(1, n - 1),
                    e2e_ms=(wave_end - r.arrival_s) * 1e3,
                    acceptance_rate=acc,
                    queue_ms=(wave_start - r.arrival_s) * 1e3))
        return self.results
