"""In-process serving on real JAX models — continuous (iteration-level)
batching over persistent :class:`repro.core.session.DecodeSession` pools,
with TOPOLOGY-FIRST multi-pair routing.

:class:`SpecDecodeServer` serves one deployment of **draft–target pairs**
(:class:`ServingPair`): each pair owns an engine, a window policy, an
optional transport (its edge–cloud link) and a mode policy, and runs its
own slot-based decode session. Requests are admitted into free slots the
moment they have arrived and a slot is open (admission policy mirroring
``sim/policies.py`` — FIFO or length-aware LAB within the chosen pair;
with ``ServerConfig.paged_kv`` admission is additionally block-aware: a
request enters only when every paged side has enough free KV blocks for
its prompt + decode budget, otherwise it waits for retirements),
routed across pairs by a pluggable :class:`PairRouter` (least-loaded by
default; routing is STICKY — a request never migrates off the pair that
admitted it). Decode proceeds in ``sync_every``-iteration chunks per pair
(pairs interleave chunk-by-chunk in one process), and finished requests
retire at chunk boundaries, freeing their slot for the next arrival
without stalling neighbours. This is the execution model DSD-Sim assumes
(``BatchingConfig.continuous=True`` plus per-pair links), so simulator
predictions and real execution are directly comparable — build both from
ONE :class:`repro.topology.ClusterSpec` and the comparison is a property
of the spec, not of per-benchmark plumbing.

The legacy single-pair surface is unchanged:
``SpecDecodeServer(engine, policy, cfg)`` wraps its arguments in a
one-pair deployment (``cfg.transport``/``cfg.mode_policy`` become the
pair's link and mode), and every admission/retirement decision is
bit-identical to the pre-topology server.

Per-request metrics include queue wait: TTFT runs from the request's own
``arrival_s`` to the end of its own prefill-insert (its anchor token), and
e2e to its retirement; token payloads come from the per-sequence cursor,
never from an assumed ``max_new_tokens``. Per-pair operating points
(mean γ, fused fraction, link bytes, measured RTT) are surfaced by
:meth:`SpecDecodeServer.pair_summaries` — heterogeneous links under one
server show per-pair AWC converging to different γ/fused mixes there.

:class:`WaveSpecDecodeServer` keeps the previous wave-batched execution
model (admit a wave, drain it fully, admit the next) as the measured
baseline: a long sequence holds every slot in its wave hostage, which is
exactly the sim↔real gap the continuous scheduler closes.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Protocol, Sequence

import numpy as np

from ..core.engine import SpecDecodeEngine
from ..core.session import DecodeSession
from ..core.window import StaticWindowPolicy, WindowPolicy


@dataclass
class ServeRequest:
    request_id: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    arrival_s: float = 0.0       # relative to the serve-loop start
    request_class: str = ""      # fleet traffic class ("" = unclassified)
    slo_ttft_ms: float = 0.0     # per-request TTFT target (0 = no SLO)
    slo_tpot_ms: float = 0.0     # per-request TPOT target (0 = no SLO)


@dataclass
class ServeResult:
    request_id: int
    tokens: np.ndarray           # exactly the tokens produced (cursor-true)
    ttft_ms: float               # arrival → own first token (queue incl.)
    tpot_ms: float               # first token → finish, per later token
    e2e_ms: float                # arrival → retirement
    acceptance_rate: float
    queue_ms: float = 0.0        # arrival → admission start
    pair_id: str = ""            # draft–target pair that served the request
    request_class: str = ""      # carried from the request (SLO grading)
    slo_ttft_ms: float = 0.0
    slo_tpot_ms: float = 0.0
    shed: bool = False           # SLO admission dropped it (no tokens)


@dataclass
class ServingPair:
    """One deployed draft→target lane: engine + policy + link + mode.

    The runtime unit :func:`repro.topology.build_deployment` emits one of
    per :class:`repro.topology.PairSpec`; constructible directly for
    tests/benchmarks. ``pair_id`` doubles as the window policy's pair key,
    so adaptive policies (Dynamic/AWC) shared across pairs still keep one
    stabilizer per pair.

    A **process-backed** pair (``PairSpec.process: true``) carries no
    local engine or transport: ``host`` is a
    :class:`repro.distributed.host.PairHostHandle` driving draft/target
    worker processes over a :class:`~repro.distributed.SocketTransport`,
    and the server delegates the pair's share of the request stream to
    it."""
    pair_id: str
    engine: Optional[SpecDecodeEngine]
    policy: WindowPolicy
    transport: Optional[object] = None   # repro.distributed.Transport
    mode_policy: str = "auto"            # auto | distributed | fused | pipeline
    host: Optional[object] = None        # repro.distributed.host.PairHostHandle
    session: Optional[DecodeSession] = None  # live session, set by run() so
                                             # α/queue-aware routers can read
                                             # acceptance counters + occupancy
    draining: bool = False               # drained pairs admit nothing new


@dataclass
class ServerConfig:
    max_batch: int = 8           # slot-pool capacity PER PAIR
    length_aware: bool = True    # LAB admission (vs FIFO), as in sim
    pad_to: int = 16             # prompt padding quantum
    max_prompt_len: Optional[int] = None   # continuous pad bound
                                           # (default: queue max, rounded)
    max_new_cap: Optional[int] = None      # output width (default: queue max)
    eos_id: int = -1
    sync_every: Optional[int] = None       # admission/retirement granularity
    transport: Optional[object] = None     # legacy one-pair surface: the
                                           # implicit pair's Transport
    mode_policy: str = "auto"              # legacy one-pair surface: the
                                           # implicit pair's mode policy
    paged_kv: bool = False       # paged block-pool KV cache per pair
    kv_block_size: int = 16      # positions per KV block (paged only)
    kv_pool_blocks: Optional[object] = None  # pool size: int, or dict
                                             # {"draft": n, "target": n};
                                             # None = dense-parity sizing
    kv_quantize: bool = False    # int8 per-entry KV quantization (paged)
    slo_admission: str = "off"   # off | reroute | shed: when a pair's rolling
                                 # p95 TTFT drifts past a request's class SLO,
                                 # reroute it to a healthy pair (or shed it
                                 # outright when none exists and mode=shed)
    slo_min_samples: int = 8     # retirements per pair before SLO admission
                                 # trusts that pair's rolling p95
    slo_window: int = 256        # rolling-quantile window size per pair


# -- pair routing ------------------------------------------------------------

class PairRouter(Protocol):
    """Chooses the draft–target pair that admits a request.

    ``free_slots[i]`` is pair i's current free-slot count; the router must
    return an index with ``free_slots[i] > 0`` (the server only consults it
    while capacity exists somewhere). Routing is sticky by construction:
    the server never migrates an admitted request."""

    def route(self, req: ServeRequest, pairs: Sequence[ServingPair],
              free_slots: Sequence[int]) -> int: ...


class LeastLoadedPairRouter:
    """Default router: the pair with the most free slots (ties break to
    the lowest pair index, which keeps the one-pair case trivially exact
    and multi-pair admission deterministic)."""

    def route(self, req: ServeRequest, pairs: Sequence[ServingPair],
              free_slots: Sequence[int]) -> int:
        return int(max(range(len(free_slots)), key=lambda i: free_slots[i]))


class RoundRobinPairRouter:
    """Cycle over pairs, skipping the ones with no free slot."""

    def __init__(self):
        self._next = 0

    def route(self, req: ServeRequest, pairs: Sequence[ServingPair],
              free_slots: Sequence[int]) -> int:
        n = len(free_slots)
        for k in range(n):
            i = (self._next + k) % n
            if free_slots[i] > 0:
                self._next = i + 1
                return i
        return self._next % n


PAIR_ROUTERS = {
    "least-loaded": LeastLoadedPairRouter,
    "round-robin": RoundRobinPairRouter,
}

# the α/link/queue-aware fleet router registers here too (late import:
# repro.fleet.routing is dependency-free, so this cannot cycle)
from ..fleet.routing import SmartPairRouter  # noqa: E402
PAIR_ROUTERS["smart"] = SmartPairRouter


class _ArrivalClock:
    """Wall clock for the serve loop; ``wait_until`` idles to an arrival."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait_until(self, t_s: float) -> None:
        d = t_s - self.now()
        if d > 0:
            time.sleep(d)


class SpecDecodeServer:
    """Continuous slot-based scheduler over a deployment of draft–target
    pairs (one decode session per pair)."""

    def __init__(self, engine: Optional[SpecDecodeEngine] = None,
                 window_policy: Optional[WindowPolicy] = None,
                 cfg: Optional[ServerConfig] = None, *,
                 pairs: Optional[Sequence[ServingPair]] = None,
                 router: Optional[PairRouter] = None):
        self.cfg = cfg or ServerConfig()
        if pairs is None:
            assert engine is not None, \
                "pass either an engine (one-pair surface) or pairs="
            pairs = [ServingPair(
                pair_id="pair0", engine=engine,
                policy=window_policy or StaticWindowPolicy(4),
                transport=self.cfg.transport,
                mode_policy=self.cfg.mode_policy)]
        else:
            assert engine is None and window_policy is None, \
                "pairs= replaces the engine/window_policy surface"
            assert len(pairs) >= 1, "a deployment needs at least one pair"
            ids = [p.pair_id for p in pairs]
            assert len(set(ids)) == len(ids), f"duplicate pair ids: {ids}"
        hosted = [p.host is not None for p in pairs]
        assert all(hosted) or not any(hosted), \
            "process-backed and in-process pairs cannot mix in one server"
        assert all(p.engine is not None or p.host is not None for p in pairs), \
            "every pair needs an engine (in-process) or a host (process)"
        self._process_backed = all(hosted) and any(hosted)
        self.pairs = list(pairs)
        self.router = router or LeastLoadedPairRouter()
        # legacy attribute surface (bench/test introspection)
        self.engine = self.pairs[0].engine
        self.policy = self.pairs[0].policy
        self.queue: list[ServeRequest] = []
        self.results: list[ServeResult] = []
        self._sessions: list[DecodeSession] = []
        self._served = [0] * len(self.pairs)
        from ..fleet.stats import RollingQuantile
        self._ttft_q = [RollingQuantile(self.cfg.slo_window)
                        for _ in self.pairs]
        self._tpot_q = [RollingQuantile(self.cfg.slo_window)
                        for _ in self.pairs]
        self._shed = [0] * len(self.pairs)

    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)

    # -- drain / re-admit ----------------------------------------------------

    def drain(self, pair_id: str) -> None:
        """Stop routing NEW requests to a pair; in-flight sequences finish
        normally (routing is sticky, so nothing migrates off)."""
        self._pair_by_id(pair_id).draining = True

    def undrain(self, pair_id: str) -> None:
        """Re-admit a drained pair into the routable set."""
        self._pair_by_id(pair_id).draining = False

    def _pair_by_id(self, pair_id: str) -> ServingPair:
        for p in self.pairs:
            if p.pair_id == pair_id:
                return p
        raise KeyError(f"no pair {pair_id!r} in this deployment")

    # -- admission (FIFO vs LAB, mirroring sim/policies.py) ------------------

    def _select_admissions(self, arrived: list[ServeRequest],
                           k: int) -> list[ServeRequest]:
        """Pick ≤ k arrived requests for ONE pair: head-of-line always
        goes; LAB fills the remaining free slots with the requests whose
        prompt lengths are closest to the head's (minimum intra-pool
        padding waste), FIFO in arrival order — the same rule
        ``sim.policies.LengthAwareBatching`` applies to a wave."""
        if not arrived or k <= 0:
            return []
        head = arrived[0]
        if not self.cfg.length_aware:
            return arrived[:k]
        rest = sorted(arrived[1:],
                      key=lambda r: abs(len(r.prompt) - len(head.prompt)))
        return [head] + rest[:k - 1]

    # -- serve loop ----------------------------------------------------------

    def _make_session(self, pair: ServingPair,
                      pending: list[ServeRequest]) -> DecodeSession:
        q = self.cfg.pad_to
        mp = self.cfg.max_prompt_len or max(len(r.prompt) for r in pending)
        mp = ((mp + q - 1) // q) * q
        cap = self.cfg.max_new_cap or max(r.max_new_tokens for r in pending)
        eng = pair.engine
        gmax = eng.gamma_max or eng._policy_gamma_bound(pair.policy)
        return DecodeSession(eng, capacity=self.cfg.max_batch,
                             max_new_cap=cap, max_prompt_len=mp,
                             gamma_max=gmax,
                             sync_every=self.cfg.sync_every,
                             eos_id=self.cfg.eos_id, log_gamma=False,
                             transport=pair.transport,
                             mode_policy=pair.mode_policy,
                             pair_key=pair.pair_id,
                             paged=self.cfg.paged_kv,
                             kv_block_size=self.cfg.kv_block_size,
                             kv_pool_blocks=self.cfg.kv_pool_blocks,
                             kv_quantize=self.cfg.kv_quantize)

    def run(self) -> list[ServeResult]:
        """Drain the submitted stream; returns per-request results.

        Loop invariant per cycle: route + admit arrived requests into free
        slots (head-of-line request picks its pair via the router, LAB/FIFO
        co-admission fills that pair's remaining slots) → run one decode
        chunk per occupied pair → retire finished slots. When no request
        is in flight the loop idles to the next arrival instead of
        spinning.
        """
        if not self.queue:
            return self.results
        if self._process_backed:
            return self._run_process_backed()
        pending = sorted(self.queue, key=lambda r: r.arrival_s)
        self.queue = []
        sessions = [self._make_session(p, pending) for p in self.pairs]
        self._sessions = sessions
        for pair, sess in zip(self.pairs, sessions):
            pair.session = sess     # routers read live acceptance/occupancy
        self._served = [0] * len(self.pairs)
        clock = _ArrivalClock()
        # request_id -> (request, admit_start_s, first_token_s, pair_idx)
        in_flight: dict[int, tuple[ServeRequest, float, float, int]] = {}

        while pending or any(s.occupied for s in sessions):
            now = clock.now()
            arrived = [r for r in pending if r.arrival_s <= now]
            if (arrived and all(p.draining for p in self.pairs)
                    and not any(s.occupied for s in sessions)):
                raise RuntimeError(
                    "every pair is draining with requests still pending — "
                    "undrain a pair to keep serving")
            while arrived:
                # a draining pair advertises zero free slots: routers skip
                # it, in-flight sequences keep decoding until retirement
                frees = [0 if p.draining else len(s.free)
                         for p, s in zip(self.pairs, sessions)]
                if not any(frees):
                    break
                idx = self.router.route(arrived[0], self.pairs, frees)
                if frees[idx] <= 0:
                    break
                routed = self._apply_slo_admission(arrived, pending, idx,
                                                   frees, clock)
                if routed is None:
                    continue    # head shed; retry with the next head
                idx = routed
                admitted_any = False
                for r in self._select_admissions(arrived, frees[idx]):
                    # block-aware admission: a paged session may have a free
                    # slot but not enough free KV blocks for this request's
                    # budget — skip it and let retirements free blocks
                    # (can_admit == slot check for dense sessions)
                    if not sessions[idx].can_admit(len(r.prompt),
                                                   r.max_new_tokens):
                        continue
                    admit_start = clock.now()
                    sessions[idx].admit(r.prompt, r.max_new_tokens,
                                        request_id=r.request_id)
                    in_flight[r.request_id] = (r, admit_start, clock.now(),
                                               idx)
                    pending.remove(r)
                    arrived.remove(r)
                    self._served[idx] += 1
                    admitted_any = True
                if not admitted_any:
                    break  # no capacity progress — decode to free blocks
            if not any(s.occupied for s in sessions):
                clock.wait_until(min(r.arrival_s for r in pending))
                continue
            # q_depth: requests that have ARRIVED and wait for a slot —
            # future arrivals must not leak into policy features
            q_depth = len(arrived) / max(1, 4 * self.cfg.max_batch)
            for idx, sess in enumerate(sessions):
                if not sess.occupied:
                    continue
                sess.run_chunk(self.pairs[idx].policy, q_depth=q_depth)
                for j in sess.finished_slots():
                    tokens, rec = sess.retire(j)
                    r, admit_s, first_tok_s, _ = in_flight.pop(rec.request_id)
                    end_s = clock.now()
                    n = len(tokens)
                    bits = rec.bits
                    ttft = (first_tok_s - r.arrival_s) * 1e3
                    tpot = (end_s - first_tok_s) * 1e3 / max(1, n - 1)
                    self._ttft_q[idx].push(ttft)
                    self._tpot_q[idx].push(tpot)
                    self.results.append(ServeResult(
                        request_id=r.request_id,
                        tokens=tokens,
                        ttft_ms=ttft,
                        tpot_ms=tpot,
                        e2e_ms=(end_s - r.arrival_s) * 1e3,
                        acceptance_rate=(sum(bits) / len(bits)) if bits
                        else 0.0,
                        queue_ms=(admit_s - r.arrival_s) * 1e3,
                        pair_id=self.pairs[idx].pair_id,
                        request_class=r.request_class,
                        slo_ttft_ms=r.slo_ttft_ms,
                        slo_tpot_ms=r.slo_tpot_ms))
        return self.results

    # -- SLO-aware admission -------------------------------------------------

    def _slo_risky(self, idx: int, req: ServeRequest) -> bool:
        """Pair idx's rolling p95 TTFT has drifted past the request's SLO
        (only once enough retirements have been observed to trust it)."""
        if req.slo_ttft_ms <= 0:
            return False
        q = self._ttft_q[idx]
        return (len(q) >= self.cfg.slo_min_samples
                and q.p95() > req.slo_ttft_ms)

    def _apply_slo_admission(self, arrived: list[ServeRequest],
                             pending: list[ServeRequest], idx: int,
                             frees: Sequence[int],
                             clock: _ArrivalClock) -> Optional[int]:
        """SLO gate between routing and admission for the head-of-line
        request. Returns the (possibly rerouted) pair index, or None when
        the head was shed (mode=shed, no healthy pair). With
        ``slo_admission='off'`` this is the identity on ``idx``."""
        if self.cfg.slo_admission == "off":
            return idx
        head = arrived[0]
        if not self._slo_risky(idx, head):
            return idx
        # healthiest alternative with a free slot: unmeasured pairs count
        # as healthy (no evidence of drift), measured ones need p95 <= SLO
        best, best_p95 = None, None
        for i in range(len(self.pairs)):
            if i == idx or frees[i] <= 0 or self._slo_risky(i, head):
                continue
            p95 = self._ttft_q[i].p95()
            key = p95 if len(self._ttft_q[i]) else 0.0
            if best_p95 is None or key < best_p95:
                best, best_p95 = i, key
        if best is not None:
            return best
        if self.cfg.slo_admission != "shed":
            return idx      # reroute mode: nowhere better, admit anyway
        end_s = clock.now()
        self._shed[idx] += 1
        pending.remove(head)
        arrived.remove(head)
        self.results.append(ServeResult(
            request_id=head.request_id, tokens=np.zeros(0, np.int32),
            ttft_ms=float("inf"), tpot_ms=0.0,
            e2e_ms=(end_s - head.arrival_s) * 1e3, acceptance_rate=0.0,
            queue_ms=(end_s - head.arrival_s) * 1e3,
            pair_id=self.pairs[idx].pair_id,
            request_class=head.request_class,
            slo_ttft_ms=head.slo_ttft_ms, slo_tpot_ms=head.slo_tpot_ms,
            shed=True))
        return None

    def _run_process_backed(self) -> list[ServeResult]:
        """Drive process-backed pairs CONCURRENTLY: each pair's host
        handle serves its round-robin share of the request stream on its
        own thread, so the pairs' draft/target worker processes decode in
        true parallel (the whole point of ``PairSpec.process``). Wave
        batching per pair mirrors :class:`WaveSpecDecodeServer` — the
        continuous chunk scheduler needs an in-process session."""
        import threading

        pending = sorted(self.queue, key=lambda r: r.arrival_s)
        self.queue = []
        buckets: list[list[ServeRequest]] = [[] for _ in self.pairs]
        for i, r in enumerate(pending):
            buckets[i % len(self.pairs)].append(r)
        self._served = [len(b) for b in buckets]
        per_pair: list[list] = [[] for _ in self.pairs]
        errors: list[BaseException] = []

        def drive(idx: int) -> None:
            try:
                per_pair[idx] = self.pairs[idx].host.serve(buckets[idx])
            except BaseException as e:   # surface on the caller's thread
                errors.append(e)

        threads = [threading.Thread(target=drive, args=(i,), daemon=True)
                   for i in range(len(self.pairs)) if buckets[i]]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        merged = [res for bucket in per_pair for res in bucket]
        merged.sort(key=lambda res: res.request_id)
        self.results.extend(merged)
        return self.results

    # -- per-pair observability ----------------------------------------------

    def pair_summaries(self) -> dict[str, dict]:
        """Per-pair operating point after :meth:`run`, keyed by pair id:
        request/iteration counts, mean effective γ, fused fraction,
        acceptance, pipeline hit counters, rolling p50/p95 TTFT/TPOT over
        the last ``slo_window`` retirements (the same windows SLO-aware
        admission consults; NaN until a retirement lands), and — when the
        pair has a transport — its link stats (bytes, messages, measured
        RTT)."""
        out: dict[str, dict] = {}
        if self._process_backed:
            for pair, served in zip(self.pairs, self._served):
                row = pair.host.summary()
                row["requests"] = served
                out[pair.pair_id] = row
            return out
        for i, (pair, sess, served) in enumerate(zip(self.pairs,
                                                     self._sessions,
                                                     self._served)):
            d = {
                "requests": served,
                "iterations": sess.iterations,
                "mean_gamma": round(sess.mean_gamma, 3),
                "fused_fraction": round(
                    sess.fused_iterations / max(1, sess.iterations), 4),
                "acceptance_rate": round(
                    sess.accepted / max(1, sess.proposed), 4),
                "pipeline_hits": sess.pipeline_hits,
                "pipeline_misses": sess.pipeline_misses,
                "link_ms": round(sess.link_ms, 2),
                "mode_policy": pair.mode_policy,
                "ttft_p50_ms": round(self._ttft_q[i].p50(), 3),
                "ttft_p95_ms": round(self._ttft_q[i].p95(), 3),
                "tpot_p50_ms": round(self._tpot_q[i].p50(), 3),
                "tpot_p95_ms": round(self._tpot_q[i].p95(), 3),
                "shed": self._shed[i],
            }
            fb = sess.free_kv_blocks()
            if fb is not None:
                d["free_kv_blocks"] = fb
            tr = pair.transport
            if tr is not None:
                d.update(
                    transport=tr.describe(),
                    bytes_sent=tr.bytes_sent,
                    messages=tr.messages_sent,
                    recent_rtt_ms=round(tr.recent_rtt_ms, 3))
            out[pair.pair_id] = d
        return out


class WaveSpecDecodeServer:
    """Wave-batched baseline: requests are admitted in waves of up to
    ``max_batch`` sequences (FIFO or LAB grouping), each wave runs
    ``engine.generate`` to the wave-max token budget, and the next wave
    starts only when the whole wave has drained. Kept as the measured
    baseline for ``benchmarks/bench_serving.py``; new code should use the
    continuous :class:`SpecDecodeServer`. Single-pair colocated only."""

    def __init__(self, engine: SpecDecodeEngine,
                 window_policy: Optional[WindowPolicy] = None,
                 cfg: Optional[ServerConfig] = None):
        self.engine = engine
        self.policy = window_policy or StaticWindowPolicy(4)
        self.cfg = cfg or ServerConfig()
        self.queue: list[ServeRequest] = []
        self.results: list[ServeResult] = []

    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)

    def _next_wave(self, arrived: list[ServeRequest]) -> list[ServeRequest]:
        head = arrived.pop(0)
        wave = [head]
        if self.cfg.length_aware:
            rest = sorted(arrived,
                          key=lambda r: abs(len(r.prompt) - len(head.prompt)))
            chosen = rest[: self.cfg.max_batch - 1]
            ids = {id(c) for c in chosen}
            arrived[:] = [r for r in arrived if id(r) not in ids]
            wave.extend(chosen)
        else:
            while arrived and len(wave) < self.cfg.max_batch:
                wave.append(arrived.pop(0))
        return wave

    def _pad_prompts(self, wave: list[ServeRequest]
                     ) -> tuple[np.ndarray, np.ndarray]:
        """RIGHT-pad to the wave max (rounded to pad_to). Right padding is
        exact here: attention pads are overwritten before any query can see
        them (kvcache pos_map induction) and SSM state is identity-masked
        past each sequence's true length."""
        q = self.cfg.pad_to
        maxlen = max(len(r.prompt) for r in wave)
        maxlen = ((maxlen + q - 1) // q) * q
        out = np.zeros((len(wave), maxlen), np.int32)
        lens = np.zeros(len(wave), np.int32)
        for i, r in enumerate(wave):
            out[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        return out, lens

    def run(self) -> list[ServeResult]:
        """Drain the queue wave by wave; returns per-request results."""
        pending = sorted(self.queue, key=lambda r: r.arrival_s)
        self.queue = []
        clock = _ArrivalClock()
        while pending:
            now = clock.now()
            arrived = [r for r in pending if r.arrival_s <= now]
            if not arrived:
                clock.wait_until(min(r.arrival_s for r in pending))
                continue
            wave = self._next_wave(arrived)
            for r in wave:
                pending.remove(r)
            prompts, lens = self._pad_prompts(wave)
            max_new = max(r.max_new_tokens for r in wave)
            wave_start = clock.now()
            assert self.cfg.transport is None, \
                "transports need the continuous server"
            tokens, stats = self.engine.generate(
                prompts, max_new, window_policy=self.policy,
                prompt_lens=lens, eos_id=self.cfg.eos_id,
                mode_policy=self.cfg.mode_policy)
            wave_end = clock.now()
            # wave-level timing attribution: the measured prefill wall time
            # IS the first-token time for every wave member (the anchor
            # token is sampled at the end of the batched prefill); decode
            # time spreads per produced token. Queue wait — arrival to the
            # wave's prefill — is part of every member's TTFT.
            first_tok_s = wave_start + stats.prefill_s
            for i, r in enumerate(wave):
                n = min(r.max_new_tokens, int(stats.produced[i]))
                seq_bits = stats.acceptance_seqs[i]
                acc = (sum(seq_bits) / len(seq_bits)) if seq_bits else 0.0
                self.results.append(ServeResult(
                    request_id=r.request_id,
                    tokens=tokens[i, :n],
                    ttft_ms=(first_tok_s - r.arrival_s) * 1e3,
                    tpot_ms=(wave_end - first_tok_s) * 1e3 / max(1, n - 1),
                    e2e_ms=(wave_end - r.arrival_s) * 1e3,
                    acceptance_rate=acc,
                    queue_ms=(wave_start - r.arrival_s) * 1e3))
        return self.results
