"""Serving substrate: continuous slot-based request serving over
per-pair persistent DecodeSessions with pluggable pair routing (plus the
wave-batched baseline)."""

from .server import (PAIR_ROUTERS, LeastLoadedPairRouter, PairRouter,
                     RoundRobinPairRouter, ServeRequest, ServeResult,
                     ServerConfig, ServingPair, SmartPairRouter,
                     SpecDecodeServer, WaveSpecDecodeServer)
