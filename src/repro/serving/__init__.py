"""Serving substrate: continuous slot-based request serving over a
persistent DecodeSession (plus the wave-batched baseline)."""

from .server import (ServeRequest, ServeResult, ServerConfig,
                     SpecDecodeServer, WaveSpecDecodeServer)
