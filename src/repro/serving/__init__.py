"""Serving substrate: batched request serving over the SD engine."""

from .server import (ServeRequest, ServeResult, ServerConfig,
                     SpecDecodeServer)
