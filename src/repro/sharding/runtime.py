"""Activation-sharding runtime hook.

``lax.scan`` over layers stores the carry (B, S, D) per layer as the
backward residual; unconstrained, XLA may keep it replicated over the
``model`` axis — 80-layer × multi-GB residuals blow the 16 GB/chip budget.
The launcher installs a sequence-parallel constraint (batch→data,
seq→model) that model code applies at every layer boundary via
:func:`constrain`; outside the launcher (tests, single-device runs) the hook
is a no-op.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax

_ACTIVATION_SHARDING: Optional[jax.sharding.NamedSharding] = None
_QKV_SHARDING: Optional[jax.sharding.NamedSharding] = None
_LOGITS_SHARDING: Optional[jax.sharding.NamedSharding] = None
_HEAD_IN_SHARDING: Optional[jax.sharding.NamedSharding] = None


def set_activation_sharding(sharding) -> None:
    global _ACTIVATION_SHARDING
    _ACTIVATION_SHARDING = sharding


@contextlib.contextmanager
def activation_sharding(sharding, qkv=None, logits=None, head_in=None):
    """Install residual (B,S,D), q/k/v (B,T,H,hd), and lm-head constraints."""
    global _ACTIVATION_SHARDING, _QKV_SHARDING, _LOGITS_SHARDING, \
        _HEAD_IN_SHARDING
    prev = (_ACTIVATION_SHARDING, _QKV_SHARDING, _LOGITS_SHARDING,
            _HEAD_IN_SHARDING)
    _ACTIVATION_SHARDING = sharding
    _QKV_SHARDING = qkv
    _LOGITS_SHARDING = logits
    _HEAD_IN_SHARDING = head_in
    try:
        yield
    finally:
        (_ACTIVATION_SHARDING, _QKV_SHARDING, _LOGITS_SHARDING,
         _HEAD_IN_SHARDING) = prev


def _apply(h: jax.Array, s) -> jax.Array:
    if s is None or len(s.spec) != h.ndim:
        return h
    # fit the spec to the concrete shape: axes that don't divide a dim are
    # relocated (e.g. 8 kv heads can't shard over model=16 — padding them
    # doubles the score tensors; shard head_dim instead)
    from .specs import fit_spec
    fitted = fit_spec(s.mesh, s.spec, tuple(h.shape))
    return jax.lax.with_sharding_constraint(
        h, jax.sharding.NamedSharding(s.mesh, fitted))


def constrain(h: jax.Array) -> jax.Array:
    """Residual-stream constraint (sequence-parallel scan carry)."""
    return _apply(h, _ACTIVATION_SHARDING)


def constrain_qkv(x: jax.Array) -> jax.Array:
    """Head-parallel constraint on attention q/k/v projections. Forces the
    seq-parallel↔head-parallel transition onto the small (B,S,H,hd)
    projections — without it XLA reshards the O(S²) attention-weight tensors
    in the backward pass (observed: 24 GiB f32 all-gathers, command-r
    train_4k; EXPERIMENTS.md §Perf cycle 1)."""
    return _apply(x, _QKV_SHARDING)


def constrain_head_in(h: jax.Array) -> jax.Array:
    """De-seq-shard the hidden states entering the lm head (vocab-parallel
    CE needs the contraction dims unsharded on 'model')."""
    return _apply(h, _HEAD_IN_SHARDING)


def constrain_logits(x: jax.Array) -> jax.Array:
    """Vocab-parallel logits: keeps the lm_head gradient sharded (D, V/16)
    instead of a replicated post-psum (D, V) f32 (§Perf cycle 6)."""
    return _apply(x, _LOGITS_SHARDING)
