"""Partition specs for the production mesh (deliverable e).

Mesh axes are fixed by the deployment contract: single-pod ``(data=16,
model=16)``, multi-pod ``(pod=2, data=16, model=16)``. Logical→mesh rules:

- batch            → (pod, data)
- vocab/heads/ffn/experts/ssm-heads → model   (tensor/expert parallel)
- d_model (weights)→ data  (ZeRO-3/FSDP: 2-D weight sharding so the 104-480B
  archs fit 16 GB/chip; XLA inserts the per-layer all-gathers)
- KV-cache: batch→(pod,data), kv_heads→model. When the global batch cannot
  cover the data axis (long_500k, batch=1) the cache *sequence* dim shards
  over data instead (context parallelism).
- uneven dims (40 heads / 16, 8 kv-heads / 16, 24 ssm-heads / 16) rely on
  GSPMD's padded uneven sharding — documented waste, attacked in §Perf.

Implemented as path-pattern rules over the parameter pytree so one table
covers every family.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """Version-compatible :class:`jax.sharding.AbstractMesh` constructor.

    jax ≤ 0.4.x takes one ``((name, size), ...)`` shape tuple; newer
    releases take ``(axis_sizes, axis_names)``. Spec/fit logic only needs
    axis names and sizes, so tests and the dry-run build meshes through this
    helper instead of pinning a jax version."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def _axis_size_of(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return int(mesh.shape[axes])
    return int(np.prod([mesh.shape[a] for a in axes]))


def fit_spec(mesh: Mesh, spec: P, shape: tuple[int, ...]) -> P:
    """Repair a preferred PartitionSpec against a concrete shape.

    pjit input shardings require exact divisibility. For every dim whose
    assigned axis doesn't divide it, the axis is *relocated* to the largest
    currently-unsharded dim it does divide (e.g. qwen3's 40 heads can't
    shard over model=16, so 'model' moves to head_dim=128; whisper's odd
    51865-vocab drops the vocab sharding entirely). Tuple axes degrade to
    the largest dividing sub-axis before relocating.
    """
    out: list = list(spec) + [None] * (len(shape) - len(spec))
    orphans: list = []
    for i, ax in enumerate(out):
        if ax is None:
            continue
        if shape[i] % _axis_size_of(mesh, ax) == 0:
            continue
        placed = False
        if isinstance(ax, tuple):
            # try sub-axes (largest first)
            for sub in sorted(ax, key=lambda a: -mesh.shape[a]):
                if shape[i] % mesh.shape[sub] == 0:
                    out[i] = sub
                    orphans.extend(a for a in ax if a != sub)
                    placed = True
                    break
        if not placed:
            orphans.extend([ax] if isinstance(ax, str) else list(ax))
            out[i] = None
    # relocate orphaned axes onto unsharded dims (largest dims first)
    for ax in orphans:
        size = mesh.shape[ax] if isinstance(ax, str) else _axis_size_of(mesh, ax)
        cands = sorted((j for j in range(len(shape))
                        if out[j] is None and shape[j] % size == 0
                        and shape[j] >= size),
                       key=lambda j: -shape[j])
        if cands:
            out[cands[0]] = ax
    while out and out[-1] is None:
        out.pop()
    return P(*out)


class ShardingRules:
    """Per-arch partition-spec factory bound to a mesh."""

    def __init__(self, mesh: Mesh, cfg: ModelConfig,
                 fsdp_over_pod: bool = True):
        self.mesh = mesh
        self.cfg = cfg
        axes = mesh.axis_names
        self.has_pod = "pod" in axes
        self.dp: Any = (("pod", "data") if self.has_pod else "data")
        # FSDP axis for weight d_model dims
        self.fsdp: Any = (("pod", "data") if (self.has_pod and fsdp_over_pod)
                          else "data")
        self.tp = "model"

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ---------------------------------------------------------------- params

    def param_spec(self, path: str, ndim: int) -> P:
        """Rules keyed on parameter path suffix. Leading ``layers/`` stacking
        axis (or encoder/) is never sharded."""
        fs, tp = self.fsdp, self.tp
        stacked = path.startswith(("layers/", "encoder/"))

        def L(*dims):   # prepend the (unsharded) layer-stack axis
            return P(None, *dims) if stacked else P(*dims)

        leaf = path.split("/")[-1]
        if leaf == "embed":
            # vocab dim UNSHARDED: a vocab-sharded gather's backward is a
            # scatter GSPMD can only handle by replicating the full (V, D)
            # f32 gradient (§Perf cycle 5: 11.7 GiB/device buffers on
            # command-r). Sharding d_model over every axis keeps both the
            # gather and its scatter-grad fully local.
            emb_axes = (("pod", "data", "model") if self.has_pod
                        else ("data", "model"))
            return P(None, emb_axes)               # (V, D)
        if leaf == "lm_head":
            return P(fs, tp)                       # (D, V)
        if leaf in ("final_norm", "enc_norm"):
            return P(None)
        if leaf in ("wq", "wk", "wv"):
            return L(fs, tp, None)                 # (D, H, hd)
        if leaf == "wo":
            return L(tp, None, fs)                 # (H, hd, D)
        if leaf in ("bq", "bk", "bv"):
            return L(tp, None)                     # (H, hd)
        if leaf in ("q_norm", "k_norm"):
            return L(None)
        if leaf in ("ln1", "ln2", "ln_x", "norm"):
            return L(None)
        if leaf in ("w_gate", "w_up"):
            if "moe" in path:
                # experts→model, d_ff→data (Megatron FFN-TP inside each
                # expert): the down-proj contracts the sharded F dim into an
                # activation-sized psum instead of FSDP re-gathering ~2 GB of
                # expert weights per layer (§Perf llama4 cycle)
                return L(tp, None, fs)             # (E, D, F)
            return L(fs, tp)                       # (D, F)
        if leaf == "w_down":
            if "moe" in path:
                return L(tp, fs, None)             # (E, F, D)
            return L(tp, fs)                       # (F, D)
        if leaf == "router":
            return L(fs, tp)                       # (D, E)
        if leaf in ("res_gate", "res_up"):
            return L(fs, tp)
        if leaf == "res_down":
            return L(tp, fs)
        if leaf == "in_proj":
            return L(fs, tp)                       # (D, 2din+2N+nh)
        if leaf == "out_proj":
            return L(tp, fs)                       # (din, D)
        if leaf in ("conv_w",):
            return L(None, tp)                     # (K, C)
        if leaf in ("conv_b",):
            return L(tp)
        if leaf in ("A_log", "D", "dt_bias"):
            return L(tp)                           # (nh,)
        # default: replicate
        return P(*([None] * ndim)) if not stacked else P(None)

    def params_sharding(self, params_shape: Any) -> Any:
        def spec_for(path, leaf):
            pref = self.param_spec(_path_str(path), leaf.ndim)
            return self.named(fit_spec(self.mesh, pref, tuple(leaf.shape)))
        return jax.tree_util.tree_map_with_path(spec_for, params_shape)

    # ----------------------------------------------------------------- data

    def batch_spec(self, global_batch: int) -> Any:
        """Batch axis factor(s) the global batch can actually cover."""
        dp_size = self._axis_size(self.dp)
        if global_batch % dp_size == 0:
            return self.dp
        if self.has_pod and global_batch % self.mesh.shape["pod"] == 0:
            return "pod"
        return None

    def _axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            return self.mesh.shape[axes]
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def tokens_sharding(self, global_batch: int) -> NamedSharding:
        return self.named(P(self.batch_spec(global_batch), None))

    def frontend_sharding(self, global_batch: int) -> NamedSharding:
        return self.named(P(self.batch_spec(global_batch), None, None))

    def vector_sharding(self, global_batch: int) -> NamedSharding:
        """(B,) vectors: tokens/positions during decode."""
        return self.named(P(self.batch_spec(global_batch)))

    # ---------------------------------------------------------------- caches

    def cache_sharding(self, cache_shape: Any, global_batch: int) -> Any:
        """AttnCache k/v (L,B,S,Hkv,hd), pos_map (L,B,S); SSMCache conv
        (L,B,K-1,C), state (L,B,nh,hd,N); nested for hybrid/encdec."""
        bspec = self.batch_spec(global_batch)
        # context parallelism when the batch can't cover the data axis
        seq_axis = None
        if bspec is None or (bspec == "pod" and not self.has_pod is None):
            seq_axis = "data"
        elif bspec == "pod":
            seq_axis = "data"

        def spec_for(path, leaf):
            name = _path_str(path)
            nd = getattr(leaf, "ndim", 0)
            lf = name.split("/")[-1]
            if nd == 0:   # ring flag etc.
                return self.named(P())
            if lf in ("k", "v", "cross_k", "cross_v") and nd == 5:
                pref = P(None, bspec, seq_axis, self.tp, None)
            elif lf == "pos_map" and nd == 3:
                pref = P(None, bspec, seq_axis)
            elif lf == "conv" and nd == 4:
                pref = P(None, bspec, None, self.tp)
            elif lf == "state" and nd == 5:
                pref = P(None, bspec, self.tp, None, None)
            else:
                pref = P(*([None] * nd))
            return self.named(fit_spec(self.mesh, pref, tuple(leaf.shape)))

        return jax.tree_util.tree_map_with_path(spec_for, cache_shape)

    # ------------------------------------------------------------- trainstate

    def train_state_sharding(self, state_shape: Any, params_sharding: Any
                             ) -> Any:
        """Optimizer moments inherit the param sharding; step replicated."""
        from ..training.train_step import TrainState
        return TrainState(
            params=params_sharding,
            opt=type(state_shape.opt)(
                step=self.named(P()),
                mu=params_sharding,
                nu=params_sharding),
            step=self.named(P()))
