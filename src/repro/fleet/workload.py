"""Trace-driven fleet workloads: request classes, load shapes, SLOs.

A :class:`TraceSpec` describes a heterogeneous request stream the way
:class:`repro.topology.ClusterSpec` describes a deployment — declaratively
and JSON round-trippable. It combines

- :class:`RequestClass` rows (chat vs long-context vs batch-offline …):
  per-class lognormal prompt/output length distributions, per-class
  TTFT/TPOT SLOs (0 = no SLO), an arrival weight, and the per-class
  acceptance regime (``alpha``/``rho``) the sim's Markov acceptance
  streams replay;
- a load *shape*: ``constant`` Poisson, ``diurnal`` (sinusoidal rate
  modulation — the day/night curve), ``burst`` (periodic rate spikes), or
  ``replay`` of an explicitly recorded arrival list.

:func:`generate_requests` expands a spec into one seeded, deterministic
:class:`FleetRequest` stream; identical specs replay identical streams.
Two adapters consume the SAME stream so sim↔real workload parity is a
property of the spec:

- :func:`fleet_serve_requests` → real-path
  :class:`repro.serving.ServeRequest` rows (token prompts drawn from the
  same seed, SLOs attached);
- :func:`fleet_trace_records` → DSD-Sim :class:`repro.sim.trace
  .TraceRecord` rows (class-matched Markov acceptance bits, SLOs
  attached, ``drafter_id = -1`` so the sim's pair router assigns the lane
  at arrival time).

Nonhomogeneous arrivals use Lewis–Shedler thinning: sample a homogeneous
Poisson stream at the shape's peak rate, keep each arrival with
probability ``rate(t)/peak`` — exact, and deterministic under one
``random.Random(seed)``.
"""

from __future__ import annotations

import dataclasses
import json
import math
import random
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


class WorkloadError(ValueError):
    """A TraceSpec / RequestClass failed validation."""


# canonical class presets (chat / long-context / batch-offline); a
# TraceSpec may declare any classes it likes — these are the paper-shaped
# defaults benches and examples start from
def default_classes() -> list["RequestClass"]:
    return [
        RequestClass(name="chat", weight=0.6, prompt_mean=24, prompt_sigma=0.4,
                     prompt_max=128, output_mean=24, output_sigma=0.4,
                     output_max=96, slo_ttft_ms=2000.0, slo_tpot_ms=120.0,
                     alpha=0.8, rho=0.5),
        RequestClass(name="long-context", weight=0.25, prompt_mean=220,
                     prompt_sigma=0.35, prompt_max=1024, output_mean=48,
                     output_sigma=0.4, output_max=192, slo_ttft_ms=6000.0,
                     slo_tpot_ms=300.0, alpha=0.7, rho=0.45),
        RequestClass(name="batch-offline", weight=0.15, prompt_mean=48,
                     prompt_sigma=0.5, prompt_max=512, output_mean=96,
                     output_sigma=0.5, output_max=384, slo_ttft_ms=0.0,
                     slo_tpot_ms=0.0, alpha=0.75, rho=0.5),
    ]


@dataclass
class RequestClass:
    """One traffic class: length distributions + SLOs + acceptance regime.

    Lengths are lognormal (empirically heavy-tailed, matching
    :mod:`repro.sim.trace`'s dataset profiles); ``slo_ttft_ms`` /
    ``slo_tpot_ms`` are per-request latency targets (0 disables that SLO —
    batch-offline traffic typically carries none); ``alpha``/``rho`` feed
    the sim's two-state Markov acceptance stream for requests of this
    class (the real path measures acceptance, the sim replays it)."""
    name: str
    weight: float = 1.0          # share of arrivals (normalized over classes)
    prompt_mean: float = 32.0    # lognormal mean prompt length (tokens)
    prompt_sigma: float = 0.4    # lognormal sigma of ln(length)
    prompt_min: int = 4
    prompt_max: int = 512
    output_mean: float = 32.0
    output_sigma: float = 0.4
    output_min: int = 4
    output_max: int = 256
    slo_ttft_ms: float = 0.0     # time-to-first-token target (0 = no SLO)
    slo_tpot_ms: float = 0.0     # time-per-output-token target (0 = no SLO)
    alpha: float = 0.8           # stationary acceptance rate (sim replay)
    rho: float = 0.5             # acceptance burstiness (sim replay)


TRACE_SHAPES = ("constant", "diurnal", "burst", "replay")


@dataclass
class TraceSpec:
    """A declarative request stream: classes × load shape × seed.

    ``rate_per_s`` is the MEAN offered load; ``shape`` modulates it:

    - ``constant`` — homogeneous Poisson at ``rate_per_s``;
    - ``diurnal``  — rate(t) = rate·(1 + amplitude·sin(2πt/period)), the
      day/night curve compressed to ``diurnal_period_s``;
    - ``burst``    — rate jumps to rate·burst_multiplier for
      ``burst_len_s`` every ``burst_every_s`` (flash crowds);
    - ``replay``   — ``replay_arrivals_s`` IS the arrival clock
      (optionally with per-arrival ``replay_classes``); ``rate_per_s``
      is ignored.
    """
    classes: list[RequestClass] = field(default_factory=default_classes)
    num_requests: int = 32
    rate_per_s: float = 4.0
    shape: str = "constant"
    diurnal_period_s: float = 60.0
    diurnal_amplitude: float = 0.5       # in [0, 1)
    burst_every_s: float = 10.0
    burst_len_s: float = 1.0
    burst_multiplier: float = 4.0
    replay_arrivals_s: list[float] = field(default_factory=list)
    replay_classes: list[str] = field(default_factory=list)
    seed: int = 0

    # -- validation ----------------------------------------------------------

    def validate(self) -> "TraceSpec":
        """Structural validation; raises :class:`WorkloadError` with the
        first violation. Returns self for chaining."""
        if not self.classes:
            raise WorkloadError("a trace needs at least one request class")
        seen: set[str] = set()
        for c in self.classes:
            if not c.name or not isinstance(c.name, str):
                raise WorkloadError(
                    f"class name must be a non-empty string, got {c.name!r}")
            if c.name in seen:
                raise WorkloadError(f"duplicate class name {c.name!r}")
            seen.add(c.name)
            if c.weight < 0:
                raise WorkloadError(f"class {c.name!r}: negative weight")
            for fname in ("prompt_mean", "prompt_sigma", "output_mean",
                          "output_sigma"):
                if getattr(c, fname) < 0:
                    raise WorkloadError(
                        f"class {c.name!r}: negative {fname}")
            if c.prompt_mean <= 0 or c.output_mean <= 0:
                raise WorkloadError(
                    f"class {c.name!r}: length means must be > 0")
            for lo, hi, what in ((c.prompt_min, c.prompt_max, "prompt"),
                                 (c.output_min, c.output_max, "output")):
                if lo < 1 or hi < lo:
                    raise WorkloadError(
                        f"class {c.name!r}: need 1 <= {what}_min <= "
                        f"{what}_max, got [{lo}, {hi}]")
            if c.slo_ttft_ms < 0 or c.slo_tpot_ms < 0:
                raise WorkloadError(
                    f"class {c.name!r}: SLOs must be >= 0 (0 = no SLO)")
            if not (0.0 <= c.alpha <= 1.0) or not (0.0 <= c.rho < 1.0):
                raise WorkloadError(
                    f"class {c.name!r}: need 0 <= alpha <= 1, 0 <= rho < 1")
        if sum(c.weight for c in self.classes) <= 0:
            raise WorkloadError("class weights sum to zero")
        if self.num_requests < 0:
            raise WorkloadError("num_requests must be >= 0")
        if self.shape not in TRACE_SHAPES:
            raise WorkloadError(
                f"shape must be one of {TRACE_SHAPES}, got {self.shape!r}")
        if self.shape == "replay":
            if not self.replay_arrivals_s:
                raise WorkloadError("shape='replay' needs replay_arrivals_s")
            if any(t < 0 for t in self.replay_arrivals_s):
                raise WorkloadError("replay arrivals must be >= 0")
            if any(b < a for a, b in zip(self.replay_arrivals_s,
                                         self.replay_arrivals_s[1:])):
                raise WorkloadError("replay arrivals must be nondecreasing")
            if self.replay_classes:
                if len(self.replay_classes) != len(self.replay_arrivals_s):
                    raise WorkloadError(
                        "replay_classes must match replay_arrivals_s length")
                for name in self.replay_classes:
                    if name not in seen:
                        raise WorkloadError(
                            f"replay class {name!r} not declared in classes")
        else:
            if self.rate_per_s <= 0:
                raise WorkloadError(
                    f"shape {self.shape!r} needs rate_per_s > 0")
        if self.shape == "diurnal" and not (0 <= self.diurnal_amplitude < 1):
            raise WorkloadError("diurnal_amplitude must be in [0, 1)")
        if self.shape == "diurnal" and self.diurnal_period_s <= 0:
            raise WorkloadError("diurnal_period_s must be > 0")
        if self.shape == "burst":
            if (self.burst_every_s <= 0 or self.burst_len_s <= 0
                    or self.burst_multiplier < 1):
                raise WorkloadError(
                    "burst shape needs burst_every_s > 0, burst_len_s > 0, "
                    "burst_multiplier >= 1")
        return self

    # -- JSON round trip -----------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent) + "\n"

    @classmethod
    def from_dict(cls, d: dict) -> "TraceSpec":
        d = dict(d)
        raw_classes = d.pop("classes", None)
        fields = {f.name for f in dataclasses.fields(cls)}
        for k in d:
            if k not in fields:
                raise WorkloadError(f"unknown field {k!r} for TraceSpec")
        spec = cls(**d)
        if raw_classes is not None:
            cfields = {f.name for f in dataclasses.fields(RequestClass)}
            classes = []
            for c in raw_classes:
                for k in c:
                    if k not in cfields:
                        raise WorkloadError(
                            f"unknown field {k!r} for RequestClass")
                classes.append(RequestClass(**c))
            spec.classes = classes
        return spec

    @classmethod
    def from_json(cls, text: str) -> "TraceSpec":
        return cls.from_dict(json.loads(text))

    # -- rate shape ----------------------------------------------------------

    def rate_at(self, t_s: float) -> float:
        """Instantaneous offered load at ``t_s`` (requests/s)."""
        r = self.rate_per_s
        if self.shape == "diurnal":
            return r * (1.0 + self.diurnal_amplitude
                        * math.sin(2.0 * math.pi * t_s
                                   / self.diurnal_period_s))
        if self.shape == "burst":
            phase = t_s % self.burst_every_s
            return r * self.burst_multiplier if phase < self.burst_len_s \
                else r
        return r

    def peak_rate(self) -> float:
        if self.shape == "diurnal":
            return self.rate_per_s * (1.0 + self.diurnal_amplitude)
        if self.shape == "burst":
            return self.rate_per_s * self.burst_multiplier
        return self.rate_per_s


@dataclass
class FleetRequest:
    """One generated request: the spec-independent unit both the real
    server adapter and the sim adapter consume."""
    request_id: int
    request_class: str
    prompt_len: int
    output_len: int
    arrival_s: float
    slo_ttft_ms: float = 0.0
    slo_tpot_ms: float = 0.0
    alpha: float = 0.8
    rho: float = 0.5


def _lognormal_int(rng: random.Random, mean: float, sigma: float,
                   lo: int, hi: int) -> int:
    mu = math.log(mean) - 0.5 * sigma * sigma
    val = int(round(math.exp(rng.gauss(mu, sigma))))
    return max(lo, min(hi, val))


def generate_requests(trace: TraceSpec) -> list[FleetRequest]:
    """Expand a validated spec into its deterministic request stream.

    One ``random.Random(trace.seed)`` drives everything (arrival thinning,
    class sampling, lengths), so identical specs produce identical streams
    — the seeded-determinism contract tests gate on."""
    trace.validate()
    rng = random.Random(trace.seed)
    weights = [max(0.0, c.weight) for c in trace.classes]
    total_w = sum(weights)

    def sample_class() -> RequestClass:
        x = rng.random() * total_w
        for c, w in zip(trace.classes, weights):
            x -= w
            if x < 0:
                return c
        return trace.classes[-1]

    by_name = {c.name: c for c in trace.classes}
    arrivals: list[tuple[float, RequestClass]] = []
    if trace.shape == "replay":
        for i, t in enumerate(trace.replay_arrivals_s[:trace.num_requests
                                                      or None]):
            cls = (by_name[trace.replay_classes[i]]
                   if trace.replay_classes else sample_class())
            arrivals.append((float(t), cls))
        if trace.num_requests:
            arrivals = arrivals[:trace.num_requests]
    else:
        peak = trace.peak_rate()
        t = 0.0
        while len(arrivals) < trace.num_requests:
            t += rng.expovariate(peak)
            # Lewis–Shedler thinning: exact nonhomogeneous Poisson
            if rng.random() * peak <= trace.rate_at(t):
                arrivals.append((t, sample_class()))

    out = []
    for rid, (t, c) in enumerate(arrivals):
        out.append(FleetRequest(
            request_id=rid, request_class=c.name,
            prompt_len=_lognormal_int(rng, c.prompt_mean, c.prompt_sigma,
                                      c.prompt_min, c.prompt_max),
            output_len=_lognormal_int(rng, c.output_mean, c.output_sigma,
                                      c.output_min, c.output_max),
            arrival_s=t, slo_ttft_ms=c.slo_ttft_ms, slo_tpot_ms=c.slo_tpot_ms,
            alpha=c.alpha, rho=c.rho))
    return out


# --------------------------------------------------------------------------
# adapters: ONE stream → real server requests AND sim trace records
# --------------------------------------------------------------------------

def fleet_serve_requests(reqs: list[FleetRequest], vocab: int,
                         seed: int = 0) -> list:
    """Real-path adapter: token prompts drawn from ``seed`` (deterministic
    given the stream), SLOs and class carried on each
    :class:`~repro.serving.ServeRequest`."""
    from ..serving import ServeRequest
    rng = np.random.default_rng(seed)
    out = []
    for r in reqs:
        out.append(ServeRequest(
            request_id=r.request_id,
            prompt=rng.integers(0, vocab, r.prompt_len).astype(np.int32),
            max_new_tokens=r.output_len, arrival_s=r.arrival_s,
            request_class=r.request_class, slo_ttft_ms=r.slo_ttft_ms,
            slo_tpot_ms=r.slo_tpot_ms))
    return out


def fleet_trace_records(reqs: list[FleetRequest], seed: int = 0,
                        max_gamma: int = 16, drafter_id: int = -1) -> list:
    """Sim adapter: class-matched Markov acceptance bits, SLOs attached.

    ``drafter_id=-1`` marks the record "route me at arrival" — the sim's
    :class:`~repro.sim.policies.PolicyStack` pair router assigns the lane
    the way the real server's :class:`PairRouter` does."""
    from ..sim.trace import TraceRecord, markov_acceptance_seq
    rng = random.Random(seed)
    out = []
    for r in reqs:
        bits = markov_acceptance_seq(rng, r.output_len * max_gamma,
                                     r.alpha, r.rho)
        out.append(TraceRecord(
            request_id=r.request_id, prompt_length=r.prompt_len,
            output_length=r.output_len, acceptance_seq=bits,
            arrival_time_ms=r.arrival_s * 1e3, drafter_id=drafter_id,
            dataset=r.request_class, request_class=r.request_class,
            slo_ttft_ms=r.slo_ttft_ms, slo_tpot_ms=r.slo_tpot_ms))
    return out


# --------------------------------------------------------------------------
# SLO attainment
# --------------------------------------------------------------------------

def slo_report(rows: list[dict]) -> dict:
    """Aggregate SLO attainment from per-request measurement rows.

    Each row: ``{"request_class", "slo_ttft_ms", "slo_tpot_ms",
    "ttft_ms", "tpot_ms", "shed"(opt)}``. A request ATTAINS when every
    SLO it carries is met and it was not shed; requests carrying no SLO
    are excluded from the attainment denominator (batch-offline traffic
    cannot pad the score). The same function grades the real server's
    results and the sim analyzer's requests, so attainment numbers are
    directly comparable."""
    graded = attained = 0
    per_class: dict[str, dict] = {}
    for row in rows:
        cls = row.get("request_class") or "default"
        pc = per_class.setdefault(
            cls, {"requests": 0, "graded": 0, "attained": 0, "shed": 0})
        pc["requests"] += 1
        has_slo = (row.get("slo_ttft_ms", 0) > 0
                   or row.get("slo_tpot_ms", 0) > 0)
        if row.get("shed"):
            pc["shed"] += 1
        if not has_slo:
            continue
        graded += 1
        pc["graded"] += 1
        ok = not row.get("shed")
        if ok and row.get("slo_ttft_ms", 0) > 0:
            ok = row.get("ttft_ms", math.inf) <= row["slo_ttft_ms"]
        if ok and row.get("slo_tpot_ms", 0) > 0:
            ok = row.get("tpot_ms", math.inf) <= row["slo_tpot_ms"]
        if ok:
            attained += 1
            pc["attained"] += 1
    for pc in per_class.values():
        pc["attainment"] = (pc["attained"] / pc["graded"]
                            if pc["graded"] else 1.0)
    return {
        "graded": graded,
        "attained": attained,
        "attainment": attained / graded if graded else 1.0,
        "per_class": per_class,
    }


def serve_results_rows(results: list) -> list[dict]:
    """ServeResult rows → :func:`slo_report` input."""
    return [{
        "request_class": r.request_class, "slo_ttft_ms": r.slo_ttft_ms,
        "slo_tpot_ms": r.slo_tpot_ms, "ttft_ms": r.ttft_ms,
        "tpot_ms": r.tpot_ms, "shed": r.shed,
    } for r in results]
