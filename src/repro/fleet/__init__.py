"""Fleet workload subsystem: trace-driven, SLO-aware serving.

The paper's pitch is *agile* edge-cloud serving under real traffic; this
package is the workload/routing layer that makes traffic a first-class,
declarative input — the production-traffic rung of the ROADMAP:

- :mod:`repro.fleet.workload` — :class:`RequestClass` (chat vs
  long-context vs batch-offline, each with length distributions and
  per-class TTFT/TPOT SLOs) and :class:`TraceSpec` (diurnal curves,
  bursts, replay of recorded arrivals), JSON round-trippable like
  :class:`repro.topology.ClusterSpec` and consumable by BOTH DSD-Sim and
  the real multi-pair server from ONE seeded request stream;
- :mod:`repro.fleet.routing` — α/link/queue-aware pair scoring shared by
  the real :class:`~repro.serving.SpecDecodeServer` router and the sim's
  arrival-time pair router, so routing-policy *ordering* is comparable
  sim↔real;
- :mod:`repro.fleet.stats` — bounded rolling-quantile windows (per-pair
  p50/p95 TTFT/TPOT) feeding both observability and SLO-aware admission;
- :mod:`repro.fleet.elastic` — queue-depth-driven scale-up/down of
  ``process: true`` pairs through the existing
  ``spawn_pair``/``PairHostHandle`` machinery.
"""

from .stats import RollingQuantile
from .workload import (FleetRequest, RequestClass, TraceSpec,
                       WorkloadError, fleet_serve_requests,
                       fleet_trace_records, generate_requests, slo_report)
from .routing import (LeastLoadedSimPairRouter, SmartPairRouter,
                      SmartSimPairRouter, pair_cost)
from .elastic import ElasticPairPool

__all__ = [
    "ElasticPairPool", "FleetRequest", "LeastLoadedSimPairRouter",
    "RequestClass", "RollingQuantile", "SmartPairRouter",
    "SmartSimPairRouter", "TraceSpec", "WorkloadError",
    "fleet_serve_requests", "fleet_trace_records", "generate_requests",
    "pair_cost", "slo_report",
]
