"""Elastic pair scale-up/down driven by queue depth.

:class:`ElasticPairPool` serves a request stream through a growing and
shrinking fleet of **process-backed** draft–target pairs: the same
``spawn_pair`` → :class:`~repro.distributed.host.PairHostHandle`
machinery ``build_deployment`` uses for ``process: true`` pairs, but with
the pair COUNT a runtime control variable instead of a spec constant.

Control law (evaluated every scheduling tick, on the ARRIVED backlog —
future arrivals never trigger scaling):

- scale UP when the backlog per active pair exceeds
  ``scale_up_depth × capacity`` and the pool is under ``max_pairs``
  (one spawn per tick — process startup is seconds, flapping is worse
  than a short queue);
- scale DOWN (reap) when the backlog per active pair falls below
  ``scale_down_depth × capacity`` and the pool is over ``min_pairs``:
  the youngest pair is put in DRAINING state — it receives no new waves,
  finishes its in-flight wave, then its worker processes are shut down.

The spawn path is injectable (``spawn_fn``) so the control law is testable
without paying multi-second process startups; the default clones the
template :class:`~repro.topology.PairSpec` under a fresh id (ephemeral
ports) and calls :func:`repro.distributed.host.spawn_pair` on the
augmented spec — exactly the deployment factory's machinery.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional


class ElasticPairPool:
    """Queue-depth-driven elastic pool of process-backed serving pairs."""

    def __init__(self, spec, template_pair_id: Optional[str] = None, *,
                 min_pairs: int = 1, max_pairs: int = 4,
                 scale_up_depth: float = 2.0, scale_down_depth: float = 0.25,
                 model_configs: Optional[dict] = None,
                 spawn_fn: Optional[Callable] = None,
                 tick_s: float = 0.02):
        assert 1 <= min_pairs <= max_pairs, (min_pairs, max_pairs)
        self.spec = spec
        pairs = [p for p in spec.pairs if p.process] or list(spec.pairs)
        if template_pair_id is not None:
            self.template = next(p for p in spec.pairs
                                 if p.id == template_pair_id)
        else:
            self.template = pairs[0]
        self.min_pairs = int(min_pairs)
        self.max_pairs = int(max_pairs)
        self.scale_up_depth = float(scale_up_depth)
        self.scale_down_depth = float(scale_down_depth)
        self.model_configs = model_configs
        self._spawn_fn = spawn_fn or self._default_spawn
        self.tick_s = float(tick_s)
        self._n_spawned = 0
        # pair_id -> handle / state ("idle" | "busy" | "draining")
        self.handles: dict[str, object] = {}
        self._state: dict[str, str] = {}
        self.events: list[tuple[float, str, str]] = []   # (t, kind, pair_id)
        self.results: list = []
        self._served: dict[str, int] = {}
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()

    # -- spawning / reaping --------------------------------------------------

    def _default_spawn(self, pair_spec):
        from ..distributed.host import spawn_pair
        spec = dataclasses.replace(self.spec, pairs=[pair_spec])
        return spawn_pair(spec, pair_spec, model_configs=self.model_configs)

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def scale_up(self) -> str:
        """Spawn one pair cloned from the template; returns its id."""
        self._n_spawned += 1
        pid = f"{self.template.id}-e{self._n_spawned}"
        pair_spec = dataclasses.replace(self.template, id=pid)
        handle = self._spawn_fn(pair_spec)
        with self._lock:
            self.handles[pid] = handle
            self._state[pid] = "idle"
            self._served[pid] = 0
            self.events.append((self._now(), "spawn", pid))
        return pid

    def _reap_candidate(self) -> Optional[str]:
        """Youngest non-draining pair (LIFO keeps the original pairs warm)."""
        alive = [pid for pid, st in self._state.items() if st != "draining"]
        return alive[-1] if len(alive) > self.min_pairs else None

    def drain(self, pair_id: str) -> None:
        """Mark a pair DRAINING: it receives no new waves; its processes
        shut down once its in-flight wave (if any) completes."""
        with self._lock:
            if self._state.get(pair_id) in ("idle", "busy"):
                self._state[pair_id] = "draining"
                self.events.append((self._now(), "reap", pair_id))

    def _finalize_drained(self) -> None:
        for pid, st in list(self._state.items()):
            if st == "draining":
                self.handles[pid].shutdown()
                del self._state[pid]

    # -- control law ---------------------------------------------------------

    def _capacity(self) -> int:
        cap = getattr(next(iter(self.handles.values()), None), "capacity", 0)
        return max(1, int(cap or self.spec.serving.max_batch))

    def evaluate_scaling(self, backlog: int) -> Optional[str]:
        """One control-law step on the current ARRIVED backlog. Returns
        "up"/"down"/None (what it did)."""
        active = [pid for pid, st in self._state.items() if st != "draining"]
        n = max(1, len(active))
        per_pair = backlog / n
        cap = self._capacity()
        if (per_pair > self.scale_up_depth * cap
                and len(active) < self.max_pairs):
            self.scale_up()
            return "up"
        if (per_pair < self.scale_down_depth * cap
                and len(active) > self.min_pairs):
            pid = self._reap_candidate()
            if pid is not None and self._state.get(pid) == "idle":
                self.drain(pid)
                return "down"
        return None

    # -- serve loop ----------------------------------------------------------

    def run(self, requests: list) -> list:
        """Drain a :class:`~repro.serving.ServeRequest` stream through the
        elastic pool; returns the merged per-request results (sorted by
        request id). Arrival times are honored against a wall clock, like
        the continuous server's loop."""
        pending = sorted(requests, key=lambda r: r.arrival_s)
        self._t0 = time.perf_counter()
        while len([s for s in self._state.values() if s != "draining"]) \
                < self.min_pairs:
            self.scale_up()
        threads: dict[str, threading.Thread] = {}
        errors: list[BaseException] = []

        def drive(pid: str, wave: list) -> None:
            try:
                rows = self.handles[pid].serve(wave)
                with self._lock:
                    self.results.extend(rows)
                    self._served[pid] += len(wave)
            except BaseException as e:
                errors.append(e)
            finally:
                with self._lock:
                    if self._state.get(pid) == "busy":
                        self._state[pid] = "idle"

        while True:
            if errors:
                raise errors[0]
            now = self._now()
            arrived = [r for r in pending if r.arrival_s <= now]
            busy = [pid for pid, st in self._state.items() if st == "busy"]
            if not pending and not busy:
                break
            self.evaluate_scaling(len(arrived))
            cap = self._capacity()
            for pid, st in list(self._state.items()):
                if st != "idle" or not arrived:
                    continue
                wave = arrived[:cap]
                for r in wave:
                    pending.remove(r)
                    arrived.remove(r)
                self._state[pid] = "busy"
                t = threading.Thread(target=drive, args=(pid, wave),
                                     daemon=True)
                threads[pid] = t
                t.start()
            # reap any drained pair that has gone idle
            for pid, st in list(self._state.items()):
                if st == "draining" and (pid not in threads
                                         or not threads[pid].is_alive()):
                    self.handles[pid].shutdown()
                    del self._state[pid]
            time.sleep(self.tick_s)
        for t in threads.values():
            t.join()
        if errors:
            raise errors[0]
        self.results.sort(key=lambda r: r.request_id)
        return self.results

    def shutdown(self) -> None:
        for pid, h in self.handles.items():
            try:
                h.shutdown()
            except Exception:
                pass
        self._state.clear()

    def summary(self) -> dict:
        return {
            "pairs_spawned": self._n_spawned,
            "events": [(round(t, 3), kind, pid)
                       for t, kind, pid in self.events],
            "served": dict(self._served),
            "max_concurrent_pairs": max(
                (sum(1 for t2, k, _ in self.events[:i + 1] if k == "spawn")
                 - sum(1 for t2, k, _ in self.events[:i + 1] if k == "reap"))
                for i in range(len(self.events))) if self.events else 0,
        }
