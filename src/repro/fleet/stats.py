"""Bounded streaming quantile windows for per-pair serving telemetry.

:class:`RollingQuantile` keeps the last ``size`` samples in a ring plus a
parallel sorted list — O(log n) lookup, O(n) insert/evict on a
few-hundred-entry window, and strictly bounded memory (no unbounded
per-request lists). It backs ``pair_summaries()``'s rolling p50/p95
TTFT/TPOT columns and the SLO-aware admission path (a pair whose rolling
p95 TTFT drifts past a request class's SLO stops admitting that class).
"""

from __future__ import annotations

import bisect
import math
from collections import deque


class RollingQuantile:
    """Sorted-window quantile estimator over the most recent ``size``
    samples (arrival order evicts)."""

    def __init__(self, size: int = 256):
        assert size >= 1, "window size must be >= 1"
        self.size = int(size)
        self._ring: deque[float] = deque()
        self._sorted: list[float] = []

    def push(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v):
            return
        if len(self._ring) >= self.size:
            old = self._ring.popleft()
            i = bisect.bisect_left(self._sorted, old)
            del self._sorted[i]
        self._ring.append(v)
        bisect.insort(self._sorted, v)

    def quantile(self, p: float) -> float:
        """Linear-interpolated quantile of the current window; NaN when
        empty (same convention as the sim analyzer's ``_percentile``)."""
        s = self._sorted
        if not s:
            return math.nan
        k = (len(s) - 1) * min(1.0, max(0.0, p))
        lo, hi = int(math.floor(k)), int(math.ceil(k))
        if lo == hi:
            return s[lo]
        return s[lo] + (s[hi] - s[lo]) * (k - lo)

    def p50(self) -> float:
        return self.quantile(0.5)

    def p95(self) -> float:
        return self.quantile(0.95)

    def mean(self) -> float:
        return sum(self._ring) / len(self._ring) if self._ring else math.nan

    def __len__(self) -> int:
        return len(self._ring)

    def __repr__(self) -> str:
        return (f"RollingQuantile(n={len(self._ring)}, "
                f"p50={self.p50():.2f}, p95={self.p95():.2f})")
