"""α/link/queue-aware pair routing — ONE scoring rule for sim and real.

"Efficient LLM Inference over Heterogeneous Edge Networks with
Speculative Decoding" (PAPERS.md) shows draft placement and link
bandwidth must be optimized JOINTLY; the scoring function here is that
joint decision reduced to serving time: :func:`pair_cost` estimates a
pair's expected time per committed token from its link RTT, its recent
acceptance rate, and its queue occupancy — the standard speculative
decoding rate model (each round pays one RTT plus one verify pass and
commits ``E[tokens] = (1 − α^(γ+1))/(1 − α)`` tokens).

Long-context requests are routed AWAY from WAN pairs: their many decode
rounds amplify the per-round RTT term, so the cost doubles the link term
for prompts past ``long_prompt_tokens`` ("Speculation at a Distance":
where edge-cloud SD pays off depends on workload shape).

Two thin adapters consume the same rule:

- :class:`SmartPairRouter` — the real server's
  :class:`~repro.serving.PairRouter`: reads each pair's MEASURED
  transport RTT and its live session's acceptance counters;
- :class:`SmartSimPairRouter` — DSD-Sim's arrival-time pair router
  (:class:`repro.sim.policies.SimPairView` snapshot of per-pair queue
  depths / link RTTs / rolling acceptance).

Because both paths rank pairs with the identical function, the
routing-policy ORDERING (smart vs least-loaded) is comparable sim↔real —
the property ``benchmarks/bench_fleet.py`` gates.
"""

from __future__ import annotations

from typing import Sequence


def pair_cost(rtt_ms: float, alpha: float, queue_frac: float,
              long_context: bool = False, gamma_hint: int = 4,
              step_ms: float = 10.0) -> float:
    """Expected serving time per committed token on one pair (lower is
    better). ``queue_frac`` (0 = idle, 1 = full) scales the whole cost:
    a busy pair delivers its per-token time later."""
    a = min(0.98, max(0.02, float(alpha)))
    e_tokens = (1.0 - a ** (gamma_hint + 1)) / (1.0 - a)
    link = float(max(0.0, rtt_ms))
    if long_context:
        link *= 2.0              # long outputs pay the RTT round after round
    per_token = (step_ms + link) / e_tokens
    return per_token * (1.0 + max(0.0, float(queue_frac)))


class SmartPairRouter:
    """α/link/queue-aware router for the real multi-pair server.

    Scores every pair with a free slot by :func:`pair_cost` using its
    transport's measured ``recent_rtt_ms`` (which falls back to the
    declared link's expected RTT before any round trip completes), the
    live session's acceptance counters, and slot occupancy; ties break to
    the lowest pair index (deterministic, matching
    :class:`~repro.serving.LeastLoadedPairRouter`)."""

    def __init__(self, long_prompt_tokens: int = 128, gamma_hint: int = 4,
                 step_ms: float = 10.0, default_alpha: float = 0.7):
        self.long_prompt_tokens = int(long_prompt_tokens)
        self.gamma_hint = int(gamma_hint)
        self.step_ms = float(step_ms)
        self.default_alpha = float(default_alpha)

    def _pair_inputs(self, pair, free: int) -> tuple[float, float, float]:
        tr = getattr(pair, "transport", None)
        rtt = float(tr.recent_rtt_ms) if tr is not None else 0.0
        sess = getattr(pair, "session", None)
        alpha = self.default_alpha
        queue_frac = 0.0
        if sess is not None:
            if sess.proposed > 0:
                alpha = sess.accepted / sess.proposed
            cap = max(1, sess.capacity)
            queue_frac = (cap - free) / cap
        return rtt, alpha, queue_frac

    def route(self, req, pairs: Sequence, free_slots: Sequence[int]) -> int:
        long_ctx = len(req.prompt) >= self.long_prompt_tokens
        best, best_cost = None, None
        for i, pair in enumerate(pairs):
            if free_slots[i] <= 0:
                continue
            rtt, alpha, qf = self._pair_inputs(pair, free_slots[i])
            cost = pair_cost(rtt, alpha, qf, long_context=long_ctx,
                             gamma_hint=self.gamma_hint,
                             step_ms=self.step_ms)
            if best_cost is None or cost < best_cost:
                best, best_cost = i, cost
        if best is None:   # contract: only called when capacity exists
            return int(max(range(len(free_slots)),
                           key=lambda i: free_slots[i]))
        return best


# --------------------------------------------------------------------------
# sim-side pair routers (arrival-time lane assignment in DSD-Sim)
# --------------------------------------------------------------------------

class LeastLoadedSimPairRouter:
    """Sim analogue of :class:`~repro.serving.LeastLoadedPairRouter`:
    the pair with the shallowest drafter queue, ties to the lowest
    index."""

    def route_pair(self, record, view) -> int:
        best, best_d = 0, None
        for i, d in enumerate(view.queue_depths):
            if best_d is None or d < best_d:
                best, best_d = i, d
        return best

    def name(self) -> str:
        return "least-loaded"


class SmartSimPairRouter:
    """Sim analogue of :class:`SmartPairRouter`: the identical
    :func:`pair_cost` over the sim's per-pair view."""

    def __init__(self, long_prompt_tokens: int = 128, gamma_hint: int = 4,
                 step_ms: float = 10.0):
        self.long_prompt_tokens = int(long_prompt_tokens)
        self.gamma_hint = int(gamma_hint)
        self.step_ms = float(step_ms)

    def route_pair(self, record, view) -> int:
        long_ctx = record.prompt_length >= self.long_prompt_tokens
        best, best_cost = 0, None
        cap = max(1, view.max_batch)
        for i in range(len(view.queue_depths)):
            cost = pair_cost(view.rtt_ms[i], view.alpha[i],
                             view.queue_depths[i] / cap,
                             long_context=long_ctx,
                             gamma_hint=self.gamma_hint,
                             step_ms=self.step_ms)
            if best_cost is None or cost < best_cost:
                best, best_cost = i, cost
        return best

    def name(self) -> str:
        return "smart"


SIM_PAIR_ROUTERS = {
    "least-loaded": LeastLoadedSimPairRouter,
    "smart": SmartSimPairRouter,
}
