"""Transports connecting the DraftWorker (edge) and TargetWorker (cloud).

A transport delivers :mod:`repro.distributed.wire` messages and reports the
one-way delay it imposed. Two implementations:

- :class:`InProcessTransport` — zero delay. The regression anchor: a
  session routed through it commits greedy tokens BIT-identical to the
  colocated ``DecodeSession`` path.
- :class:`EmulatedLinkTransport` — samples the SAME delay model DSD-Sim's
  :class:`repro.sim.network.Link` uses (RTT/2 + symmetric truncated jitter
  + payload/bandwidth serialization, from one :class:`LinkSpec`) and
  imposes it as measured wall-clock sleep, so real-model decoding
  experiences the network the simulator predicts.

Every transport keeps measured statistics. Consecutive window→verdict
deliveries pair into round trips; :attr:`Transport.recent_rtt_ms` is the
mean of the recent pairs and is what
:meth:`repro.core.session.DecodeSession._features` feeds the window policy
as ``rtt_recent_ms`` — AWC adapts to the link actually observed, not to a
configured constant.
"""

from __future__ import annotations

import random
import time

from ..sim.network import (LinkSpec, RttTracker, expected_rtt_ms,
                           sample_one_way_ms)
from .wire import VerdictMsg, WindowMsg

CONTROL_PAYLOAD_BYTES = 64   # fused-mode chunk flush / control messages


class Transport:
    """Base transport: delivery accounting + paired RTT measurement.

    Subclasses implement :meth:`_transmit` (returns the imposed one-way
    delay in ms). ``wall_clock`` tells the session whether imposed delays
    are already part of measured wall time (sleeping transports) or must
    be added to the virtual clock (non-sleeping emulation).
    """

    wall_clock: bool = True

    def __init__(self):
        self.bytes_sent = 0
        self.messages_sent = 0
        # same paired estimator the sim's Link uses — sim and real paths
        # must compute the AWC rtt_recent_ms feature identically
        self._rtt = RttTracker()

    # -- delivery -----------------------------------------------------------

    def _transmit(self, payload_bytes: int) -> float:
        raise NotImplementedError

    def _deliver(self, payload_bytes: int) -> float:
        delay = self._transmit(payload_bytes)
        self.bytes_sent += payload_bytes
        self.messages_sent += 1
        self._rtt.record(delay)
        return delay

    def send_window(self, msg: WindowMsg) -> float:
        """Draft → target. Returns the imposed one-way delay (ms)."""
        return self._deliver(msg.payload_bytes)

    def send_verdict(self, msg: VerdictMsg) -> float:
        """Target → draft. Returns the imposed one-way delay (ms)."""
        return self._deliver(msg.payload_bytes)

    def control_roundtrip(self,
                          payload_bytes: int = CONTROL_PAYLOAD_BYTES) -> float:
        """One small out+back exchange (fused-mode token-stream flush)."""
        return self._deliver(payload_bytes) + self._deliver(payload_bytes)

    # -- measurement --------------------------------------------------------

    @property
    def recent_rtt_ms(self) -> float:
        """Mean of the recently measured round trips (paired deliveries)."""
        return self._rtt.mean_recent_ms(self._default_rtt_ms())

    def _default_rtt_ms(self) -> float:
        return 0.0

    def describe(self) -> str:
        return type(self).__name__


class InProcessTransport(Transport):
    """Colocated draft and target: zero-delay delivery.

    The messages still materialize on the host (token ids leave the device
    exactly as they would for a real link), so the protocol is identical —
    only the imposed delay is zero. Greedy tokens through this transport
    are bit-identical to the colocated ``DecodeSession`` fast path."""

    wall_clock = True

    def _transmit(self, payload_bytes: int) -> float:
        return 0.0

    def describe(self) -> str:
        return "in-process"


class EmulatedLinkTransport(Transport):
    """Edge–cloud link emulation driven by a :class:`LinkSpec`.

    Each delivery samples :func:`repro.sim.network.sample_one_way_ms` —
    the exact delay model DSD-Sim's ``Link`` uses — and, with
    ``sleep=True`` (default), blocks for that long and records the
    MEASURED elapsed wall time (what the OS actually imposed). With
    ``sleep=False`` the sampled delay is recorded without blocking and the
    session adds it to its virtual clock instead (fast deterministic
    tests)."""

    def __init__(self, spec: LinkSpec, seed: int = 0, sleep: bool = True):
        super().__init__()
        self.spec = spec
        self.sleep = bool(sleep)
        self.wall_clock = self.sleep
        self._rng = random.Random(seed)

    def _transmit(self, payload_bytes: int) -> float:
        delay_ms = sample_one_way_ms(self.spec, self._rng, payload_bytes)
        if not self.sleep:
            return delay_ms
        t0 = time.perf_counter()
        if delay_ms > 0.0:
            time.sleep(delay_ms / 1e3)
        return (time.perf_counter() - t0) * 1e3

    def _default_rtt_ms(self) -> float:
        return expected_rtt_ms(self.spec)

    def describe(self) -> str:
        return (f"emulated-link(rtt={self.spec.rtt_ms}ms, "
                f"jitter={self.spec.jitter_ms}ms, "
                f"bw={self.spec.bandwidth_gbps}Gbps, sleep={self.sleep})")
