"""Transports connecting the DraftWorker (edge) and TargetWorker (cloud).

A transport delivers :mod:`repro.distributed.wire` messages and accounts
the one-way delay each delivery imposes. The link is FULL-DUPLEX: each
direction (window stream draft→target, verdict stream target→draft) is an
independent in-flight queue, so a speculative window for round k+1 can be
on the wire while round k's verdict travels the other way — the seam the
cross-round pipelined session overlaps drafting and verification through.

Two delay models:

- :class:`InProcessTransport` — zero delay. The regression anchor: a
  session routed through it commits greedy tokens BIT-identical to the
  colocated ``DecodeSession`` path.
- :class:`EmulatedLinkTransport` — samples the SAME delay model DSD-Sim's
  :class:`repro.sim.network.Link` uses (RTT/2 + symmetric truncated jitter
  + payload/bandwidth serialization, from one :class:`LinkSpec`).

Delivery protocol: ``post_*`` stamps a message with its sampled one-way
delay and enqueues it (never blocks — the caller's compute between post
and receive overlaps the flight, which is where pipelining's win comes
from); ``recv_*`` dequeues the oldest message and waits out whatever part
of its flight the caller's compute did not already hide. With
``sleep=True`` (wall-clock transports) the residual wait is a real
``time.sleep``; with ``sleep=False`` it accumulates on a virtual clock
offset instead so tests stay fast and deterministic while the overlap
arithmetic is identical.

Every transport keeps per-direction ``delay_log`` lists of the SAMPLED
delays it imposed — timing tests assert on these instead of measuring
wall-clock sleeps (which deflakes them under scheduler noise). Window and
verdict deliveries pair into round trips BY ``round_id`` (not delivery
order, which pipelining scrambles); :attr:`Transport.recent_rtt_ms` is
the mean of the recent pairs and is what
:meth:`repro.core.session.DecodeSession._features` feeds the window
policy as ``rtt_recent_ms`` — AWC adapts to the link actually observed,
not to a configured constant.
"""

from __future__ import annotations

import random
import time
from collections import deque

from ..sim.network import (LinkSpec, RttTracker, expected_rtt_ms,
                           sample_one_way_ms)
from .wire import TransportProtocolError, VerdictMsg, WindowMsg

CONTROL_PAYLOAD_BYTES = 64   # fused-mode chunk flush / control messages

FWD = "window"    # draft → target
BWD = "verdict"   # target → draft


class Transport:
    """Base transport: full-duplex queues + delivery accounting + paired
    RTT measurement.

    Subclasses implement :meth:`_sample_delay_ms` (the imposed one-way
    delay for a payload). ``wall_clock`` tells both the transport and the
    session whether residual waits are real sleeps (part of measured wall
    time) or virtual-clock charges.
    """

    wall_clock: bool = True

    def __init__(self):
        self.bytes_sent = 0
        self.messages_sent = 0
        self.discarded_messages = 0
        # same paired estimator the sim's Link uses — sim and real paths
        # must compute the AWC rtt_recent_ms feature identically
        self._rtt = RttTracker()
        self._queues = {FWD: deque(), BWD: deque()}
        self._out_delay_ms: dict = {}          # round_id → window delay
        self.delay_log = {FWD: [], BWD: []}    # sampled delays, per direction
        self._voffset_s = 0.0                  # virtual clock (sleep=False)

    # -- delay model ---------------------------------------------------------

    def _sample_delay_ms(self, payload_bytes: int) -> float:
        raise NotImplementedError

    def _default_rtt_ms(self) -> float:
        return 0.0

    # -- clock ---------------------------------------------------------------

    def _now_s(self) -> float:
        """Hybrid clock: real compute time plus virtually-elapsed link
        waits (identical to wall time for sleeping transports)."""
        return time.perf_counter() + self._voffset_s

    # -- full-duplex post / recv ---------------------------------------------

    def _post(self, direction: str, msg, payload_bytes: int,
              round_id=None) -> float:
        delay_ms = self._sample_delay_ms(payload_bytes)
        self.bytes_sent += payload_bytes
        self.messages_sent += 1
        log = self.delay_log[direction]
        log.append(delay_ms)
        if len(log) > 512:
            del log[:256]
        if round_id is not None:
            if direction == FWD:
                self._out_delay_ms[round_id] = delay_ms
            else:
                out = self._out_delay_ms.pop(round_id, None)
                if out is not None:
                    self._rtt.record_rtt(out + delay_ms)
        self._queues[direction].append((msg, self._now_s() + delay_ms / 1e3))
        return delay_ms

    def _recv(self, direction: str):
        """Dequeue the oldest in-flight message on ``direction``; wait out
        the part of its flight not already hidden by the caller's compute.
        Returns ``(msg, waited_ms)`` — ``waited_ms`` is the UNHIDDEN link
        time actually imposed on the caller."""
        try:
            msg, ready_s = self._queues[direction].popleft()
        except IndexError:
            raise TransportProtocolError(
                f"recv on empty {direction!r} stream: nothing in flight "
                f"(recv-before-post or double-recv)") from None
        wait_s = ready_s - self._now_s()
        if wait_s <= 0.0:
            return msg, 0.0
        if self.wall_clock:
            t0 = time.perf_counter()
            time.sleep(wait_s)
            return msg, (time.perf_counter() - t0) * 1e3
        self._voffset_s += wait_s
        return msg, wait_s * 1e3

    def post_window(self, msg: WindowMsg) -> float:
        """Draft → target, non-blocking. Returns the sampled delay (ms)."""
        return self._post(FWD, msg, msg.payload_bytes, msg.round_id)

    def recv_window(self) -> tuple:
        return self._recv(FWD)

    def post_verdict(self, msg: VerdictMsg) -> float:
        """Target → draft, non-blocking. Returns the sampled delay (ms)."""
        return self._post(BWD, msg, msg.payload_bytes, msg.round_id)

    def recv_verdict(self) -> tuple:
        return self._recv(BWD)

    def discard_window(self):
        """Drop the oldest in-flight draft→target message without waiting:
        a verdict invalidated the speculative window it answers. The bytes
        were already spent on the wire (they stay counted); the pending
        RTT half-pair is cleared so it can never mismatch a later verdict."""
        try:
            msg, _ready = self._queues[FWD].popleft()
        except IndexError:
            raise TransportProtocolError(
                "discard_window on empty 'window' stream: no superseded "
                "speculative window in flight") from None
        self.discarded_messages += 1
        rid = getattr(msg, "round_id", None)
        if rid is not None:
            self._out_delay_ms.pop(rid, None)
        return msg

    # -- half-duplex convenience (propose → ship → verify → verdict) ---------

    def send_window(self, msg: WindowMsg) -> float:
        """Post + immediately wait out the delivery (half-duplex path).
        Returns the imposed one-way delay (ms)."""
        self.post_window(msg)
        return self._recv(FWD)[1]

    def send_verdict(self, msg: VerdictMsg) -> float:
        """Target → draft, blocking. Returns the imposed delay (ms)."""
        self.post_verdict(msg)
        return self._recv(BWD)[1]

    def control_roundtrip(self,
                          payload_bytes: int = CONTROL_PAYLOAD_BYTES) -> float:
        """One small out+back exchange (fused-mode token-stream flush)."""
        out = self._post(FWD, None, payload_bytes)
        _, w1 = self._recv(FWD)
        back = self._post(BWD, None, payload_bytes)
        _, w2 = self._recv(BWD)
        self._rtt.record_rtt(out + back)
        return w1 + w2

    # -- measurement ---------------------------------------------------------

    @property
    def recent_rtt_ms(self) -> float:
        """Mean of the recently completed round trips (window/verdict
        pairs matched by ``round_id``)."""
        return self._rtt.mean_recent_ms(self._default_rtt_ms())

    @property
    def in_flight(self) -> int:
        return len(self._queues[FWD]) + len(self._queues[BWD])

    def describe(self) -> str:
        return type(self).__name__


class InProcessTransport(Transport):
    """Colocated draft and target: zero-delay delivery.

    The messages still materialize on the host (token ids leave the device
    exactly as they would for a real link), so the protocol is identical —
    only the imposed delay is zero. Greedy tokens through this transport
    are bit-identical to the colocated ``DecodeSession`` fast path."""

    wall_clock = True

    def _sample_delay_ms(self, payload_bytes: int) -> float:
        return 0.0

    def describe(self) -> str:
        return "in-process"


class EmulatedLinkTransport(Transport):
    """Edge–cloud link emulation driven by a :class:`LinkSpec`.

    Each delivery samples :func:`repro.sim.network.sample_one_way_ms` —
    the exact delay model DSD-Sim's ``Link`` uses. With ``sleep=True``
    (default) the unhidden part of each flight blocks as real wall-clock
    sleep, so real-model decoding experiences the network the simulator
    predicts; with ``sleep=False`` it lands on the virtual clock instead
    (fast deterministic tests — seed the jitter RNG per test)."""

    def __init__(self, spec: LinkSpec, seed: int = 0, sleep: bool = True):
        super().__init__()
        self.spec = spec
        self.sleep = bool(sleep)
        self.wall_clock = self.sleep
        self._rng = random.Random(seed)

    def _sample_delay_ms(self, payload_bytes: int) -> float:
        return sample_one_way_ms(self.spec, self._rng, payload_bytes)

    def _default_rtt_ms(self) -> float:
        return expected_rtt_ms(self.spec)

    def describe(self) -> str:
        return (f"emulated-link(rtt={self.spec.rtt_ms}ms, "
                f"jitter={self.spec.jitter_ms}ms, "
                f"bw={self.spec.bandwidth_gbps}Gbps, sleep={self.sleep})")


def make_transport(link: LinkSpec | None, seed: int = 0,
                   sleep: bool = True) -> Transport | None:
    """Transport for one draft–target pair from its declarative
    :class:`LinkSpec` — the single construction rule every deployment
    surface (``launch.serve`` flags, ``repro.topology`` specs, benches)
    shares:

    - ``link is None``      → ``None`` (colocated pair: no transport, the
      engine's virtual ``rtt_ms`` accounting applies);
    - ``link.rtt_ms <= 0``  → :class:`InProcessTransport` (zero delay,
      bit-identical to the colocated path at temperature 0);
    - otherwise             → :class:`EmulatedLinkTransport` on ``link``
      (``sleep=False`` routes imposed delays to the virtual clock for
      fast deterministic tests).
    """
    if link is None:
        return None
    if link.rtt_ms <= 0:
        return InProcessTransport()
    return EmulatedLinkTransport(link, seed=seed, sleep=sleep)
