"""Distributed draft–target execution on real models (paper Fig. 1b).

The speculative-decoding engine split at the network boundary: an
edge-side :class:`DraftWorker` proposes speculation windows, a cloud-side
:class:`TargetWorker` verifies and commits them, and a :class:`Transport`
carries the :class:`WindowMsg`/:class:`VerdictMsg` wire messages between
them — zero-delay in process (the bit-identity regression anchor) or over
an emulated edge–cloud link whose measured delays feed the AWC window
policy's ``rtt_recent_ms`` feature. The transport is full-duplex: the
pipelined session keeps a speculative window for round k+1 in flight
while round k's verdict travels the other way.
"""

from .socket_transport import (FRAME_CONTROL, FRAME_VERDICT, FRAME_WINDOW,
                               SocketTransport, recv_frame, send_frame)
from .transport import (CONTROL_PAYLOAD_BYTES, EmulatedLinkTransport,
                        InProcessTransport, Transport, make_transport)
from .wire import (TransportProtocolError, VerdictMsg, WindowMsg,
                   decode_verdict, decode_window, encode_verdict,
                   encode_window)
from .workers import DraftWorker, TargetWorker

__all__ = [
    "CONTROL_PAYLOAD_BYTES", "EmulatedLinkTransport", "FRAME_CONTROL",
    "FRAME_VERDICT", "FRAME_WINDOW", "InProcessTransport", "SocketTransport",
    "Transport", "TransportProtocolError", "VerdictMsg", "WindowMsg",
    "DraftWorker", "TargetWorker", "decode_verdict", "decode_window",
    "encode_verdict", "encode_window", "make_transport", "recv_frame",
    "send_frame",
]
