"""Worker-host entrypoint: one draft or target process per node.

``python -m repro.distributed.host --role {draft,target} --topology
cluster.json --pair pair0 ...`` runs ONE side of a draft–target pair in
its own OS process with its own jax device context — the paper's Fig. 1b
deployment with an actual process boundary instead of the in-process
emulation. The two sides connect over the two TCP streams of a
:class:`repro.distributed.SocketTransport` (windows one way, verdicts the
other, control frames on both) and exchange exactly the bytes
:mod:`repro.distributed.wire` frames.

Determinism across the boundary: both hosts rebuild their model
parameters from the topology's seed with the SAME PRNG scheme
:func:`repro.topology.build_deployment` uses (``kd, kt = split(
PRNGKey(spec.seed))``, i-th node of a role folds in ``i``), so no
parameter shipping is needed; overridden tiny configs/params travel as
JSON/npz files written by :func:`spawn_pair`. Each wave both hosts admit
the SAME prompts into a persistent session through the engine's jitted
per-slot prefill-insert program (duplicated prefill — the admission cost
of not shipping KV; only decode-round bytes cross the wire, as in the
paper), and the target replies with the per-slot anchor tokens so drift
is caught at admission, not as a token mismatch downstream. Reusing one
session per wave geometry keeps admission on the compiled path: the
first wave pays every jit compile once, steady-state waves cost one
batch-1 insert per slot plus the decode rounds. Greedy decoding ignores PRNG keys entirely, which is why
process pairs are restricted to ``temperature == 0``.

Per decode round the draft host proposes ``γ_max`` tokens and ships a
:class:`~repro.distributed.wire.WindowMsg`; the target host verifies and
commits on ITS session (the ground-truth output buffers live target-side,
as they would in a real cloud) and ships the
:class:`~repro.distributed.wire.VerdictMsg` back; the draft reconstructs
its state from the verdict alone (``pos += num_new``, anchor =
``last_token``, attention drafts keep the propose cache, recurrent drafts
re-advance) — the same reconstruction rule
``DecodeSession._run_chunk_transport`` applies in process.

Steady-state waves (after the first, which absorbs jit compilation) run
under the :func:`repro.analysis.sanitize.compile_guard` sentry on both
hosts: a recompile mid-measurement crashes the host with a nonzero exit
instead of silently poisoning throughput numbers.

The parent side (:func:`spawn_pair` → :class:`PairHostHandle`) is what
``repro.topology.build_deployment`` uses for ``process: true`` pairs: it
launches the two hosts, performs the port handshake over their stdout,
and drives waves over a framed control connection to the draft host.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import select
import socket
import subprocess
import sys
import time
from contextlib import nullcontext
from dataclasses import dataclass

import numpy as np

from .socket_transport import (FRAME_CONTROL, SocketTransport, recv_frame,
                               send_frame)
from .transport import CONTROL_PAYLOAD_BYTES
from .wire import TransportProtocolError, VerdictMsg, WindowMsg

_HELLO = {b"W": "window", b"V": "verdict"}
_READY_TIMEOUT_S = 300.0     # engine build + warmup on a cold jit cache


# --------------------------------------------------------------------------
# config / param shipping (overrides only; defaults rebuild from the seed)
# --------------------------------------------------------------------------

def save_model_config(cfg, path: str) -> None:
    with open(path, "w") as f:
        json.dump(dataclasses.asdict(cfg), f)


def load_model_config(path: str):
    from ..configs.base import ModelConfig
    with open(path) as f:
        return ModelConfig(**json.load(f))


def save_params(params, path: str) -> None:
    """Flatten a param tree to an npz in traversal order. The structure
    is NOT stored: :func:`load_params` rebuilds the template tree from
    the node's config, so order-stable flattening is enough."""
    import jax
    leaves = jax.tree.leaves(params)
    np.savez(path, **{f"leaf_{i}": np.asarray(a)
                      for i, a in enumerate(leaves)})


def load_params(cfg, path: str):
    import jax

    from ..models.model import build_model
    template = build_model(cfg).init_params(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree.flatten(template)
    with np.load(path) as z:
        loaded = [z[f"leaf_{i}"] for i in range(len(leaves))]
    if len(loaded) != len(leaves):  # pragma: no cover - config drift
        raise ValueError(f"param file {path} has {len(loaded)} leaves, "
                         f"config expects {len(leaves)}")
    return jax.tree.unflatten(treedef, [
        np.asarray(a, dtype=np.asarray(t).dtype)
        for a, t in zip(loaded, leaves)])


# --------------------------------------------------------------------------
# shared host plumbing
# --------------------------------------------------------------------------

def _parse_kv(entries) -> dict:
    out = {}
    for e in entries or []:
        k, _, v = e.partition("=")
        if not k or not v:
            raise SystemExit(f"expected NAME=PATH, got {e!r}")
        out[k] = v
    return out


class _HostContext:
    """Everything one host process shares across waves: the resolved
    spec/pair, the engine (params rebuilt from the seed scheme), and the
    socket endpoint."""

    def __init__(self, args):
        from ..topology import ClusterSpec, TopologyError
        self.args = args
        self.spec = ClusterSpec.load(args.topology).validate()
        for p in self.spec.pairs:
            if p.id == args.pair:
                self.pair = p
                break
        else:
            raise TopologyError(f"unknown pair id {args.pair!r}")
        validate_process_pair(self.spec, self.pair)
        self.model_configs = {}
        for name, path in _parse_kv(args.model_config).items():
            self.model_configs[name] = load_model_config(path)
        self.node_param_paths = _parse_kv(args.node_params)
        self.role = args.role
        self.node = self.spec.node(self.pair.draft if self.role == "draft"
                                   else self.pair.target)
        self.engine = None
        self.wave_index = 0
        self.sess = None
        self._sess_geom = None

    # -- engine (same construction rule as build_deployment) ---------------

    def build_engine(self):
        import jax

        from ..configs import get_config
        from ..core.engine import SpecDecodeEngine
        from ..models.model import build_model
        spec, s = self.spec, self.spec.serving

        def resolve(node):
            if node.model in self.model_configs:
                return self.model_configs[node.model]
            return get_config(node.model).reduced()

        raw = {n.id: resolve(n) for n in spec.nodes}
        vocab = min(c.vocab for c in raw.values())
        configs = {nid: (c if c.vocab == vocab
                         else dataclasses.replace(c, vocab=vocab))
                   for nid, c in raw.items()}

        kd, kt = jax.random.split(jax.random.PRNGKey(spec.seed))
        need = {self.pair.draft, self.pair.target}
        params = {}
        role_index = {"draft": 0, "target": 0}
        for n in spec.nodes:         # full sweep: role indices must match
            i = role_index[n.role]   # build_deployment's numbering exactly
            role_index[n.role] += 1
            if n.id not in need:
                continue
            if n.id in self.node_param_paths:
                params[n.id] = load_params(configs[n.id],
                                           self.node_param_paths[n.id])
                continue
            k = kd if n.role == "draft" else kt
            if i > 0:
                k = jax.random.fold_in(k, i)
            params[n.id] = build_model(configs[n.id]).init_params(k)

        self.engine = SpecDecodeEngine(
            configs[self.pair.draft], configs[self.pair.target],
            draft_params=params[self.pair.draft],
            target_params=params[self.pair.target],
            temperature=s.temperature, rtt_ms=s.rtt_ms,
            gamma_max=s.gamma_max, sync_every=s.sync_every,
            key=jax.random.PRNGKey(spec.seed))
        return self.engine

    def wave_session(self, capacity: int, max_new_cap: int, pad_len: int):
        """ONE persistent session per wave geometry. Waves admit into
        retired slots through the engine's jitted prefill-insert program,
        so steady-state admission costs one compiled batch-1 insert per
        slot — ``admit_batch``'s eager batched prefill re-traces its
        layer scans every call (seconds per wave on a small host). A
        geometry change rebuilds the session and resets the recompile
        guard to a cold wave (new programs legitimately compile)."""
        from ..core.session import DecodeSession
        geom = (capacity, max_new_cap, pad_len)
        if self.sess is not None and self._sess_geom == geom:
            return self.sess
        s = self.spec.serving
        self.sess = DecodeSession(self.engine, capacity=capacity,
                                  max_new_cap=max_new_cap,
                                  max_prompt_len=pad_len,
                                  gamma_max=s.gamma_max,
                                  sync_every=s.sync_every,
                                  eos_id=s.eos_id, log_gamma=False,
                                  mode_policy="distributed")
        self._sess_geom = geom
        self.wave_index = 0
        return self.sess

    def guard(self):
        """Recompile sentry for steady-state waves; the first wave absorbs
        every jit compile (prefill, propose, verify) unguarded."""
        if self.wave_index == 0:
            return nullcontext()
        from ..analysis.sanitize import compile_guard
        return compile_guard(
            allowed=0,
            what=f"{self.role} host steady-state wave {self.wave_index}")


def validate_process_pair(spec, pair) -> None:
    """The restrictions a pair must satisfy before a process boundary can
    split it (raises :class:`repro.topology.TopologyError`)."""
    from ..topology import TopologyError
    if spec.serving.temperature > 0.0:
        raise TopologyError(
            f"pair {pair.id!r}: process-backed pairs are greedy-only "
            "(temperature 0) — q_probs never crosses the byte seam")
    if pair.mode_policy != "distributed":
        raise TopologyError(
            f"pair {pair.id!r}: process-backed pairs need "
            f"mode_policy='distributed' (got {pair.mode_policy!r}); "
            "fused flushes and pipelined rollback are not split yet")
    if pair.window.kind != "static":
        raise TopologyError(
            f"pair {pair.id!r}: process-backed pairs need a static window "
            f"policy (got {pair.window.kind!r}); feature-driven policies "
            "would need feature mirroring across the boundary")


def _admit_wave(sess, prompts, lens, max_new, request_ids) -> None:
    """Admit one wave per slot via the jitted prefill-insert. Free slots
    are taken in ascending index order, so slot i holds request i on both
    hosts — the anchor-divergence check below compares row for row."""
    ids = request_ids if request_ids is not None else list(range(len(lens)))
    for i in range(prompts.shape[0]):
        sess.admit(prompts[i, :int(lens[i])], int(max_new[i]),
                   request_id=int(ids[i]))


def _retire_wave(sess) -> None:
    """Free every slot after a wave's tokens have been shipped, so the
    next wave re-admits into the same live session."""
    for j in list(sess.occupied):
        sess.retire(j)


def _log(role: str, msg: str) -> None:
    print(f"{msg}", flush=True)
    print(f"[{role}-host] {msg}", file=sys.stderr, flush=True)


# --------------------------------------------------------------------------
# target host
# --------------------------------------------------------------------------

def run_target(args) -> int:
    """Accept the two streams, build the engine, then serve verify/commit
    rounds and control commands until ``shutdown``."""
    import jax

    from ..core.specdec import SpecDecodeState

    ctx = _HostContext(args)
    lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lst.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    port = args.listen_port if args.listen_port else (ctx.node.port or 0)
    lst.bind((args.bind_host, port))
    lst.listen(2)
    _log("target", f"listening port={lst.getsockname()[1]}")
    lst.settimeout(args.timeout_s)
    streams = {}
    for _ in range(2):
        conn, _addr = lst.accept()
        conn.settimeout(args.timeout_s)
        hello = conn.recv(1)
        tag = _HELLO.get(hello)
        if tag is None or tag in streams:
            raise TransportProtocolError(f"bad stream hello {hello!r}")
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        streams[tag] = conn
    lst.close()
    link = ctx.pair.link if (ctx.pair.link and ctx.pair.link.rtt_ms > 0) \
        else None
    ep = SocketTransport.target_endpoint(
        streams["window"], streams["verdict"], link=link,
        seed=ctx.spec.seed, timeout_s=args.timeout_s)

    ctx.build_engine()
    _, tw = ctx.engine.split_workers()
    _log("target", "ready")

    kv_key = None
    sess = None
    r_in_chunk = 0
    chunk_gammas: list[int] = []
    chunk_t0 = time.perf_counter()

    def flush_attribution():
        nonlocal r_in_chunk, chunk_gammas, chunk_t0
        if sess is not None and r_in_chunk:
            sess._sync_and_attribute(r_in_chunk, chunk_gammas, chunk_t0,
                                     non_target_ms=0.0)
        r_in_chunk = 0
        chunk_gammas = []
        chunk_t0 = time.perf_counter()

    while True:
        item, _w = ep.recv_window()
        if isinstance(item, dict):
            cmd = item.get("cmd")
            if cmd == "admit":
                prompts = np.asarray(item["prompts"], np.int32)
                lens = np.asarray(item["prompt_lens"], np.int32)
                max_new = np.asarray(item["max_new"], np.int32)
                sess = ctx.wave_session(prompts.shape[0],
                                        int(item["max_new_cap"]),
                                        prompts.shape[1])
                _admit_wave(sess, prompts, lens, max_new,
                            item.get("request_ids"))
                r_in_chunk, chunk_gammas = 0, []
                chunk_t0 = time.perf_counter()
                anchors = np.asarray(sess._state.last_token)
                ep._post("verdict", {"cmd": "admitted",
                                     "last_token": anchors.tolist()},
                         CONTROL_PAYLOAD_BYTES)
            elif cmd == "fetch":
                flush_attribution()
                tokens, stats = sess.snapshot()
                ep._post("verdict", {
                    "cmd": "tokens",
                    "tokens": tokens.tolist(),
                    "produced": np.asarray(stats.produced).tolist(),
                    "acceptance_seqs": [list(map(int, b))
                                        for b in stats.acceptance_seqs],
                    "stats": {"iterations": sess.iterations,
                              "proposed": sess.proposed,
                              "accepted": sess.accepted,
                              "prefill_s": sess.prefill_s},
                }, CONTROL_PAYLOAD_BYTES)
                ctx.wave_index += 1
                _retire_wave(sess)
            elif cmd == "shutdown":
                ep._post("verdict", {"cmd": "bye"}, CONTROL_PAYLOAD_BYTES)
                ep.close()
                return 0
            else:
                raise TransportProtocolError(f"unknown control {item!r}")
            continue

        msg: WindowMsg = item
        state = sess._state
        window_np = np.concatenate(
            [np.asarray(state.last_token)[:, None], msg.tokens], axis=1)
        if kv_key is None:
            kv_key = jax.random.PRNGKey(0)   # greedy: never read
        with ctx.guard():
            (tcache, new_pos, new_last, num_new_dev, nacc_dev,
             next_raw) = sess._verify_commit_round(
                tw, window_np, msg.gamma, r_in_chunk, None, False, kv_key)
            done_host = np.asarray(sess._done)
        verdict = VerdictMsg(
            n_accepted=np.asarray(nacc_dev), num_new=np.asarray(num_new_dev),
            next_token=np.asarray(next_raw), last_token=np.asarray(new_last),
            done=done_host, gamma=msg.gamma, n_active=msg.n_active,
            round_id=msg.round_id)
        ep.post_verdict(verdict)
        sess._state = SpecDecodeState(
            draft_cache=state.draft_cache, target_cache=tcache,
            last_token=new_last, pos=new_pos)
        chunk_gammas.append(msg.gamma)
        sess.iterations += 1
        r_in_chunk += 1
        if r_in_chunk >= sess.sync_every:
            flush_attribution()


# --------------------------------------------------------------------------
# draft host
# --------------------------------------------------------------------------

def run_draft(args) -> int:
    """Connect the two streams to the target host, build the engine, then
    serve framed control commands (``run``/``stats``/``shutdown``) from
    the parent over a local TCP control port."""
    import jax
    import jax.numpy as jnp

    from ..core.specdec import SpecDecodeState

    ctx = _HostContext(args)
    # control listener FIRST so the parent can read the port while the
    # target is still building (the connect below may wait on its accept)
    ctrl_lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    ctrl_lst.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    ctrl_lst.bind((args.bind_host, args.listen_port or 0))
    ctrl_lst.listen(1)
    _log("draft", f"listening port={ctrl_lst.getsockname()[1]}")
    ctrl_lst.settimeout(_READY_TIMEOUT_S)

    if args.connect:
        host, _, port_s = args.connect.rpartition(":")
        t_addr = (host or "127.0.0.1", int(port_s))
    else:
        t_node = ctx.spec.node(ctx.pair.target)
        t_addr = (t_node.address or "127.0.0.1", t_node.port)
    socks = {}
    for hello in (b"W", b"V"):
        s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        s.settimeout(args.timeout_s)
        s.connect(t_addr)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        s.sendall(hello)
        socks[hello] = s
    link = ctx.pair.link if (ctx.pair.link and ctx.pair.link.rtt_ms > 0) \
        else None
    ep = SocketTransport.draft_endpoint(
        socks[b"W"], socks[b"V"], link=link, seed=ctx.spec.seed,
        timeout_s=args.timeout_s)

    ctx.build_engine()
    dw, _ = ctx.engine.split_workers()
    gamma = min(ctx.pair.window.gamma, ctx.engine.gamma_max)
    _log("draft", "ready")

    ctrl, _addr = ctrl_lst.accept()
    ctrl.settimeout(args.timeout_s)
    ctrl_lst.close()
    round_seq = 0

    def run_wave(cmd: dict) -> dict:
        nonlocal round_seq
        prompts = np.asarray(cmd["prompts"], np.int32)
        lens = np.asarray(cmd["prompt_lens"], np.int32)
        max_new = np.asarray(cmd["max_new"], np.int32)
        max_new_cap = int(cmd["max_new_cap"])
        B = prompts.shape[0]
        G = ctx.engine.gamma_max

        sess = ctx.wave_session(B, max_new_cap, prompts.shape[1])
        t_admit0 = time.perf_counter()
        _admit_wave(sess, prompts, lens, max_new, cmd.get("request_ids"))
        prefill_s = time.perf_counter() - t_admit0
        ep._post("window", {"cmd": "admit", "prompts": prompts.tolist(),
                            "prompt_lens": lens.tolist(),
                            "max_new": max_new.tolist(),
                            "max_new_cap": max_new_cap,
                            "request_ids": cmd.get("request_ids")},
                 CONTROL_PAYLOAD_BYTES)
        reply, _ = ep.recv_verdict()
        if not (isinstance(reply, dict) and reply.get("cmd") == "admitted"):
            raise TransportProtocolError(f"expected admitted, got {reply!r}")
        anchors_local = np.asarray(sess._state.last_token)
        anchors_remote = np.asarray(reply["last_token"], np.int32)
        if not np.array_equal(anchors_local, anchors_remote):
            raise TransportProtocolError(
                f"prefill anchors diverged across the process boundary: "
                f"draft {anchors_local.tolist()} vs target "
                f"{anchors_remote.tolist()} — params/config drift")

        state = sess._state
        done = np.zeros(B, bool)
        rounds, cap = 0, 2 * max_new_cap + 4
        key = jax.random.PRNGKey(0)                  # greedy: never read
        t_decode0 = time.perf_counter()
        while not done.all() and rounds < cap:
            with ctx.guard():
                toks, _q, dcache_prop = dw.propose(G)(
                    dw.params, state.draft_cache, state.last_token,
                    state.pos, key)
                toks_np = np.asarray(toks)
            msg = WindowMsg(tokens=toks_np, gamma=gamma,
                            n_active=int(B - done.sum()),
                            round_id=round_seq)
            round_seq += 1
            ep.post_window(msg)
            verdict, _w = ep.recv_verdict()
            num_new = jnp.asarray(verdict.num_new)
            new_last = jnp.asarray(verdict.last_token)
            with ctx.guard():
                if dw.attention:
                    dcache = dcache_prop   # pos_map masks the stale tail
                else:
                    window_np = np.concatenate(
                        [np.asarray(state.last_token)[:, None], toks_np],
                        axis=1)
                    dcache = dw.advance(G)(dw.params, state.draft_cache,
                                           jnp.asarray(window_np),
                                           state.pos, num_new)
            state = SpecDecodeState(
                draft_cache=dcache, target_cache=state.target_cache,
                last_token=new_last, pos=state.pos + num_new)
            done = np.asarray(verdict.done)
            rounds += 1
        decode_s = time.perf_counter() - t_decode0

        ep._post("window", {"cmd": "fetch"}, CONTROL_PAYLOAD_BYTES)
        result, _ = ep.recv_verdict()
        if not (isinstance(result, dict) and result.get("cmd") == "tokens"):
            raise TransportProtocolError(f"expected tokens, got {result!r}")
        ctx.wave_index += 1
        _retire_wave(sess)
        result.update(cmd="result", rounds=rounds,
                      prefill_s=prefill_s, decode_s=decode_s,
                      link_stats=transport_stats(ep))
        return result

    while True:
        kind, payload, _r, _d = recv_frame(ctrl)
        if kind != FRAME_CONTROL:
            raise TransportProtocolError(
                f"parent control channel got frame kind {kind}")
        cmd = json.loads(payload.decode("utf-8"))
        op = cmd.get("cmd")
        if op == "run":
            out = run_wave(cmd)
        elif op == "stats":
            out = {"cmd": "stats", "link_stats": transport_stats(ep),
                   "waves": ctx.wave_index}
        elif op == "shutdown":
            ep._post("window", {"cmd": "shutdown"}, CONTROL_PAYLOAD_BYTES)
            bye, _ = ep.recv_verdict()
            ep.close()
            send_frame(ctrl, FRAME_CONTROL,
                       json.dumps({"cmd": "bye"}).encode("utf-8"))
            ctrl.close()
            return 0
        else:
            raise TransportProtocolError(f"unknown parent command {cmd!r}")
        send_frame(ctrl, FRAME_CONTROL, json.dumps(out).encode("utf-8"))


def transport_stats(tr: SocketTransport) -> dict:
    return {"bytes_sent": tr.bytes_sent, "wire_bytes": tr.wire_bytes,
            "messages_sent": tr.messages_sent,
            "recent_rtt_ms": tr.recent_rtt_ms,
            "transport": tr.describe()}


# --------------------------------------------------------------------------
# parent side: spawn + drive a process-backed pair
# --------------------------------------------------------------------------

def _read_line(proc: subprocess.Popen, match: str, timeout_s: float,
               who: str) -> str:
    """Read stdout lines until one starts with ``match`` (deadline-bound,
    non-blocking so a wedged child cannot hang the parent forever)."""
    fd = proc.stdout.fileno()
    os.set_blocking(fd, False)
    deadline = time.monotonic() + timeout_s
    buf = b""
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(
                f"{who} exited with code {proc.returncode} before "
                f"printing {match!r}")
        r, _, _ = select.select([fd], [], [], 0.25)
        if not r:
            continue
        chunk = os.read(fd, 4096)
        if not chunk:
            continue
        buf += chunk
        while b"\n" in buf:
            line, buf = buf.split(b"\n", 1)
            text = line.decode("utf-8", "replace").strip()
            if text.startswith(match):
                return text
    raise TimeoutError(f"{who} did not print {match!r} in {timeout_s:.0f}s")


def _ctrl_call(sock: socket.socket, cmd: dict) -> dict:
    send_frame(sock, FRAME_CONTROL, json.dumps(cmd).encode("utf-8"))
    kind, payload, _r, _d = recv_frame(sock)
    if kind != FRAME_CONTROL:
        raise TransportProtocolError(f"control reply had frame kind {kind}")
    return json.loads(payload.decode("utf-8"))


@dataclass
class PairHostHandle:
    """Parent-side handle to one process-backed pair: the two host
    processes plus the framed control connection to the draft host."""
    pair_id: str
    procs: list
    ctrl: socket.socket
    capacity: int
    max_new_cap: int
    pad_to: int = 16
    _last_stats: dict = dataclasses.field(default_factory=dict)
    _waves: int = 0

    def run_wave(self, prompts: np.ndarray, prompt_lens: np.ndarray,
                 max_new, request_ids=None) -> dict:
        prompts = np.asarray(prompts, np.int32)
        B = prompts.shape[0]
        mn = np.broadcast_to(np.asarray(max_new, np.int32), (B,))
        out = _ctrl_call(self.ctrl, {
            "cmd": "run", "prompts": prompts.tolist(),
            "prompt_lens": np.asarray(prompt_lens, np.int32).tolist(),
            "max_new": mn.tolist(), "max_new_cap": self.max_new_cap,
            "request_ids": (list(map(int, request_ids))
                            if request_ids is not None else None)})
        if out.get("cmd") != "result":
            raise RuntimeError(f"pair {self.pair_id}: bad wave reply {out!r}")
        self._last_stats = out
        self._waves += 1
        return out

    def serve(self, reqs) -> list:
        """Drive a request bucket wave-by-wave (the process-backed analogue
        of one pair's share of ``SpecDecodeServer.run``); returns
        :class:`repro.serving.ServeResult` rows."""
        from ..serving.server import ServeResult
        results = []
        t_start = time.perf_counter()
        for w0 in range(0, len(reqs), self.capacity):
            wave = list(reqs[w0:w0 + self.capacity])
            n_real = len(wave)
            while len(wave) < self.capacity:   # pad short waves; extras
                wave.append(wave[-1])          # decode but are dropped
            q = self.pad_to
            maxlen = max(len(r.prompt) for r in wave)
            maxlen = ((maxlen + q - 1) // q) * q
            prompts = np.zeros((self.capacity, maxlen), np.int32)
            lens = np.zeros(self.capacity, np.int32)
            for i, r in enumerate(wave):
                prompts[i, :len(r.prompt)] = r.prompt
                lens[i] = len(r.prompt)
            mn = np.array([r.max_new_tokens for r in wave], np.int32)
            wave_t0 = time.perf_counter() - t_start
            out = self.run_wave(prompts, lens, mn,
                                request_ids=[r.request_id for r in wave])
            wave_t1 = time.perf_counter() - t_start
            tokens = np.asarray(out["tokens"], np.int64)
            produced = np.asarray(out["produced"], np.int64)
            seqs = out.get("acceptance_seqs") or [[]] * self.capacity
            first_tok_s = wave_t0 + float(out.get("prefill_s", 0.0))
            for i in range(n_real):
                r = wave[i]
                n = min(int(produced[i]), self.max_new_cap)
                bits = seqs[i] if i < len(seqs) else []
                results.append(ServeResult(
                    request_id=r.request_id, tokens=tokens[i, :n],
                    ttft_ms=(first_tok_s - r.arrival_s) * 1e3,
                    tpot_ms=(wave_t1 - first_tok_s) * 1e3 / max(1, n - 1),
                    e2e_ms=(wave_t1 - r.arrival_s) * 1e3,
                    acceptance_rate=(sum(bits) / len(bits)) if bits else 0.0,
                    queue_ms=(wave_t0 - r.arrival_s) * 1e3,
                    pair_id=self.pair_id,
                    request_class=r.request_class,
                    slo_ttft_ms=r.slo_ttft_ms,
                    slo_tpot_ms=r.slo_tpot_ms))
        return results

    def stats(self) -> dict:
        return _ctrl_call(self.ctrl, {"cmd": "stats"})

    def summary(self) -> dict:
        """``SpecDecodeServer.pair_summaries``-shaped row for this pair."""
        st = self._last_stats.get("stats", {})
        link = self._last_stats.get("link_stats", {})
        return {"requests": self._waves * self.capacity,
                "iterations": st.get("iterations", 0),
                "acceptance_rate": round(
                    st.get("accepted", 0) / max(1, st.get("proposed", 0)), 4),
                "mode_policy": "distributed", "process": True,
                **{k: link[k] for k in ("bytes_sent", "wire_bytes",
                                        "messages_sent", "transport")
                   if k in link}}

    def shutdown(self) -> None:
        try:
            if self.ctrl is not None:
                _ctrl_call(self.ctrl, {"cmd": "shutdown"})
                self.ctrl.close()
        except Exception:
            pass
        self.ctrl = None
        deadline = time.monotonic() + 10.0
        for p in self.procs:
            while p.poll() is None and time.monotonic() < deadline:
                time.sleep(0.05)
            if p.poll() is None:
                p.kill()

    close = shutdown


def spawn_pair(spec, pair, *, model_configs=None, node_params=None,
               workdir=None, timeout_s: float = 120.0,
               python: str = sys.executable) -> PairHostHandle:
    """Launch a target host + draft host for one ``process: true`` pair
    on localhost and hand back the driving handle. Topology, overridden
    model configs and overridden node params are written to ``workdir``
    and shipped by path; everything else rebuilds from the spec's seed."""
    import tempfile
    validate_process_pair(spec, pair)
    workdir = workdir or tempfile.mkdtemp(prefix=f"dsd-{pair.id}-")
    os.makedirs(workdir, exist_ok=True)
    topo_path = os.path.join(workdir, "topology.json")
    with open(topo_path, "w") as f:
        f.write(spec.to_json())

    cfg_flags = []
    for name, cfg in (model_configs or {}).items():
        path = os.path.join(workdir, f"cfg_{name}.json")
        save_model_config(cfg, path)
        cfg_flags += ["--model-config", f"{name}={path}"]
    for node_id, params in (node_params or {}).items():
        path = os.path.join(workdir, f"params_{node_id}.npz")
        save_params(params, path)
        cfg_flags += ["--node-params", f"{node_id}={path}"]

    env = dict(os.environ)
    src_dir = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    prev = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src_dir + (os.pathsep + prev if prev else "")

    def launch(role, extra):
        err = open(os.path.join(workdir, f"{role}.stderr.log"), "wb")
        return subprocess.Popen(
            [python, "-m", "repro.distributed.host", "--role", role,
             "--topology", topo_path, "--pair", pair.id,
             "--timeout-s", str(timeout_s)] + cfg_flags + extra,
            stdout=subprocess.PIPE, stderr=err, env=env)

    procs = []
    try:
        tgt = launch("target", [])
        procs.append(tgt)
        line = _read_line(tgt, "listening port=", 60.0,
                          f"target host ({pair.id})")
        t_port = int(line.split("=", 1)[1])
        drf = launch("draft", ["--connect", f"127.0.0.1:{t_port}"])
        procs.append(drf)
        line = _read_line(drf, "listening port=", 60.0,
                          f"draft host ({pair.id})")
        c_port = int(line.split("=", 1)[1])
        _read_line(tgt, "ready", _READY_TIMEOUT_S,
                   f"target host ({pair.id})")
        _read_line(drf, "ready", _READY_TIMEOUT_S,
                   f"draft host ({pair.id})")
        ctrl = socket.create_connection(("127.0.0.1", c_port),
                                        timeout=timeout_s)
        ctrl.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        ctrl.settimeout(max(timeout_s, 600.0))
    except Exception:
        for p in procs:
            p.kill()
        raise
    s = spec.serving
    return PairHostHandle(pair_id=pair.id, procs=procs, ctrl=ctrl,
                          capacity=s.max_batch,
                          max_new_cap=s.max_new_cap or spec.workload.max_new,
                          pad_to=s.pad_to)


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.distributed.host",
        description="Run one side of a draft-target pair in this process.")
    ap.add_argument("--role", required=True, choices=("draft", "target"))
    ap.add_argument("--topology", required=True,
                    help="ClusterSpec JSON path")
    ap.add_argument("--pair", required=True, help="pair id in the topology")
    ap.add_argument("--listen-port", type=int, default=0,
                    help="target: stream listen port; draft: control port "
                         "(0 = ephemeral, printed as 'listening port=N')")
    ap.add_argument("--bind-host", default="127.0.0.1")
    ap.add_argument("--connect", default="",
                    help="draft only: HOST:PORT of the target host "
                         "(default: the target node's address/port)")
    ap.add_argument("--model-config", action="append", default=[],
                    metavar="NAME=PATH",
                    help="override a model name with a ModelConfig JSON")
    ap.add_argument("--node-params", action="append", default=[],
                    metavar="NODE=PATH",
                    help="override a node's params with an npz file")
    ap.add_argument("--timeout-s", type=float, default=120.0)
    args = ap.parse_args(argv)
    try:
        if args.role == "target":
            return run_target(args)
        return run_draft(args)
    except TransportProtocolError as e:
        print(f"[{args.role}-host] protocol error: {e}", file=sys.stderr,
              flush=True)
        return 2


if __name__ == "__main__":
    sys.exit(main())
