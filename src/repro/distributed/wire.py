"""Wire types for distributed draft–target execution.

These are the ONLY objects that cross the edge–cloud boundary in the real
execution path (paper Fig. 1b): the draft ships a speculation window
(token ids + per-token draft probabilities), the target ships back a
verdict (accept count + corrected/bonus token + per-position logprobs).
Payload sizes come from the same models DSD-Sim charges
(:func:`repro.sim.network.window_payload_bytes` /
:func:`repro.sim.network.verdict_payload_bytes`), scaled by the number of
slots actively decoding — so a transport imposes exactly the bytes the
simulator predicts for the same exchange.

Cross-round pipelining additions:

- ``round_id`` orders the exchange stream: a window and its verdict carry
  the same id, which is what lets a full-duplex transport pair the two
  one-way delays of one exchange into a measured RTT even when deliveries
  interleave out of order (a speculative window for round k+1 can be in
  flight before round k's verdict lands).
- ``speculative`` marks a window the draft proposed OPTIMISTICALLY from
  its own continuation while the previous window was still being
  verified. A late verdict showing a partial accept invalidates it: the
  receiver discards the message unverified (its bytes were already spent
  on the wire) and the draft rolls back and re-drafts.

``q_probs`` (needed by the stochastic accept/resample rule at
temperature > 0) is carried as a device-array pass-through: the paper's
wire format ships only the per-token draft probability q(t_i) (8B/token,
already priced into ``window_payload_bytes``), and the residual
distribution is reconstructed target-side; this in-process reproduction
skips the reconstruction and hands the full distribution over, without
charging extra bytes. Greedy decoding (temperature 0 — the bit-identity
anchor) does not use it.

:func:`encode_window` / :func:`decode_window` (and the verdict pair) give
the messages an actual byte representation — the seam a future
multi-process transport serializes through. The encoded size is the
implementation's framing (int32 ids, no q_probs); the ``payload_bytes``
properties keep charging the PAPER's modeled wire format so sim and real
link costs stay comparable.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Any

import numpy as np

from ..sim.network import verdict_payload_bytes, window_payload_bytes


class TransportProtocolError(RuntimeError):
    """The transport delivery contract was broken: a recv/discard on an
    empty stream, a malformed or truncated frame off a real socket, or a
    peer that hung up mid-exchange. Deliberately defined HERE (the only
    jax-free module of the transport stack) so the protocol checker in
    :mod:`repro.analysis.protocol` can translate it into a
    ``ProtocolViolation`` without importing the transports."""


@dataclass
class WindowMsg:
    """Draft → target: one speculation window for the whole slot batch.

    Tree rounds (``n_nodes > 0``) ship the (B, T) grid window — entry 0
    is the anchor — plus the (T,) parent table that pins the tree
    topology; the payload is then priced per NODE (token id + parent
    index + per-node q(t)), strictly more bytes than a linear window of
    the same depth. ``n_nodes == 0`` is today's linear chain, byte-for-
    byte unchanged on the wire."""
    tokens: np.ndarray            # (B, gamma_max | n_nodes) int32 proposals
    gamma: int                    # active window size this round (≤ gamma_max)
    n_active: int                 # slots actually decoding (payload scaling)
    q_probs: Any = None           # wire-passthrough: (B, gamma_max, V) draft
                                  # dists stay on device, never serialized
    round_id: int = 0             # exchange ordinal (pairs with its verdict)
    speculative: bool = False     # optimistic pipeline window (invalidatable)
    n_nodes: int = 0              # tree entries incl. anchor (0 = linear)
    branches: int = 1             # active branch width this round (≤ b_max)
    parent: Any = None            # (n_nodes,) int32 parent table (tree only)

    @property
    def payload_bytes(self) -> int:
        per = (window_payload_bytes(self.gamma, n_nodes=self.n_nodes)
               if self.n_nodes else window_payload_bytes(self.gamma))
        return max(1, self.n_active) * per


@dataclass
class VerdictMsg:
    """Target → draft: the verdict for one speculation window.

    ``n_accepted``/``num_new`` are post-lifecycle (budget/EOS-clamped)
    counts; ``next_token`` is the raw corrected/bonus token and
    ``last_token`` the per-slot anchor for the next round (frozen for done
    rows)."""
    n_accepted: np.ndarray        # (B,) int32
    num_new: np.ndarray           # (B,) int32
    next_token: np.ndarray        # (B,) int32 raw corrected/bonus token
    last_token: np.ndarray        # (B,) int32 next-round anchor
    done: np.ndarray              # (B,) bool
    gamma: int
    n_active: int
    round_id: int = 0             # id of the window this verdict answers
    path: Any = None              # (B, d_max) int32 winning-path entries
                                  # (tree rounds — drives the draft's KV
                                  # relocation; None for linear rounds)

    @property
    def payload_bytes(self) -> int:
        return max(1, self.n_active) * verdict_payload_bytes(self.gamma)


# --------------------------------------------------------------------------
# Byte serialization (the multi-process-transport seam)
# --------------------------------------------------------------------------

# magic, round, γ, n_active, B, Γ|T, spec byte, n_nodes, branches
_WINDOW_HDR = struct.Struct("<4sqiiiiBii")
# magic, round, γ, n_active, B, path width (0 = linear verdict)
_VERDICT_HDR = struct.Struct("<4sqiiii")
_WINDOW_MAGIC = b"DSDW"
_VERDICT_MAGIC = b"DSDV"


def encode_window(msg: WindowMsg) -> bytes:
    """Serialize a window to bytes (token ids only — ``q_probs`` is the
    documented device pass-through and does not cross this seam). Tree
    windows append the (n_nodes,) int32 parent table after the tokens.

    A window carrying ``q_probs`` is REFUSED: those are the draft
    distributions the stochastic accept rule needs at temperature > 0,
    and silently dropping them here would make a byte-serializing
    transport decode wrong tokens downstream. Sampled decoding stays on
    device-passthrough transports until distribution shipping lands."""
    if msg.q_probs is not None:
        raise ValueError(
            "encode_window: window carries q_probs (temperature > 0 "
            "sampling); draft distributions do not cross the byte seam — "
            "use an in-process transport for sampled decoding")
    tokens = np.ascontiguousarray(msg.tokens, np.int32)
    B, G = tokens.shape
    head = _WINDOW_HDR.pack(_WINDOW_MAGIC, msg.round_id, msg.gamma,
                            msg.n_active, B, G, 1 if msg.speculative else 0,
                            msg.n_nodes, msg.branches)
    blob = head + tokens.tobytes()
    if msg.n_nodes:
        parent = np.ascontiguousarray(msg.parent, np.int32)
        assert parent.shape == (msg.n_nodes,), (parent.shape, msg.n_nodes)
        blob += parent.tobytes()
    return blob


def _check_magic(blob: bytes, magic: bytes, what: str) -> None:
    """Magic FIRST: a frame of the wrong type (or line noise) must fail
    on its first 4 bytes, before any header field is trusted."""
    if len(blob) < 4:
        raise ValueError(
            f"truncated {what}: {len(blob)} bytes, need at least 4 for the "
            f"magic at offset 0")
    if blob[:4] != magic:
        raise ValueError(
            f"bad {what} magic {bytes(blob[:4])!r} at offset 0 "
            f"(want {magic!r})")


def decode_window(blob: bytes) -> WindowMsg:
    """Inverse of :func:`encode_window`, hardened for bytes off a real
    socket: magic first, then header completeness, header plausibility,
    and an EXACT total-length check against the header-declared counts —
    a truncated or corrupted blob raises ``ValueError`` naming the
    offset instead of a cryptic ``struct.error`` / short ``frombuffer``."""
    _check_magic(blob, _WINDOW_MAGIC, "window")
    if len(blob) < _WINDOW_HDR.size:
        raise ValueError(
            f"truncated window header: {len(blob)} bytes, need "
            f"{_WINDOW_HDR.size} (truncation at offset {len(blob)})")
    (_magic, round_id, gamma, n_active, B, G, spec, n_nodes,
     branches) = _WINDOW_HDR.unpack_from(blob)
    if B < 1 or G < 1 or gamma < 0 or n_active < 0 or n_nodes < 0 \
            or branches < 1 or (n_nodes and n_nodes != G):
        raise ValueError(
            f"implausible window header (B={B}, G={G}, gamma={gamma}, "
            f"n_active={n_active}, n_nodes={n_nodes}, branches={branches})")
    off = _WINDOW_HDR.size
    expected = off + 4 * B * G + (4 * n_nodes if n_nodes else 0)
    if len(blob) != expected:
        raise ValueError(
            f"window length mismatch: header declares B={B}, G={G}, "
            f"n_nodes={n_nodes} → {expected} bytes, got {len(blob)} "
            f"(truncation/corruption at offset {min(len(blob), expected)})")
    tokens = np.frombuffer(blob, np.int32, count=B * G,
                           offset=off).reshape(B, G).copy()
    off += 4 * B * G
    parent = None
    if n_nodes:
        parent = np.frombuffer(blob, np.int32, count=n_nodes,
                               offset=off).copy()
    return WindowMsg(tokens=tokens, gamma=gamma, n_active=n_active,
                     round_id=round_id, speculative=bool(spec),
                     n_nodes=n_nodes, branches=branches, parent=parent)


def encode_verdict(msg: VerdictMsg) -> bytes:
    arrs = [np.ascontiguousarray(a, np.int32) for a in
            (msg.n_accepted, msg.num_new, msg.next_token, msg.last_token)]
    done = np.ascontiguousarray(msg.done, np.uint8)
    B = arrs[0].shape[0]
    path = (None if msg.path is None
            else np.ascontiguousarray(msg.path, np.int32))
    D = 0 if path is None else path.shape[1]
    head = _VERDICT_HDR.pack(_VERDICT_MAGIC, msg.round_id, msg.gamma,
                             msg.n_active, B, D)
    blob = head + b"".join(a.tobytes() for a in arrs) + done.tobytes()
    if path is not None:
        assert path.shape == (B, D), (path.shape, B, D)
        blob += path.tobytes()
    return blob


def decode_verdict(blob: bytes) -> VerdictMsg:
    """Inverse of :func:`encode_verdict`, hardened the same way as
    :func:`decode_window`: magic → header → plausibility → exact length,
    each failure a ``ValueError`` naming the offending offset."""
    _check_magic(blob, _VERDICT_MAGIC, "verdict")
    if len(blob) < _VERDICT_HDR.size:
        raise ValueError(
            f"truncated verdict header: {len(blob)} bytes, need "
            f"{_VERDICT_HDR.size} (truncation at offset {len(blob)})")
    (_magic, round_id, gamma, n_active, B, D) = _VERDICT_HDR.unpack_from(blob)
    if B < 1 or D < 0 or gamma < 0 or n_active < 0:
        raise ValueError(
            f"implausible verdict header (B={B}, D={D}, gamma={gamma}, "
            f"n_active={n_active})")
    expected = _VERDICT_HDR.size + 16 * B + B + 4 * B * D
    if len(blob) != expected:
        raise ValueError(
            f"verdict length mismatch: header declares B={B}, D={D} → "
            f"{expected} bytes, got {len(blob)} "
            f"(truncation/corruption at offset {min(len(blob), expected)})")
    off = _VERDICT_HDR.size
    arrs = []
    for _ in range(4):
        arrs.append(np.frombuffer(blob, np.int32, count=B, offset=off).copy())
        off += 4 * B
    done = np.frombuffer(blob, np.uint8, count=B, offset=off).astype(bool)
    off += B
    path = None
    if D:
        path = np.frombuffer(blob, np.int32, count=B * D,
                             offset=off).reshape(B, D).copy()
    return VerdictMsg(n_accepted=arrs[0], num_new=arrs[1], next_token=arrs[2],
                      last_token=arrs[3], done=done, gamma=gamma,
                      n_active=n_active, round_id=round_id, path=path)
