"""Wire types for distributed draft–target execution.

These are the ONLY objects that cross the edge–cloud boundary in the real
execution path (paper Fig. 1b): the draft ships a speculation window
(token ids + per-token draft probabilities), the target ships back a
verdict (accept count + corrected/bonus token + per-position logprobs).
Payload sizes come from the same models DSD-Sim charges
(:func:`repro.sim.network.window_payload_bytes` /
:func:`repro.sim.network.verdict_payload_bytes`), scaled by the number of
slots actively decoding — so a transport imposes exactly the bytes the
simulator predicts for the same exchange.

``q_probs`` (needed by the stochastic accept/resample rule at
temperature > 0) is carried as a device-array pass-through: the paper's
wire format ships only the per-token draft probability q(t_i) (8B/token,
already priced into ``window_payload_bytes``), and the residual
distribution is reconstructed target-side; this in-process reproduction
skips the reconstruction and hands the full distribution over, without
charging extra bytes. Greedy decoding (temperature 0 — the bit-identity
anchor) does not use it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

from ..sim.network import verdict_payload_bytes, window_payload_bytes


@dataclass
class WindowMsg:
    """Draft → target: one speculation window for the whole slot batch."""
    tokens: np.ndarray            # (B, gamma_max) int32 draft proposals
    gamma: int                    # active window size this round (≤ gamma_max)
    n_active: int                 # slots actually decoding (payload scaling)
    q_probs: Any = None           # (B, gamma_max, V) draft dists (temp > 0)

    @property
    def payload_bytes(self) -> int:
        return max(1, self.n_active) * window_payload_bytes(self.gamma)


@dataclass
class VerdictMsg:
    """Target → draft: the verdict for one speculation window.

    ``n_accepted``/``num_new`` are post-lifecycle (budget/EOS-clamped)
    counts; ``next_token`` is the raw corrected/bonus token and
    ``last_token`` the per-slot anchor for the next round (frozen for done
    rows)."""
    n_accepted: np.ndarray        # (B,) int32
    num_new: np.ndarray           # (B,) int32
    next_token: np.ndarray        # (B,) int32 raw corrected/bonus token
    last_token: np.ndarray        # (B,) int32 next-round anchor
    done: np.ndarray              # (B,) bool
    gamma: int
    n_active: int

    @property
    def payload_bytes(self) -> int:
        return max(1, self.n_active) * verdict_payload_bytes(self.gamma)
