"""TCP socket transport: the multi-process edge–cloud boundary.

:class:`SocketTransport` is a :class:`repro.distributed.transport.
Transport` whose two protocol directions are two REAL TCP streams —
windows draft→target on one, verdicts target→draft on the other — so the
full-duplex contract (a speculative window in flight while the previous
verdict travels back) maps one-to-one onto two independent byte pipes.
Messages cross as length-prefixed frames over the hardened
:func:`repro.distributed.wire.encode_window` /
:func:`~repro.distributed.wire.decode_window` codecs (and the verdict
pair); a third frame kind carries small JSON control messages for the
fused-mode flush and the worker-host command channel.

Frame layout (little-endian)::

    4s  magic           b"DSDF"
    B   kind            FRAME_WINDOW | FRAME_VERDICT | FRAME_CONTROL
    d   ready_s         sender CLOCK_MONOTONIC deadline for link emulation
    d   delay_ms        the sampled one-way delay behind ``ready_s``
    I   length          payload byte count (0 allowed for control frames)

Link emulation across processes: when the transport carries a
:class:`repro.sim.network.LinkSpec`, the SENDER samples the one-way
delay (same model DSD-Sim charges) and stamps ``ready_s = now + delay``
into the frame; the RECEIVER sleeps only the residual part of the flight
its own compute did not hide. ``time.perf_counter`` is CLOCK_MONOTONIC
on Linux — comparable across processes on one machine — so the overlap
arithmetic matches the in-process :class:`EmulatedLinkTransport` while
the bytes genuinely cross the kernel's TCP stack.

Three constructors cover the deployment shapes:

- :meth:`SocketTransport.loopback` — one object holding BOTH ends of two
  localhost streams. Drop-in for a single-process session (the
  conformance harness's fourth transport column): every message round-
  trips through real sockets, yet the session drives draft and target
  itself.
- :meth:`SocketTransport.draft_endpoint` /
  :meth:`SocketTransport.target_endpoint` — one HALF each, for the
  worker hosts in :mod:`repro.distributed.host`: the draft half sends
  windows / receives verdicts, the target half the reverse.

``bytes_sent`` keeps charging the PAPER's modeled payload bytes (sim ↔
real comparability, like every other transport); the actual framed bytes
that crossed the socket are accounted separately in ``wire_bytes``.
Protocol breakage — EOF mid-frame, bad magic, unknown kind, oversized
length, recv timeout, sending on a direction this endpoint does not own
— raises :class:`repro.distributed.wire.TransportProtocolError`.
"""

from __future__ import annotations

import json
import random
import socket
import struct
import time

from ..sim.network import LinkSpec, expected_rtt_ms, sample_one_way_ms
from .transport import BWD, FWD, Transport
from .wire import (TransportProtocolError, VerdictMsg, WindowMsg,
                   decode_verdict, decode_window, encode_verdict,
                   encode_window)

# magic, kind, ready_s (monotonic deadline), delay_ms (sampled), length
_FRAME_HDR = struct.Struct("<4sBddI")
_FRAME_MAGIC = b"DSDF"
_MAX_FRAME_BYTES = 64 << 20          # sanity bound on header-declared length

FRAME_WINDOW = 1
FRAME_VERDICT = 2
FRAME_CONTROL = 3


def _encode_control(obj) -> bytes:
    return b"" if obj is None else json.dumps(obj).encode("utf-8")


def _decode_control(payload: bytes):
    return None if not payload else json.loads(payload.decode("utf-8"))


# Kind ↔ codec tables. The DSD003 lint cross-checks these two dicts cover
# the same frame kinds the module declares — wire-schema drift (a new
# FRAME_* without both halves of its codec) fails the lint, same as a
# *Msg field without its encode/decode counterpart.
FRAME_ENCODERS = {
    FRAME_WINDOW: encode_window,
    FRAME_VERDICT: encode_verdict,
    FRAME_CONTROL: _encode_control,
}
FRAME_DECODERS = {
    FRAME_WINDOW: decode_window,
    FRAME_VERDICT: decode_verdict,
    FRAME_CONTROL: _decode_control,
}


def send_frame(sock: socket.socket, kind: int, payload: bytes,
               ready_s: float = 0.0, delay_ms: float = 0.0) -> int:
    """Write one length-prefixed frame; returns total bytes on the wire."""
    if kind not in FRAME_ENCODERS:
        raise TransportProtocolError(f"send_frame: unknown frame kind {kind}")
    if len(payload) > _MAX_FRAME_BYTES:
        raise TransportProtocolError(
            f"send_frame: payload of {len(payload)} bytes exceeds the "
            f"{_MAX_FRAME_BYTES}-byte frame bound")
    head = _FRAME_HDR.pack(_FRAME_MAGIC, kind, ready_s, delay_ms,
                           len(payload))
    try:
        sock.sendall(head + payload)
    except OSError as e:
        raise TransportProtocolError(f"send_frame: peer gone ({e})") from e
    return len(head) + len(payload)


def _recv_exact(sock: socket.socket, n: int, what: str) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            raise TransportProtocolError(
                f"recv_frame: timed out waiting for {what} "
                f"({len(buf)}/{n} bytes)") from None
        except OSError as e:
            raise TransportProtocolError(
                f"recv_frame: socket error reading {what} ({e})") from e
        if not chunk:
            raise TransportProtocolError(
                f"recv_frame: peer closed the stream mid-{what} "
                f"({len(buf)}/{n} bytes)")
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket):
    """Read one frame; returns ``(kind, payload, ready_s, delay_ms)``.
    Malformed framing raises :class:`TransportProtocolError`."""
    head = _recv_exact(sock, _FRAME_HDR.size, "frame header")
    magic, kind, ready_s, delay_ms, length = _FRAME_HDR.unpack(head)
    if magic != _FRAME_MAGIC:
        raise TransportProtocolError(
            f"recv_frame: bad frame magic {magic!r} at offset 0 "
            f"(want {_FRAME_MAGIC!r}) — streams out of sync")
    if kind not in FRAME_DECODERS:
        raise TransportProtocolError(f"recv_frame: unknown frame kind {kind}")
    if length > _MAX_FRAME_BYTES:
        raise TransportProtocolError(
            f"recv_frame: declared payload of {length} bytes exceeds the "
            f"{_MAX_FRAME_BYTES}-byte frame bound — corrupt length prefix")
    payload = _recv_exact(sock, length, "frame payload") if length else b""
    return kind, payload, ready_s, delay_ms


def _tcp_pair(timeout_s: float):
    """One connected localhost TCP stream; returns (client, server) ends."""
    lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    try:
        lst.bind(("127.0.0.1", 0))
        lst.listen(1)
        cli = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        cli.connect(lst.getsockname())
        srv, _ = lst.accept()
    finally:
        lst.close()
    for s in (cli, srv):
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        s.settimeout(timeout_s)
    return cli, srv


class SocketTransport(Transport):
    """Transport over two TCP streams (see module docstring).

    ``tx`` / ``rx`` map protocol directions (``FWD`` = window stream,
    ``BWD`` = verdict stream) to connected sockets. The loopback shape
    owns all four endpoints; an endpoint half owns one per table.
    """

    wall_clock = True

    def __init__(self, tx: dict, rx: dict, *, link: LinkSpec | None = None,
                 seed: int = 0, timeout_s: float = 30.0, owned=()):
        super().__init__()
        self._tx = dict(tx)
        self._rx = dict(rx)
        self.link = link
        self.timeout_s = float(timeout_s)
        self._rng = random.Random(seed)
        self._owned = list(owned)
        self.wire_bytes = 0              # actual framed bytes, incl. headers
        self._live = {FWD: 0, BWD: 0}    # best-effort in-flight counters
        for s in set(self._tx.values()) | set(self._rx.values()):
            s.settimeout(self.timeout_s)

    # -- construction shapes -------------------------------------------------

    @classmethod
    def loopback(cls, link: LinkSpec | None = None, seed: int = 0,
                 timeout_s: float = 30.0) -> "SocketTransport":
        """Both ends of both streams in one object: a drop-in transport
        for a single-process session whose every message still crosses
        the kernel's TCP stack."""
        w_tx, w_rx = _tcp_pair(timeout_s)
        v_tx, v_rx = _tcp_pair(timeout_s)
        return cls(tx={FWD: w_tx, BWD: v_tx}, rx={FWD: w_rx, BWD: v_rx},
                   link=link, seed=seed, timeout_s=timeout_s,
                   owned=[w_tx, w_rx, v_tx, v_rx])

    @classmethod
    def draft_endpoint(cls, window_sock: socket.socket,
                       verdict_sock: socket.socket, *,
                       link: LinkSpec | None = None, seed: int = 0,
                       timeout_s: float = 30.0) -> "SocketTransport":
        """Edge half: sends windows, receives verdicts."""
        return cls(tx={FWD: window_sock}, rx={BWD: verdict_sock}, link=link,
                   seed=seed, timeout_s=timeout_s,
                   owned=[window_sock, verdict_sock])

    @classmethod
    def target_endpoint(cls, window_sock: socket.socket,
                        verdict_sock: socket.socket, *,
                        link: LinkSpec | None = None, seed: int = 0,
                        timeout_s: float = 30.0) -> "SocketTransport":
        """Cloud half: receives windows, sends verdicts."""
        return cls(tx={BWD: verdict_sock}, rx={FWD: window_sock}, link=link,
                   seed=seed, timeout_s=timeout_s,
                   owned=[window_sock, verdict_sock])

    def _sock(self, table: dict, direction: str, op: str) -> socket.socket:
        try:
            return table[direction]
        except KeyError:
            raise TransportProtocolError(
                f"{op} on {direction!r}: this endpoint does not own that "
                f"direction (split draft/target half)") from None

    # -- delay model ---------------------------------------------------------

    def _sample_delay_ms(self, payload_bytes: int) -> float:
        if self.link is None:
            return 0.0
        return sample_one_way_ms(self.link, self._rng, payload_bytes)

    def _default_rtt_ms(self) -> float:
        return expected_rtt_ms(self.link) if self.link is not None else 0.0

    # -- framed post / recv --------------------------------------------------

    def _post(self, direction: str, msg, payload_bytes: int,
              round_id=None) -> float:
        sock = self._sock(self._tx, direction, "post")
        if msg is None or isinstance(msg, dict):
            kind = FRAME_CONTROL
        else:
            kind = FRAME_WINDOW if direction == FWD else FRAME_VERDICT
        try:
            payload = FRAME_ENCODERS[kind](msg)
        except ValueError as e:
            raise TransportProtocolError(
                f"post on {direction!r}: message refused by the wire codec "
                f"({e})") from e
        delay_ms = self._sample_delay_ms(payload_bytes)
        ready_s = time.perf_counter() + delay_ms / 1e3
        self.wire_bytes += send_frame(sock, kind, payload, ready_s, delay_ms)
        self.bytes_sent += payload_bytes     # modeled bytes (sim parity)
        self.messages_sent += 1
        log = self.delay_log[direction]
        log.append(delay_ms)
        if len(log) > 512:
            del log[:256]
        if round_id is not None and direction == FWD:
            # RTT pairing completes at recv(BWD) — the verdict frame
            # carries its own sampled delay — so a SPLIT draft endpoint
            # measures round trips too, not just the loopback shape.
            self._out_delay_ms[round_id] = delay_ms
        self._live[direction] += 1
        return delay_ms

    def _recv(self, direction: str):
        sock = self._sock(self._rx, direction, "recv")
        kind, payload, ready_s, delay_ms = recv_frame(sock)
        expected = FRAME_WINDOW if direction == FWD else FRAME_VERDICT
        if kind not in (expected, FRAME_CONTROL):
            raise TransportProtocolError(
                f"recv on {direction!r}: got frame kind {kind}, want "
                f"{expected} or control — streams crossed")
        try:
            msg = FRAME_DECODERS[kind](payload)
        except ValueError as e:
            raise TransportProtocolError(
                f"recv on {direction!r}: undecodable payload ({e})") from e
        if direction == BWD and isinstance(msg, VerdictMsg):
            out = self._out_delay_ms.pop(msg.round_id, None)
            if out is not None:
                self._rtt.record_rtt(out + delay_ms)
        self._live[direction] -= 1
        wait_s = ready_s - time.perf_counter()
        if wait_s <= 0.0:
            return msg, 0.0
        t0 = time.perf_counter()
        time.sleep(wait_s)
        return msg, (time.perf_counter() - t0) * 1e3

    def discard_window(self):
        """Read and drop the oldest window frame without waiting out its
        emulated flight (the bytes were already spent on the wire)."""
        sock = self._sock(self._rx, FWD, "discard_window")
        kind, payload, _ready_s, _delay_ms = recv_frame(sock)
        if kind != FRAME_WINDOW:
            raise TransportProtocolError(
                f"discard_window: got frame kind {kind}, want window")
        try:
            msg = FRAME_DECODERS[kind](payload)
        except ValueError as e:
            raise TransportProtocolError(
                f"discard_window: undecodable window ({e})") from e
        self.discarded_messages += 1
        self._live[FWD] -= 1
        self._out_delay_ms.pop(msg.round_id, None)
        return msg

    def control_roundtrip(self, payload_bytes: int = 64) -> float:
        if FWD not in self._tx or FWD not in self._rx:
            raise TransportProtocolError(
                "control_roundtrip needs both ends of both streams "
                "(loopback shape); split endpoints exchange control frames "
                "through the host command loop instead")
        return super().control_roundtrip(payload_bytes)

    # -- lifecycle / measurement ---------------------------------------------

    @property
    def in_flight(self) -> int:
        return max(0, self._live[FWD]) + max(0, self._live[BWD])

    def close(self) -> None:
        for s in self._owned:
            try:
                s.close()
            except OSError:
                pass
        self._owned = []

    def __del__(self):  # pragma: no cover - interpreter-shutdown best effort
        try:
            self.close()
        except Exception:
            pass

    def describe(self) -> str:
        shape = ("loopback" if FWD in self._tx and FWD in self._rx
                 else "draft-endpoint" if FWD in self._tx
                 else "target-endpoint")
        link = ("none" if self.link is None
                else f"rtt={self.link.rtt_ms}ms")
        return f"socket({shape}, link={link})"
