"""DraftWorker / TargetWorker — the engine's decode step split at the wire.

The colocated :class:`repro.core.engine.SpecDecodeEngine` fuses one
speculation iteration (draft propose → target verify → commit) into a
single XLA program. Distributed execution splits that program at exactly
the points where bytes cross the network:

- :class:`DraftWorker` (edge) owns the draft model and compiles
  ``propose`` (the γ_max-wide autoregressive proposal scan), ``ingest``
  (advance one committed token during fused rounds) and ``advance``
  (recurrent-draft re-advance over the committed prefix).
- :class:`TargetWorker` (cloud) owns the target model and compiles
  ``verify_commit``: window verification, the accept/resample rule,
  per-slot lifecycle masking (:func:`repro.core.specdec.slot_stop_mask`)
  and output-buffer accumulation — byte-for-byte the target half of the
  engine's fused/split step, so a round through
  :class:`repro.distributed.transport.InProcessTransport` commits greedy
  tokens bit-identical to the colocated path.

Both workers register their jitted programs in the owning engine's
``_jit_cache`` so ``engine.compiled_programs()`` keeps counting every XLA
program and the session's zero-recompile invariant extends to the
distributed path (γ and the slot lifecycle stay traced).

The workers do not donate their cache operands: the draft's pre-window
cache doubles as the recurrent-family rollback checkpoint, and the
round-trip through the transport keeps a host sync per iteration anyway —
simplicity wins over the colocated path's in-place-update optimization
here. Cross-round pipelining leans on exactly this: the session's
optimistic draft of window k+1 reuses ``advance`` (recurrent drafts: the
same re-advance program runs once under the all-accept assumption and
again from the kept checkpoint on a rollback) and the undonated
``propose`` output (attention drafts: the pre-speculation propose cache
IS the rollback state — the speculative window's extra KV writes live
only in the discarded cache), so hits, rollbacks and mode switches add
zero XLA programs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.engine import _accumulate, _scan_cache_advance, _tree_where
from ..core.specdec import (SpecDecodeOut, _temperature_probs, draft_propose,
                            slot_stop_mask, verify_window,
                            verify_window_greedy)
from ..core.tree import (TreeSpec, tree_committed, tree_propose,
                         verify_tree_greedy)
from ..models.kvcache import tree_commit_cache


class DraftWorker:
    """Edge-side worker: proposes speculation windows, tracks the committed
    prefix through verdicts."""

    def __init__(self, engine):
        self.engine = engine
        self.model = engine.draft
        self.params = engine.draft_params
        self.attention = engine._draft_attention
        self.temperature = engine.temperature

    # -- jitted programs ----------------------------------------------------

    def propose(self, gamma_max: int):
        """(params, cache, last_token, pos, key) → (tokens, q_probs, cache).

        Always scans the full ``gamma_max`` window (the compile-once
        invariant); the active γ of the round only masks acceptance on the
        target side and prices the wire payload."""
        keyt = ("dw_propose", gamma_max)
        cache = self.engine._jit_cache
        if keyt in cache:
            return cache[keyt]
        decode = lambda p, t, c, pos: self.model.decode_step(p, t, c, pos)

        def fn(params, dcache, last_token, pos, key):
            prop = draft_propose(decode, params, dcache, last_token, pos,
                                 gamma_max, key, self.temperature)
            return prop.tokens, prop.q_probs, prop.cache

        cache[keyt] = jax.jit(fn)
        return cache[keyt]

    def propose_tree(self, d_max: int, b_max: int):
        """(params, cache, last_token, pos) → (tree_tokens (B, T), cache).

        Greedy grid-tree proposal (:func:`repro.core.tree.tree_propose`):
        one anchor decode + ``d_max − 1`` lockstep frontier passes, always
        the full (d_max, b_max) grid — the round's (γ, b) only masks
        acceptance target-side, like the linear propose always scanning
        γ_max. Attention drafts only (tree slots need a KV pos_map)."""
        keyt = ("dw_propose_tree", d_max, b_max)
        cache = self.engine._jit_cache
        if keyt in cache:
            return cache[keyt]
        assert self.attention, \
            "tree speculation needs an attention-family draft"
        spec = TreeSpec(d_max, b_max)

        def fn(params, dcache, last_token, pos):
            return tree_propose(self.model, params, dcache, last_token,
                                pos, spec)

        cache[keyt] = jax.jit(fn)
        return cache[keyt]

    def ingest_tree(self, d_max: int, b_max: int):
        """(propose_cache, pos, path, n_accepted) → cache.

        Verdict application for tree rounds: relocate the winning path's
        KV from grid slots onto the canonical linear slots and scrub the
        losing branches — the draft-side mirror of the target's tree
        commit, so both caches agree on the committed prefix layout."""
        keyt = ("dw_ingest_tree", d_max, b_max)
        cache = self.engine._jit_cache
        if keyt in cache:
            return cache[keyt]
        assert self.attention, \
            "tree speculation needs an attention-family draft"
        n_entries = 1 + d_max * b_max

        def fn(dcache, pos, path, n_accepted):
            return tree_commit_cache(dcache, pos, path, n_accepted,
                                     n_entries)

        cache[keyt] = jax.jit(fn)
        return cache[keyt]

    def ingest(self):
        """(params, cache, token, pos, num_new) → cache.

        Fused rounds produce one target token per iteration without a
        draft window; the draft still ingests the previous anchor token at
        its position so its cache tracks the committed prefix and a later
        switch back to distributed mode proposes from a coherent state.
        Rows with ``num_new == 0`` (done/free) keep their old cache."""
        keyt = ("dw_ingest",)
        cache = self.engine._jit_cache
        if keyt in cache:
            return cache[keyt]

        def fn(params, dcache, token, pos, num_new):
            _, cnew = self.model.decode_step(params, token, dcache, pos)
            return _tree_where(num_new > 0, cnew, dcache)

        cache[keyt] = jax.jit(fn)
        return cache[keyt]

    def advance(self, gamma_max: int):
        """(params, checkpoint_cache, adv_tokens, pos, num_new) → cache.

        Recurrent-draft verdict application: re-advance the pre-window
        cache checkpoint over the committed prefix (the SSM analogue of
        attention's pos_map rollback — same scan the colocated split step
        runs)."""
        keyt = ("dw_advance", gamma_max)
        cache = self.engine._jit_cache
        if keyt in cache:
            return cache[keyt]
        decode = lambda p, t, c, pos: self.model.decode_step(p, t, c, pos)

        def fn(params, dcache, adv_tokens, pos, num_new):
            return _scan_cache_advance(decode, params, dcache, adv_tokens,
                                       pos, num_new)

        cache[keyt] = jax.jit(fn)
        return cache[keyt]


class TargetWorker:
    """Cloud-side worker: verifies windows, owns the committed-token
    buffers and the per-slot lifecycle (budget/EOS enforcement lives where
    the tokens are produced)."""

    def __init__(self, engine):
        self.engine = engine
        self.model = engine.target
        self.params = engine.target_params
        self.attention = engine._target_attention
        self.temperature = engine.temperature

    def verify_commit(self, gamma_max: int):
        """One jitted verdict program at the static window bound.

        Signature (``q_probs`` present only at temperature > 0)::

            (params, tcache, window, pos, active_gamma, key, [q_probs,]
             out_buf, cursor, nacc_buf, nn_buf, max_new, done, row_idx,
             eos_id)
            → (tcache, pos, last_token, out_buf, cursor, nacc_buf, nn_buf,
               done, num_new, n_accepted, next_token_raw)

        ``window`` is ``[last_token, draft_tokens]`` (γ_max+1 wide);
        ``active_gamma`` masks acceptance exactly as in the colocated step
        — γ = 0 is the fused round: nothing accepted, the target's own
        next token at position 0 is committed, no draft required.
        Attention targets keep the speculative window writes (pos_map
        masks the stale tail); SSM/hybrid targets verify on a throwaway
        cache and re-advance the committed prefix with the same masked
        scan the colocated split step uses."""
        keyt = ("tw_verify", gamma_max)
        cache = self.engine._jit_cache
        if keyt in cache:
            return cache[keyt]
        greedy = self.temperature <= 0.0

        def core(params, tcache, window, pos, active_gamma, key, q_probs,
                 out_buf, cursor, nacc_buf, nn_buf, max_new, done, row_idx,
                 eos_id):
            draft_tokens = window[:, 1:]
            p_logits, tcache_spec = self.model.verify_step(
                params, window, tcache, pos)
            if greedy:
                res = verify_window_greedy(draft_tokens, p_logits,
                                           active_gamma=active_gamma)
            else:
                p_probs = _temperature_probs(p_logits, self.temperature)
                res = verify_window(key, draft_tokens, q_probs, p_probs,
                                    active_gamma=active_gamma)

            arange = jnp.arange(gamma_max + 1)[None, :]
            acc_part = jnp.concatenate(
                [draft_tokens, jnp.zeros_like(draft_tokens[:, :1])], axis=1)
            committed = jnp.where(arange == res.n_accepted[:, None],
                                  res.next_token[:, None], acc_part)
            new_tokens = jnp.where(arange < res.num_new[:, None],
                                   committed, -1)
            stop = slot_stop_mask(res.num_new, res.n_accepted, new_tokens,
                                  cursor, max_new, done, eos_id)

            if self.attention:
                tcache_new = tcache_spec
            else:
                adv_tokens = jnp.concatenate(
                    [window[:, :1], committed[:, :gamma_max]], axis=1)
                tcache_new = _scan_cache_advance(
                    self.model.decode_step, params, tcache, adv_tokens,
                    pos, stop.num_new)

            out = SpecDecodeOut(state=None, new_tokens=new_tokens,
                                num_new=stop.num_new,
                                n_accepted=stop.n_accepted)
            out_buf, cursor, nacc_buf, nn_buf = _accumulate(
                out, out_buf, cursor, nacc_buf, nn_buf, row_idx)
            last = jnp.where(done, window[:, 0], res.next_token)
            return (tcache_new, pos + stop.num_new, last, out_buf, cursor,
                    nacc_buf, nn_buf, stop.done, stop.num_new,
                    stop.n_accepted, res.next_token)

        if greedy:
            def fn(params, tcache, window, pos, active_gamma, key, out_buf,
                   cursor, nacc_buf, nn_buf, max_new, done, row_idx, eos_id):
                return core(params, tcache, window, pos, active_gamma, key,
                            None, out_buf, cursor, nacc_buf, nn_buf,
                            max_new, done, row_idx, eos_id)
        else:
            fn = core
        cache[keyt] = jax.jit(fn)
        return cache[keyt]

    def verify_commit_tree(self, d_max: int, b_max: int):
        """The tree-round verdict program (greedy only).

        Signature::

            (params, tcache, tree_tokens, pos, active_gamma, branches,
             out_buf, cursor, nacc_buf, nn_buf, max_new, done, row_idx,
             eos_id)
            → (tcache, pos, last_token, out_buf, cursor, nacc_buf, nn_buf,
               done, num_new, n_accepted, next_token_raw, path)

        ``tree_tokens`` is the (B, T) grid window (entry 0 = anchor); one
        ancestor-masked verify pass scores every entry, the longest-
        accepted-root-path rule picks the winner, and
        :func:`repro.models.kvcache.tree_commit_cache` relocates the
        winning path onto the canonical linear slots. The extra ``path``
        output lets the draft side run the same relocation on its propose
        cache (:meth:`DraftWorker.ingest_tree`). Attention targets only —
        the grid writes slots ≠ positions, which needs a pos_map."""
        keyt = ("tw_verify_tree", d_max, b_max)
        cache = self.engine._jit_cache
        if keyt in cache:
            return cache[keyt]
        assert self.temperature <= 0.0, \
            "tree speculation is greedy-only (no per-branch q dists yet)"
        assert self.attention, \
            "tree speculation needs an attention-family target"
        spec = TreeSpec(d_max, b_max)
        T = spec.n_entries

        def fn(params, tcache, tree_tokens, pos, active_gamma, branches,
               out_buf, cursor, nacc_buf, nn_buf, max_new, done, row_idx,
               eos_id):
            p_logits, tcache_spec = self.model.verify_step(
                params, tree_tokens, tcache, pos,
                slot_off=jnp.arange(T, dtype=jnp.int32),
                pos_off=spec.tree_pos, win_mask=spec.win_mask)
            node_valid = spec.node_valid(active_gamma, branches)
            res = verify_tree_greedy(tree_tokens, p_logits,
                                     spec.parent_entry, spec.tree_pos,
                                     node_valid, spec.win_mask, d_max)
            new_tokens, num_new = tree_committed(tree_tokens, res, d_max)
            stop = slot_stop_mask(num_new, res.n_accepted, new_tokens,
                                  cursor, max_new, done, eos_id)
            tcache_new = tree_commit_cache(tcache_spec, pos, res.path,
                                           stop.n_accepted, T)
            out = SpecDecodeOut(state=None, new_tokens=new_tokens,
                                num_new=stop.num_new,
                                n_accepted=stop.n_accepted)
            out_buf, cursor, nacc_buf, nn_buf = _accumulate(
                out, out_buf, cursor, nacc_buf, nn_buf, row_idx)
            last = jnp.where(done, tree_tokens[:, 0], res.next_token)
            return (tcache_new, pos + stop.num_new, last, out_buf, cursor,
                    nacc_buf, nn_buf, stop.done, stop.num_new,
                    stop.n_accepted, res.next_token, res.path)

        cache[keyt] = jax.jit(fn)
        return cache[keyt]
