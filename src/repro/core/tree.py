"""Tree-structured speculation: grid-shaped multi-branch drafts verified in
one masked target pass.

A speculation *tree* generalizes the linear window: instead of one γ-token
chain, the draft proposes several candidate continuations that share a
prefix, and the target verifies all of them in a single ancestor-masked
pass — the same pass cost buys more chances to commit tokens when the
chain would have broken early (low α).

Compile-once shape. The engine compiles ONE program per (d_max, b_max)
bound, exactly like the linear step compiles once at γ_max. To keep every
per-round tree inside that single program, trees are drawn from a
*canonical grid family*:

- ``T = 1 + d_max·b_max`` window entries; entry 0 is the anchor (the last
  committed token), entry ``1 + d·b_max + k`` is depth ``d`` of branch
  ``k`` (depth-major flattening).
- branch ``k`` is a greedy chain rooted at the draft anchor
  distribution's k-th-best token; all branches share the anchor, so
  ``parent(d, k) = (d−1, k)`` for d > 0 and the anchor otherwise.
- a round's active shape (γ ≤ d_max depths, b ≤ b_max branches) enters
  the trace ONLY through the ``node_valid`` mask (and the traced parent /
  position / ancestor-mask buffers) — never through array shapes, so γ
  and b vary per round with zero recompiles.
- ``b_max = 1`` degenerates to today's linear chain: entries are the
  window positions, the ancestor mask is the causal mask, and the accept
  rule below reduces to the masked-window prefix rule bit-for-bit.

Accept rule (greedy, longest accepted root path). With ``tgt[e]`` the
target argmax at entry ``e``, an entry is *accepted* iff every tree edge
on its root path predicted correctly::

    accept[e] = node_valid[e] ∧ (token[e] == tgt[parent[e]]) ∧ accept[parent[e]]

The committed path is the deepest accepted entry (ties → lowest entry
index, i.e. the best-ranked branch), and the bonus token is the target's
own prediction AT the winning entry — the tree generalization of the
linear rule's corrected/bonus token. The anchor is always accepted, so
the rule always commits ≥ 1 token, like the linear path.

KV discipline. Entry ``e`` writes cache slot ``pos + e`` while its
*logical* position (RoPE phase, pos_map value) is ``pos + tree_pos[e]``
— siblings share positions but never slots. During the round the
ancestor bitmap masks cross-branch attention (the base ``slot_pos ≤
q_pos`` rule cannot: siblings tie on position); after the verdict
:func:`repro.models.kvcache.tree_commit_cache` relocates the winning
path onto the canonical linear slots and scrubs the losing branches'
pos_map — the same pos_map mechanism the linear path uses for rollback,
plus a relocation because tree slots ≠ positions.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class TreeSpec:
    """Static (d_max, b_max) grid-family descriptor.

    Holds the numpy layout tables and their device mirrors. Everything
    here depends only on the compile-time bounds; the per-round shape is
    produced by :meth:`node_valid` from traced (γ, branches) scalars.
    """

    def __init__(self, d_max: int, b_max: int):
        if d_max < 1 or b_max < 1:
            raise ValueError(f"TreeSpec needs d_max, b_max >= 1, got "
                             f"({d_max}, {b_max})")
        self.d_max = int(d_max)
        self.b_max = int(b_max)
        T = 1 + self.d_max * self.b_max
        self.n_entries = T

        depth = np.full((T,), -1, np.int32)    # anchor = -1
        branch = np.zeros((T,), np.int32)
        parent = np.zeros((T,), np.int32)      # anchor's parent = itself
        tpos = np.zeros((T,), np.int32)        # window-relative position
        for d in range(self.d_max):
            for k in range(self.b_max):
                e = 1 + d * self.b_max + k
                depth[e], branch[e], tpos[e] = d, k, 1 + d
                parent[e] = 0 if d == 0 else 1 + (d - 1) * self.b_max + k
        mask = np.zeros((T, T), bool)          # ancestor-or-self bitmap
        for e in range(T):
            a = e
            while True:
                mask[e, a] = True
                if a == 0:
                    break
                a = int(parent[a])

        self.depth_np, self.branch_np = depth, branch
        self.parent_np, self.tree_pos_np, self.mask_np = parent, tpos, mask
        # Device mirrors — passed into the jitted step as traced buffers.
        self.parent_entry = jnp.asarray(parent)
        self.tree_pos = jnp.asarray(tpos)
        self.win_mask = jnp.asarray(mask)
        self.depth = jnp.asarray(depth)
        self.branch = jnp.asarray(branch)

    def node_valid(self, gamma, branches) -> jax.Array:
        """(T,) bool — which grid entries the round's (γ, b) activates.

        ``gamma``/``branches`` may be traced scalars; the anchor (depth
        −1, branch 0) is always valid."""
        return (self.depth < gamma) & (self.branch < branches)

    def row_slice(self, d: int) -> tuple[int, int]:
        """Entry range [lo, hi) of depth ``d``'s b_max-wide frontier."""
        lo = 1 + d * self.b_max
        return lo, lo + self.b_max


def tree_expected_accepted(alpha: float, gamma: float, branches: float,
                           decay: float = 0.4) -> float:
    """E[accepted draft tokens] of a (γ, b) grid tree at acceptance α.

    The primary branch is the ordinary chain: E_chain(α, γ) =
    α(1 − α^γ)/(1 − α) accepted tokens. Extra branches only matter when
    the primary ROOT is rejected (prob 1 − α): an alternative root is the
    draft's k-th-best token, which matches the target's argmax with a
    decayed probability r = decay·α (top-2 swaps dominate draft–target
    disagreement, but each further rank is less likely — ``decay``
    calibrates how much of α survives the rank demotion). A rescued
    branch contributes its root plus a fresh (γ − 1)-deep chain below it.

    With b = 1 this reduces exactly to E_chain — the analytic mirror of
    the degenerate-tree bit-identity. Host-side float math (feeds the AWC
    joint {γ, b} decision and DSD-Sim's tree acceptance replay)."""
    a = min(max(float(alpha), 0.0), 1.0 - 1e-9)
    g = max(float(gamma), 0.0)
    b = max(float(branches), 1.0)

    def chain(depth: float) -> float:
        return a * (1.0 - a ** depth) / (1.0 - a) if depth > 0 else 0.0

    r = min(max(decay * a, 0.0), 1.0)
    rescue_p = (1.0 - a) * (1.0 - (1.0 - r) ** (b - 1.0))
    return chain(g) + rescue_p * (1.0 + chain(g - 1.0))


class TreeVerifyResult(NamedTuple):
    """Per-slot verdict of one tree verify pass (pre-lifecycle)."""
    n_accepted: jax.Array   # (B,) int32 — depth of the winning entry
    next_token: jax.Array   # (B,) int32 — target prediction at the winner
    winner: jax.Array       # (B,) int32 — winning entry index
    path: jax.Array         # (B, d_max) int32 — root-path entries (0 pad)
    accept: jax.Array       # (B, T) bool — accepted-entry bitmap


def verify_tree_greedy(tree_tokens: jax.Array,    # (B, T) int32
                       p_logits: jax.Array,       # (B, T, V)
                       parent_entry: jax.Array,   # (T,) int32
                       tree_pos: jax.Array,       # (T,) int32
                       node_valid: jax.Array,     # (T,) bool
                       win_mask: jax.Array,       # (T, T) bool ancestor map
                       d_max: int) -> TreeVerifyResult:
    """Longest-accepted-root-path rule over one target pass's logits.

    Generalizes :func:`repro.core.specdec.verify_window_greedy`: with the
    degenerate chain grid (b_max = 1) the two agree bit-for-bit (accept
    prefix, count, bonus token)."""
    B, T = tree_tokens.shape
    tgt = jnp.argmax(p_logits, axis=-1).astype(jnp.int32)          # (B, T)
    parent_tgt = jnp.take_along_axis(
        tgt, jnp.broadcast_to(parent_entry[None, :], (B, T)), axis=1)
    match = node_valid[None, :] & (tree_tokens == parent_tgt)
    match = match.at[:, 0].set(True)                               # anchor
    # accept[e] = AND over ancestors-or-self of match — one masked all().
    accept = jnp.all(match[:, None, :] | ~win_mask[None, :, :], axis=-1)

    # Deepest accepted entry; ties break toward the lowest entry index
    # (the best-ranked branch of that depth).
    entry = jnp.arange(T)
    score = jnp.where(accept, tree_pos[None, :] * T + (T - entry)[None, :],
                      -1)
    winner = jnp.argmax(score, axis=-1).astype(jnp.int32)          # (B,)
    n_acc = jnp.take(tree_pos, winner).astype(jnp.int32)
    bonus = jnp.take_along_axis(tgt, winner[:, None], axis=1)[:, 0]

    path = tree_path_from_winner(winner, parent_entry, tree_pos, d_max)
    return TreeVerifyResult(n_accepted=n_acc,
                            next_token=bonus.astype(jnp.int32),
                            winner=winner, path=path, accept=accept)


def tree_path_from_winner(winner: jax.Array, parent_entry: jax.Array,
                          tree_pos: jax.Array, d_max: int) -> jax.Array:
    """(B, d_max) root-path entries of ``winner``: a static d_max-step
    parent walk scattering each visited entry into its depth slot (the
    anchor contributes nothing; depths beyond the winner stay 0)."""
    B = winner.shape[0]
    path = jnp.zeros((B, d_max), jnp.int32)
    darange = jnp.arange(d_max)[None, :]
    cur = winner
    for _ in range(d_max):
        dcur = jnp.take(tree_pos, cur)                             # (B,)
        hit = (darange == (dcur - 1)[:, None]) & (cur != 0)[:, None]
        path = jnp.where(hit, cur[:, None], path)
        cur = jnp.take(parent_entry, cur)
    return path


def tree_committed(tree_tokens: jax.Array, res: TreeVerifyResult,
                   d_max: int) -> tuple[jax.Array, jax.Array]:
    """(new_tokens (B, d_max+1), num_new (B,)) — the committed window.

    Mirrors the linear step's corrected/bonus assembly: positions
    0..n_acc−1 are the winning path's draft tokens, position n_acc is the
    bonus token, the rest are −1-padded."""
    path_tokens = jnp.take_along_axis(tree_tokens, res.path, axis=1)
    committed = jnp.concatenate(
        [path_tokens, jnp.zeros_like(path_tokens[:, :1])], axis=1)
    arange = jnp.arange(d_max + 1)[None, :]
    committed = jnp.where(arange == res.n_accepted[:, None],
                          res.next_token[:, None], committed)
    num_new = res.n_accepted + 1
    new_tokens = jnp.where(arange < num_new[:, None], committed, -1)
    return new_tokens, num_new


def tree_propose(model, params, cache, last_token: jax.Array,
                 pos: jax.Array, spec: TreeSpec):
    """Draft a full (d_max, b_max) grid in lockstep depth rounds.

    One anchor decode yields the top-b_max root tokens; each subsequent
    depth is ONE b_max-wide masked window pass (all branches advance
    together), writing slots ``pos + entry`` at logical positions
    ``pos + 1 + d`` under the ancestor mask. The final depth's KV is not
    written — the same tail hole the linear propose scan leaves, masked
    by pos_map either way.

    Returns ``(tree_tokens (B, T) int32, cache)``. The grid is proposed
    unconditionally; the round's (γ, b) only masks acceptance, exactly
    like the linear path always scanning γ_max.
    """
    d_max, b_max, T = spec.d_max, spec.b_max, spec.n_entries
    logits, cache = model.decode_step(params, last_token, cache, pos)
    _, roots = jax.lax.top_k(logits, b_max)
    frontier = roots.astype(jnp.int32)                       # (B, b_max)
    rows = [frontier]
    for d in range(d_max - 1):
        lo, hi = spec.row_slice(d)
        slot_off = jnp.arange(lo, hi, dtype=jnp.int32)
        pos_off = jnp.full((b_max,), 1 + d, jnp.int32)
        lg, cache = model.verify_step(
            params, frontier, cache, pos, slot_off=slot_off,
            pos_off=pos_off, win_mask=spec.win_mask[lo:hi, :])
        frontier = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        rows.append(frontier)
    tree_tokens = jnp.concatenate([last_token[:, None]] + rows, axis=1)
    return tree_tokens, cache
