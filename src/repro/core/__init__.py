"""DSD core — the paper's contribution: distributed speculative decoding
(algorithm + engine) and Adaptive Window Control."""

from .specdec import (DraftProposal, SlotStop, SpecDecodeOut,
                      SpecDecodeState, VerifyResult, draft_propose,
                      expected_accepted, expected_speedup, optimal_gamma,
                      slot_stop_mask, spec_decode_step, verify_window,
                      verify_window_greedy)
from .window import (AWCWindowPolicy, DynamicWindowPolicy, FeatureSnapshot,
                     OracleStaticPolicy, StaticWindowPolicy, WindowDecision)
from .engine import GenerationStats, SpecDecodeEngine
from .session import DecodeSession, SlotRecord
