"""WC-DNN — the AWC window-control network (paper §4.1, §4.3, Fig. 3).

A residual MLP: 5-dim feature vector → input projection → two residual
blocks with SiLU activations → scalar head predicting the speculation window
size γ as a continuous value. Features are z-normalized with statistics
stored inside the parameter pytree so the deployed predictor is
self-contained.

Two inference paths:
- JAX (:func:`forward`) for training,
- numpy (:func:`numpy_predictor`) for the simulator's per-iteration inner
  loop, where jit dispatch overhead would dominate.

:func:`bootstrap_predictor` is the analytic controller used before any
training data exists: it maximizes the paper's Eq. (2) speedup corrected for
the network round-trip — the same objective the learned labels encode.
"""

from __future__ import annotations

import math
import os
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


# [q_depth, alpha_recent, rtt_ms, tpot_ms, gamma_prev, pipe_hit_recent,
#  branches_prev]
FEATURE_DIM = 7


class WCDNNParams(NamedTuple):
    feat_mean: jax.Array   # (FEATURE_DIM,)
    feat_std: jax.Array    # (FEATURE_DIM,)
    w_in: jax.Array        # (FEATURE_DIM, H)
    b_in: jax.Array        # (H,)
    blocks: tuple          # ((w1,b1,w2,b2), ...) residual blocks
    w_out: jax.Array       # (H, 1)
    b_out: jax.Array       # (1,)


def init(key: jax.Array, hidden: int = 64, n_blocks: int = 2) -> WCDNNParams:
    ks = jax.random.split(key, 2 + 2 * n_blocks)

    def dense(k, fan_in, fan_out):
        scale = math.sqrt(2.0 / fan_in)
        return jax.random.normal(k, (fan_in, fan_out), jnp.float32) * scale

    blocks = []
    for i in range(n_blocks):
        w1 = dense(ks[2 + 2 * i], hidden, hidden)
        w2 = dense(ks[3 + 2 * i], hidden, hidden)
        blocks.append((w1, jnp.zeros((hidden,)), w2, jnp.zeros((hidden,))))
    return WCDNNParams(
        feat_mean=jnp.zeros((FEATURE_DIM,)),
        feat_std=jnp.ones((FEATURE_DIM,)),
        w_in=dense(ks[0], FEATURE_DIM, hidden),
        b_in=jnp.zeros((hidden,)),
        blocks=tuple(blocks),
        w_out=dense(ks[1], hidden, 1) * 0.1,
        b_out=jnp.full((1,), 4.0),   # bias toward the paper's default γ=4
    )


def set_normalization(params: WCDNNParams, x: jax.Array) -> WCDNNParams:
    mean = jnp.mean(x, axis=0)
    std = jnp.maximum(jnp.std(x, axis=0), 1e-3)
    return params._replace(feat_mean=mean, feat_std=std)


def forward(params: WCDNNParams, x: jax.Array) -> jax.Array:
    """x: (..., FEATURE_DIM) → (...,) continuous γ prediction."""
    h = (x - params.feat_mean) / params.feat_std
    h = jax.nn.silu(h @ params.w_in + params.b_in)
    for (w1, b1, w2, b2) in params.blocks:
        r = jax.nn.silu(h @ w1 + b1)
        r = jax.nn.silu(r @ w2 + b2)
        h = h + r
    out = h @ params.w_out + params.b_out
    return out[..., 0]


# --------------------------------------------------------------------------
# Deployment paths
# --------------------------------------------------------------------------

def numpy_predictor(params: WCDNNParams) -> Callable[[list[float]], float]:
    """Export to numpy for sub-microsecond per-call inference in DSD-Sim."""
    mean = np.asarray(params.feat_mean)
    std = np.asarray(params.feat_std)
    w_in, b_in = np.asarray(params.w_in), np.asarray(params.b_in)
    blocks = [(np.asarray(w1), np.asarray(b1), np.asarray(w2), np.asarray(b2))
              for (w1, b1, w2, b2) in params.blocks]
    w_out, b_out = np.asarray(params.w_out), np.asarray(params.b_out)

    def silu(v):
        # numerically stable x·sigmoid(x)
        pos = v >= 0
        ev = np.exp(np.where(pos, -v, v))
        sig = np.where(pos, 1.0 / (1.0 + ev), ev / (1.0 + ev))
        return v * sig

    def predict(feats: list[float]) -> float:
        h = (np.asarray(feats, np.float32) - mean) / std
        h = silu(h @ w_in + b_in)
        for (w1, b1, w2, b2) in blocks:
            h = h + silu(silu(h @ w1 + b1) @ w2 + b2)
        return float((h @ w_out + b_out)[0])

    return predict


def save(params: WCDNNParams, path: str) -> None:
    flat = {
        "feat_mean": params.feat_mean, "feat_std": params.feat_std,
        "w_in": params.w_in, "b_in": params.b_in,
        "w_out": params.w_out, "b_out": params.b_out,
        "n_blocks": np.asarray(len(params.blocks)),
    }
    for i, (w1, b1, w2, b2) in enumerate(params.blocks):
        flat[f"blk{i}_w1"], flat[f"blk{i}_b1"] = w1, b1
        flat[f"blk{i}_w2"], flat[f"blk{i}_b2"] = w2, b2
    np.savez(path, **{k: np.asarray(v) for k, v in flat.items()})


def load(path: str) -> WCDNNParams:
    z = np.load(path)
    got = int(z["w_in"].shape[0])
    if got != FEATURE_DIM:
        raise ValueError(
            f"{path} was trained on {got}-dim features but this build "
            f"expects FEATURE_DIM={FEATURE_DIM} (the pipeline-hit-rate "
            f"and tree-branch signals were appended); re-train or delete "
            f"the stale checkpoint")
    n = int(z["n_blocks"])
    blocks = tuple(
        (jnp.asarray(z[f"blk{i}_w1"]), jnp.asarray(z[f"blk{i}_b1"]),
         jnp.asarray(z[f"blk{i}_w2"]), jnp.asarray(z[f"blk{i}_b2"]))
        for i in range(n))
    return WCDNNParams(
        feat_mean=jnp.asarray(z["feat_mean"]), feat_std=jnp.asarray(z["feat_std"]),
        w_in=jnp.asarray(z["w_in"]), b_in=jnp.asarray(z["b_in"]),
        blocks=blocks, w_out=jnp.asarray(z["w_out"]), b_out=jnp.asarray(z["b_out"]))


# --------------------------------------------------------------------------
# Analytic bootstrap controller (pre-training fallback + label prior)
# --------------------------------------------------------------------------

# mirrors repro.sim.network.DEFAULT_FUSED_CHUNK — not imported because
# core.window → core.awc → this module loads while repro.sim.scheduler
# (which imports core.window) may be mid-import; keep the two in sync
_FUSED_CHUNK_DEFAULT = 8


def bootstrap_gamma(feats: list[float], cost_ratio: float = 0.12,
                    gmax: int = 12,
                    fused_chunk: int = _FUSED_CHUNK_DEFAULT,
                    mode_aware: bool = True) -> float:
    """γ* maximizing tokens/second from Eq. (1) with network-, queue- and
    pipeline-aware iteration cost:

        rate(γ) = E[τ](α, γ) / (γ·c + 1 + ((1−h)·RTT + queue·TPOT) / t_verify)

    where t_verify ≈ TPOT is the per-iteration verification service time
    and h is the recent pipeline hit rate (``pipe_hit_recent``, the 6th
    feature; 0 when feats has only the classic 5). Cross-round pipelining
    overlaps a hit round's RTT with the next window's drafting, so the
    expected per-round stall shrinks by the hit fraction — the
    overlapped-RTT term. High queue depth or RTT pushes γ up (amortize
    round trips); low α pushes γ down (rollback waste); a high hit rate
    keeps γ in distributed mode on links where the unpipelined controller
    would already have fled to fused.

    The controller is MODE-aware (paper Fig. 6 / §3.3): the best
    distributed rate is compared against the fused (cloud-only)
    alternative, which produces one token per target step, pays the round
    trip only once per ``fused_chunk``-token chunk, and — having no
    speculation to overlap — never benefits from pipelining:

        rate_fused = 1 / (1 + (RTT + queue·TPOT) / (chunk · t_verify))

    When fused wins — high RTT relative to target service time, or low α
    draining E[τ] toward 1 — the controller returns 1.0, which the
    stabilizer's hysteresis maps to fused mode (γ ≤ 1 ⇒ fused).
    ``mode_aware=False`` disables the comparison and returns the pure
    distributed-mode argmax — callers that treat this function as the
    analytic γ* controller (the WC-DNN label sweep shifts it by δ and
    runs its OWN fused-vs-distributed objective comparison) must not
    receive the mode sentinel.
    """
    q_depth, alpha, rtt_ms, tpot_ms = feats[0], feats[1], feats[2], feats[3]
    pipe_hit = min(1.0, max(0.0, float(feats[5]))) if len(feats) > 5 else 0.0
    alpha = min(0.98, max(0.02, alpha))
    t_verify = max(1.0, tpot_ms)
    queue_ms = max(0.0, q_depth) * tpot_ms
    stall_ms = rtt_ms + queue_ms
    # overlapped-RTT term: a hit round's RTT hides behind the next draft
    overhead = ((1.0 - pipe_hit) * rtt_ms + queue_ms) / t_verify
    best_g, best_rate = 1, -1.0
    for g in range(1, gmax + 1):
        e_tau = (1.0 - alpha ** (g + 1)) / (1.0 - alpha)
        rate = e_tau / (g * cost_ratio + 1.0 + overhead)
        if rate > best_rate:
            best_g, best_rate = g, rate
    if mode_aware:
        fused_rate = 1.0 / (1.0 + stall_ms / (fused_chunk * t_verify))
        if fused_rate > best_rate:
            return 1.0
    return float(best_g)


DEFAULT_CKPT = os.path.join(os.path.dirname(__file__), "data", "wcdnn_default.npz")


def default_predictor() -> Callable[[list[float]], float]:
    """Trained checkpoint if present, analytic bootstrap otherwise."""
    if os.path.exists(DEFAULT_CKPT):
        return numpy_predictor(load(DEFAULT_CKPT))
    return bootstrap_gamma
