"""Stabilized execution of WC-DNN window predictions (paper §4.4).

Three techniques, applied in order per draft–target pair:

1. **Clamping** of raw predictions to a configured range (default [1, 12]).
2. **Exponential smoothing** — EMA with smoothing factor α=0.4 across
   iterations, damping high-frequency oscillation in the predicted γ.
3. **Hysteresis for mode switching** — a sticky fused/distributed policy:
   while distributed, the smoothed prediction must sit at γ≤1 for k
   consecutive steps (default k=2) before the switch to fused mode is
   permitted; symmetric logic applies for leaving fused mode.

The smoothed value is finally quantized to the nearest integer in range.
State is per draft–target pair (paper: "smoothing state is maintained per
draft-target pair so each connection follows its own trajectory").
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class StabilizerConfig:
    clamp_lo: float = 1.0
    clamp_hi: float = 12.0
    ema_alpha: float = 0.4          # weight of the *new* prediction
    hysteresis_k: int = 2
    fused_threshold: float = 1.0    # gamma <= 1  =>  fused mode


class WindowStabilizer:
    """Per-pair stabilization state machine."""

    def __init__(self, cfg: StabilizerConfig | None = None):
        self.cfg = cfg or StabilizerConfig()
        self._ema: float | None = None
        self._below_count = 0
        self._above_count = 0
        self.mode = "distributed"

    def reset(self) -> None:
        self._ema = None
        self._below_count = 0
        self._above_count = 0
        self.mode = "distributed"

    def step(self, raw_prediction: float) -> tuple[int, str]:
        """Apply clamp → EMA → hysteresis → quantize. Returns (γ, mode)."""
        c = self.cfg
        # 1. clamp
        x = min(c.clamp_hi, max(c.clamp_lo, float(raw_prediction)))
        # 2. EMA
        if self._ema is None:
            self._ema = x
        else:
            self._ema = c.ema_alpha * x + (1.0 - c.ema_alpha) * self._ema
        # 3. hysteresis on mode switching
        near_one = self._ema <= c.fused_threshold + 0.25  # "remains near γ=1"
        if self.mode == "distributed":
            self._below_count = self._below_count + 1 if near_one else 0
            if self._below_count >= c.hysteresis_k:
                self.mode = "fused"
                self._above_count = 0
        else:  # fused
            self._above_count = 0 if near_one else self._above_count + 1
            if self._above_count >= c.hysteresis_k:
                self.mode = "distributed"
                self._below_count = 0
        # 4. quantize
        gamma = int(round(self._ema))
        gamma = int(min(c.clamp_hi, max(c.clamp_lo, gamma)))
        if self.mode == "fused":
            gamma = 1
        return gamma, self.mode
