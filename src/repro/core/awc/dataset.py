"""AWC training-dataset generation (paper §4.2).

For every scenario — (workload trace, network configuration, hardware
deployment) — the simulator sweeps speculation window sizes γ ∈ [2, 12] plus
the fused execution mode, records feature vectors + policy outputs +
performance metrics (TTFT/TPOT/throughput), and labels each feature snapshot
of the *winning* configuration with the γ minimizing a weighted SLO
objective:

    J(cfg) = w_tpot · TPOT + w_ttft · TTFT + w_thr / throughput

(fused is encoded as label γ=1 — the deployment rule γ≤1 ⇒ fused).
"""

from __future__ import annotations

import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np

from ...sim.hwmodel import HardwareModel
from ...sim.network import LinkSpec
from ...sim.policies import BatchingConfig, LengthAwareBatching, JSQRouting
from ...sim.scheduler import ClusterSpec, DSDSimulation, PolicyStack
from ...sim.trace import WorkloadGenerator
from ..window import FeatureSnapshot, OracleStaticPolicy, WindowDecision


class RecordingWindowPolicy:
    """Wraps a policy; logs every (feature, decision) pair it makes."""

    def __init__(self, inner):
        self.inner = inner
        self.log: list[tuple[list[float], int]] = []

    def decide(self, pair_key: str, feats: FeatureSnapshot) -> WindowDecision:
        dec = self.inner.decide(pair_key, feats)
        self.log.append((feats.as_list(),
                         1 if dec.mode == "fused" else dec.gamma))
        return dec

    def name(self) -> str:
        return f"recording({self.inner.name()})"


@dataclass
class Scenario:
    dataset: str = "gsm8k"
    rtt_ms: float = 10.0
    rate_per_s: float = 30.0
    num_targets: int = 4
    num_drafters: int = 64
    target_hw: str = "A100"
    target_model: str = "llama2-70b"
    target_tp: int = 4
    draft_hw: str = "A40"
    draft_model: str = "llama2-7b"
    num_requests: int = 60
    seed: int = 0
    heterogeneous: bool = False   # paper §5.2 mixed pools


@dataclass
class SweepResult:
    scenario: Scenario
    gamma: int            # winning label (1 == fused)
    objective: float
    per_gamma: dict[int, float]
    rows: list[tuple[list[float], int]]


def default_grid(seed: int = 0, small: bool = False) -> list[Scenario]:
    """Scenario grid; the full grid reaches the paper's >2000-scenario scale
    when combined with per-seed replication (benchmarks/table2 uses it)."""
    # Target-bound serving regimes (paper §5.2: ~30 drafters per target).
    rtts = [5.0, 10.0, 20.0, 30.0, 45.0, 60.0] if not small else [10.0, 60.0]
    rates = [30.0, 50.0, 70.0] if not small else [40.0]
    datasets = ["gsm8k", "cnndm", "humaneval"] if not small else ["gsm8k"]
    sizes = [(2, 64), (2, 128)] if not small else [(2, 64)]
    out = []
    i = 0
    for rtt, rate, ds, (nt, nd) in itertools.product(rtts, rates, datasets, sizes):
        out.append(Scenario(dataset=ds, rtt_ms=rtt, rate_per_s=rate,
                            num_targets=nt, num_drafters=nd,
                            seed=seed + i))
        i += 1
    return out


def _run(scn: Scenario, window_policy, hw: Optional[HardwareModel] = None):
    from ...sim.scheduler import PAPER_DRAFT_POOL, PAPER_TARGET_POOL
    cluster = ClusterSpec(
        num_targets=scn.num_targets, target_hw=scn.target_hw,
        target_model=scn.target_model, target_tp=scn.target_tp,
        num_drafters=scn.num_drafters, draft_hw=scn.draft_hw,
        draft_model=scn.draft_model,
        target_pool=PAPER_TARGET_POOL if scn.heterogeneous else None,
        draft_pool=PAPER_DRAFT_POOL if scn.heterogeneous else None,
        link=LinkSpec(rtt_ms=scn.rtt_ms, jitter_ms=max(0.5, scn.rtt_ms * 0.08)))
    policies = PolicyStack(routing=JSQRouting(), batching=LengthAwareBatching(),
                           batching_cfg=BatchingConfig(max_batch=16),
                           window=window_policy)
    gen = WorkloadGenerator(scn.dataset, scn.rate_per_s, scn.num_drafters,
                            seed=scn.seed)
    sim = DSDSimulation(cluster, policies, gen.generate(scn.num_requests),
                        hwmodel=hw, seed=scn.seed)
    return sim.run().summary()


def objective(summary: dict, w_tpot: float = 1.0, w_ttft: float = 0.1,
              w_thr: float = 2000.0) -> float:
    tpot = summary["tpot_ms"]["mean"]
    ttft = summary["ttft_ms"]["mean"]
    thr = max(1e-6, summary["throughput_rps"])
    if math.isnan(tpot):
        tpot = 1e4
    if math.isnan(ttft):
        ttft = 1e4
    return w_tpot * tpot + w_ttft * ttft + w_thr / thr


def sweep_scenario(scn: Scenario, gammas: Iterable[int] = range(2, 13),
                   include_fused: bool = True,
                   hw: Optional[HardwareModel] = None) -> SweepResult:
    """Paper §4.2: record (feature vector, policy output, metrics) during
    EVERY sweep run; after the sweep, label all recorded snapshots with the
    scenario's objective-minimizing configuration. Recording only the
    winner's replay would leak the label through the γ_prev feature (the
    net would learn the copy-γ_prev shortcut — observed before this fix)."""
    per_gamma: dict[int, float] = {}
    recorders: dict[int, RecordingWindowPolicy] = {}
    for g in gammas:
        rec = RecordingWindowPolicy(OracleStaticPolicy(g))
        per_gamma[g] = objective(_run(scn, rec, hw))
        recorders[g] = rec
    if include_fused:
        rec = RecordingWindowPolicy(OracleStaticPolicy(1, fused=True))
        per_gamma[1] = objective(_run(scn, rec, hw))
        recorders[1] = rec
    best = min(per_gamma, key=per_gamma.get)
    # Soft regression target: objective-weighted γ average. Near-ties
    # (γ=2/3/4 within a few % of each other) should pull the prediction to
    # their centroid rather than collapse onto an arbitrary winner — the
    # WC-DNN regresses a continuous γ (paper §4.3), so the target should be
    # continuous too.
    o_min = per_gamma[best]
    temp = max(1e-6, 0.04 * o_min)
    ws = {g: math.exp(-(o - o_min) / temp) for g, o in per_gamma.items()}
    z = sum(ws.values())
    soft = sum(g * w for g, w in ws.items()) / z
    rows = [(f, soft) for rec in recorders.values() for f, _ in rec.log]
    return SweepResult(scenario=scn, gamma=best, objective=per_gamma[best],
                       per_gamma=per_gamma, rows=rows)


def generate_dataset(scenarios: list[Scenario],
                     max_rows_per_scenario: int = 256,
                     hw: Optional[HardwareModel] = None,
                     rng_seed: int = 0) -> tuple[np.ndarray, np.ndarray, list[SweepResult]]:
    """Returns (X (N,5), y (N,), sweep results)."""
    rng = random.Random(rng_seed)
    X, y, results = [], [], []
    for scn in scenarios:
        res = sweep_scenario(scn, hw=hw)
        rows = res.rows
        if len(rows) > max_rows_per_scenario:
            rows = rng.sample(rows, max_rows_per_scenario)
        for feats, label in rows:
            X.append(feats)
            y.append(float(label))
        results.append(res)
    return (np.asarray(X, np.float32), np.asarray(y, np.float32), results)


# --------------------------------------------------------------------------
# Sweep-calibrated per-pair labels (v2 — the shipped WC-DNN training path)
# --------------------------------------------------------------------------

def sweep_scenario_pairwise(scn: Scenario,
                            deltas=(-2.0, -1.0, 0.0, 1.0, 2.0),
                            hw: Optional[HardwareModel] = None,
                            obj_seeds: tuple = (0,)
                            ) -> SweepResult:
    """Per-pair labels via a sweep over *shifted analytic controllers*.

    Global-γ sweeps can only label a whole scenario with one γ — useless in
    heterogeneous clusters where each draft–target pair wants a different
    window. Instead we sweep the per-pair analytic controller
    (Eq.(1)/(2)-based ``bootstrap_gamma``) shifted by a scalar δ, pick the
    objective-minimizing δ*, and label every recorded feature vector with
    ``bootstrap(features) + δ*`` — a per-pair target the 5-feature WC-DNN
    can actually express. γ_prev leaks nothing: bootstrap ignores it.
    """
    from .model import bootstrap_gamma
    from .stabilize import StabilizerConfig
    from ..window import AWCWindowPolicy

    import dataclasses as _dc
    per_delta: dict[float, float] = {}
    recorders: dict[float, RecordingWindowPolicy] = {}
    for d in deltas:
        objs = []
        pol = None
        for s in obj_seeds:     # seed-averaged objective: stabler δ*
            pol = RecordingWindowPolicy(AWCWindowPolicy(
                lambda f, d=d: bootstrap_gamma(f, mode_aware=False) + d))
            objs.append(objective(_run(
                _dc.replace(scn, seed=scn.seed + 1000 * s), pol, hw)))
        per_delta[d] = sum(objs) / len(objs)
        recorders[d] = pol
    # fused-everywhere alternative (γ ≡ 1)
    fused_obj = sum(
        objective(_run(_dc.replace(scn, seed=scn.seed + 1000 * s),
                       OracleStaticPolicy(1, fused=True), hw))
        for s in obj_seeds) / len(obj_seeds)
    best = min(per_delta, key=per_delta.get)
    rows: list[tuple[list[float], float]] = []
    if fused_obj < per_delta[best] * 0.97:
        # the scenario prefers cloud-only execution: label everything 1
        for rec in recorders.values():
            rows.extend((f, 1.0) for f, _ in rec.log)
        gamma_repr = 1
    else:
        # floor at 2: in a distributed-optimal scenario a small window must
        # stay distributed — labels of ~1 would push the deployed policy
        # through the fused hysteresis on transient low-α features (observed
        # fused-thrash collapse on the bursty humaneval workload)
        for rec in recorders.values():
            rows.extend(
                (f, max(2.0, min(12.0,
                                 bootstrap_gamma(f, mode_aware=False)
                                 + best)))
                for f, _ in rec.log)
        gamma_repr = int(round(4 + best))
    return SweepResult(scenario=scn, gamma=gamma_repr,
                       objective=min(per_delta[best], fused_obj),
                       per_gamma={int(d): v for d, v in per_delta.items()},
                       rows=rows)


def default_grid_v2(seed: int = 0, small: bool = False) -> list[Scenario]:
    """Heterogeneous-heavy grid for the shipped checkpoint."""
    rtts = [5.0, 10.0, 20.0, 30.0, 45.0, 60.0, 90.0] if not small else [10.0]
    rates = [30.0, 50.0, 70.0] if not small else [40.0]
    datasets = ["gsm8k", "cnndm", "humaneval"] if not small else ["gsm8k"]
    out = []
    i = 0
    for rtt, rate, ds in itertools.product(rtts, rates, datasets):
        out.append(Scenario(dataset=ds, rtt_ms=rtt, rate_per_s=rate,
                            num_targets=3, num_drafters=60,
                            heterogeneous=True, seed=seed + i))
        i += 1
        if not small and rtt in (10.0, 45.0):
            out.append(Scenario(dataset=ds, rtt_ms=rtt, rate_per_s=rate,
                                num_targets=2, num_drafters=64,
                                heterogeneous=False, seed=seed + i))
            i += 1
    return out


def generate_dataset_v2(scenarios: list[Scenario],
                        max_rows_per_scenario: int = 256,
                        hw: Optional[HardwareModel] = None,
                        rng_seed: int = 0):
    rng = random.Random(rng_seed)
    X, y, results = [], [], []
    for scn in scenarios:
        res = sweep_scenario_pairwise(scn, hw=hw)
        rows = res.rows
        if len(rows) > max_rows_per_scenario:
            rows = rng.sample(rows, max_rows_per_scenario)
        for feats, label in rows:
            X.append(feats)
            y.append(float(label))
        results.append(res)
    return (np.asarray(X, np.float32), np.asarray(y, np.float32), results)
