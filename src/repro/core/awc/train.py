"""WC-DNN supervised training (paper §4.3): L1 regression, AdamW, 100 epochs."""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import model as wcdnn
from ...training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclass
class TrainConfig:
    hidden: int = 64
    n_blocks: int = 2
    epochs: int = 100
    batch_size: int = 256
    lr: float = 3e-3
    weight_decay: float = 1e-4
    val_frac: float = 0.15
    seed: int = 0


def l1_loss(params, x, y):
    pred = wcdnn.forward(params, x)
    return jnp.mean(jnp.abs(pred - y))


@functools.partial(jax.jit, static_argnames=("lr", "wd"))
def _train_step(params, opt_state, x, y, lr, wd):
    loss, grads = jax.value_and_grad(l1_loss)(params, x, y)
    # Do not update normalization statistics by gradient.
    grads = grads._replace(feat_mean=jnp.zeros_like(grads.feat_mean),
                           feat_std=jnp.zeros_like(grads.feat_std))
    cfg = AdamWConfig(lr=lr, weight_decay=wd)
    params, opt_state = adamw_update(grads, opt_state, params, cfg)
    return params, opt_state, loss


def train(X: np.ndarray, y: np.ndarray,
          cfg: Optional[TrainConfig] = None) -> tuple[wcdnn.WCDNNParams, dict]:
    cfg = cfg or TrainConfig()
    rng = np.random.default_rng(cfg.seed)
    n = len(X)
    perm = rng.permutation(n)
    n_val = max(1, int(n * cfg.val_frac))
    val_idx, tr_idx = perm[:n_val], perm[n_val:]
    Xtr, ytr = jnp.asarray(X[tr_idx]), jnp.asarray(y[tr_idx])
    Xva, yva = jnp.asarray(X[val_idx]), jnp.asarray(y[val_idx])

    key = jax.random.PRNGKey(cfg.seed)
    params = wcdnn.init(key, hidden=cfg.hidden, n_blocks=cfg.n_blocks)
    params = wcdnn.set_normalization(params, Xtr)
    opt_state = adamw_init(params, AdamWConfig(lr=cfg.lr,
                                               weight_decay=cfg.weight_decay))

    n_tr = len(tr_idx)
    bs = min(cfg.batch_size, n_tr)
    history = []
    for epoch in range(cfg.epochs):
        order = rng.permutation(n_tr)
        losses = []
        for i in range(0, n_tr, bs):
            idx = order[i:i + bs]
            params, opt_state, loss = _train_step(
                params, opt_state, Xtr[idx], ytr[idx],
                lr=cfg.lr, wd=cfg.weight_decay)
            losses.append(float(loss))
        history.append(sum(losses) / len(losses))
    val_mae = float(l1_loss(params, Xva, yva))
    info = {"train_l1": history[-1] if history else float("nan"),
            "val_mae": val_mae, "n_train": int(n_tr), "n_val": int(n_val),
            "history": history}
    return params, info


def train_default_and_save(scenarios=None, path: Optional[str] = None,
                           small: bool = False) -> tuple[wcdnn.WCDNNParams, dict]:
    """End-to-end: sweep → dataset → train → save default checkpoint."""
    import os
    from .dataset import default_grid, generate_dataset
    scenarios = scenarios or default_grid(small=small)
    X, y, _ = generate_dataset(scenarios)
    params, info = train(X, y, TrainConfig(epochs=40 if small else 100))
    path = path or wcdnn.DEFAULT_CKPT
    os.makedirs(os.path.dirname(path), exist_ok=True)
    wcdnn.save(params, path)
    return params, info
