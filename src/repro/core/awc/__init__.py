"""Adaptive Window Control (paper §4): WC-DNN + stabilized execution."""

from . import model
from .stabilize import StabilizerConfig, WindowStabilizer
from .model import (WCDNNParams, bootstrap_gamma, default_predictor, forward,
                    init, load, numpy_predictor, save, set_normalization)
