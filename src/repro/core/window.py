"""Speculation-window policies (paper §3.4 "Window Size Policy", §4).

Every policy consumes a read-only :class:`FeatureSnapshot` of recent system
metrics and returns a :class:`WindowDecision` — the speculation window size γ
and the execution mode (``distributed`` draft→verify vs ``fused``
cloud-only). Policies keep any adaptation state *per draft–target pair*.

- :class:`StaticWindowPolicy`   — fixed γ (paper baseline, γ=4).
- :class:`DynamicWindowPolicy`  — threshold heuristic: γ+1 when the recent
  acceptance rate exceeds 0.75, γ−1 when it falls below 0.25 (paper §5.2).
- :class:`AWCWindowPolicy`      — the paper's learned controller: WC-DNN
  prediction + clamp/EMA/hysteresis stabilization (§4.4). γ≤1 ⇒ fused mode.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Protocol

from .awc.stabilize import StabilizerConfig, WindowStabilizer


@dataclass(frozen=True)
class FeatureSnapshot:
    """The AWC feature vector (paper §4.1, plus the pipeline-hit signal).

    ``pipe_hit_recent`` is the recent fraction of cross-round speculative
    windows that survived their verdict (pipelined execution overlaps
    window k+1's draft with window k's verification; a hit means the
    overlapped RTT was genuinely hidden). 0.0 whenever pipelining is off —
    the controller's overlapped-RTT discount must stay inert there.

    ``branches_prev`` is the branch width of the previous round's
    speculation tree (1.0 outside tree sessions — the feature is inert on
    linear deployments, like ``pipe_hit_recent`` outside pipelining)."""
    q_depth: float        # recent target-queue depth utilization in [0, ~]
    alpha_recent: float   # recent token acceptance rate in [0,1]
    rtt_recent_ms: float  # recent link round-trip time
    tpot_recent_ms: float # recent time-per-output-token of the target
    gamma_prev: float     # previous window size
    pipe_hit_recent: float = 0.0  # recent pipeline hit rate in [0,1]
    branches_prev: float = 1.0    # previous tree branch width (1 = linear)

    def as_list(self) -> list[float]:
        return [self.q_depth, self.alpha_recent, self.rtt_recent_ms,
                self.tpot_recent_ms, self.gamma_prev, self.pipe_hit_recent,
                self.branches_prev]


@dataclass(frozen=True)
class WindowDecision:
    gamma: int
    mode: str  # "distributed" | "fused"
    branches: int = 1  # speculation-tree branch width (1 = linear chain)


class WindowPolicy(Protocol):
    def decide(self, pair_key: str, feats: FeatureSnapshot) -> WindowDecision: ...
    def name(self) -> str: ...


class StaticWindowPolicy:
    def __init__(self, gamma: int = 4, branches: int = 1):
        self.gamma = int(gamma)
        self.branches = max(1, int(branches))

    def decide(self, pair_key: str, feats: FeatureSnapshot) -> WindowDecision:
        return WindowDecision(self.gamma, "distributed", self.branches)

    def gamma_bound(self) -> int:
        """Largest γ this policy can ever emit — the engine compiles its
        single masked-window step at this width."""
        return self.gamma

    def name(self) -> str:
        if self.branches > 1:
            return f"static-{self.gamma}x{self.branches}"
        return f"static-{self.gamma}"


class DynamicWindowPolicy:
    """Threshold heuristic from the paper's 'Dynamic/Simple' baseline."""

    def __init__(self, hi: float = 0.75, lo: float = 0.25,
                 gamma0: int = 4, gmin: int = 1, gmax: int = 12):
        self.hi, self.lo = hi, lo
        self.gamma0, self.gmin, self.gmax = gamma0, gmin, gmax
        self._state: dict[str, int] = {}

    def decide(self, pair_key: str, feats: FeatureSnapshot) -> WindowDecision:
        g = self._state.get(pair_key, self.gamma0)
        if feats.alpha_recent > self.hi:
            g = min(self.gmax, g + 1)
        elif feats.alpha_recent < self.lo:
            g = max(self.gmin, g - 1)
        self._state[pair_key] = g
        return WindowDecision(g, "distributed")

    def gamma_bound(self) -> int:
        return self.gmax

    def name(self) -> str:
        return "dynamic"


class AWCWindowPolicy:
    """Adaptive Window Control: WC-DNN prediction + per-pair stabilization.

    ``predictor`` maps a 5-float feature list → raw continuous γ. In the
    simulator this is the trained WC-DNN exported to numpy
    (:func:`repro.core.awc.model.numpy_predictor`); in unit tests it can be
    any callable.
    """

    def __init__(self, predictor: Callable[[list[float]], float],
                 stab_cfg: StabilizerConfig | None = None,
                 max_branches: int = 1, bandwidth_gbps: float = 1.0):
        self.predictor = predictor
        self.stab_cfg = stab_cfg or StabilizerConfig()
        self._stab: dict[str, WindowStabilizer] = {}
        self.max_branches = max(1, int(max_branches))
        self.bandwidth_gbps = float(bandwidth_gbps)

    def _pick_branches(self, gamma: int, feats: FeatureSnapshot) -> int:
        """Joint {γ, b} decision: widen the tree while the marginal
        expected-accepted gain of one more branch beats its cost.

        The gain comes from :func:`repro.core.tree.tree_expected_accepted`
        (branch rescue only pays off when α is low — the formula encodes
        that, no separate α threshold needed). The cost is the extra wire
        serialization a wider grid adds (12 B/node at the link's
        bandwidth), converted to token-equivalents via the recent TPOT,
        plus a small floor so near-zero gains do not buy extra draft
        passes."""
        from .tree import tree_expected_accepted
        if self.max_branches <= 1 or gamma < 1:
            return 1
        tpot = max(0.1, feats.tpot_recent_ms)
        # one extra branch adds γ grid nodes → 12·γ bytes on the uplink
        ser_ms = 12 * gamma * 8 / (self.bandwidth_gbps * 1e9) * 1e3
        floor = max(0.02, ser_ms / tpot)
        b = 1
        prev = tree_expected_accepted(feats.alpha_recent, gamma, 1)
        while b < self.max_branches:
            nxt = tree_expected_accepted(feats.alpha_recent, gamma, b + 1)
            if nxt - prev <= floor:
                break
            prev = nxt
            b += 1
        return b

    def decide(self, pair_key: str, feats: FeatureSnapshot) -> WindowDecision:
        stab = self._stab.get(pair_key)
        if stab is None:
            stab = self._stab[pair_key] = WindowStabilizer(self.stab_cfg)
        raw = float(self.predictor(feats.as_list()))
        gamma, mode = stab.step(raw)
        branches = (self._pick_branches(gamma, feats)
                    if mode == "distributed" else 1)
        return WindowDecision(gamma, mode, branches)

    def gamma_bound(self) -> int:
        return int(self.stab_cfg.clamp_hi)

    def name(self) -> str:
        return "awc"


def make_window_policy(kind: str, *, gamma: int = 4, hi: float = 0.75,
                       lo: float = 0.25, gmax: int = 12, predictor=None,
                       stab_cfg: StabilizerConfig | None = None,
                       branches: int = 1, max_branches: int = 1,
                       bandwidth_gbps: float = 1.0):
    """One window-policy factory for every config surface (the topology
    spec layer, ``launch.serve`` flags, DSD-Sim's YAML reader): a policy
    *kind* plus its knobs → a fresh policy instance. Fresh matters — each
    call returns its own adaptation state, so two deployment surfaces can
    never accidentally share a stabilizer. ``branches``/``max_branches``
    opt a policy into tree speculation (static width vs AWC's joint
    {γ, b} choice); both default to 1 — the linear chain."""
    if kind == "static":
        return StaticWindowPolicy(int(gamma), branches=int(branches))
    if kind == "dynamic":
        return DynamicWindowPolicy(hi=float(hi), lo=float(lo),
                                   gamma0=int(gamma), gmax=int(gmax))
    if kind == "awc":
        if predictor is None:
            from .awc.model import default_predictor
            predictor = default_predictor()
        return AWCWindowPolicy(predictor, stab_cfg=stab_cfg,
                               max_branches=int(max_branches),
                               bandwidth_gbps=float(bandwidth_gbps))
    raise ValueError(f"unknown window policy kind {kind!r}; "
                     "expected static | dynamic | awc")


class OracleStaticPolicy:
    """Upper-bound helper used for AWC dataset labeling sweeps: behaves like
    StaticWindowPolicy but records nothing; separate class only so sweep code
    can distinguish label-generation runs."""

    def __init__(self, gamma: int, fused: bool = False):
        self.gamma = int(gamma)
        self.fused = fused

    def decide(self, pair_key: str, feats: FeatureSnapshot) -> WindowDecision:
        if self.fused:
            return WindowDecision(1, "fused")
        return WindowDecision(self.gamma, "distributed")

    def gamma_bound(self) -> int:
        return 1 if self.fused else self.gamma

    def name(self) -> str:
        return f"oracle-{'fused' if self.fused else self.gamma}"
