"""Distributed speculative-decoding engine on *real* JAX models.

This is the execution layer the simulator abstracts: an edge draft model and
a cloud target model exchanging speculation windows (Fig. 1b). Two ways to
run the exchange:

- **colocated** (default): one fused jitted step per iteration; any network
  hop is accounted virtually (``rtt_ms`` on a virtual clock).
- **distributed** (:meth:`SpecDecodeEngine.split_workers` +
  :mod:`repro.distributed`): the step is split into a draft-side propose
  program and a target-side verify/commit program whose token/verdict
  payloads cross a ``Transport`` — zero-delay in process (bit-identical to
  the colocated path at temperature 0) or over an emulated edge–cloud link
  with measured wall-clock delays that feed the AWC ``rtt_recent_ms``
  feature.

Either way *acceptance outcomes are real* — this engine is what captures
the ground-truth ``acceptance_seq`` traces DSD-Sim replays (DESIGN.md
§7.3).

Decode hot loop — compiled ONCE, adaptive-γ AND continuous batching for
free:

- One XLA program per draft/target pair, compiled at the static window
  bound ``gamma_max``. The per-iteration window size γ chosen by the window
  policy (AWC changes it every iteration) enters as a *traced* int32
  ``active_gamma`` that masks acceptance in ``verify_window`` — any
  γ ∈ [1, gamma_max] runs with zero recompiles. At temperature 0 causality
  makes the masked step's committed tokens BIT-identical to a dedicated
  per-γ program; sampled decoding (temperature > 0) is identical in
  distribution but consumes the PRNG stream at gamma_max width, so
  individual sampled tokens differ from a per-γ program run with the same
  key. (The MoE family is the other caveat: capacity-based routing sees
  the full batch × full-width window, so capacity-binding configs may drop
  tokens differently depending on co-tenants.)
- The same program is *slot-aware*: every batch row carries a per-slot
  token budget (``max_new``) and a ``done`` flag, and
  :func:`repro.core.specdec.slot_stop_mask` zeroes ``num_new`` for
  finished/free rows so their cursor, position, KV writes and recurrent
  state freeze while neighbouring rows keep decoding. This is what lets
  :class:`repro.core.session.DecodeSession` admit and retire requests
  in-flight (continuous batching) without ever recompiling: the active-slot
  pattern is data, not shape.
- ``SpecDecodeState`` caches, the output token buffer, the write cursors
  and the stats buffers are DONATED to the jitted step
  (``donate_argnums``) so KV/SSM buffers update in place instead of copying
  every iteration.
- Committed tokens accumulate into a preallocated on-device
  ``(B, max_new)`` buffer with per-sequence write cursors; per-iteration
  ``n_accepted``/``num_new`` land in device-side ring buffers. The host
  syncs cursors/stats only every ``sync_every`` iterations, so the loop
  keeps ``sync_every`` steps in flight instead of blocking on
  ``new_tokens`` / ``num_new`` transfers per step. Window-policy features
  (recent α, TPOT) and admission/retirement decisions consequently happen
  at sync granularity.

Cache-rollback semantics per family:

- attention families (dense/moe/vlm/encdec): stale window entries are
  masked via ``pos_map`` (models/kvcache.py) — single fused
  :func:`repro.core.specdec.spec_decode_step`.
- ssm/hybrid: the recurrent state cannot be masked retroactively; the
  engine keeps the window-start state as the checkpoint, verifies on a
  throwaway copy, then *advances* the committed prefix with per-sequence
  active-masking (``_tree_where``) — the SSM analogue of cache rollback.
  The advance is a ``lax.scan`` over the window, so HLO size and compile
  time stay flat in ``gamma_max``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ModelConfig
from ..models.kvcache import (PagedAttnCache, insert_slot, paged_insert_row,
                              paged_release_slot)
from ..models.model import build_model
from .specdec import (SpecDecodeOut, SpecDecodeState, draft_propose,
                      slot_stop_mask, spec_decode_step, verify_window,
                      verify_window_greedy, _temperature_probs,
                      sample_from_probs)
from .window import StaticWindowPolicy, WindowPolicy


def _tree_where(active: jax.Array, new: Any, old: Any, batch_axis: int = 1):
    """Per-sequence select over cache pytrees; non-array leaves pass through.

    ``active``: (B,) bool. Cache leaves carry batch on ``batch_axis``
    (layer-stacked caches are (L, B, ...))."""
    def sel(n, o):
        if not isinstance(n, jax.Array) or n.ndim == 0:
            return o
        shape = [1] * n.ndim
        ax = batch_axis if n.ndim > batch_axis else 0
        shape[ax] = active.shape[0]
        return jnp.where(active.reshape(shape), n, o)
    return jax.tree.map(sel, new, old)


def _scan_cache_advance(decode_fn, params, cache, adv_tokens: jax.Array,
                        pos: jax.Array, num_new: jax.Array):
    """Advance a recurrent cache over the committed window with ``lax.scan``.

    ``adv_tokens``: (B, T); step t feeds token t at position pos+t and keeps
    the updated cache only for sequences with t < num_new. Non-array cache
    leaves (e.g. the static ``ring`` flag) stay out of the scan carry so
    their treatment as static metadata survives the loop.
    """
    leaves, treedef = jax.tree.flatten(cache)
    is_arr = [isinstance(l, jax.Array) for l in leaves]

    def pack(c):
        return [l for l, a in zip(jax.tree.leaves(c), is_arr) if a]

    def unpack(arrs):
        it = iter(arrs)
        return jax.tree.unflatten(
            treedef, [next(it) if a else l for l, a in zip(leaves, is_arr)])

    toks = jnp.moveaxis(adv_tokens, 0, 1)          # (T, B)
    steps = jnp.arange(adv_tokens.shape[1])

    def body(carry, inp):
        tok, t = inp
        cur = unpack(carry)
        _, cnew = decode_fn(params, tok, cur, pos + t)
        cnew = _tree_where(t < num_new, cnew, cur)
        return pack(cnew), None

    out, _ = lax.scan(body, pack(cache), (toks, steps))
    return unpack(out)


def _accumulate(res: SpecDecodeOut, out_buf: jax.Array, cursor: jax.Array,
                nacc_buf: jax.Array, nn_buf: jax.Array, row_idx: jax.Array):
    """Scatter this iteration's committed tokens into the device-resident
    output buffer at per-sequence cursors; record n_accepted / num_new in
    row ``row_idx`` of the stats ring buffers (num_new == 0 marks a slot
    that was inactive this iteration — the host uses it to attribute
    acceptance bits to the right request). Writes past the buffer edge are
    dropped — those tokens are beyond ``max_new`` and would be discarded on
    extraction."""
    B, W = res.new_tokens.shape
    cap = out_buf.shape[1]
    widx = cursor[:, None] + jnp.arange(W)[None, :]
    valid = jnp.arange(W)[None, :] < res.num_new[:, None]
    widx = jnp.where(valid, widx, cap)             # out-of-bounds ⇒ dropped
    out_buf = out_buf.at[jnp.arange(B)[:, None], widx].set(
        res.new_tokens, mode="drop")
    cursor = cursor + res.num_new
    nacc_buf = lax.dynamic_update_slice(
        nacc_buf, res.n_accepted[None, :].astype(nacc_buf.dtype),
        (row_idx, 0))
    nn_buf = lax.dynamic_update_slice(
        nn_buf, res.num_new[None, :].astype(nn_buf.dtype), (row_idx, 0))
    return out_buf, cursor, nacc_buf, nn_buf


@dataclass
class GenerationStats:
    iterations: int = 0
    proposed: int = 0
    accepted: int = 0
    tokens: int = 0
    wall_s: float = 0.0
    prefill_s: float = 0.0           # prompt-processing wall time (≈ TTFT)
    virtual_ms: float = 0.0          # simulated edge-cloud time (incl. RTT)
    acceptance_seqs: list = field(default_factory=list)  # per-seq 0/1 bits
    gamma_seq: list = field(default_factory=list)
    produced: Any = None             # (B,) per-sequence tokens produced
                                     # (anchor included; ≤ max_new; < only
                                     # on EOS stop)
    pipeline_hits: int = 0           # optimistic cross-round windows kept
    pipeline_misses: int = 0         # optimistic windows rolled back

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(1, self.proposed)

    @property
    def tokens_per_iteration(self) -> float:
        return self.tokens / max(1, self.iterations)

    @property
    def prefill_ms(self) -> float:
        return self.prefill_s * 1e3


DEFAULT_GAMMA_MAX = 8


class SpecDecodeEngine:
    """Edge draft + cloud target, window policy in the loop.

    ``gamma_max`` pins the compile-time window width: when set, the decode
    step is compiled once at that width and serves every policy and every
    γ ∈ [1, gamma_max] via acceptance masking (policy decisions above it
    are clamped). When ``None`` the width is derived per-generate from the
    policy's own ``gamma_bound()`` — a static-γ workload then compiles at
    exactly its γ. ``sync_every`` sets how many iterations run between host
    synchronizations of the device-resident cursors/stats.
    """

    def __init__(self, draft_cfg: ModelConfig, target_cfg: ModelConfig,
                 draft_params=None, target_params=None,
                 key: Optional[jax.Array] = None,
                 temperature: float = 1.0, rtt_ms: float = 0.0,
                 use_verify_kernel: bool = False,
                 gamma_max: Optional[int] = None, sync_every: int = 8):
        assert draft_cfg.vocab == target_cfg.vocab, \
            "draft/target must share a tokenizer/vocab"
        self.draft_cfg, self.target_cfg = draft_cfg, target_cfg
        self.draft = build_model(draft_cfg)
        self.target = build_model(target_cfg)
        key = key if key is not None else jax.random.PRNGKey(0)
        kd, kt = jax.random.split(key)
        self.draft_params = (draft_params if draft_params is not None
                             else self.draft.init_params(kd))
        self.target_params = (target_params if target_params is not None
                              else self.target.init_params(kt))
        self.temperature = temperature
        self.rtt_ms = rtt_ms
        self.use_verify_kernel = use_verify_kernel
        self.gamma_max = None if gamma_max is None else int(gamma_max)
        self.sync_every = int(sync_every)
        self._target_attention = target_cfg.arch_type in (
            "dense", "moe", "vlm", "encdec")
        self._draft_attention = draft_cfg.arch_type in (
            "dense", "moe", "vlm", "encdec")
        self._jit_cache: dict = {}
        self._split = None

    def split_workers(self):
        """The engine split at the wire: ``(DraftWorker, TargetWorker)``.

        The workers share this engine's models, params and ``_jit_cache``
        (so :meth:`compiled_programs` counts their programs and the
        zero-recompile invariant covers the distributed path). Built
        lazily — colocated sessions never construct them."""
        if self._split is None:
            from ..distributed.workers import DraftWorker, TargetWorker
            self._split = (DraftWorker(self), TargetWorker(self))
        return self._split

    # ------------------------------------------------------------- jit paths

    def _fused_step(self, gamma_max: int):
        """Attention-target path: ONE jitted program at gamma_max; the
        per-iteration γ arrives as the traced ``active_gamma`` scalar and
        the per-slot lifecycle (budget/EOS/done) as traced (B,) buffers.

        Finished/free rows commit nothing and their position freezes; the
        window KV they still write lands in the speculative region
        ``pos..pos+γ`` (beyond their committed prefix, masked out of
        attention by ``pos_map``) and is fully overwritten by the next
        prefill-insert into that slot, so no per-row cache select is
        needed."""
        keyt = ("fused", gamma_max)
        if keyt in self._jit_cache:
            return self._jit_cache[keyt]

        draft_decode = lambda p, t, c, pos: self.draft.decode_step(p, t, c, pos)
        target_verify = lambda p, w, c, pos: self.target.verify_step(p, w, c, pos)

        def step(draft_params, target_params, state, key, active_gamma,
                 row_idx, out_buf, cursor, nacc_buf, nn_buf, max_new, done,
                 eos_id):
            res = spec_decode_step(draft_decode, target_verify,
                                   draft_params, target_params,
                                   state, gamma_max, key, self.temperature,
                                   active_gamma=active_gamma)
            stop = slot_stop_mask(res.num_new, res.n_accepted,
                                  res.new_tokens, cursor, max_new, done,
                                  eos_id)
            new_state = SpecDecodeState(
                draft_cache=res.state.draft_cache,
                target_cache=res.state.target_cache,
                last_token=jnp.where(done, state.last_token,
                                     res.state.last_token),
                pos=state.pos + stop.num_new)
            out = SpecDecodeOut(state=new_state, new_tokens=res.new_tokens,
                                num_new=stop.num_new,
                                n_accepted=stop.n_accepted)
            out_buf, cursor, nacc_buf, nn_buf = _accumulate(
                out, out_buf, cursor, nacc_buf, nn_buf, row_idx)
            return new_state, out_buf, cursor, nacc_buf, nn_buf, stop.done

        jitted = jax.jit(step, donate_argnums=(2, 6, 7, 8, 9, 11))
        self._jit_cache[keyt] = jitted
        return jitted

    def _tree_step(self, d_max: int, b_max: int):
        """Tree-speculation path: ONE jitted program per (d_max, b_max)
        grid bound. The per-round shape — active depth γ ≤ d_max and
        branch count b ≤ b_max — arrives as traced scalars that only mask
        acceptance (``node_valid``), so {γ, b} vary every round with zero
        recompiles, exactly like the linear step's ``active_gamma``.

        Greedy-only (the longest-accepted-root-path rule is the greedy
        accept rule's generalization; stochastic tree acceptance would
        need per-branch residual bookkeeping) and dense/moe-only on both
        sides (the relocation commit is pos_map surgery on a dense
        non-ring cache)."""
        keyt = ("tree", d_max, b_max)
        if keyt in self._jit_cache:
            return self._jit_cache[keyt]
        if self.temperature > 0.0:
            raise NotImplementedError(
                "tree speculation is greedy-only (temperature 0)")
        if not (self._target_attention and self._draft_attention):
            raise NotImplementedError(
                "tree speculation needs attention-family draft and target")
        from .tree import (TreeSpec, tree_committed, tree_path_from_winner,
                           tree_propose, verify_tree_greedy)
        from ..models.kvcache import tree_commit_cache
        spec = TreeSpec(d_max, b_max)
        T = spec.n_entries

        def step(draft_params, target_params, state, key, active_gamma,
                 branches, row_idx, out_buf, cursor, nacc_buf, nn_buf,
                 max_new, done, eos_id):
            tree_tokens, dcache = tree_propose(
                self.draft, draft_params, state.draft_cache,
                state.last_token, state.pos, spec)
            p_logits, tcache = self.target.verify_step(
                target_params, tree_tokens, state.target_cache, state.pos,
                slot_off=jnp.arange(T), pos_off=spec.tree_pos,
                win_mask=spec.win_mask)
            node_valid = spec.node_valid(active_gamma, branches)
            if self.use_verify_kernel:
                from ..kernels.verify.ops import tree_verify_fused
                n_acc, winner, bonus = tree_verify_fused(
                    tree_tokens, p_logits, spec.parent_entry, spec.tree_pos,
                    node_valid, spec.win_mask)
                from .tree import TreeVerifyResult
                res = TreeVerifyResult(
                    n_accepted=n_acc, next_token=bonus, winner=winner,
                    path=tree_path_from_winner(winner, spec.parent_entry,
                                               spec.tree_pos, d_max),
                    accept=jnp.zeros_like(tree_tokens, bool))
            else:
                res = verify_tree_greedy(
                    tree_tokens, p_logits, spec.parent_entry, spec.tree_pos,
                    node_valid, spec.win_mask, d_max)
            new_tokens, num_new = tree_committed(tree_tokens, res, d_max)
            stop = slot_stop_mask(num_new, res.n_accepted, new_tokens,
                                  cursor, max_new, done, eos_id)
            # Relocate the winning path onto canonical slots in BOTH caches
            # (tree slots ≠ positions, so the linear path's implicit
            # stale-masking is not enough here). Lifecycle-clamped counts:
            # tokens beyond the budget/EOS cut are scrubbed, not kept.
            tcache = tree_commit_cache(tcache, state.pos, res.path,
                                       stop.n_accepted, T)
            dcache = tree_commit_cache(dcache, state.pos, res.path,
                                       stop.n_accepted, T)
            new_state = SpecDecodeState(
                draft_cache=dcache, target_cache=tcache,
                last_token=jnp.where(done, state.last_token,
                                     res.next_token),
                pos=state.pos + stop.num_new)
            out = SpecDecodeOut(state=new_state, new_tokens=new_tokens,
                                num_new=stop.num_new,
                                n_accepted=stop.n_accepted)
            out_buf, cursor, nacc_buf, nn_buf = _accumulate(
                out, out_buf, cursor, nacc_buf, nn_buf, row_idx)
            return new_state, out_buf, cursor, nacc_buf, nn_buf, stop.done

        jitted = jax.jit(step, donate_argnums=(2, 7, 8, 9, 10, 12))
        self._jit_cache[keyt] = jitted
        return jitted

    def _split_step(self, gamma_max: int):
        """SSM/hybrid-target path: verify on a throwaway cache, then advance
        the committed prefix with an active-masked ``lax.scan``. Per-slot
        stopping composes naturally: the advance is masked by the *stopped*
        ``num_new``, so a finished/free row's recurrent state (and hybrid
        shared-attention cache) never advances."""
        keyt = ("split", gamma_max)
        if keyt in self._jit_cache:
            return self._jit_cache[keyt]

        draft_decode = lambda p, t, c, pos: self.draft.decode_step(p, t, c, pos)

        def step(draft_params, target_params, state, key, active_gamma,
                 row_idx, out_buf, cursor, nacc_buf, nn_buf, max_new, done,
                 eos_id):
            kd, kv = jax.random.split(key)
            prop = draft_propose(draft_decode, draft_params,
                                 state.draft_cache, state.last_token,
                                 state.pos, gamma_max, kd, self.temperature)
            window = jnp.concatenate(
                [state.last_token[:, None], prop.tokens], axis=1)
            p_logits, _discard = self.target.verify_step(
                target_params, window, state.target_cache, state.pos)
            if self.temperature <= 0.0:
                res = verify_window_greedy(prop.tokens, p_logits,
                                           active_gamma=active_gamma)
            else:
                p_probs = _temperature_probs(p_logits, self.temperature)
                res = verify_window(kv, prop.tokens, prop.q_probs, p_probs,
                                    active_gamma=active_gamma)

            arange = jnp.arange(gamma_max + 1)[None, :]
            acc_part = jnp.concatenate(
                [prop.tokens, jnp.zeros_like(prop.tokens[:, :1])], axis=1)
            committed = jnp.where(arange == res.n_accepted[:, None],
                                  res.next_token[:, None], acc_part)
            new_tokens = jnp.where(arange < res.num_new[:, None],
                                   committed, -1)
            stop = slot_stop_mask(res.num_new, res.n_accepted, new_tokens,
                                  cursor, max_new, done, eos_id)

            # advance target over [last_token, committed[:num_new-1]] — i.e.
            # the tokens whose state transitions are now final. committed[t]
            # enters the state only when the *next* window processes it, so
            # we advance exactly num_new tokens starting from last_token.
            adv_tokens = jnp.concatenate(
                [state.last_token[:, None], committed[:, :gamma_max]], axis=1)
            tcache = _scan_cache_advance(
                self.target.decode_step, target_params, state.target_cache,
                adv_tokens, state.pos, stop.num_new)

            dcache = prop.cache
            if not self._draft_attention:
                # same treatment for a recurrent draft: re-advance from the
                # window-start checkpoint over the committed prefix
                dcache = _scan_cache_advance(
                    self.draft.decode_step, draft_params, state.draft_cache,
                    adv_tokens, state.pos, stop.num_new)

            out = SpecDecodeOut(
                state=SpecDecodeState(
                    draft_cache=dcache, target_cache=tcache,
                    last_token=jnp.where(done, state.last_token,
                                         res.next_token),
                    pos=state.pos + stop.num_new),
                new_tokens=new_tokens, num_new=stop.num_new,
                n_accepted=stop.n_accepted)
            out_buf, cursor, nacc_buf, nn_buf = _accumulate(
                out, out_buf, cursor, nacc_buf, nn_buf, row_idx)
            return out.state, out_buf, cursor, nacc_buf, nn_buf, stop.done

        jitted = jax.jit(step, donate_argnums=(2, 6, 7, 8, 9, 11))
        self._jit_cache[keyt] = jitted
        return jitted

    def _step_fn(self, gamma_max: int):
        if self._target_attention and self._draft_attention:
            return self._fused_step(gamma_max)
        return self._split_step(gamma_max)

    def compiled_programs(self) -> int:
        """Number of distinct XLA step programs compiled so far (the
        compile-once invariant: adaptive-γ generation keeps this at 1)."""
        from ..analysis.sanitize import jit_cache_programs
        return jit_cache_programs(self._jit_cache.values())

    def _policy_gamma_bound(self, policy) -> int:
        """Static window bound to compile the step at: the policy's own
        declared bound when it has one, else the module default."""
        bound = getattr(policy, "gamma_bound", None)
        g = bound() if callable(bound) else DEFAULT_GAMMA_MAX
        return max(1, int(g))

    def _insert_step(self, capacity: int, slots: int, pad_len: int):
        """ONE jitted prefill-insert program per session geometry: prefill a
        single ``pad_len``-padded prompt (true length ``plen`` traced) and
        write its cache row, anchor token, position and lifecycle entries
        into batch row ``slot`` of a LIVE session — neighbouring rows'
        buffers are donated through untouched. ``slot``, ``plen`` and
        ``req_max_new`` are traced, so admission into any slot at any
        prompt length ≤ pad_len reuses the same XLA program."""
        keyt = ("insert", capacity, slots, pad_len)
        if keyt in self._jit_cache:
            return self._jit_cache[keyt]

        def insert(draft_params, target_params, state, out_buf, cursor,
                   max_new_buf, done, prompt, plen, slot, req_max_new, key):
            one = self._prefill(prompt, slots, key, prompt_lens=plen,
                                draft_params=draft_params,
                                target_params=target_params)
            state = insert_slot(state, one, slot)
            row = jnp.full((1, out_buf.shape[1]), -1, jnp.int32)
            row = row.at[0, 0].set(one.last_token[0])
            out_buf = lax.dynamic_update_index_in_dim(out_buf, row, slot, 0)
            cursor = cursor.at[slot].set(1)
            max_new_buf = max_new_buf.at[slot].set(req_max_new)
            done = done.at[slot].set(False)
            return state, out_buf, cursor, max_new_buf, done

        jitted = jax.jit(insert, donate_argnums=(2, 3, 4, 5, 6))
        self._jit_cache[keyt] = jitted
        return jitted

    def _insert_step_paged(self, capacity: int, slots: int, pad_len: int,
                           d_nlog: int, t_nlog: int):
        """Paged-session admission program: prefill one prompt into a DENSE
        batch-1 row (``slots`` = the pool's logical length), then scatter
        that row into the reserved pool blocks and point the slot's block
        table at them (:func:`paged_insert_row`). Non-paged sides (e.g. an
        SSM draft) insert dense as before. ``draft_blocks``/``target_blocks``
        are traced (−1-padded, fixed widths ``d_nlog``/``t_nlog``; width 0
        for an unpaged side), so any slot with any block reservation reuses
        one XLA program — the zero-recompile invariant extends to paged
        admission."""
        keyt = ("insert-paged", capacity, slots, pad_len, d_nlog, t_nlog)
        if keyt in self._jit_cache:
            return self._jit_cache[keyt]

        def insert(draft_params, target_params, state, out_buf, cursor,
                   max_new_buf, done, prompt, plen, slot, req_max_new, key,
                   draft_blocks, target_blocks):
            one = self._prefill(prompt, slots, key, prompt_lens=plen,
                                draft_params=draft_params,
                                target_params=target_params)

            def put(cache, row, blocks):
                if isinstance(cache, PagedAttnCache):
                    return paged_insert_row(cache, row, blocks, slot)
                return insert_slot(cache, row, slot)

            state = SpecDecodeState(
                draft_cache=put(state.draft_cache, one.draft_cache,
                                draft_blocks),
                target_cache=put(state.target_cache, one.target_cache,
                                 target_blocks),
                last_token=state.last_token.at[slot].set(one.last_token[0]),
                pos=state.pos.at[slot].set(one.pos[0]))
            row = jnp.full((1, out_buf.shape[1]), -1, jnp.int32)
            row = row.at[0, 0].set(one.last_token[0])
            out_buf = lax.dynamic_update_index_in_dim(out_buf, row, slot, 0)
            cursor = cursor.at[slot].set(1)
            max_new_buf = max_new_buf.at[slot].set(req_max_new)
            done = done.at[slot].set(False)
            return state, out_buf, cursor, max_new_buf, done

        jitted = jax.jit(insert, donate_argnums=(2, 3, 4, 5, 6))
        self._jit_cache[keyt] = jitted
        return jitted

    def _release_step(self):
        """Retirement program for paged sessions: scrub the slot's block
        table rows to −1 so the frozen slot's ongoing (masked) speculative
        window writes DROP instead of stomping blocks the allocator is
        about to hand to the next request. Runs on the device stream before
        any later insert can reuse the blocks. Dense caches pass through
        untouched (their rows are fully overwritten at the next insert)."""
        keyt = ("release",)
        if keyt in self._jit_cache:
            return self._jit_cache[keyt]

        def release(state, slot):
            def rel(cache):
                if isinstance(cache, PagedAttnCache):
                    return paged_release_slot(cache, slot)
                return cache
            return SpecDecodeState(draft_cache=rel(state.draft_cache),
                                   target_cache=rel(state.target_cache),
                                   last_token=state.last_token,
                                   pos=state.pos)

        jitted = jax.jit(release, donate_argnums=(0,))
        self._jit_cache[keyt] = jitted
        return jitted

    # --------------------------------------------------------------- prefill

    def _prefill(self, prompts: jax.Array, slots: int, key: jax.Array,
                 frontend=None, prompt_lens: Optional[jax.Array] = None,
                 draft_params=None, target_params=None
                 ) -> SpecDecodeState:
        """Right-padded batched prefill. With ``prompt_lens``, the anchor
        logit is gathered at each sequence's true last prompt token; padded
        cache slots are later overwritten before any query can attend them
        (slot j is rewritten by the window covering position j), and SSM
        state is identity-masked past the true length. ``draft_params`` /
        ``target_params`` override the engine's own (so jitted callers can
        pass them as traced arguments instead of baked-in constants)."""
        B, S = prompts.shape
        dp = self.draft_params if draft_params is None else draft_params
        tp = self.target_params if target_params is None else target_params
        dlg, dcache = self.draft.prefill(dp, prompts, slots,
                                         frontend=frontend,
                                         prompt_lens=prompt_lens)
        tlg, tcache = self.target.prefill(tp, prompts, slots,
                                          frontend=frontend,
                                          prompt_lens=prompt_lens)
        if prompt_lens is None:
            anchor = tlg[:, -1, :]
            pos = jnp.full((B,), S, jnp.int32)
        else:
            idx = (prompt_lens - 1)[:, None, None]
            anchor = jnp.take_along_axis(tlg, idx, axis=1)[:, 0, :]
            pos = prompt_lens.astype(jnp.int32)
        if self.temperature <= 0.0:
            first = jnp.argmax(anchor, axis=-1).astype(jnp.int32)
        else:
            probs = _temperature_probs(anchor, self.temperature)
            first = sample_from_probs(key, probs).astype(jnp.int32)
        return SpecDecodeState(draft_cache=dcache, target_cache=tcache,
                               last_token=first, pos=pos)

    # -------------------------------------------------------------- generate

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 window_policy: Optional[WindowPolicy] = None,
                 key: Optional[jax.Array] = None, frontend=None,
                 prompt_lens: Optional[np.ndarray] = None,
                 gamma_max: Optional[int] = None,
                 sync_every: Optional[int] = None,
                 eos_id: int = -1, transport=None,
                 mode_policy: str = "auto"
                 ) -> tuple[np.ndarray, GenerationStats]:
        """Batched generation. Returns (tokens (B, max_new), stats).

        This is now a thin ONE-WAVE wrapper over
        :class:`repro.core.session.DecodeSession`: all B prompts are
        admitted together via a batched prefill, the session's masked-γ /
        masked-slot step runs until every row stops (per-row budget, or a
        committed ``eos_id`` ≥ 0), and the device-resident output buffer is
        extracted once. Continuous serving — in-flight admission into freed
        slots — uses the session directly (``repro.serving``). Compile-width
        resolution for ``gamma_max``: this call's override > the
        engine-level pin > the policy's declared bound; policy γ decisions
        above the width are clamped. ``transport``/``mode_policy`` pass
        through to the session: with a transport, every speculation round
        is a real draft→verify→verdict exchange between the split workers
        (:mod:`repro.distributed`).
        """
        from .session import DecodeSession    # session imports engine types
        policy = window_policy or StaticWindowPolicy(4)
        if gamma_max:
            gmax = int(gamma_max)
        elif self.gamma_max:
            gmax = self.gamma_max
        else:
            gmax = self._policy_gamma_bound(policy)
        sync = max(1, int(sync_every if sync_every else self.sync_every))
        B = prompts.shape[0]
        t0 = time.perf_counter()
        sess = DecodeSession(self, capacity=B, max_new_cap=max_new_tokens,
                             gamma_max=gmax, sync_every=sync, eos_id=eos_id,
                             key=key, transport=transport,
                             mode_policy=mode_policy)
        sess.admit_batch(prompts, max_new_tokens, prompt_lens=prompt_lens,
                         frontend=frontend)
        max_iters = max_new_tokens + sync
        while sess.unfinished and sess.iterations < max_iters:
            sess.run_chunk(policy, max_iters=max_iters)
        tokens, stats = sess.snapshot()
        stats.wall_s = time.perf_counter() - t0
        return tokens, stats

    # ------------------------------------------------------------ trace capture

    def capture_traces(self, prompts: np.ndarray, max_new_tokens: int,
                       gamma: int = 8, key=None) -> list[list[int]]:
        """Ground-truth acceptance sequences for DSD-Sim (paper §3.2)."""
        _, stats = self.generate(prompts, max_new_tokens,
                                 StaticWindowPolicy(gamma), key=key)
        return stats.acceptance_seqs
