"""Distributed speculative-decoding engine on *real* JAX models.

This is the execution layer the simulator abstracts: an edge draft model and
a cloud target model exchanging speculation windows (Fig. 1b). On real
hardware the two jitted programs run on separate pods and exchange only the
tiny token/verdict payloads; in this container both run on the host and the
network hop is accounted virtually (``rtt_ms``), while *acceptance outcomes
are real* — this engine is what captures the ground-truth
``acceptance_seq`` traces DSD-Sim replays (DESIGN.md §7.3).

Cache-rollback semantics per family:

- attention families (dense/moe/vlm/encdec): stale window entries are
  masked via ``pos_map`` (models/kvcache.py) — single fused
  :func:`repro.core.specdec.spec_decode_step`.
- ssm/hybrid: the recurrent state cannot be masked retroactively; the
  engine keeps the window-start state as the checkpoint, verifies on a
  throwaway copy, then *advances* the committed prefix with per-sequence
  active-masking (``_tree_where``) — the SSM analogue of cache rollback.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import Model, build_model
from .specdec import (SpecDecodeState, draft_propose, spec_decode_step,
                      verify_window, verify_window_greedy, _temperature_probs,
                      sample_from_probs)
from .window import FeatureSnapshot, StaticWindowPolicy, WindowPolicy


def _tree_where(active: jax.Array, new: Any, old: Any, batch_axis: int = 1):
    """Per-sequence select over cache pytrees; non-array leaves pass through.

    ``active``: (B,) bool. Cache leaves carry batch on ``batch_axis``
    (layer-stacked caches are (L, B, ...))."""
    def sel(n, o):
        if not isinstance(n, jax.Array) or n.ndim == 0:
            return o
        shape = [1] * n.ndim
        ax = batch_axis if n.ndim > batch_axis else 0
        shape[ax] = active.shape[0]
        return jnp.where(active.reshape(shape), n, o)
    return jax.tree.map(sel, new, old)


@dataclass
class GenerationStats:
    iterations: int = 0
    proposed: int = 0
    accepted: int = 0
    tokens: int = 0
    wall_s: float = 0.0
    virtual_ms: float = 0.0          # simulated edge-cloud time (incl. RTT)
    acceptance_seqs: list = field(default_factory=list)  # per-seq 0/1 bits
    gamma_seq: list = field(default_factory=list)

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(1, self.proposed)

    @property
    def tokens_per_iteration(self) -> float:
        return self.tokens / max(1, self.iterations)


class SpecDecodeEngine:
    """Edge draft + cloud target, window policy in the loop."""

    def __init__(self, draft_cfg: ModelConfig, target_cfg: ModelConfig,
                 draft_params=None, target_params=None,
                 key: Optional[jax.Array] = None,
                 temperature: float = 1.0, rtt_ms: float = 0.0,
                 use_verify_kernel: bool = False):
        assert draft_cfg.vocab == target_cfg.vocab, \
            "draft/target must share a tokenizer/vocab"
        self.draft_cfg, self.target_cfg = draft_cfg, target_cfg
        self.draft = build_model(draft_cfg)
        self.target = build_model(target_cfg)
        key = key if key is not None else jax.random.PRNGKey(0)
        kd, kt = jax.random.split(key)
        self.draft_params = (draft_params if draft_params is not None
                             else self.draft.init_params(kd))
        self.target_params = (target_params if target_params is not None
                              else self.target.init_params(kt))
        self.temperature = temperature
        self.rtt_ms = rtt_ms
        self.use_verify_kernel = use_verify_kernel
        self._target_attention = target_cfg.arch_type in (
            "dense", "moe", "vlm", "encdec")
        self._draft_attention = draft_cfg.arch_type in (
            "dense", "moe", "vlm", "encdec")
        self._jit_cache: dict = {}

    # ------------------------------------------------------------- jit paths

    def _fused_step(self, gamma: int):
        """Attention-target path: one jitted program per γ."""
        keyt = ("fused", gamma)
        if keyt in self._jit_cache:
            return self._jit_cache[keyt]

        draft_decode = lambda p, t, c, pos: self.draft.decode_step(p, t, c, pos)
        target_verify = lambda p, w, c, pos: self.target.verify_step(p, w, c, pos)

        @jax.jit
        def step(draft_params, target_params, state, key):
            return spec_decode_step(draft_decode, target_verify,
                                    draft_params, target_params,
                                    state, gamma, key, self.temperature)

        self._jit_cache[keyt] = step
        return step

    def _split_step(self, gamma: int):
        """SSM/hybrid-target path: verify on a throwaway cache, then advance
        the committed prefix with active-masked decode steps."""
        keyt = ("split", gamma)
        if keyt in self._jit_cache:
            return self._jit_cache[keyt]

        draft_decode = lambda p, t, c, pos: self.draft.decode_step(p, t, c, pos)

        @jax.jit
        def step(draft_params, target_params, state, key):
            kd, kv = jax.random.split(key)
            prop = draft_propose(draft_decode, draft_params,
                                 state.draft_cache, state.last_token,
                                 state.pos, gamma, kd, self.temperature)
            window = jnp.concatenate(
                [state.last_token[:, None], prop.tokens], axis=1)
            p_logits, _discard = self.target.verify_step(
                target_params, window, state.target_cache, state.pos)
            if self.temperature <= 0.0:
                res = verify_window_greedy(prop.tokens, p_logits)
            else:
                p_probs = _temperature_probs(p_logits, self.temperature)
                res = verify_window(kv, prop.tokens, prop.q_probs, p_probs)

            arange = jnp.arange(gamma + 1)[None, :]
            acc_part = jnp.concatenate(
                [prop.tokens, jnp.zeros_like(prop.tokens[:, :1])], axis=1)
            committed = jnp.where(arange == res.n_accepted[:, None],
                                  res.next_token[:, None], acc_part)

            # advance target over [last_token, committed[:num_new-1]] — i.e.
            # the tokens whose state transitions are now final. committed[t]
            # enters the state only when the *next* window processes it, so
            # we advance exactly num_new tokens starting from last_token.
            adv_tokens = jnp.concatenate(
                [state.last_token[:, None], committed[:, :gamma]], axis=1)
            tcache = state.target_cache
            for t in range(gamma + 1):
                active = t < res.num_new
                _, cnew = self.target.decode_step(
                    target_params, adv_tokens[:, t], tcache, state.pos + t)
                tcache = _tree_where(active, cnew, tcache)

            dcache = prop.cache
            if not self._draft_attention:
                # same treatment for a recurrent draft: re-advance from the
                # window-start checkpoint over the committed prefix
                dcache = state.draft_cache
                for t in range(gamma + 1):
                    active = t < res.num_new
                    _, cnew = self.draft.decode_step(
                        draft_params, adv_tokens[:, t], dcache, state.pos + t)
                    dcache = _tree_where(active, cnew, dcache)

            new_tokens = jnp.where(arange < res.num_new[:, None], committed, -1)
            state = SpecDecodeState(
                draft_cache=dcache, target_cache=tcache,
                last_token=res.next_token, pos=state.pos + res.num_new)
            from .specdec import SpecDecodeOut
            return SpecDecodeOut(state=state, new_tokens=new_tokens,
                                 num_new=res.num_new,
                                 n_accepted=res.n_accepted)

        self._jit_cache[keyt] = step
        return step

    def _step_fn(self, gamma: int):
        if self._target_attention and self._draft_attention:
            return self._fused_step(gamma)
        return self._split_step(gamma)

    # --------------------------------------------------------------- prefill

    def _prefill(self, prompts: jax.Array, slots: int, key: jax.Array,
                 frontend=None, prompt_lens: Optional[jax.Array] = None
                 ) -> SpecDecodeState:
        """Right-padded batched prefill. With ``prompt_lens``, the anchor
        logit is gathered at each sequence's true last prompt token; padded
        cache slots are later overwritten before any query can attend them
        (slot j is rewritten by the window covering position j), and SSM
        state is identity-masked past the true length."""
        B, S = prompts.shape
        dlg, dcache = self.draft.prefill(self.draft_params, prompts, slots,
                                         frontend=frontend,
                                         prompt_lens=prompt_lens)
        tlg, tcache = self.target.prefill(self.target_params, prompts, slots,
                                          frontend=frontend,
                                          prompt_lens=prompt_lens)
        if prompt_lens is None:
            anchor = tlg[:, -1, :]
            pos = jnp.full((B,), S, jnp.int32)
        else:
            idx = (prompt_lens - 1)[:, None, None]
            anchor = jnp.take_along_axis(tlg, idx, axis=1)[:, 0, :]
            pos = prompt_lens.astype(jnp.int32)
        if self.temperature <= 0.0:
            first = jnp.argmax(anchor, axis=-1).astype(jnp.int32)
        else:
            probs = _temperature_probs(anchor, self.temperature)
            first = sample_from_probs(key, probs).astype(jnp.int32)
        return SpecDecodeState(draft_cache=dcache, target_cache=tcache,
                               last_token=first, pos=pos)

    # -------------------------------------------------------------- generate

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 window_policy: Optional[WindowPolicy] = None,
                 key: Optional[jax.Array] = None, frontend=None,
                 prompt_lens: Optional[np.ndarray] = None
                 ) -> tuple[np.ndarray, GenerationStats]:
        """Batched generation. Returns (tokens (B, ≥max_new), stats)."""
        policy = window_policy or StaticWindowPolicy(4)
        key = key if key is not None else jax.random.PRNGKey(0)
        prompts = jnp.asarray(prompts, jnp.int32)
        B, S = prompts.shape
        slots = S + max_new_tokens + 16
        key, kp = jax.random.split(key)
        t0 = time.perf_counter()
        pl = None if prompt_lens is None else jnp.asarray(prompt_lens, jnp.int32)
        state = self._prefill(prompts, slots, kp, frontend=frontend,
                              prompt_lens=pl)

        stats = GenerationStats()
        stats.acceptance_seqs = [[] for _ in range(B)]
        out = [[int(state.last_token[b])] for b in range(B)]
        produced = np.ones(B, np.int64)
        alpha_recent: list[float] = []
        tpot_recent: list[float] = []
        gamma_prev = 4.0

        while produced.min() < max_new_tokens:
            feats = FeatureSnapshot(
                q_depth=0.0,
                alpha_recent=(sum(alpha_recent[-16:]) /
                              max(1, len(alpha_recent[-16:]))
                              if alpha_recent else 0.7),
                rtt_recent_ms=self.rtt_ms,
                tpot_recent_ms=(sum(tpot_recent[-16:]) /
                                max(1, len(tpot_recent[-16:]))
                                if tpot_recent else 50.0),
                gamma_prev=gamma_prev)
            dec = policy.decide("engine", feats)
            gamma = max(1, int(dec.gamma))
            stats.gamma_seq.append(gamma)
            it0 = time.perf_counter()
            key, ks = jax.random.split(key)
            res = self._step_fn(gamma)(self.draft_params, self.target_params,
                                       state, ks)
            state = res.state
            new = np.asarray(res.new_tokens)
            num_new = np.asarray(res.num_new)
            n_acc = np.asarray(res.n_accepted)
            for b in range(B):
                bits = [1] * int(n_acc[b])
                if n_acc[b] < gamma:
                    bits.append(0)
                stats.acceptance_seqs[b].extend(bits)
                take = int(num_new[b])
                out[b].extend(int(t) for t in new[b, :take])
            produced += num_new
            stats.iterations += 1
            stats.proposed += int(gamma * B)
            stats.accepted += int(n_acc.sum())
            stats.tokens += int(num_new.sum())
            it_wall = time.perf_counter() - it0
            tpot_recent.append(it_wall * 1e3 / max(1.0, float(num_new.mean())))
            alpha_recent.append(float(n_acc.mean()) / gamma)
            stats.virtual_ms += self.rtt_ms + it_wall * 1e3
            gamma_prev = float(gamma)

        stats.wall_s = time.perf_counter() - t0
        tokens = np.full((B, max_new_tokens), -1, np.int64)
        for b in range(B):
            seq = out[b][:max_new_tokens]
            tokens[b, :len(seq)] = seq
        return tokens, stats

    # ------------------------------------------------------------ trace capture

    def capture_traces(self, prompts: np.ndarray, max_new_tokens: int,
                       gamma: int = 8, key=None) -> list[list[int]]:
        """Ground-truth acceptance sequences for DSD-Sim (paper §3.2)."""
        _, stats = self.generate(prompts, max_new_tokens,
                                 StaticWindowPolicy(gamma), key=key)
        return stats.acceptance_seqs
