"""Speculative decoding algorithm (paper §2.1) — pure JAX, model-agnostic.

Implements the Leviathan/Chen accept–resample rule, fully vectorized over a
batch with no data-dependent Python control flow (everything is ``jnp`` /
``lax`` so it jits, shards and lowers for TPU):

- draft model proposes γ tokens with per-position distributions q_i,
- target evaluates all positions in parallel giving p_i (i = 1..γ+1),
- token i is accepted iff u_i < min(1, p_i(t_i)/q_i(t_i)); on the first
  rejection the target's residual distribution norm(max(p_i − q_i, 0)) is
  sampled instead; if all γ accept, a bonus token is drawn from p_{γ+1}.

Per-token acceptance probability α gives (paper Eqs. (1)–(2)):

    E[τ] = (1 − α^{γ+1}) / (1 − α)
    S    = (1 − α^{γ+1}) / ((1 − α)(cγ + 1))

which :func:`expected_accepted` / :func:`expected_speedup` expose for the
analytic benchmark and the AWC bootstrap controller.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


# --------------------------------------------------------------------------
# Analytic formulas (Eqs. 1 and 2)
# --------------------------------------------------------------------------

def expected_accepted(alpha, gamma):
    """E[tokens per iteration] = (1 - alpha^(gamma+1)) / (1 - alpha)."""
    alpha = jnp.asarray(alpha, dtype=jnp.float32)
    g = jnp.asarray(gamma, dtype=jnp.float32)
    near_one = jnp.abs(1.0 - alpha) < 1e-6
    safe = jnp.where(near_one, 0.5, alpha)
    val = (1.0 - safe ** (g + 1.0)) / (1.0 - safe)
    return jnp.where(near_one, g + 1.0, val)


def expected_speedup(alpha, gamma, cost_ratio):
    """S = (1 - alpha^(gamma+1)) / ((1 - alpha) (c*gamma + 1))."""
    return expected_accepted(alpha, gamma) / (
        jnp.asarray(cost_ratio, jnp.float32) * jnp.asarray(gamma, jnp.float32) + 1.0)


def optimal_gamma(alpha: float, cost_ratio: float, gmax: int = 12) -> int:
    """argmax_γ of Eq. (2) over the integer range [1, gmax]."""
    gammas = jnp.arange(1, gmax + 1, dtype=jnp.float32)
    s = expected_speedup(alpha, gammas, cost_ratio)
    return int(jnp.argmax(s)) + 1


# --------------------------------------------------------------------------
# Sampling helpers
# --------------------------------------------------------------------------

def _temperature_probs(logits: jax.Array, temperature: float) -> jax.Array:
    """Softmax at temperature; temperature == 0 degenerates to one-hot argmax."""
    if temperature <= 0.0:
        return jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1],
                              dtype=logits.dtype)
    return jax.nn.softmax(logits / temperature, axis=-1)


def sample_from_probs(key: jax.Array, probs: jax.Array) -> jax.Array:
    """Categorical sample via Gumbel-max on log-probs (batched)."""
    logp = jnp.log(jnp.maximum(probs, 1e-20))
    return jax.random.categorical(key, logp, axis=-1)


# --------------------------------------------------------------------------
# Verification: accept / resample (the paper's step 2-4 of Fig 1c)
# --------------------------------------------------------------------------

class VerifyResult(NamedTuple):
    n_accepted: jax.Array      # (B,) int32 — accepted draft tokens in [0, γ]
    next_token: jax.Array      # (B,) int32 — corrected or bonus token
    accept_mask: jax.Array     # (B, γ) bool — per-position acceptance
    num_new: jax.Array         # (B,) int32 — n_accepted + 1 tokens produced


def _active_gamma_vec(active_gamma, B: int, gamma_max: int) -> jax.Array:
    """Normalize ``active_gamma`` (None | python int | traced scalar | (B,))
    to a (B,) int32 vector. ``None`` means the full static window."""
    if active_gamma is None:
        return jnp.full((B,), gamma_max, jnp.int32)
    return jnp.broadcast_to(jnp.asarray(active_gamma, jnp.int32), (B,))


def verify_window(key: jax.Array,
                  draft_tokens: jax.Array,   # (B, Γ) int32
                  q_probs: jax.Array,        # (B, Γ, V) draft distributions
                  p_probs: jax.Array,        # (B, Γ+1, V) target distributions
                  active_gamma=None,
                  ) -> VerifyResult:
    """Vectorized accept/resample over the speculation window.

    ``active_gamma`` (traced scalar or (B,) int32, or None) masks the window
    to the first ``active_gamma`` positions: positions ≥ active_gamma are
    force-rejected, the bonus distribution is taken at position
    ``active_gamma`` and the all-accepted condition is ``n_acc ==
    active_gamma``. With ``active_gamma=None`` this is exactly the classic
    static-γ rule (bit-identical RNG consumption) — which makes one program
    compiled at Γ=gamma_max serve every γ ∈ [1, Γ] with zero recompiles.
    Masked acceptance at γ < Γ is the per-γ rule *in distribution*; the
    uniforms are drawn at width Γ, so sampled outcomes are not bitwise
    reproductions of a width-γ program (greedy verification is — see
    :func:`verify_window_greedy`).

    The reference (oracle) semantics for the Pallas kernel in
    ``repro.kernels.verify`` — see ``kernels/verify/ref.py`` which wraps this.
    """
    B, gamma = draft_tokens.shape
    ag = _active_gamma_vec(active_gamma, B, gamma)
    ku, kr = jax.random.split(key)
    u = jax.random.uniform(ku, (B, gamma))

    p_at = jnp.take_along_axis(p_probs[:, :gamma, :], draft_tokens[..., None],
                               axis=-1)[..., 0]                      # (B, Γ)
    q_at = jnp.take_along_axis(q_probs, draft_tokens[..., None],
                               axis=-1)[..., 0]                      # (B, Γ)
    ratio = p_at / jnp.maximum(q_at, 1e-20)
    accept = u < jnp.minimum(1.0, ratio)                             # (B, Γ)
    accept = accept & (jnp.arange(gamma)[None, :] < ag[:, None])
    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n_acc = prefix.sum(axis=-1)                                      # (B,)

    # Distribution for the extra token: residual at the reject position,
    # or p_{active_gamma+1} when everything accepted.
    idx = jnp.minimum(n_acc, ag - 1)                                 # reject pos
    p_rej = jnp.take_along_axis(p_probs, idx[:, None, None], axis=1)[:, 0]
    q_rej = jnp.take_along_axis(q_probs, idx[:, None, None], axis=1)[:, 0]
    residual = jnp.maximum(p_rej - q_rej, 0.0)
    res_mass = residual.sum(axis=-1, keepdims=True)
    # Degenerate residual (p == q exactly) falls back to p itself.
    residual = jnp.where(res_mass > 1e-12, residual / jnp.maximum(res_mass, 1e-20),
                         p_rej)
    bonus = jnp.take_along_axis(p_probs, ag[:, None, None], axis=1)[:, 0]
    all_accepted = (n_acc == ag)[:, None]
    dist = jnp.where(all_accepted, bonus, residual)
    next_token = sample_from_probs(kr, dist).astype(jnp.int32)
    return VerifyResult(n_accepted=n_acc.astype(jnp.int32),
                        next_token=next_token,
                        accept_mask=accept,
                        num_new=(n_acc + 1).astype(jnp.int32))


def verify_window_greedy(draft_tokens: jax.Array,
                         p_logits: jax.Array,
                         active_gamma=None) -> VerifyResult:
    """Deterministic variant: accept while the draft token equals the
    target argmax; the correction/bonus token is the target argmax at the
    first mismatch (or the extra position). ``active_gamma`` masks the
    window as in :func:`verify_window`; because attention/SSM decoding is
    causal, the committed tokens of the masked step at any γ are
    bit-identical to a dedicated per-γ program."""
    B, gamma = draft_tokens.shape
    ag = _active_gamma_vec(active_gamma, B, gamma)
    tgt = jnp.argmax(p_logits, axis=-1).astype(jnp.int32)   # (B, Γ+1)
    accept = tgt[:, :gamma] == draft_tokens
    accept = accept & (jnp.arange(gamma)[None, :] < ag[:, None])
    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n_acc = prefix.sum(axis=-1)
    next_token = jnp.take_along_axis(tgt, n_acc[:, None], axis=1)[:, 0]
    return VerifyResult(n_accepted=n_acc.astype(jnp.int32),
                        next_token=next_token.astype(jnp.int32),
                        accept_mask=accept,
                        num_new=(n_acc + 1).astype(jnp.int32))


# --------------------------------------------------------------------------
# Per-slot stopping (continuous batching)
# --------------------------------------------------------------------------

class SlotStop(NamedTuple):
    num_new: jax.Array      # (B,) int32 — tokens to commit after masking
    n_accepted: jax.Array   # (B,) int32 — masked acceptance count
    done: jax.Array         # (B,) bool  — updated finished flags


def slot_stop_mask(num_new: jax.Array, n_accepted: jax.Array,
                   new_tokens: jax.Array, cursor: jax.Array,
                   max_new: jax.Array, done: jax.Array,
                   eos_id) -> SlotStop:
    """Per-slot active masking + EOS/length stopping for a batch whose rows
    ("slots") belong to independent requests at different lifecycle stages.

    - rows with ``done`` commit nothing (``num_new → 0``) so their cursor,
      position and recurrent state freeze while neighbours keep decoding;
    - active rows are clamped to their remaining budget
      ``max_new − cursor`` and marked done when they exhaust it;
    - a committed ``eos_id`` token (traced int32; −1 disables) truncates
      the window after the EOS position and marks the row done.

    Pure ``jnp`` on (B,)-shaped operands: one program compiled at the batch
    capacity serves every admission/retirement pattern with zero recompiles.
    Any clamp implies ``done``, so a row's ``last_token``/state being "one
    step ahead" of its committed prefix is never observable.
    """
    B, W = new_tokens.shape
    active = ~done
    eos = jnp.asarray(eos_id, jnp.int32)
    num_eff = jnp.where(active,
                        jnp.minimum(num_new, jnp.maximum(0, max_new - cursor)),
                        0)
    arange = jnp.arange(W)[None, :]
    is_eos = (new_tokens == eos) & (arange < num_eff[:, None]) & (eos >= 0)
    has_eos = is_eos.any(axis=-1)
    eos_pos = jnp.argmax(is_eos, axis=-1).astype(jnp.int32)
    num_eff = jnp.where(has_eos, jnp.minimum(num_eff, eos_pos + 1), num_eff)
    new_done = done | (cursor + num_eff >= max_new) | has_eos
    # acceptance stats reflect COMMITTED tokens only: a budget/EOS clamp
    # that cuts accepted drafts also cuts them from n_accepted, so traces
    # and acceptance rates match the emitted sequence exactly
    n_eff = jnp.where(active, jnp.minimum(n_accepted, num_eff), 0)
    return SlotStop(num_new=num_eff.astype(jnp.int32),
                    n_accepted=n_eff.astype(jnp.int32),
                    done=new_done)


# --------------------------------------------------------------------------
# Draft proposal loop
# --------------------------------------------------------------------------

class DraftProposal(NamedTuple):
    tokens: jax.Array     # (B, γ) int32
    q_probs: jax.Array    # (B, γ, V)
    cache: object         # draft model cache after the window


def draft_propose(decode_fn: Callable, params, cache, last_token: jax.Array,
                  start_pos: jax.Array, gamma: int, key: jax.Array,
                  temperature: float = 1.0) -> DraftProposal:
    """Autoregressively propose γ tokens with the draft model.

    ``decode_fn(params, token, cache, pos) -> (logits, cache)`` is the
    single-token decode step of any model in the zoo. γ is static (python
    int) so this unrolls into a ``lax.scan`` of fixed length — required for
    jit/lowering.
    """
    keys = jax.random.split(key, gamma)

    def step(carry, k):
        tok, cache, pos = carry
        logits, cache = decode_fn(params, tok, cache, pos)
        probs = _temperature_probs(logits, temperature)
        nxt = sample_from_probs(k, probs).astype(jnp.int32)
        return (nxt, cache, pos + 1), (nxt, probs)

    (_, cache, _), (toks, qs) = lax.scan(
        step, (last_token, cache, start_pos), keys)
    # scan stacks on axis 0: (γ, B) / (γ, B, V) → batch-major
    return DraftProposal(tokens=jnp.moveaxis(toks, 0, 1),
                         q_probs=jnp.moveaxis(qs, 0, 1),
                         cache=cache)


# --------------------------------------------------------------------------
# One full speculation iteration (draft γ → verify → commit)
# --------------------------------------------------------------------------

class SpecDecodeState(NamedTuple):
    draft_cache: object
    target_cache: object
    last_token: jax.Array     # (B,) most recent committed token
    pos: jax.Array            # (B,) absolute position OF last_token

class SpecDecodeOut(NamedTuple):
    state: SpecDecodeState
    new_tokens: jax.Array     # (B, γ+1) committed tokens, padded with -1
    num_new: jax.Array        # (B,)
    n_accepted: jax.Array     # (B,)


def spec_decode_step(draft_decode_fn: Callable, target_verify_fn: Callable,
                     draft_params, target_params,
                     state: SpecDecodeState, gamma: int, key: jax.Array,
                     temperature: float = 1.0,
                     active_gamma=None) -> SpecDecodeOut:
    """One distributed-SD iteration, jittable end to end.

    ``target_verify_fn(params, tokens, cache, pos) -> (logits, cache)``
    runs the target over the γ+1 window ``[last_token, draft_tokens]`` and
    returns logits for every window position. Cache-rollback semantics:
    callers commit only ``num_new`` tokens; stale cache entries beyond the
    committed position are overwritten by later iterations (attention) or
    restored from the pre-window checkpoint (SSM — see models/ssm.py).

    ``gamma`` is the STATIC window width the program is compiled at;
    ``active_gamma`` (traced, None ⇒ gamma) masks acceptance to the first
    ``active_gamma`` draft positions so a single program compiled at
    ``gamma_max`` serves any γ ∈ [1, gamma_max] without recompiling.
    """
    kd, kv = jax.random.split(key)
    prop = draft_propose(draft_decode_fn, draft_params, state.draft_cache,
                         state.last_token, state.pos, gamma, kd, temperature)
    window = jnp.concatenate([state.last_token[:, None], prop.tokens], axis=1)
    # window occupies absolute positions pos .. pos+γ (last_token sits at pos;
    # its KV is not yet in the target cache — sampled, never forwarded).
    p_logits, target_cache = target_verify_fn(
        target_params, window, state.target_cache, state.pos)
    if temperature <= 0.0:
        res = verify_window_greedy(prop.tokens, p_logits,
                                   active_gamma=active_gamma)
    else:
        p_probs = _temperature_probs(p_logits, temperature)
        res = verify_window(kv, prop.tokens, prop.q_probs, p_probs,
                            active_gamma=active_gamma)

    # committed tokens: accepted prefix then the corrected/bonus token
    arange = jnp.arange(gamma + 1)[None, :]
    acc_part = jnp.concatenate(
        [prop.tokens, jnp.zeros_like(prop.tokens[:, :1])], axis=1)
    corrected = jnp.where(arange == res.n_accepted[:, None],
                          res.next_token[:, None], acc_part)
    new_tokens = jnp.where(arange < res.num_new[:, None], corrected, -1)
    last = res.next_token
    state = SpecDecodeState(draft_cache=prop.cache, target_cache=target_cache,
                            last_token=last,
                            pos=state.pos + res.num_new)
    return SpecDecodeOut(state=state, new_tokens=new_tokens,
                         num_new=res.num_new, n_accepted=res.n_accepted)
