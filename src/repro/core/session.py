"""Persistent slot-based decode session — continuous batching on real models.

:class:`DecodeSession` owns a fixed-capacity pool of batch rows ("slots")
whose KV/SSM caches, output buffers and cursors live ON DEVICE across
requests. Requests are admitted into free slots by a jitted prefill-insert
(one program per session geometry, any slot / any prompt length ≤ the pad
bound) and retired from finished slots at ``sync_every`` boundaries; the
engine's compile-once masked-γ step keeps running untouched while the
active-slot pattern changes — admission and retirement are *data*, never a
new XLA program.

Lifecycle of one slot::

    admit (prefill-insert row j)  →  decode chunks (slot active)
        →  done (budget / EOS; num_new masked to 0, row freezes)
        →  retire (tokens extracted, host record closed, slot free)
        →  admit next request (row j fully overwritten)

Invariants the tests pin down:

- a request decoded with staggered co-tenants commits the SAME greedy
  tokens as a solo :meth:`SpecDecodeEngine.generate` run (attention *and*
  SSM/hybrid families) — per-row independence of the masked step;
- retire → re-admit leaves no stale cache state (the insert overwrites the
  whole row; :func:`repro.models.kvcache.reset_slot` additionally scrubs it
  for long-lived sessions);
- the number of compiled XLA programs is constant across any
  admission/retirement pattern after warmup (one step + one insert).

``SpecDecodeEngine.generate`` is a thin one-wave wrapper over this class;
the continuous scheduler in :mod:`repro.serving.server` drives it with a
live arrival queue.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.kvcache import reset_slot
from .engine import DEFAULT_GAMMA_MAX, GenerationStats
from .specdec import SpecDecodeState
from .window import FeatureSnapshot


@dataclass
class SlotRecord:
    """Host-side bookkeeping for the request occupying one slot."""
    request_id: int
    max_new: int
    admit_it: int                    # session iteration at admission
    bits: list = field(default_factory=list)   # acceptance 0/1 stream
    produced: int = 1                # tokens in out_buf row (anchor incl.)
    proposed: int = 0
    accepted: int = 0
    done: bool = False


def _canon(tree):
    """Array-ify non-array leaves (the caches' static ``ring`` flag) so the
    first jitted call sees the same signature the step returns."""
    return jax.tree.map(
        lambda x: x if isinstance(x, jax.Array) else jnp.asarray(x), tree)


class DecodeSession:
    """Fixed-capacity slot pool over a :class:`SpecDecodeEngine`.

    ``capacity``       batch rows (the compiled batch size),
    ``max_new_cap``    output-buffer width (per-request budgets clamp to it),
    ``max_prompt_len`` pad bound for per-slot admission (``admit``); a
                       session only ever driven by ``admit_batch`` may leave
                       it None and inherits the wave's prompt width,
    ``gamma_max``      compile-once window bound (session > engine > default),
    ``sync_every``     decode iterations between host syncs — the admission/
                       retirement granularity,
    ``eos_id``         stop token (−1 disables; per-slot budgets always cap).
    """

    def __init__(self, engine, capacity: int, max_new_cap: int,
                 max_prompt_len: Optional[int] = None,
                 gamma_max: Optional[int] = None,
                 sync_every: Optional[int] = None,
                 eos_id: int = -1, key: Optional[jax.Array] = None,
                 log_gamma: bool = True):
        self.engine = engine
        self.capacity = int(capacity)
        self.max_new_cap = int(max_new_cap)
        self.max_prompt_len = (None if max_prompt_len is None
                               else int(max_prompt_len))
        if gamma_max:
            self.gamma_max = int(gamma_max)
        elif engine.gamma_max:
            self.gamma_max = engine.gamma_max
        else:
            self.gamma_max = DEFAULT_GAMMA_MAX
        self.sync_every = max(1, int(sync_every or engine.sync_every))
        self.eos_id = -1 if eos_id is None else int(eos_id)
        self._key = key if key is not None else jax.random.PRNGKey(0)

        self.slots_len = (None if self.max_prompt_len is None
                          else self._cache_len(self.max_prompt_len))
        self._state: Optional[SpecDecodeState] = None
        self._slots: list[Optional[SlotRecord]] = [None] * self.capacity
        self._out_buf = None
        self._cursor = None
        self._max_new = None
        self._done = None
        self._nacc = None
        self._nn = None

        # engine-wide accounting / window-policy features. Feature lists
        # are bounded (only the last 16 samples feed FeatureSnapshot) and
        # gamma_seq logging is optional so a long-lived serving session
        # does not grow host state linearly in decode iterations.
        self.iterations = 0
        self.proposed = 0
        self.accepted = 0
        self.prefill_s = 0.0
        self.decode_wall_s = 0.0
        self.virtual_ms = 0.0
        self.log_gamma = bool(log_gamma)
        self.gamma_seq: list[int] = []
        self._alpha_recent: list[float] = []
        self._tpot_recent: list[float] = []
        self._gamma_prev = 4.0

    # ------------------------------------------------------------- geometry

    def _cache_len(self, prompt_len: int) -> int:
        return prompt_len + self.max_new_cap + self.gamma_max + 17

    def _init_buffers(self) -> None:
        B = self.capacity
        self._out_buf = jnp.full((B, self.max_new_cap), -1, jnp.int32)
        self._cursor = jnp.zeros((B,), jnp.int32)
        self._max_new = jnp.zeros((B,), jnp.int32)
        self._done = jnp.ones((B,), bool)          # free slots are inert
        self._nacc = jnp.zeros((self.sync_every, B), jnp.int32)
        self._nn = jnp.zeros((self.sync_every, B), jnp.int32)

    def _ensure_state(self) -> None:
        """Lazily build an all-free device state for per-slot admission."""
        if self._state is not None:
            return
        eng = self.engine
        assert self.max_prompt_len is not None, \
            "per-slot admission needs max_prompt_len at session creation"
        for cfg in (eng.draft_cfg, eng.target_cfg):
            assert cfg.arch_type not in ("vlm", "encdec"), \
                "per-slot admission needs a frontend-free arch; use " \
                "admit_batch for vlm/encdec waves"
        self._state = _canon(SpecDecodeState(
            draft_cache=eng.draft.init_cache(self.capacity, self.slots_len),
            target_cache=eng.target.init_cache(self.capacity, self.slots_len),
            last_token=jnp.zeros((self.capacity,), jnp.int32),
            pos=jnp.zeros((self.capacity,), jnp.int32)))
        self._init_buffers()

    # ------------------------------------------------------------ occupancy

    @property
    def occupied(self) -> list[int]:
        return [j for j, r in enumerate(self._slots) if r is not None]

    @property
    def free(self) -> list[int]:
        return [j for j, r in enumerate(self._slots) if r is None]

    @property
    def unfinished(self) -> bool:
        return any(r is not None and not r.done for r in self._slots)

    def finished_slots(self) -> list[int]:
        return [j for j, r in enumerate(self._slots)
                if r is not None and r.done]

    def record(self, slot: int) -> Optional[SlotRecord]:
        return self._slots[slot]

    # ------------------------------------------------------------- admission

    def admit_batch(self, prompts: np.ndarray, max_new,
                    prompt_lens: Optional[np.ndarray] = None,
                    frontend=None,
                    request_ids: Optional[Sequence[int]] = None) -> list[int]:
        """Admit one full wave into a FRESH session via batched prefill.

        This is the ``generate()`` path (and the only admission path for
        frontend archs). ``max_new`` may be a scalar or a per-slot vector.
        """
        assert self._state is None and not self.occupied, \
            "admit_batch only fills a fresh session; use admit() for " \
            "in-flight admission"
        prompts = jnp.asarray(prompts, jnp.int32)
        B, S = prompts.shape
        assert B == self.capacity, (B, self.capacity)
        if self.max_prompt_len is not None:
            assert S <= self.max_prompt_len, (S, self.max_prompt_len)
            if S < self.max_prompt_len:
                if prompt_lens is None:
                    prompt_lens = np.full((B,), S, np.int32)
                prompts = jnp.pad(prompts,
                                  ((0, 0), (0, self.max_prompt_len - S)))
        else:
            self.slots_len = self._cache_len(S)

        t0 = time.perf_counter()
        self._key, kp = jax.random.split(self._key)
        pl = (None if prompt_lens is None
              else jnp.asarray(prompt_lens, jnp.int32))
        state = self.engine._prefill(prompts, self.slots_len, kp,
                                     frontend=frontend, prompt_lens=pl)
        state = _canon(state)
        self._init_buffers()
        mn = np.minimum(np.broadcast_to(np.asarray(max_new), (B,)),
                        self.max_new_cap).astype(np.int32)
        self._max_new = jnp.asarray(mn)
        self._done = jnp.zeros((B,), bool)
        self._cursor = jnp.ones((B,), jnp.int32)
        self._out_buf = self._out_buf.at[:, 0].set(state.last_token)
        self._state = jax.block_until_ready(state)
        self.prefill_s = time.perf_counter() - t0
        ids = list(request_ids) if request_ids is not None else list(range(B))
        self._slots = [SlotRecord(request_id=ids[j], max_new=int(mn[j]),
                                  admit_it=self.iterations)
                       for j in range(B)]
        return list(range(B))

    def admit(self, prompt: np.ndarray, max_new: int, request_id: int = 0,
              slot: Optional[int] = None, block: bool = True) -> int:
        """Admit one request into a free slot of a LIVE session.

        Runs the jitted prefill-insert: the prompt (right-padded to
        ``max_prompt_len``) is prefilled at batch size 1 and its cache row,
        anchor token and lifecycle entries are scattered into the chosen
        slot. The request's first token exists when this returns (with
        ``block=True``) — per-request TTFT is measured from its own
        prefill-insert, not from any wave's."""
        free = self.free
        if not free:
            raise RuntimeError("no free slot; retire a finished request first")
        j = free[0] if slot is None else slot
        assert self._slots[j] is None, f"slot {j} is occupied"
        self._ensure_state()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        P = self.max_prompt_len
        assert 1 <= prompt.size <= P, (prompt.size, P)
        padded = np.zeros((1, P), np.int32)
        padded[0, :prompt.size] = prompt
        budget = min(int(max_new), self.max_new_cap)
        insert = self.engine._insert_step(self.capacity, self.slots_len, P)
        self._key, kk = jax.random.split(self._key)
        (self._state, self._out_buf, self._cursor, self._max_new,
         self._done) = insert(
            self.engine.draft_params, self.engine.target_params,
            self._state, self._out_buf, self._cursor, self._max_new,
            self._done, jnp.asarray(padded),
            jnp.asarray([prompt.size], jnp.int32),
            jnp.asarray(j, jnp.int32), jnp.asarray(budget, jnp.int32), kk)
        if block:
            jax.block_until_ready(self._cursor)
        self._slots[j] = SlotRecord(request_id=request_id, max_new=budget,
                                    admit_it=self.iterations)
        return j

    # -------------------------------------------------------------- decode

    def run_chunk(self, policy, max_iters: Optional[int] = None,
                  q_depth: float = 0.0) -> int:
        """Dispatch up to ``sync_every`` masked steps, then sync the host:
        cursors/done flags come off-device once, acceptance bits are
        attributed to the request occupying each slot (``num_new == 0``
        rows were inactive), and window-policy features update. Returns the
        number of iterations run."""
        n = self.sync_every
        if max_iters is not None:
            n = min(n, max_iters - self.iterations)
        if n <= 0 or not self.occupied:
            return 0
        eng = self.engine
        step = eng._step_fn(self.gamma_max)
        chunk_t0 = time.perf_counter()
        chunk_gammas: list[int] = []
        for r in range(n):
            dec = policy.decide("engine", self._features(q_depth))
            gamma = min(self.gamma_max, max(1, int(dec.gamma)))
            if self.log_gamma:
                self.gamma_seq.append(gamma)
            chunk_gammas.append(gamma)
            self._key, ks = jax.random.split(self._key)
            (self._state, self._out_buf, self._cursor, self._nacc,
             self._nn, self._done) = step(
                eng.draft_params, eng.target_params, self._state, ks,
                jnp.asarray(gamma, jnp.int32), jnp.asarray(r, jnp.int32),
                self._out_buf, self._cursor, self._nacc, self._nn,
                self._max_new, self._done,
                jnp.asarray(self.eos_id, jnp.int32))
            self._gamma_prev = float(gamma)
            self.iterations += 1
        # -- sync point: one tiny host transfer per chunk -------------------
        cur = np.asarray(self._cursor)
        done = np.asarray(self._done)
        nacc = np.asarray(self._nacc[:n])
        nn = np.asarray(self._nn[:n])
        chunk_wall = time.perf_counter() - chunk_t0

        for r in range(n):
            act = nn[r] > 0
            n_act = int(act.sum())
            if n_act:
                self._alpha_recent.append(
                    float(nacc[r][act].sum()) / (chunk_gammas[r] * n_act))
                self.proposed += chunk_gammas[r] * n_act
        self.accepted += int(nacc.sum())

        chunk_tokens = 0
        for j, rec in enumerate(self._slots):
            if rec is None:
                continue
            for r in range(n):
                ne = int(nn[r, j])
                if ne > 0:
                    # n_accepted is pre-clamped to committed tokens; a
                    # reject bit exists only when a correction token was
                    # actually committed (num_new exceeded the accepted
                    # prefix without the window being fully accepted)
                    na = int(nacc[r, j])
                    rec.bits.extend([1] * na)
                    if ne > na and na < chunk_gammas[r]:
                        rec.bits.append(0)
                    rec.proposed += chunk_gammas[r]
                    rec.accepted += na
            chunk_tokens += int(cur[j]) - rec.produced
            rec.produced = int(cur[j])
            rec.done = bool(done[j])

        active_iters = int((nn > 0).sum())
        mean_tok = chunk_tokens / max(1, active_iters)
        self._tpot_recent.append((chunk_wall * 1e3 / n) / max(1.0, mean_tok))
        del self._alpha_recent[:-16], self._tpot_recent[:-16]
        self.virtual_ms += n * eng.rtt_ms + chunk_wall * 1e3
        self.decode_wall_s += chunk_wall
        return n

    def _features(self, q_depth: float) -> FeatureSnapshot:
        a = self._alpha_recent[-16:]
        t = self._tpot_recent[-16:]
        return FeatureSnapshot(
            q_depth=q_depth,
            alpha_recent=(sum(a) / len(a)) if a else 0.7,
            rtt_recent_ms=self.engine.rtt_ms,
            tpot_recent_ms=(sum(t) / len(t)) if t else 50.0,
            gamma_prev=self._gamma_prev)

    # ------------------------------------------------------------ retirement

    def retire(self, slot: int, scrub: bool = False
               ) -> tuple[np.ndarray, SlotRecord]:
        """Extract a slot's committed tokens (ONE row transfer, length from
        the per-slot cursor) and free the slot. The device row stays inert
        (``done`` masks it) until the next admission overwrites it;
        ``scrub=True`` additionally resets the row's caches immediately so
        a long-lived session holds no retired request's KV."""
        rec = self._slots[slot]
        assert rec is not None, f"slot {slot} is empty"
        n = min(rec.produced, self.max_new_cap)
        tokens = np.asarray(self._out_buf[slot])[:n].astype(np.int64)
        self._slots[slot] = None
        if scrub:
            self._state = reset_slot(self._state, slot)
        return tokens, rec

    # -------------------------------------------------------------- extract

    def snapshot(self) -> tuple[np.ndarray, GenerationStats]:
        """Wave-style extraction: the full output buffer plus engine-schema
        stats over currently-occupied slots (the ``generate()`` epilogue)."""
        tokens = np.asarray(self._out_buf).astype(np.int64) \
            if self._out_buf is not None \
            else np.empty((self.capacity, 0), np.int64)
        produced = np.array([r.produced if r else 0 for r in self._slots],
                            np.int64)
        n_occ = len(self.occupied)
        stats = GenerationStats(
            iterations=self.iterations, proposed=self.proposed,
            accepted=self.accepted,
            tokens=int(produced.sum()) - n_occ,
            prefill_s=self.prefill_s, virtual_ms=self.virtual_ms,
            acceptance_seqs=[r.bits for r in self._slots if r is not None],
            gamma_seq=list(self.gamma_seq), produced=produced)
        return tokens, stats
