"""Persistent slot-based decode session — continuous batching on real models.

:class:`DecodeSession` owns a fixed-capacity pool of batch rows ("slots")
whose KV/SSM caches, output buffers and cursors live ON DEVICE across
requests. Requests are admitted into free slots by a jitted prefill-insert
(one program per session geometry, any slot / any prompt length ≤ the pad
bound) and retired from finished slots at ``sync_every`` boundaries; the
engine's compile-once masked-γ step keeps running untouched while the
active-slot pattern changes — admission and retirement are *data*, never a
new XLA program.

Lifecycle of one slot::

    admit (prefill-insert row j)  →  decode chunks (slot active)
        →  done (budget / EOS; num_new masked to 0, row freezes)
        →  retire (tokens extracted, host record closed, slot free)
        →  admit next request (row j fully overwritten)

Invariants the tests pin down:

- a request decoded with staggered co-tenants commits the SAME greedy
  tokens as a solo :meth:`SpecDecodeEngine.generate` run (attention *and*
  SSM/hybrid families) — per-row independence of the masked step;
- retire → re-admit leaves no stale cache state (the insert overwrites the
  whole row; :func:`repro.models.kvcache.reset_slot` additionally scrubs it
  for long-lived sessions);
- the number of compiled XLA programs is constant across any
  admission/retirement pattern after warmup (one step + one insert).

``SpecDecodeEngine.generate`` is a thin one-wave wrapper over this class;
the continuous scheduler in :mod:`repro.serving.server` drives it with a
live arrival queue.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.kvcache import BlockAllocator, logical_blocks, reset_slot
# fused-mode tokens stream edge-ward one control round trip per this many
# committed tokens — the same amortization DSD-Sim's ``fused_chunk``
# charges (one shared constant so sim and real paths cannot drift)
from ..sim.network import DEFAULT_FUSED_CHUNK as FUSED_FLUSH_TOKENS
from .engine import DEFAULT_GAMMA_MAX, GenerationStats
from .specdec import SpecDecodeState
from .window import FeatureSnapshot


@dataclass
class SlotRecord:
    """Host-side bookkeeping for the request occupying one slot."""
    request_id: int
    max_new: int
    admit_it: int                    # session iteration at admission
    bits: list = field(default_factory=list)   # acceptance 0/1 stream
    produced: int = 1                # tokens in out_buf row (anchor incl.)
    proposed: int = 0
    accepted: int = 0
    done: bool = False


def _canon(tree):
    """Array-ify non-array leaves (the caches' static ``ring`` flag) so the
    first jitted call sees the same signature the step returns."""
    return jax.tree.map(
        lambda x: x if isinstance(x, jax.Array) else jnp.asarray(x), tree)


class DecodeSession:
    """Fixed-capacity slot pool over a :class:`SpecDecodeEngine`.

    ``capacity``       batch rows (the compiled batch size),
    ``max_new_cap``    output-buffer width (per-request budgets clamp to it),
    ``max_prompt_len`` pad bound for per-slot admission (``admit``); a
                       session only ever driven by ``admit_batch`` may leave
                       it None and inherits the wave's prompt width,
    ``gamma_max``      compile-once window bound (session > engine > default),
    ``sync_every``     decode iterations between host syncs — the admission/
                       retirement granularity,
    ``eos_id``         stop token (−1 disables; per-slot budgets always cap),
    ``transport``      a :class:`repro.distributed.Transport`: when set,
                       speculation rounds run as real draft→verify→verdict
                       exchanges between the engine's DraftWorker and
                       TargetWorker over this transport (colocated fused
                       step otherwise),
    ``mode_policy``    ``"auto"`` honors ``WindowDecision.mode``,
                       ``"distributed"``/``"fused"`` force one mode,
                       ``"pipeline"`` honors the decision like ``auto`` but
                       overlaps rounds: while the target verifies window k
                       the draft optimistically drafts window k+1 from its
                       own proposed continuation, rolling back on partial
                       accepts (requires a transport; γ is capped at
                       ``gamma_max − 1`` because one proposal slot is
                       reserved as the bonus-token guess the next window
                       anchors on),
    ``paged``          attention-family sides store KV in a paged block
                       pool (:class:`repro.models.kvcache.PagedAttnCache`)
                       instead of dense per-slot rows: admission reserves
                       only the blocks the request's ``prompt + budget +
                       2γ`` footprint needs and retirement frees them, so
                       pool bytes bound ADMITTED WORK, not
                       capacity × worst-case length. Greedy committed
                       tokens are bit-identical to the dense layout
                       (``kv_quantize=False``),
    ``kv_block_size``  positions per pool block,
    ``kv_pool_blocks`` physical blocks per pool (int, or
                       ``{"draft": n, "target": m}``); ``None`` sizes the
                       pool at full dense parity — no memory saving, used
                       by the bit-identity tests,
    ``kv_quantize``    int8 per-entry K/V with f32 scales (≈4× fewer pool
                       bytes, approximate attention — see README
                       “Memory & capacity”),
    ``max_branches``   opt-in to TREE speculation: > 0 compiles the
                       (γ_max, max_branches) grid-tree step and lets the
                       window policy pick a per-round branch width b ≤
                       the bound (``WindowDecision.branches``); 0 (the
                       default) keeps the linear chain path untouched.
                       ``max_branches=1`` is the degenerate tree — same
                       committed greedy tokens as the linear path.
                       Greedy-only, attention-family both sides, dense
                       KV, and mutually exclusive with pipeline mode.
    """

    def __init__(self, engine, capacity: int, max_new_cap: int,
                 max_prompt_len: Optional[int] = None,
                 gamma_max: Optional[int] = None,
                 sync_every: Optional[int] = None,
                 eos_id: int = -1, key: Optional[jax.Array] = None,
                 log_gamma: bool = True, transport=None,
                 mode_policy: str = "auto", pair_key: str = "engine",
                 paged: bool = False, kv_block_size: int = 16,
                 kv_pool_blocks: Optional[int] = None,
                 kv_quantize: bool = False,
                 max_branches: int = 0):
        self.engine = engine
        self.capacity = int(capacity)
        self.max_new_cap = int(max_new_cap)
        self.max_prompt_len = (None if max_prompt_len is None
                               else int(max_prompt_len))
        if gamma_max:
            self.gamma_max = int(gamma_max)
        elif engine.gamma_max:
            self.gamma_max = engine.gamma_max
        else:
            self.gamma_max = DEFAULT_GAMMA_MAX
        self.sync_every = max(1, int(sync_every or engine.sync_every))
        self.eos_id = -1 if eos_id is None else int(eos_id)
        self._key = key if key is not None else jax.random.PRNGKey(0)
        assert mode_policy in ("auto", "distributed", "fused",
                               "pipeline"), mode_policy
        if mode_policy == "pipeline":
            assert transport is not None, \
                "pipeline mode overlaps rounds across a transport; " \
                "colocated sessions have nothing to overlap"
            assert self.gamma_max >= 2, \
                "pipeline mode reserves one proposal slot as the bonus " \
                "guess; gamma_max must be ≥ 2"
        self.transport = transport
        self.mode_policy = mode_policy
        # the key this session presents to the window policy: adaptive
        # policies (Dynamic, AWC) keep per-key state, so a multi-pair
        # deployment sharing one policy object still gets one stabilizer
        # per draft–target pair
        self.pair_key = str(pair_key)

        # ---- tree speculation (core/tree.py) ----------------------------
        self.max_branches = int(max_branches or 0)
        self._tree_spec = None
        self._branches_eff = 1
        self._branches_prev = 1.0
        if self.max_branches:
            if mode_policy == "pipeline":
                raise ValueError(
                    "tree speculation does not compose with pipeline mode "
                    "(one in-flight window shape per exchange)")
            if engine.temperature > 0.0:
                raise ValueError("tree speculation is greedy-only "
                                 "(temperature 0)")
            if not (engine._draft_attention and engine._target_attention):
                raise ValueError("tree speculation needs attention-family "
                                 "draft and target")
            if paged:
                raise ValueError(
                    "tree speculation needs dense KV slots (the winning-"
                    "path relocation is pos_map surgery on dense rows)")
            from .tree import TreeSpec
            self._tree_spec = TreeSpec(self.gamma_max, self.max_branches)

        # ---- paged KV slot pool (models/kvcache.PagedAttnCache) ---------
        self.paged = bool(paged)
        self.kv_block_size = int(kv_block_size)
        self.kv_pool_blocks = kv_pool_blocks
        self.kv_quantize = bool(kv_quantize)
        self._paged_sides = {
            "draft": engine.draft_cfg.arch_type in ("dense", "moe"),
            "target": engine.target_cfg.arch_type in ("dense", "moe")}
        if self.paged:
            assert any(self._paged_sides.values()), \
                "paged sessions need at least one attention-family side " \
                "(recurrent state has no positions to page)"
        self._alloc: dict[str, Optional[BlockAllocator]] = {
            "draft": None, "target": None}
        self._slot_blocks: list[Optional[dict]] = [None] * self.capacity

        self.slots_len = (None if self.max_prompt_len is None
                          else self._cache_len(self.max_prompt_len))
        self._state: Optional[SpecDecodeState] = None
        self._slots: list[Optional[SlotRecord]] = [None] * self.capacity
        self._out_buf = None
        self._cursor = None
        self._max_new = None
        self._done = None
        self._nacc = None
        self._nn = None

        # engine-wide accounting / window-policy features. Feature lists
        # are bounded (only the last 16 samples feed FeatureSnapshot) and
        # gamma_seq logging is optional so a long-lived serving session
        # does not grow host state linearly in decode iterations.
        self.iterations = 0
        self.proposed = 0
        self.accepted = 0
        self.prefill_s = 0.0
        self.decode_wall_s = 0.0
        self.virtual_ms = 0.0
        self.log_gamma = bool(log_gamma)
        self.gamma_seq: list[int] = []
        self.gamma_sum = 0           # Σ effective γ over distributed rounds
        self.gamma_rounds = 0        # distributed rounds decided (O(1) mean
                                     # γ even with log_gamma off)
        self.fused_iterations = 0
        self.link_ms = 0.0               # unhidden transport delay so far
        self.pipeline_hits = 0           # optimistic windows kept
        self.pipeline_misses = 0         # optimistic windows rolled back
        self._fused_pending = 0          # fused tokens since last flush
        self._q_zero = None              # cached fused-round q placeholder
        self._alpha_recent: list[float] = []
        self._tpot_recent: list[float] = []
        self._pipe_recent: list[float] = []
        self._round_seq = 0              # wire round ids (RTT pairing)
        self._gamma_prev = 4.0

    # ------------------------------------------------------------- geometry

    def _cache_len(self, prompt_len: int) -> int:
        # 2× the window bound: a pipelined round's optimistic propose can
        # write up to gamma_max positions beyond the half-duplex high-water
        # mark. Applied to every mode so sessions that differ only in
        # mode_policy share one cache geometry (state-comparison tests and
        # jit keys line up; pos_map masking makes the headroom free).
        if self.max_branches:
            # tree rounds write the full (γ_max, b_max) grid past the
            # high-water mark: anchor + γ_max·b_max entries at slots
            # pos .. pos+T−1 (no pipelining, so the 2γ overhang shrinks
            # to γ + grid)
            return (prompt_len + self.max_new_cap + self.gamma_max
                    + self._tree_spec.n_entries + 18)
        return prompt_len + self.max_new_cap + 2 * self.gamma_max + 18

    def _n_logical(self) -> int:
        """Block-table width: logical blocks covering one slot's length."""
        return logical_blocks(self.slots_len, self.kv_block_size)

    def blocks_needed(self, prompt_len: int, max_new: int) -> int:
        """Blocks one request must reserve on each paged side: its prompt
        + clamped budget + speculative-window overhang (2γ covers the
        pipelined optimistic window; +2 the correction/bonus tokens).
        Writes past the reservation are stale speculation by construction
        and DROP harmlessly (models/kvcache.py)."""
        need = min(self.slots_len,
                   int(prompt_len) + min(int(max_new), self.max_new_cap)
                   + 2 * self.gamma_max + 2)
        return logical_blocks(need, self.kv_block_size)

    def _pool_blocks(self, side: str) -> int:
        n = self.kv_pool_blocks
        if isinstance(n, dict):
            n = n.get(side)
        # default: full dense parity (capacity × per-slot blocks) — no
        # memory saving, but functionally identical; benches size it down
        return int(n) if n else self.capacity * self._n_logical()

    def free_kv_blocks(self) -> Optional[int]:
        """Min free blocks across paged sides (None for dense sessions)."""
        if not self.paged:
            return None
        self._ensure_state()
        return min(a.free_blocks for a in self._alloc.values()
                   if a is not None)

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        """True when a free slot AND (paged) every side's reservation fits.
        The block-aware admission predicate serving uses instead of plain
        free-slot counting."""
        if not self.free:
            return False
        if not self.paged:
            return True
        self._ensure_state()
        need = self.blocks_needed(prompt_len, max_new)
        return all(a is None or a.free_blocks >= need
                   for a in self._alloc.values())

    def _init_buffers(self) -> None:
        B = self.capacity
        self._out_buf = jnp.full((B, self.max_new_cap), -1, jnp.int32)
        self._cursor = jnp.zeros((B,), jnp.int32)
        self._max_new = jnp.zeros((B,), jnp.int32)
        self._done = jnp.ones((B,), bool)          # free slots are inert
        self._nacc = jnp.zeros((self.sync_every, B), jnp.int32)
        self._nn = jnp.zeros((self.sync_every, B), jnp.int32)

    def _ensure_state(self) -> None:
        """Lazily build an all-free device state for per-slot admission."""
        if self._state is not None:
            return
        eng = self.engine
        assert self.max_prompt_len is not None, \
            "per-slot admission needs max_prompt_len at session creation"
        for cfg in (eng.draft_cfg, eng.target_cfg):
            assert cfg.arch_type not in ("vlm", "encdec"), \
                "per-slot admission needs a frontend-free arch; use " \
                "admit_batch for vlm/encdec waves"
        def make_cache(model, side):
            if self.paged and self._paged_sides[side]:
                n_blocks = self._pool_blocks(side)
                self._alloc[side] = BlockAllocator(n_blocks)
                return model.init_paged_cache(
                    self.capacity, self.slots_len, n_blocks,
                    self.kv_block_size, quantize=self.kv_quantize)
            return model.init_cache(self.capacity, self.slots_len)

        self._state = _canon(SpecDecodeState(
            draft_cache=make_cache(eng.draft, "draft"),
            target_cache=make_cache(eng.target, "target"),
            last_token=jnp.zeros((self.capacity,), jnp.int32),
            pos=jnp.zeros((self.capacity,), jnp.int32)))
        self._init_buffers()

    # ------------------------------------------------------------ occupancy

    @property
    def occupied(self) -> list[int]:
        return [j for j, r in enumerate(self._slots) if r is not None]

    @property
    def free(self) -> list[int]:
        return [j for j, r in enumerate(self._slots) if r is None]

    @property
    def unfinished(self) -> bool:
        return any(r is not None and not r.done for r in self._slots)

    def finished_slots(self) -> list[int]:
        return [j for j, r in enumerate(self._slots)
                if r is not None and r.done]

    def record(self, slot: int) -> Optional[SlotRecord]:
        return self._slots[slot]

    @property
    def mean_gamma(self) -> float:
        """Mean effective γ over distributed rounds — O(1) accumulators,
        so it is available even with ``log_gamma`` off (serving sessions)."""
        return (self.gamma_sum / self.gamma_rounds if self.gamma_rounds
                else 0.0)

    # ------------------------------------------------------------- admission

    def admit_batch(self, prompts: np.ndarray, max_new,
                    prompt_lens: Optional[np.ndarray] = None,
                    frontend=None,
                    request_ids: Optional[Sequence[int]] = None) -> list[int]:
        """Admit one full wave into a FRESH session via batched prefill.

        This is the ``generate()`` path (and the only admission path for
        frontend archs). ``max_new`` may be a scalar or a per-slot vector.
        """
        assert self._state is None and not self.occupied, \
            "admit_batch only fills a fresh session; use admit() for " \
            "in-flight admission"
        assert not self.paged, \
            "paged sessions admit per-slot (block reservations are " \
            "per-request); use admit()"
        prompts = jnp.asarray(prompts, jnp.int32)
        B, S = prompts.shape
        assert B == self.capacity, (B, self.capacity)
        if self.max_prompt_len is not None:
            assert S <= self.max_prompt_len, (S, self.max_prompt_len)
            if S < self.max_prompt_len:
                if prompt_lens is None:
                    prompt_lens = np.full((B,), S, np.int32)
                prompts = jnp.pad(prompts,
                                  ((0, 0), (0, self.max_prompt_len - S)))
        else:
            self.slots_len = self._cache_len(S)

        t0 = time.perf_counter()
        self._key, kp = jax.random.split(self._key)
        pl = (None if prompt_lens is None
              else jnp.asarray(prompt_lens, jnp.int32))
        state = self.engine._prefill(prompts, self.slots_len, kp,
                                     frontend=frontend, prompt_lens=pl)
        state = _canon(state)
        self._init_buffers()
        mn = np.minimum(np.broadcast_to(np.asarray(max_new), (B,)),
                        self.max_new_cap).astype(np.int32)
        self._max_new = jnp.asarray(mn)
        self._done = jnp.zeros((B,), bool)
        self._cursor = jnp.ones((B,), jnp.int32)
        self._out_buf = self._out_buf.at[:, 0].set(state.last_token)
        self._state = jax.block_until_ready(state)
        self.prefill_s = time.perf_counter() - t0
        ids = list(request_ids) if request_ids is not None else list(range(B))
        self._slots = [SlotRecord(request_id=ids[j], max_new=int(mn[j]),
                                  admit_it=self.iterations)
                       for j in range(B)]
        return list(range(B))

    def admit(self, prompt: np.ndarray, max_new: int, request_id: int = 0,
              slot: Optional[int] = None, block: bool = True) -> int:
        """Admit one request into a free slot of a LIVE session.

        Runs the jitted prefill-insert: the prompt (right-padded to
        ``max_prompt_len``) is prefilled at batch size 1 and its cache row,
        anchor token and lifecycle entries are scattered into the chosen
        slot. The request's first token exists when this returns (with
        ``block=True``) — per-request TTFT is measured from its own
        prefill-insert, not from any wave's."""
        free = self.free
        if not free:
            raise RuntimeError("no free slot; retire a finished request first")
        j = free[0] if slot is None else slot
        assert self._slots[j] is None, f"slot {j} is occupied"
        self._ensure_state()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        P = self.max_prompt_len
        assert 1 <= prompt.size <= P, (prompt.size, P)
        padded = np.zeros((1, P), np.int32)
        padded[0, :prompt.size] = prompt
        budget = min(int(max_new), self.max_new_cap)
        self._key, kk = jax.random.split(self._key)
        args = (self.engine.draft_params, self.engine.target_params,
                self._state, self._out_buf, self._cursor, self._max_new,
                self._done, jnp.asarray(padded),
                jnp.asarray([prompt.size], jnp.int32),
                jnp.asarray(j, jnp.int32), jnp.asarray(budget, jnp.int32),
                kk)
        if self.paged:
            blocks = self._reserve_blocks(prompt.size, budget)
            insert = self.engine._insert_step_paged(
                self.capacity, self.slots_len, P,
                blocks["draft"].shape[0], blocks["target"].shape[0])
            (self._state, self._out_buf, self._cursor, self._max_new,
             self._done) = insert(*args, jnp.asarray(blocks["draft"]),
                                  jnp.asarray(blocks["target"]))
            self._slot_blocks[j] = {
                s: [int(i) for i in ids if i >= 0]
                for s, ids in blocks.items() if ids.size}
        else:
            insert = self.engine._insert_step(self.capacity, self.slots_len,
                                              P)
            (self._state, self._out_buf, self._cursor, self._max_new,
             self._done) = insert(*args)
        if block:
            jax.block_until_ready(self._cursor)
        self._slots[j] = SlotRecord(request_id=request_id, max_new=budget,
                                    admit_it=self.iterations)
        return j

    def _reserve_blocks(self, prompt_len: int, budget: int
                        ) -> dict[str, np.ndarray]:
        """Reserve each paged side's blocks for one admission, all-or-
        nothing (checks both sides before allocating either, so a shortfall
        never leaks a half-reservation). Returns per-side block-id rows
        padded to the full table width with −1 (unreserved tail)."""
        need = self.blocks_needed(prompt_len, budget)
        n_log = self._n_logical()
        for side, a in self._alloc.items():
            if a is not None and a.free_blocks < need:
                raise RuntimeError(
                    f"insufficient free KV blocks on {side}: need {need}, "
                    f"{a.free_blocks} free of {a.n_blocks} — retire "
                    f"finished requests or grow kv_pool_blocks")
        out = {}
        for side, a in self._alloc.items():
            if a is None:
                out[side] = np.zeros((0,), np.int32)
                continue
            row = np.full((n_log,), -1, np.int32)
            row[:need] = a.alloc(need)
            out[side] = row
        return out

    # -------------------------------------------------------------- decode

    def _decide(self, policy, q_depth: float) -> tuple[int, bool]:
        """One window-policy decision → (effective γ, fused?).

        ``mode_policy`` overrides the decision's mode; a fused round runs
        with effective γ = 0 — the traced ``active_gamma`` masks the whole
        window, so nothing is accepted and the target's own next token is
        committed (a pure cloud-side autoregressive step). γ = 0 is data,
        not shape: fused/distributed switching never recompiles."""
        dec = policy.decide(self.pair_key, self._features(q_depth))
        if self.mode_policy == "fused":
            fused = True
        elif self.mode_policy == "distributed":
            fused = False
        else:                     # auto and pipeline honor the decision
            fused = dec.mode == "fused"
        # pipeline mode reserves the (γ+1)-th proposal as the bonus guess
        # the optimistic next window anchors on, so γ caps one below the
        # compiled width
        cap = (self.gamma_max - 1 if self.mode_policy == "pipeline"
               else self.gamma_max)
        gamma_eff = 0 if fused else min(cap, max(1, int(dec.gamma)))
        # tree sessions additionally honor the decision's branch width,
        # clamped to the compiled bound; linear sessions pin b = 1 so a
        # tree-aware policy driving a linear session stays harmless
        if self.max_branches and not fused:
            self._branches_eff = min(self.max_branches,
                                     max(1, int(getattr(dec, "branches", 1))))
        else:
            self._branches_eff = 1
        self._branches_prev = float(self._branches_eff)
        if self.log_gamma:
            self.gamma_seq.append(1 if fused else gamma_eff)
        if fused:
            self.fused_iterations += 1
        else:
            self.gamma_sum += gamma_eff
            self.gamma_rounds += 1
        self._gamma_prev = 1.0 if fused else float(gamma_eff)
        return gamma_eff, fused

    def run_chunk(self, policy, max_iters: Optional[int] = None,
                  q_depth: float = 0.0) -> int:
        """Dispatch up to ``sync_every`` speculation rounds, then sync the
        host: cursors/done flags come off-device once, acceptance bits are
        attributed to the request occupying each slot (``num_new == 0``
        rows were inactive), and window-policy features update. Returns the
        number of iterations run.

        With a ``transport``, each round is a real draft→verify→verdict
        exchange between the engine's split workers
        (:meth:`_run_chunk_transport`); otherwise the engine's fused
        colocated step runs with ``sync_every`` iterations in flight.
        Both paths honor ``WindowDecision.mode`` — a fused decision
        commits target-only tokens (the colocated step still pays the
        draft proposal compute, which is masked dead weight there; the
        transport path skips the draft and the round trip entirely).
        ``mode_policy="pipeline"`` overlaps consecutive distributed rounds
        over the full-duplex transport (:meth:`_run_chunk_pipeline`); the
        half-duplex exchange stays the default."""
        if self.transport is not None:
            if self.mode_policy == "pipeline":
                return self._run_chunk_pipeline(policy, max_iters, q_depth)
            return self._run_chunk_transport(policy, max_iters, q_depth)
        n = self.sync_every
        if max_iters is not None:
            n = min(n, max_iters - self.iterations)
        if n <= 0 or not self.occupied:
            return 0
        eng = self.engine
        tree = bool(self.max_branches)
        step = (eng._tree_step(self.gamma_max, self.max_branches) if tree
                else eng._step_fn(self.gamma_max))
        chunk_t0 = time.perf_counter()
        chunk_gammas: list[int] = []
        for r in range(n):
            gamma, _fused = self._decide(policy, q_depth)
            chunk_gammas.append(gamma)
            self._key, ks = jax.random.split(self._key)
            if tree:
                # γ = 0 (fused decision) masks every non-anchor node:
                # only the target's own next token commits, same as the
                # linear step's fused round
                (self._state, self._out_buf, self._cursor, self._nacc,
                 self._nn, self._done) = step(
                    eng.draft_params, eng.target_params, self._state, ks,
                    jnp.asarray(gamma, jnp.int32),
                    jnp.asarray(self._branches_eff, jnp.int32),
                    jnp.asarray(r, jnp.int32),
                    self._out_buf, self._cursor, self._nacc, self._nn,
                    self._max_new, self._done,
                    jnp.asarray(self.eos_id, jnp.int32))
            else:
                (self._state, self._out_buf, self._cursor, self._nacc,
                 self._nn, self._done) = step(
                    eng.draft_params, eng.target_params, self._state, ks,
                    jnp.asarray(gamma, jnp.int32), jnp.asarray(r, jnp.int32),
                    self._out_buf, self._cursor, self._nacc, self._nn,
                    self._max_new, self._done,
                    jnp.asarray(self.eos_id, jnp.int32))
            self.iterations += 1
        self._sync_and_attribute(n, chunk_gammas, chunk_t0,
                                 non_target_ms=0.0,
                                 colocated_rtt_ms=eng.rtt_ms)
        return n

    def _verify_commit_round(self, tw, window_np: np.ndarray, gamma: int,
                             row_idx: int, q_probs, sampled: bool, key):
        """Run the TargetWorker's verify/commit program on one window
        against the session's ground-truth target-side buffers (cache,
        output buffer, cursors, lifecycle flags — all updated in place).
        Shared by the half-duplex and pipelined transport paths."""
        eng = self.engine
        state = self._state
        args = [tw.params, state.target_cache, jnp.asarray(window_np),
                state.pos, jnp.asarray(gamma, jnp.int32), key]
        if sampled:
            if q_probs is None:       # fused round: q is never read
                if self._q_zero is None:
                    self._q_zero = jnp.zeros(
                        (self.capacity, self.gamma_max, eng.draft_cfg.vocab),
                        jnp.float32)
                q_probs = self._q_zero
            args.append(q_probs)
        (tcache, new_pos, new_last, self._out_buf, self._cursor,
         self._nacc, self._nn, self._done, num_new_dev, nacc_dev,
         next_raw) = tw.verify_commit(self.gamma_max)(
            *args, self._out_buf, self._cursor, self._nacc, self._nn,
            self._max_new, self._done,
            jnp.asarray(row_idx, jnp.int32), jnp.asarray(self.eos_id,
                                                         jnp.int32))
        return tcache, new_pos, new_last, num_new_dev, nacc_dev, next_raw

    def _verify_commit_tree_round(self, tw, tree_np: np.ndarray, gamma: int,
                                  branches: int, row_idx: int):
        """Tree analogue of :meth:`_verify_commit_round`: one ancestor-
        masked verify pass + longest-accepted-root-path verdict + winning-
        path KV relocation on the target cache. Returns the winning path
        too — the draft side relocates its propose cache with it."""
        state = self._state
        (tcache, new_pos, new_last, self._out_buf, self._cursor,
         self._nacc, self._nn, self._done, num_new_dev, nacc_dev,
         next_raw, path_dev) = tw.verify_commit_tree(
            self.gamma_max, self.max_branches)(
            tw.params, state.target_cache, jnp.asarray(tree_np), state.pos,
            jnp.asarray(gamma, jnp.int32), jnp.asarray(branches, jnp.int32),
            self._out_buf, self._cursor, self._nacc, self._nn,
            self._max_new, self._done, jnp.asarray(row_idx, jnp.int32),
            jnp.asarray(self.eos_id, jnp.int32))
        return (tcache, new_pos, new_last, num_new_dev, nacc_dev, next_raw,
                path_dev)

    def _fused_round(self, dw, tw, row_idx: int, sampled: bool, key) -> float:
        """One fused (cloud-only) round over the transport: γ = 0 verify
        commits the target's own next token, the draft ingests it so its
        cache stays coherent for a later distributed round, and tokens
        stream edge-ward one control round trip per ``FUSED_FLUSH_TOKENS``
        committed tokens — the same per-chunk amortization DSD-Sim charges
        (``fused_chunk``; per-request streams overlap on the link in the
        sim, so batch-level amortization approximates their wall-clock
        cost). Returns the unhidden link delay imposed by stream flushes."""
        state = self._state
        window_np = np.zeros((self.capacity, self.gamma_max + 1), np.int32)
        window_np[:, 0] = np.asarray(state.last_token)
        (tcache, new_pos, new_last, num_new_dev, _nacc, _next) = \
            self._verify_commit_round(tw, window_np, 0, row_idx, None,
                                      sampled, key)
        dcache = dw.ingest()(dw.params, state.draft_cache, state.last_token,
                             state.pos, num_new_dev)
        link_ms = 0.0
        self._fused_pending += int(np.asarray(num_new_dev).sum())
        while self._fused_pending >= FUSED_FLUSH_TOKENS:
            link_ms += self.transport.control_roundtrip()
            self._fused_pending -= FUSED_FLUSH_TOKENS
        self._state = SpecDecodeState(draft_cache=dcache, target_cache=tcache,
                                      last_token=new_last, pos=new_pos)
        return link_ms

    def _run_chunk_transport(self, policy, max_iters: Optional[int],
                             q_depth: float) -> int:
        """Up to ``sync_every`` HALF-DUPLEX speculation rounds over the
        transport (the default exchange; ``mode_policy="pipeline"`` routes
        to :meth:`_run_chunk_pipeline` instead).

        Per distributed round: the DraftWorker proposes γ_max tokens, the
        token ids materialize on the host and cross the transport as a
        :class:`~repro.distributed.wire.WindowMsg` (paying the link's
        imposed delay), the TargetWorker verifies/commits, and the
        :class:`~repro.distributed.wire.VerdictMsg` pays the return delay.
        A fused round skips the draft and both hops
        (:meth:`_fused_round`). The per-round host sync is inherent —
        tokens must exist as bytes to cross a wire — so this path pays a
        full RTT of dead time per committed window; hiding it is exactly
        what the pipelined mode is for."""
        from ..distributed.wire import VerdictMsg, WindowMsg
        n = self.sync_every
        if max_iters is not None:
            n = min(n, max_iters - self.iterations)
        if n <= 0 or not self.occupied:
            return 0
        eng = self.engine
        dw, tw = eng.split_workers()
        G = self.gamma_max
        B = self.capacity
        tr = self.transport
        sampled = eng.temperature > 0.0
        chunk_t0 = time.perf_counter()
        chunk_gammas: list[int] = []
        link_ms = 0.0
        draft_ms = 0.0
        done_host = np.asarray(self._done)
        it_run = 0
        for r in range(n):
            if done_host.all():
                break
            gamma, fused = self._decide(policy, q_depth)
            n_active = int(B - done_host.sum())
            self._key, ks = jax.random.split(self._key)
            kd, kv = jax.random.split(ks)
            state = self._state
            if fused:
                link_ms += self._fused_round(dw, tw, r, sampled, kv)
                done_host = np.asarray(self._done)
            elif self.max_branches:
                # tree round: the grid window crosses the wire with its
                # parent table (node-count-priced payload), the verdict
                # carries the winning path back so the draft can relocate
                # its propose cache identically to the target's commit
                b = self._branches_eff
                t_draft = time.perf_counter()
                toks, dcache_prop = dw.propose_tree(
                    self.gamma_max, self.max_branches)(
                    dw.params, state.draft_cache, state.last_token,
                    state.pos)
                toks_np = np.asarray(toks)
                draft_ms += (time.perf_counter() - t_draft) * 1e3
                rid = self._round_seq
                self._round_seq += 1
                msg = WindowMsg(tokens=toks_np, gamma=gamma,
                                n_active=n_active, round_id=rid,
                                n_nodes=toks_np.shape[1], branches=b,
                                parent=self._tree_spec.parent_np)
                link_ms += tr.send_window(msg)
                (tcache, new_pos, new_last, num_new_dev, nacc_dev,
                 next_raw, path_dev) = self._verify_commit_tree_round(
                    tw, msg.tokens, gamma, b, r)
                done_host = np.asarray(self._done)
                verdict = VerdictMsg(
                    n_accepted=np.asarray(nacc_dev),
                    num_new=np.asarray(num_new_dev),
                    next_token=np.asarray(next_raw),
                    last_token=np.asarray(new_last),
                    done=done_host, gamma=gamma, n_active=n_active,
                    round_id=rid, path=np.asarray(path_dev))
                link_ms += tr.send_verdict(verdict)
                dcache = dw.ingest_tree(self.gamma_max, self.max_branches)(
                    dcache_prop, state.pos, jnp.asarray(verdict.path),
                    jnp.asarray(verdict.n_accepted))
                self._state = SpecDecodeState(
                    draft_cache=dcache, target_cache=tcache,
                    last_token=new_last, pos=new_pos)
            else:
                # timing the propose dispatch through the host materialize
                # isolates the draft's serial scan — excluded from the
                # TPOT feature like the sim excludes its draft time
                t_draft = time.perf_counter()
                toks, q_probs, dcache_prop = dw.propose(G)(
                    dw.params, state.draft_cache, state.last_token,
                    state.pos, kd)
                toks_np = np.asarray(toks)
                draft_ms += (time.perf_counter() - t_draft) * 1e3
                rid = self._round_seq
                self._round_seq += 1
                msg = WindowMsg(tokens=toks_np, gamma=gamma,
                                n_active=n_active,
                                q_probs=q_probs if sampled else None,
                                round_id=rid)
                link_ms += tr.send_window(msg)
                window_np = np.concatenate(
                    [np.asarray(state.last_token)[:, None], msg.tokens],
                    axis=1)
                (tcache, new_pos, new_last, num_new_dev, nacc_dev,
                 next_raw) = self._verify_commit_round(
                    tw, window_np, gamma, r,
                    q_probs if sampled else None, sampled, kv)
                done_host = np.asarray(self._done)
                verdict = VerdictMsg(
                    n_accepted=np.asarray(nacc_dev),
                    num_new=np.asarray(num_new_dev),
                    next_token=np.asarray(next_raw),
                    last_token=np.asarray(new_last),
                    done=done_host, gamma=gamma, n_active=n_active,
                    round_id=rid)
                link_ms += tr.send_verdict(verdict)
                if dw.attention:
                    dcache = dcache_prop   # pos_map masks the stale tail
                else:
                    # recurrent draft: re-advance the pre-window checkpoint
                    # over the committed prefix. The correction token never
                    # enters the advance (it is committed at position
                    # pos+num_new−1 and only processed by the NEXT round),
                    # so the [anchor, proposals] window is the advance input.
                    dcache = dw.advance(G)(dw.params, state.draft_cache,
                                           jnp.asarray(window_np),
                                           state.pos, num_new_dev)
                self._state = SpecDecodeState(
                    draft_cache=dcache, target_cache=tcache,
                    last_token=new_last, pos=new_pos)
            chunk_gammas.append(gamma)
            self.iterations += 1
            it_run += 1
        if it_run == 0:
            return 0
        if self._fused_pending and done_host.all():
            # the batch drained: flush the sub-chunk tail of fused tokens
            # so short fused outputs still pay their stream delivery (a
            # session abandoned mid-stream drains the tail in snapshot())
            link_ms += tr.control_roundtrip()
            self._fused_pending = 0
        self.link_ms += link_ms
        # the TPOT feature tracks TARGET service time: subtract the
        # measured draft proposal time and the link delay (only when the
        # transport really slept it into wall time — a non-sleeping
        # transport's delay goes to the virtual clock instead)
        self._sync_and_attribute(
            it_run, chunk_gammas, chunk_t0,
            non_target_ms=draft_ms + (link_ms if tr.wall_clock else 0.0),
            virtual_extra_ms=0.0 if tr.wall_clock else link_ms)
        return it_run

    # ----------------------------------------------------- pipelined decode

    def _make_window(self, dw, state: SpecDecodeState, gamma: int,
                     done_host: np.ndarray, cursor_host: np.ndarray,
                     speculative: bool) -> dict:
        """Propose one speculation window from ``state``, post it on the
        transport, and precompute BOTH resolutions of its verdict:

        - the OPTIMISTIC post-round state (all ``gamma`` proposals
          accepted, bonus token = the reserved (γ+1)-th proposal the draft
          would anchor its next window on), including a host mirror of
          :func:`repro.core.specdec.slot_stop_mask` so budget/EOS clamps
          are predicted exactly — a verdict matching the predicted
          ``(num_new, last_token, done)`` triple implies the optimistic
          draft state is bitwise the committed one (the draft's cache
          advance only ever consumes the anchor + accepted window prefix,
          all of which the triple pins down);
        - the ROLLBACK materials (pre-window checkpoint + window) that
          reconstruct the exact half-duplex post-verdict state on a miss.
        """
        from ..distributed.wire import WindowMsg
        eng = self.engine
        G = self.gamma_max
        B = self.capacity
        sampled = eng.temperature > 0.0
        max_new_host = np.asarray(self._max_new)
        t0 = time.perf_counter()
        self._key, kd = jax.random.split(self._key)
        toks, q_probs, dcache_prop = dw.propose(G)(
            dw.params, state.draft_cache, state.last_token, state.pos, kd)
        toks_np = np.asarray(toks)
        last_host = np.asarray(state.last_token)
        window_np = np.concatenate([last_host[:, None], toks_np], axis=1)

        # -- optimistic post-round prediction (slot_stop_mask mirror) ------
        active = ~done_host
        bonus = toks_np[:, gamma]
        committed = np.full((B, G + 1), -1, np.int32)
        committed[:, :gamma] = toks_np[:, :gamma]
        committed[:, gamma] = bonus
        num_eff = np.where(
            active,
            np.minimum(gamma + 1, np.maximum(0, max_new_host - cursor_host)),
            0).astype(np.int32)
        eos = self.eos_id
        ar = np.arange(G + 1)[None, :]
        is_eos = (committed == eos) & (ar < num_eff[:, None]) & (eos >= 0)
        has_eos = is_eos.any(axis=1)
        eos_pos = is_eos.argmax(axis=1).astype(np.int32)
        num_eff = np.where(has_eos, np.minimum(num_eff, eos_pos + 1),
                           num_eff).astype(np.int32)
        done_opt = done_host | (cursor_host + num_eff >= max_new_host) \
            | has_eos
        last_opt = np.where(done_host, last_host, bonus).astype(np.int32)
        num_eff_dev = jnp.asarray(num_eff)
        if dw.attention:
            opt_cache = dcache_prop    # pos_map masks the stale tail
        else:
            # recurrent draft: optimistic re-advance of the pre-window
            # checkpoint over the assumed-committed prefix — the same
            # jitted program a miss's rollback runs (zero recompiles)
            opt_cache = dw.advance(G)(dw.params, state.draft_cache,
                                      jnp.asarray(window_np), state.pos,
                                      num_eff_dev)
        draft_ms = (time.perf_counter() - t0) * 1e3

        rid = self._round_seq
        self._round_seq += 1
        msg = WindowMsg(tokens=toks_np, gamma=gamma,
                        n_active=int(B - done_host.sum()),
                        q_probs=q_probs if sampled else None,
                        round_id=rid, speculative=speculative)
        self.transport.post_window(msg)
        return dict(
            msg=msg, gamma=gamma, round_id=rid, draft_ms=draft_ms,
            q_probs=q_probs if sampled else None,
            window_dev=jnp.asarray(window_np),
            base_pos=state.pos,              # pre-window position (rollback)
            ckpt_cache=state.draft_cache,    # recurrent rollback checkpoint
            prop_cache=dcache_prop,          # attention rollback basis
            opt_state=SpecDecodeState(
                draft_cache=opt_cache, target_cache=None,
                last_token=jnp.asarray(last_opt),
                pos=state.pos + num_eff_dev),
            opt_num_new=num_eff, opt_done=done_opt, opt_last=last_opt)

    def _run_chunk_pipeline(self, policy, max_iters: Optional[int],
                            q_depth: float) -> int:
        """Up to ``sync_every`` CROSS-ROUND PIPELINED speculation rounds:
        while the target verifies window k, the draft optimistically
        drafts window k+1 from its own proposed continuation and posts it
        speculatively on the full-duplex transport, so the draft scan and
        the window's outbound hop overlap window k's verification and
        verdict flight instead of serializing after them.

        On verdict arrival the optimistic prediction is checked against
        the actual ``(num_new, last_token, done)`` triple: a HIT keeps the
        pipelined window (it becomes the in-flight exchange — its verify
        starts without waiting a draft scan + upload); a MISS (partial or
        zero accept, bonus-token mismatch, or a mispredicted budget/EOS
        stop) discards the in-flight window unverified and rolls the
        draft's recurrent/KV state back to the commit point — attention
        drafts reuse the kept pre-speculation propose cache, recurrent
        drafts re-advance the pre-window checkpoint, both bitwise equal to
        the half-duplex state (at temperature 0 committed tokens are
        bit-identical to the half-duplex path by construction: the target
        only ever verifies windows whose anchor matches its committed
        prefix). In-flight speculation never crosses a chunk boundary, so
        admissions/retirements at ``sync_every`` granularity can never
        invalidate a window the transport still carries."""
        n = self.sync_every
        if max_iters is not None:
            n = min(n, max_iters - self.iterations)
        if n <= 0 or not self.occupied:
            return 0
        from ..distributed.wire import VerdictMsg
        eng = self.engine
        dw, tw = eng.split_workers()
        G = self.gamma_max
        tr = self.transport
        sampled = eng.temperature > 0.0
        chunk_t0 = time.perf_counter()
        chunk_gammas: list[int] = []
        link_ms = 0.0
        draft_ms = 0.0
        done_host = np.asarray(self._done)
        cursor_host = np.asarray(self._cursor).copy()
        it_run = 0
        pending = None   # posted window whose verdict is outstanding
        carry = None     # (γ, fused) decided during the previous flight
        while it_run < n and not done_host.all():
            if pending is None:
                gamma, fused = (carry if carry is not None
                                else self._decide(policy, q_depth))
                carry = None
                if fused:
                    self._key, kf = jax.random.split(self._key)
                    link_ms += self._fused_round(dw, tw, it_run, sampled, kf)
                    done_host = np.asarray(self._done)
                    # the fused round advanced the device cursors: refresh
                    # the host mirror or later optimistic budget/EOS
                    # predictions in this chunk would run understated and
                    # force spurious rollbacks near the budget edge
                    cursor_host = np.asarray(self._cursor).copy()
                    chunk_gammas.append(0)
                    self.iterations += 1
                    it_run += 1
                    continue
                pending = self._make_window(dw, self._state, gamma,
                                            done_host, cursor_host,
                                            speculative=False)
                draft_ms += pending["draft_ms"]

            # -- target: receive + verify the in-flight window ------------
            wmsg, waited = tr.recv_window()
            link_ms += waited
            window_np = np.concatenate(
                [np.asarray(self._state.last_token)[:, None], wmsg.tokens],
                axis=1)
            self._key, kv = jax.random.split(self._key)
            (tcache, new_pos, new_last, num_new_dev, nacc_dev, next_raw) = \
                self._verify_commit_round(tw, window_np, wmsg.gamma, it_run,
                                          pending["q_probs"], sampled, kv)
            verdict = VerdictMsg(
                n_accepted=np.asarray(nacc_dev),
                num_new=np.asarray(num_new_dev),
                next_token=np.asarray(next_raw),
                last_token=np.asarray(new_last),
                done=np.asarray(self._done), gamma=wmsg.gamma,
                n_active=wmsg.n_active, round_id=wmsg.round_id)
            tr.post_verdict(verdict)

            # -- draft: speculate window k+1 while the verdict flies -------
            spec = None
            if it_run + 1 < n and not pending["opt_done"].all():
                gamma2, fused2 = self._decide(policy, q_depth)
                if fused2:
                    carry = (gamma2, fused2)   # fused runs unpipelined
                else:
                    spec = self._make_window(
                        dw, pending["opt_state"], gamma2,
                        pending["opt_done"],
                        cursor_host + pending["opt_num_new"],
                        speculative=True)
                    draft_ms += spec["draft_ms"]

            # -- resolve the verdict --------------------------------------
            _vmsg, waited = tr.recv_verdict()
            link_ms += waited
            hit = (np.array_equal(verdict.num_new, pending["opt_num_new"])
                   and np.array_equal(verdict.done, pending["opt_done"])
                   and np.array_equal(verdict.last_token,
                                      pending["opt_last"]))
            if hit:
                self.pipeline_hits += 1
                self._pipe_recent.append(1.0)
                dcache = pending["opt_state"].draft_cache
            else:
                self.pipeline_misses += 1
                self._pipe_recent.append(0.0)
                if dw.attention:
                    dcache = pending["prop_cache"]
                else:
                    dcache = dw.advance(G)(dw.params, pending["ckpt_cache"],
                                           pending["window_dev"],
                                           pending["base_pos"], num_new_dev)
            self._state = SpecDecodeState(
                draft_cache=dcache, target_cache=tcache,
                last_token=new_last, pos=new_pos)
            done_host = verdict.done
            cursor_host = cursor_host + verdict.num_new
            chunk_gammas.append(wmsg.gamma)
            self.iterations += 1
            it_run += 1
            if hit and spec is not None:
                pending = spec            # the pipelined window is live
            else:
                if spec is not None:      # late verdict invalidates it
                    tr.discard_window()
                    # the re-draft reuses the invalidated window's γ
                    # decision (it was made pre-verdict — that is what
                    # pipelining means), keeping policy calls and
                    # gamma_seq 1:1 with committed rounds
                    carry = (spec["gamma"], False)
                pending = None
        if carry is not None:
            # a decision was made for a round that never ran (the batch
            # drained or the chunk ended first): unwind its bookkeeping
            if carry[1]:
                self.fused_iterations -= 1
            else:
                self.gamma_sum -= carry[0]
                self.gamma_rounds -= 1
            if self.log_gamma and self.gamma_seq:
                self.gamma_seq.pop()
        if it_run == 0:
            return 0
        if self._fused_pending and done_host.all():
            link_ms += tr.control_roundtrip()
            self._fused_pending = 0
        self.link_ms += link_ms
        del self._pipe_recent[:-16]
        self._sync_and_attribute(
            it_run, chunk_gammas, chunk_t0,
            non_target_ms=draft_ms + (link_ms if tr.wall_clock else 0.0),
            virtual_extra_ms=0.0 if tr.wall_clock else link_ms)
        return it_run

    def _sync_and_attribute(self, n: int, chunk_gammas: list[int],
                            chunk_t0: float, non_target_ms: float,
                            virtual_extra_ms: float = 0.0,
                            colocated_rtt_ms: float = 0.0) -> None:
        """Chunk epilogue shared by the colocated and transport paths: one
        host transfer of cursors/flags/stat rows, per-request acceptance
        attribution, window-policy feature update. ``chunk_gammas`` holds
        the EFFECTIVE per-round γ (0 for fused rounds, which propose
        nothing — their commits enter token counts but not acceptance
        stats). ``non_target_ms`` (imposed link delay + measured draft
        proposal time) is excluded from the TPOT feature so it tracks
        target service time, matching what DSD-Sim's analyzer feeds AWC;
        the link shows up in ``rtt_recent_ms`` instead.

        Virtual-clock network accounting: the transport path passes its
        imposed-but-not-slept delay as ``virtual_extra_ms``; the colocated
        path passes ``colocated_rtt_ms`` and is billed one RTT per
        distributed round plus the per-token amortized stream flush for
        fused commits — the same charges the transport path and DSD-Sim
        make, so ``virtual_ms`` stays comparable across paths."""
        cur = np.asarray(self._cursor)
        done = np.asarray(self._done)
        nacc = np.asarray(self._nacc[:n])
        nn = np.asarray(self._nn[:n])
        # wall time is measured AFTER the blocking host transfers above:
        # the colocated loop dispatches its jitted steps asynchronously,
        # so the chunk's device compute only completes here
        chunk_wall = time.perf_counter() - chunk_t0

        for r in range(n):
            act = nn[r] > 0
            n_act = int(act.sum())
            if n_act and chunk_gammas[r] > 0:
                self._alpha_recent.append(
                    float(nacc[r][act].sum()) / (chunk_gammas[r] * n_act))
                self.proposed += chunk_gammas[r] * n_act
        self.accepted += int(nacc.sum())

        chunk_tokens = 0
        for j, rec in enumerate(self._slots):
            if rec is None:
                continue
            for r in range(n):
                ne = int(nn[r, j])
                if ne > 0 and chunk_gammas[r] > 0:
                    # n_accepted is pre-clamped to committed tokens; a
                    # reject bit exists only when a correction token was
                    # actually committed (num_new exceeded the accepted
                    # prefix without the window being fully accepted)
                    na = int(nacc[r, j])
                    rec.bits.extend([1] * na)
                    if ne > na and na < chunk_gammas[r]:
                        rec.bits.append(0)
                    rec.proposed += chunk_gammas[r]
                    rec.accepted += na
            chunk_tokens += int(cur[j]) - rec.produced
            rec.produced = int(cur[j])
            rec.done = bool(done[j])

        active_iters = int((nn > 0).sum())
        mean_tok = chunk_tokens / max(1, active_iters)
        compute_ms = max(0.0, chunk_wall * 1e3 - non_target_ms)
        self._tpot_recent.append((compute_ms / n) / max(1.0, mean_tok))
        del self._alpha_recent[:-16], self._tpot_recent[:-16]
        if colocated_rtt_ms > 0.0:
            n_dist = sum(1 for g in chunk_gammas if g > 0)
            fused_tokens = int(sum(nn[r].sum() for r in range(n)
                                   if chunk_gammas[r] == 0))
            virtual_extra_ms += colocated_rtt_ms * (
                n_dist + fused_tokens / FUSED_FLUSH_TOKENS)
        self.virtual_ms += virtual_extra_ms + chunk_wall * 1e3
        self.decode_wall_s += chunk_wall

    def _features(self, q_depth: float) -> FeatureSnapshot:
        a = self._alpha_recent[-16:]
        t = self._tpot_recent[-16:]
        p = self._pipe_recent[-16:]
        if self.transport is not None:
            rtt = self.transport.recent_rtt_ms
        else:
            rtt = self.engine.rtt_ms
        return FeatureSnapshot(
            q_depth=q_depth,
            alpha_recent=(sum(a) / len(a)) if a else 0.7,
            rtt_recent_ms=rtt,
            tpot_recent_ms=(sum(t) / len(t)) if t else 50.0,
            gamma_prev=self._gamma_prev,
            # outside pipeline mode no RTT is ever overlapped: report 0 so
            # bootstrap_gamma's overlapped-RTT term stays inert
            pipe_hit_recent=((sum(p) / len(p)) if p else 0.0)
            if self.mode_policy == "pipeline" else 0.0,
            branches_prev=self._branches_prev if self.max_branches else 1.0)

    # ------------------------------------------------------------ retirement

    def retire(self, slot: int, scrub: bool = False
               ) -> tuple[np.ndarray, SlotRecord]:
        """Extract a slot's committed tokens (ONE row transfer, length from
        the per-slot cursor) and free the slot. The device row stays inert
        (``done`` masks it) until the next admission overwrites it;
        ``scrub=True`` additionally resets the row's caches immediately so
        a long-lived session holds no retired request's KV."""
        rec = self._slots[slot]
        assert rec is not None, f"slot {slot} is empty"
        n = min(rec.produced, self.max_new_cap)
        tokens = np.asarray(self._out_buf[slot])[:n].astype(np.int64)
        self._slots[slot] = None
        if self.paged and self._slot_blocks[slot] is not None:
            # unmap BEFORE freeing: the frozen slot still writes its masked
            # speculative window every step, and the device stream orders
            # this release ahead of any later insert that reuses the blocks
            # (see models/kvcache.py module docstring)
            release = self.engine._release_step()
            self._state = release(self._state, jnp.asarray(slot, jnp.int32))
            for side, ids in self._slot_blocks[slot].items():
                self._alloc[side].free(ids)
            self._slot_blocks[slot] = None
        if scrub:
            self._state = reset_slot(self._state, slot)
        return tokens, rec

    # -------------------------------------------------------------- extract

    def snapshot(self) -> tuple[np.ndarray, GenerationStats]:
        """Wave-style extraction: the full output buffer plus engine-schema
        stats over currently-occupied slots (the ``generate()`` epilogue).
        Drains any sub-chunk tail of fused-mode tokens still pending
        stream delivery, so sessions that stop on the iteration bound pay
        the final control round trip too."""
        if self.transport is not None and self._fused_pending:
            self.link_ms += self.transport.control_roundtrip()
            self._fused_pending = 0
        tokens = np.asarray(self._out_buf).astype(np.int64) \
            if self._out_buf is not None \
            else np.empty((self.capacity, 0), np.int64)
        produced = np.array([r.produced if r else 0 for r in self._slots],
                            np.int64)
        n_occ = len(self.occupied)
        stats = GenerationStats(
            iterations=self.iterations, proposed=self.proposed,
            accepted=self.accepted,
            tokens=int(produced.sum()) - n_occ,
            prefill_s=self.prefill_s, virtual_ms=self.virtual_ms,
            acceptance_seqs=[r.bits for r in self._slots if r is not None],
            gamma_seq=list(self.gamma_seq), produced=produced,
            pipeline_hits=self.pipeline_hits,
            pipeline_misses=self.pipeline_misses)
        return tokens, stats
