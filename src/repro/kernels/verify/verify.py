"""Pallas TPU kernels for fused speculative-window verification.

The verification hot-spot streams the (γ, V) target/draft probability rows
through VMEM in 128-aligned vocab tiles (V is 100k–256k for the assigned
archs — far beyond VMEM, so HBM→VMEM tiling is mandatory). Two passes:

- :func:`gather_reduce_kernel` — one sweep over (p, q): gathers p/q at the
  draft-token ids (one-hot compare against an in-tile iota, no dynamic HBM
  gathers — TPU-friendly) and reduces the per-position residual mass
  Σ_v max(p−q, 0).
- :func:`cdf_sample_kernel` — a second sweep over the *single* selected row
  per sequence (scalar-prefetch row index): running-cumsum inverse-CDF
  threshold crossing, emitting the corrected/bonus token.

Elementwise/VPU-bound (no MXU): block shapes keep the lane dimension at a
multiple of 128 and the sublane at γ(+1) rows. The GPU version of this op
materializes full (B, γ, V) residual tensors; the TPU adaptation never
materializes them in HBM (DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from .. import resolve_interpret, tpu_compiler_params

VOCAB_TILE = 512


def gather_reduce_kernel(tokens_ref, p_ref, q_ref,
                         p_at_ref, q_at_ref, mass_ref,
                         acc_p, acc_q, acc_m):
    """Grid (B, V/TV); accumulates across vocab tiles in VMEM scratch.

    tokens: (1, γ) i32 | p: (1, γ+1, TV) | q: (1, γ, TV)
    outputs (written at the last tile): p_at/q_at/mass (1, γ).
    """
    vt = pl.program_id(1)

    @pl.when(vt == 0)
    def _init():
        acc_p[...] = jnp.zeros_like(acc_p)
        acc_q[...] = jnp.zeros_like(acc_q)
        acc_m[...] = jnp.zeros_like(acc_m)

    tv = p_ref.shape[-1]
    gamma = q_ref.shape[1]
    base = vt * tv
    vocab_ids = base + jax.lax.broadcasted_iota(jnp.int32, (gamma, tv), 1)
    tok = tokens_ref[0, :][:, None]                     # (γ, 1)
    onehot = (vocab_ids == tok)                         # (γ, TV)

    p = p_ref[0, :gamma, :].astype(jnp.float32)         # (γ, TV)
    q = q_ref[0, :, :].astype(jnp.float32)              # (γ, TV)
    acc_p[...] += jnp.sum(jnp.where(onehot, p, 0.0), axis=-1)
    acc_q[...] += jnp.sum(jnp.where(onehot, q, 0.0), axis=-1)
    acc_m[...] += jnp.sum(jnp.maximum(p - q, 0.0), axis=-1)

    @pl.when(vt == pl.num_programs(1) - 1)
    def _done():
        p_at_ref[0, :] = acc_p[...]
        q_at_ref[0, :] = acc_q[...]
        mass_ref[0, :] = acc_m[...]


def cdf_sample_kernel(jrow_ref, qrow_ref, use_p_ref,     # scalar prefetch
                      p_ref, q_ref, thresh_ref,
                      token_ref, cum, found):
    """Grid (B, V/TV); inverse-CDF over the selected distribution row.

    p: (1, 1, TV) — row jrow[b] via scalar-prefetch index map
    q: (1, 1, TV) — row qrow[b]
    thresh: (1, 1) f32 — r·mass, precomputed by ops glue
    token out: (1, 1) i32
    """
    b = pl.program_id(0)
    vt = pl.program_id(1)
    tv = p_ref.shape[-1]

    @pl.when(vt == 0)
    def _init():
        cum[...] = jnp.zeros_like(cum)
        found[...] = jnp.full_like(found, -1)

    p = p_ref[0, 0, :].astype(jnp.float32)
    q = q_ref[0, 0, :].astype(jnp.float32)
    dist = jnp.where(use_p_ref[b] > 0, p, jnp.maximum(p - q, 0.0))
    local_cdf = jnp.cumsum(dist) + cum[0, 0]
    hit = local_cdf > thresh_ref[0, 0]
    any_hit = jnp.any(hit)
    local_idx = jnp.argmax(hit).astype(jnp.int32)

    @pl.when((found[0, 0] < 0) & any_hit)
    def _record():
        found[0, 0] = vt * tv + local_idx

    cum[0, 0] = local_cdf[-1]

    @pl.when(vt == pl.num_programs(1) - 1)
    def _done():
        # degenerate all-zero distribution → clamp to the final vocab id
        token_ref[0, 0] = jnp.where(found[0, 0] < 0,
                                    pl.num_programs(1) * tv - 1,
                                    found[0, 0])


def gather_reduce_call(tokens, p, q, tile: int = VOCAB_TILE,
                       interpret=None):
    interpret = resolve_interpret(interpret)  # None → compiled on TPU only
    B, gamma = tokens.shape
    V = p.shape[-1]
    assert V % tile == 0, "ops.py pads the vocab to the tile size"
    grid = (B, V // tile)
    return pl.pallas_call(
        gather_reduce_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, gamma), lambda b, v: (b, 0)),
            pl.BlockSpec((1, gamma + 1, tile), lambda b, v: (b, 0, v)),
            pl.BlockSpec((1, gamma, tile), lambda b, v: (b, 0, v)),
        ],
        out_specs=[
            pl.BlockSpec((1, gamma), lambda b, v: (b, 0)),
            pl.BlockSpec((1, gamma), lambda b, v: (b, 0)),
            pl.BlockSpec((1, gamma), lambda b, v: (b, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, gamma), jnp.float32)] * 3,
        scratch_shapes=[pltpu.VMEM((gamma,), jnp.float32)] * 3,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tokens, p, q)


def cdf_sample_call(jrow, qrow, use_p, p, q, thresh, tile: int = VOCAB_TILE,
                    interpret=None):
    interpret = resolve_interpret(interpret)
    B = jrow.shape[0]
    V = p.shape[-1]
    assert V % tile == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, V // tile),
        in_specs=[
            pl.BlockSpec((1, 1, tile), lambda b, v, jr, qr, up: (b, jr[b], v)),
            pl.BlockSpec((1, 1, tile), lambda b, v, jr, qr, up: (b, qr[b], v)),
            pl.BlockSpec((1, 1), lambda b, v, jr, qr, up: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, v, jr, qr, up: (b, 0)),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.int32)],
    )
    return pl.pallas_call(
        cdf_sample_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        interpret=interpret,
    )(jrow, qrow, use_p, p, q, thresh)
