from .ops import verify_window_fused
from .ref import VerifyOut, verify_reference
