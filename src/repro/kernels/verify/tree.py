"""Pallas TPU kernels for fused tree-verify (greedy, grid-family trees).

The tree verify hot-spot is extracting the target argmax at every tree
entry from the (B, T, V) logits of the single masked pass — V is far
beyond VMEM, so the vocab streams through in 128-aligned tiles exactly
like the linear verify kernels. Two passes:

- :func:`tree_argmax_kernel` — one sweep over the vocab per (batch,
  entry) row keeping a running (max, argmax) pair in VMEM scratch.
  Cross-tile ties break toward the LOWER vocab id (strict ``>`` update;
  in-tile ``argmax`` already ties-to-first) so the kernel matches
  ``jnp.argmax`` bit-for-bit — the contract
  :func:`repro.core.tree.verify_tree_greedy` is written against.
- :func:`tree_accept_kernel` — the longest-accepted-root-path rule on
  the (T,) target tokens: parent gathers become one-hot compares against
  an in-tile iota (no dynamic indexing), the ancestor-AND becomes a
  masked violation count over the (T, T) bitmap, and the winner/bonus
  come out of a one-hot reduction. All O(T²) on T = 1 + d_max·b_max ≤ a
  few dozen — pure VPU work on a single VMEM-resident block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import resolve_interpret, tpu_compiler_params

NEG_INF = float("-inf")


def tree_argmax_kernel(p_ref, tgt_ref, acc_max, acc_idx):
    """Grid (B, V/TV); running argmax across vocab tiles in VMEM scratch.

    p: (1, T, TV) | tgt out (written at the last tile): (1, T) i32.
    """
    vt = pl.program_id(1)

    @pl.when(vt == 0)
    def _init():
        acc_max[...] = jnp.full_like(acc_max, NEG_INF)
        acc_idx[...] = jnp.zeros_like(acc_idx)

    tv = p_ref.shape[-1]
    base = vt * tv
    p = p_ref[0, :, :].astype(jnp.float32)                 # (T, TV)
    local_max = jnp.max(p, axis=-1)                        # (T,)
    local_idx = base + jnp.argmax(p, axis=-1).astype(jnp.int32)
    better = local_max > acc_max[...]                      # strict: keep
    acc_idx[...] = jnp.where(better, local_idx, acc_idx[...])  # earlier tile
    acc_max[...] = jnp.where(better, local_max, acc_max[...])  # on ties

    @pl.when(vt == pl.num_programs(1) - 1)
    def _done():
        tgt_ref[0, :] = acc_idx[...]


def tree_accept_kernel(tok_ref, tgt_ref, parent_ref, tpos_ref, valid_ref,
                       mask_ref, nacc_ref, winner_ref, bonus_ref):
    """Grid (B,); accept rule + winner selection on one sequence's tree.

    tok/tgt: (1, T) i32 | parent/tpos/valid: (1, T) i32 (shared rows) |
    mask: (T, T) i32 ancestor-or-self bitmap | outputs: (1, 1) i32 each.
    """
    T = tok_ref.shape[-1]
    tok = tok_ref[0, :]
    tgt = tgt_ref[0, :]
    parent = parent_ref[0, :]
    tpos = tpos_ref[0, :]
    valid = valid_ref[0, :] > 0
    mask = mask_ref[...] > 0                               # (T, T)

    # entry ids — 2D iota then collapse (1D iota is unsupported on TPU)
    col = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    entry = col[0, :]                                      # (T,)

    # parent gather as a one-hot reduce: row e picks column parent[e]
    onehot_parent = col == parent[:, None]
    parent_tgt = jnp.sum(jnp.where(onehot_parent, tgt[None, :], 0), axis=-1)

    match = (valid & (tok == parent_tgt)) | (entry == 0)   # anchor free
    # accept[e] = AND over ancestors-or-self of match ⇔ zero violations
    viol = jnp.sum(jnp.where(mask & (~match)[None, :], 1, 0), axis=-1)
    accept = viol == 0

    # deepest accepted entry, ties → lowest entry index (best branch)
    score = jnp.where(accept, tpos * T + (T - entry), -1)
    w = jnp.argmax(score).astype(jnp.int32)
    onehot_w = entry == w
    nacc_ref[0, 0] = jnp.sum(jnp.where(onehot_w, tpos, 0))
    winner_ref[0, 0] = w
    bonus_ref[0, 0] = jnp.sum(jnp.where(onehot_w, tgt, 0))


def tree_argmax_call(p_logits, tile: int, interpret=None):
    """(B, T, V) logits → (B, T) i32 per-entry target argmax."""
    interpret = resolve_interpret(interpret)
    B, T, V = p_logits.shape
    assert V % tile == 0, "ops.py pads the vocab to the tile size"
    return pl.pallas_call(
        tree_argmax_kernel,
        grid=(B, V // tile),
        in_specs=[pl.BlockSpec((1, T, tile), lambda b, v: (b, 0, v))],
        out_specs=pl.BlockSpec((1, T), lambda b, v: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T), jnp.int32),
        scratch_shapes=[pltpu.VMEM((T,), jnp.float32),
                        pltpu.VMEM((T,), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(p_logits)


def tree_accept_call(tree_tokens, tgt, parent, tpos, valid, mask,
                     interpret=None):
    """Per-batch accept/winner/bonus. Tree tables arrive as (1, T) /
    (T, T) i32 rows shared across the batch grid."""
    interpret = resolve_interpret(interpret)
    B, T = tree_tokens.shape
    shared = pl.BlockSpec((1, T), lambda b: (0, 0))
    outs = pl.pallas_call(
        tree_accept_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T), lambda b: (b, 0)),   # tokens
            pl.BlockSpec((1, T), lambda b: (b, 0)),   # target argmax
            shared, shared, shared,                   # parent/tpos/valid
            pl.BlockSpec((T, T), lambda b: (0, 0)),   # ancestor bitmap
        ],
        out_specs=[pl.BlockSpec((1, 1), lambda b: (b, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((B, 1), jnp.int32)] * 3,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(tree_tokens, tgt, parent, tpos, valid, mask)
    n_acc, winner, bonus = outs
    return n_acc[:, 0], winner[:, 0], bonus[:, 0]
