"""Pure-jnp oracle for the fused speculative-verification kernel.

Deterministic given explicit uniforms (u for per-position acceptance, r for
the correction/bonus sample) so kernel↔oracle comparison is exact. The
random-API wrapper in ``repro.core.specdec.verify_window`` implements the
same math; this module is the kernel's contract.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class VerifyOut(NamedTuple):
    n_accepted: jax.Array   # (B,) int32
    next_token: jax.Array   # (B,) int32
    accept_mask: jax.Array  # (B, γ) bool


def verify_reference(draft_tokens: jax.Array,   # (B, γ) int32
                     q_probs: jax.Array,        # (B, γ, V)
                     p_probs: jax.Array,        # (B, γ+1, V)
                     u: jax.Array,              # (B, γ) uniforms
                     r: jax.Array,              # (B,) uniform for resample
                     eps: float = 1e-12) -> VerifyOut:
    B, gamma = draft_tokens.shape
    V = p_probs.shape[-1]

    p_at = jnp.take_along_axis(p_probs[:, :gamma, :], draft_tokens[..., None],
                               axis=-1)[..., 0]
    q_at = jnp.take_along_axis(q_probs, draft_tokens[..., None],
                               axis=-1)[..., 0]
    accept = u < jnp.minimum(1.0, p_at / jnp.maximum(q_at, 1e-20))
    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n_acc = prefix.sum(axis=-1)

    all_acc = n_acc == gamma
    jrow = jnp.where(all_acc, gamma, n_acc)                      # p row
    qrow = jnp.minimum(jrow, gamma - 1)                          # q row
    p_j = jnp.take_along_axis(p_probs, jrow[:, None, None], axis=1)[:, 0]
    q_j = jnp.take_along_axis(q_probs, qrow[:, None, None], axis=1)[:, 0]
    residual = jnp.maximum(p_j - q_j, 0.0)
    mass = residual.sum(-1)
    use_p = all_acc | (mass <= eps)
    dist = jnp.where(use_p[:, None], p_j, residual)
    total = dist.sum(-1)

    # inverse-CDF with threshold r·total: first index where cdf > threshold
    cdf = jnp.cumsum(dist, axis=-1)
    thresh = (r * total)[:, None]
    hit = cdf > thresh
    token = jnp.argmax(hit, axis=-1)
    # degenerate all-zero dist → clamp to last index
    token = jnp.where(hit.any(-1), token, V - 1).astype(jnp.int32)
    return VerifyOut(n_accepted=n_acc.astype(jnp.int32),
                     next_token=token, accept_mask=accept)
