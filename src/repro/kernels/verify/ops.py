"""Jitted wrapper for the fused verification kernels: pad → pass A (gather +
residual reduce) → O(Bγ) acceptance glue → pass B (inverse-CDF sample)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .verify import VOCAB_TILE, cdf_sample_call, gather_reduce_call
from .ref import VerifyOut


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def verify_window_fused(draft_tokens: jax.Array,   # (B, γ) int32
                        q_probs: jax.Array,        # (B, γ, V)
                        p_probs: jax.Array,        # (B, γ+1, V)
                        u: jax.Array,              # (B, γ)
                        r: jax.Array,              # (B,)
                        tile: int = VOCAB_TILE,
                        eps: float = 1e-12,
                        interpret=None) -> VerifyOut:
    B, gamma = draft_tokens.shape
    V = p_probs.shape[-1]
    pad = (-V) % tile
    if pad:
        p_probs = jnp.pad(p_probs, ((0, 0), (0, 0), (0, pad)))
        q_probs = jnp.pad(q_probs, ((0, 0), (0, 0), (0, pad)))

    p_at, q_at, mass = gather_reduce_call(draft_tokens, p_probs, q_probs,
                                          tile, interpret=interpret)

    accept = u < jnp.minimum(1.0, p_at / jnp.maximum(q_at, 1e-20))
    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n_acc = prefix.sum(axis=-1)
    all_acc = n_acc == gamma
    jrow = jnp.where(all_acc, gamma, n_acc).astype(jnp.int32)
    qrow = jnp.minimum(jrow, gamma - 1).astype(jnp.int32)
    mass_j = jnp.take_along_axis(mass, qrow[:, None], axis=1)[:, 0]
    use_p = (all_acc | (mass_j <= eps)).astype(jnp.int32)
    total = jnp.where(use_p > 0, 1.0, mass_j)   # p rows sum to ~1
    # exact total for the use_p branch: Σ p_j — reuse pass-A trick is not
    # needed; p is a softmax output ⇒ Σ = 1 up to fp error, and the CDF clamp
    # handles the residual error at the last tile.
    thresh = (r * total)[:, None].astype(jnp.float32)

    token = cdf_sample_call(jrow, qrow, use_p, p_probs, q_probs, thresh,
                            tile, interpret=interpret)[:, 0]
    token = jnp.minimum(token, V - 1)           # strip vocab padding
    return VerifyOut(n_accepted=n_acc.astype(jnp.int32),
                     next_token=token.astype(jnp.int32),
                     accept_mask=accept)
