"""Jitted wrapper for the fused verification kernels: pad → pass A (gather +
residual reduce) → O(Bγ) acceptance glue → pass B (inverse-CDF sample)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import kernel_op
from .verify import VOCAB_TILE, cdf_sample_call, gather_reduce_call
from .tree import tree_accept_call, tree_argmax_call
from .ref import VerifyOut


@kernel_op("tile")
def verify_window_fused(draft_tokens: jax.Array,   # (B, γ) int32
                        q_probs: jax.Array,        # (B, γ, V)
                        p_probs: jax.Array,        # (B, γ+1, V)
                        u: jax.Array,              # (B, γ)
                        r: jax.Array,              # (B,)
                        tile: int = VOCAB_TILE,
                        eps: float = 1e-12,
                        interpret=None) -> VerifyOut:
    B, gamma = draft_tokens.shape
    V = p_probs.shape[-1]
    pad = (-V) % tile
    if pad:
        p_probs = jnp.pad(p_probs, ((0, 0), (0, 0), (0, pad)))
        q_probs = jnp.pad(q_probs, ((0, 0), (0, 0), (0, pad)))

    p_at, q_at, mass = gather_reduce_call(draft_tokens, p_probs, q_probs,
                                          tile, interpret=interpret)

    accept = u < jnp.minimum(1.0, p_at / jnp.maximum(q_at, 1e-20))
    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n_acc = prefix.sum(axis=-1)
    all_acc = n_acc == gamma
    jrow = jnp.where(all_acc, gamma, n_acc).astype(jnp.int32)
    qrow = jnp.minimum(jrow, gamma - 1).astype(jnp.int32)
    mass_j = jnp.take_along_axis(mass, qrow[:, None], axis=1)[:, 0]
    use_p = (all_acc | (mass_j <= eps)).astype(jnp.int32)
    total = jnp.where(use_p > 0, 1.0, mass_j)   # p rows sum to ~1
    # exact total for the use_p branch: Σ p_j — reuse pass-A trick is not
    # needed; p is a softmax output ⇒ Σ = 1 up to fp error, and the CDF clamp
    # handles the residual error at the last tile.
    thresh = (r * total)[:, None].astype(jnp.float32)

    token = cdf_sample_call(jrow, qrow, use_p, p_probs, q_probs, thresh,
                            tile, interpret=interpret)[:, 0]
    token = jnp.minimum(token, V - 1)           # strip vocab padding
    return VerifyOut(n_accepted=n_acc.astype(jnp.int32),
                     next_token=token.astype(jnp.int32),
                     accept_mask=accept)


@kernel_op("tile")
def tree_verify_fused(tree_tokens: jax.Array,    # (B, T) int32
                      p_logits: jax.Array,       # (B, T, V)
                      parent_entry: jax.Array,   # (T,) int32
                      tree_pos: jax.Array,       # (T,) int32
                      node_valid: jax.Array,     # (T,) bool (traced mask)
                      win_mask: jax.Array,       # (T, T) bool ancestor map
                      tile: int = VOCAB_TILE,
                      interpret=None):
    """Fused greedy tree-verify: (n_accepted, winner, bonus) — the same
    verdict triple :func:`repro.core.tree.verify_tree_greedy` derives,
    without materializing the (B, T) argmax glue in HBM. Pass A streams
    the vocab in tiles for the per-entry target argmax; pass B runs the
    longest-accepted-root-path rule per batch row on VMEM-resident tree
    tables."""
    B, T = tree_tokens.shape
    V = p_logits.shape[-1]
    pad = (-V) % tile
    if pad:
        # -inf padding keeps the argmax on real vocab entries
        p_logits = jnp.pad(p_logits, ((0, 0), (0, 0), (0, pad)),
                           constant_values=float("-inf"))

    tgt = tree_argmax_call(p_logits, tile, interpret=interpret)
    n_acc, winner, bonus = tree_accept_call(
        tree_tokens.astype(jnp.int32), tgt,
        parent_entry[None, :].astype(jnp.int32),
        tree_pos[None, :].astype(jnp.int32),
        node_valid[None, :].astype(jnp.int32),
        win_mask.astype(jnp.int32), interpret=interpret)
    return (n_acc.astype(jnp.int32), winner.astype(jnp.int32),
            bonus.astype(jnp.int32))
