"""Pallas TPU kernel for the Mamba2/SSD chunked scan.

State-space duality on the MXU: within a chunk of L tokens the recurrence is
computed as a masked (L, L) quadratic form (three MXU matmuls per chunk —
C·Bᵀ scores, scores·x, and the state in/out products); across chunks the
(hd, N) state carries in VMEM scratch along the sequential chunk grid
dimension. This is the TPU-native shape of the SSD algorithm: the GPU
implementation leans on warp-level scans, which have no MXU analogue —
the chunked duality *is* the adaptation (DESIGN.md §3).

Grid (B, nh, S/L): batch and head parallel, chunks sequential. Block sizes:
L=128 tokens (8×128-aligned score tiles), hd=64/128 lanes, N=64/128 lanes.
VMEM per cell ≈ L·(hd+2N)·4 + L²·4 + hd·N·4 ≈ 170 KiB at L=128, hd=64,
N=128 — comfortably within the 16 MiB v5e VMEM budget with double-buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from .. import tpu_compiler_params

CHUNK = 128


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, h0_ref,
                y_ref, hout_ref, h_scr):
    """x: (1,L,1,hd) | B,C: (1,L,N) | dt: (1,L,1) | A: (1,) | h0: (1,1,hd,N)
    outputs: y (1,L,1,hd); h_out (1,1,hd,N) at the last chunk."""
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # (L, hd)
    Bm = b_ref[0].astype(jnp.float32)               # (L, N)
    Cm = c_ref[0].astype(jnp.float32)               # (L, N)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (L,)
    A = a_ref[0].astype(jnp.float32)                # scalar

    L = x.shape[0]
    la = A * dt                                     # (L,) log-decay ≤ 0
    Lc = jnp.cumsum(la)

    h = h_scr[...]                                  # (hd, N)
    # inter-chunk: y_state[t] = exp(Lc_t) · C_t h^T
    y_state = jax.lax.dot_general(Cm, h, (((1,), (1,)), ((), ()))) \
        * jnp.exp(Lc)[:, None]                      # (L, hd)

    # intra-chunk masked quadratic form
    seg = Lc[:, None] - Lc[None, :]                 # (L, L)
    mask = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    w = jnp.where(mask, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (L, L)
    scores = cb * w * dt[None, :]
    y_intra = jnp.dot(scores, x)                    # (L, hd)
    y_ref[0, :, 0, :] = (y_state + y_intra).astype(y_ref.dtype)

    # state update: h' = exp(Lc_last)·h + Σ_s exp(Lc_last − Lc_s)·dt_s·x_s⊗B_s
    decay_out = jnp.exp(Lc[-1] - Lc) * dt           # (L,)
    contrib = jax.lax.dot_general(x * decay_out[:, None], Bm,
                                  (((0,), (0,)), ((), ())))      # (hd, N)
    h_scr[...] = jnp.exp(Lc[-1]) * h + contrib

    @pl.when(c == pl.num_programs(2) - 1)
    def _done():
        hout_ref[0, 0] = h_scr[...].astype(hout_ref.dtype)


def ssd_call(x: jax.Array,    # (B, S, nh, hd)
             Bm: jax.Array,   # (B, S, N)
             Cm: jax.Array,   # (B, S, N)
             dt: jax.Array,   # (B, S, nh)
             A: jax.Array,    # (nh,)
             h_in: jax.Array, # (B, nh, hd, N) f32
             chunk: int = CHUNK,
             interpret=None):
    from .. import resolve_interpret
    interpret = resolve_interpret(interpret)  # None → compiled on TPU only
    B, S, nh, hd = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, "ops.py pads the sequence to the chunk size"
    grid = (B, nh, S // chunk)
    y, h_out = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, Bm, Cm, dt, A, h_in)
    return y, h_out
