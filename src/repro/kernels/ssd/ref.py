"""Pure-jnp oracles for the SSD kernel.

Two references:
- :func:`ssd_recurrent_reference` — the literal token-by-token recurrence
  (the ground truth both the chunked jnp path and the Pallas kernel must
  match),
- :func:`ssd_chunked_reference`   — re-export of the chunked jnp
  implementation from models/ssm.py (itself validated against the
  recurrence here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...models.ssm import ssd_chunked as ssd_chunked_reference  # noqa: F401


def ssd_recurrent_reference(x, Bm, Cm, dt, A, h_in):
    """x: (B,S,nh,hd); Bm,Cm: (B,S,N); dt: (B,S,nh); A: (nh,);
    h_in: (B,nh,hd,N). Returns (y (B,S,nh,hd), h_out)."""

    def step(h, inp):
        xt, bt, ct, dtt = inp     # (B,nh,hd), (B,N), (B,N), (B,nh)
        a = jnp.exp(A[None, :] * dtt)                      # (B,nh)
        upd = jnp.einsum("bh,bn,bhd->bhdn", dtt, bt.astype(jnp.float32),
                         xt.astype(jnp.float32))
        h = a[..., None, None] * h + upd
        y = jnp.einsum("bn,bhdn->bhd", ct.astype(jnp.float32), h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(Bm, 1, 0),
          jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(dt, 1, 0))
    h_out, ys = jax.lax.scan(step, h_in, xs)
    return jnp.moveaxis(ys, 0, 1), h_out
