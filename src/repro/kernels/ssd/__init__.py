from .ops import ssd_chunked_kernel
from .ref import ssd_chunked_reference, ssd_recurrent_reference
