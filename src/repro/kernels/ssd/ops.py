"""Jitted wrapper matching models.ssm.ssd_chunked's signature (drop-in via
``use_kernel=True`` in ssm_block_train): pads S to the chunk, strips pads."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import kernel_op
from .ssd import CHUNK, ssd_call


@kernel_op("chunk")
def ssd_chunked_kernel(x, Bm, Cm, dt, A, h_in, chunk: int = CHUNK,
                       interpret=None):
    """Same contract as models.ssm.ssd_chunked: padded dt rows must be zero
    (identity steps) — ssm_block_train guarantees this."""
    B, S, nh, hd = x.shape
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_call(x, Bm, Cm, dt, A, h_in, chunk=chunk,
                    interpret=interpret)
    return y[:, :S], h
