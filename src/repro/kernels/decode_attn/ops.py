"""Jitted wrapper: (B,T,H,hd) query layout ↔ kernel's grouped layout, cache
padding to the sequence tile, static window/shape handling."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .. import kernel_op
from .decode_attn import S_TILE, decode_attn_call


@kernel_op("window", "s_tile")
def decode_attention(q: jax.Array,        # (B, T, H, hd)
                     k: jax.Array,        # (B, S, Hkv, hd)
                     v: jax.Array,
                     pos_map: jax.Array,  # (B, S)
                     q_pos: jax.Array,    # (B, T)
                     window: int = 0,
                     s_tile: int = S_TILE,
                     interpret: Optional[bool] = None) -> jax.Array:
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    tile = min(s_tile, S) if S % min(s_tile, S) == 0 else s_tile
    pad = (-S) % tile
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_map = jnp.pad(pos_map, ((0, 0), (0, pad)), constant_values=-1)
    qg = q.reshape(B, T, Hkv, G, hd)
    out = decode_attn_call(qg, k, v, pos_map, q_pos, window=window,
                           s_tile=tile, interpret=interpret)
    return out.reshape(B, T, H, hd)
