"""Pallas TPU paged flash-decode kernel: the block-table gather fused into
the decode grid.

Same online-softmax flash-decode as decode_attn.py, but K/V live in a
shared paged pool (n_blocks, block_size, Hkv, hd) and each sequence reads
only its own mapped blocks: the grid's sequential dimension walks the
sequence's LOGICAL block list ``0..n_log-1`` and a
``PrefetchScalarGridSpec`` scalar-prefetched block table indirects the K/V
BlockSpec index maps to the physical block — ``(tbl[b, i], 0, h, 0)`` —
so paging costs zero extra HBM traffic on the hot path (no dense gather
materializes; each pool block streams HBM→VMEM exactly once per kv-head,
identical to the dense kernel's tile traffic).

Unmapped table entries (−1) clamp to block 0 for the prefetch and are
masked out wholesale in-kernel (``phys < 0``), exactly like a dense empty
slot; ``pos_map`` masking (speculative-rollback stale entries, sliding
window) carries over unchanged. int8 pools dequantize in VMEM from the
per-entry scales streamed alongside the blocks.

The dense kernel (decode_attn.py) + the XLA gather path
(models/kvcache.gather_layer_paged) stay as the reference oracles.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import resolve_interpret
from .decode_attn import NEG_INF


def _paged_decode_kernel(tbl_ref, qpos_ref, q_ref, k_ref, v_ref, pm_ref,
                         *rest, window: int, scale: float, length: int,
                         bs: int, quant: bool):
    """Grid (B, Hkv, n_log) — last dim sequential over the slot's logical
    block list (online softmax).

    tbl (scalar prefetch): (B, n_log) | qpos: (1, T) | q: (1, T, 1, G, hd)
    k,v: (1, bs, 1, hd) — the PHYSICAL block tbl[b, i] | pm: (1, bs)
    [quant: ks,vs (1, bs, 1)] | out: (1, T, 1, G, hd)
    scratch: m,l (T, G) f32; acc (T, G, hd) f32.
    """
    if quant:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    phys = tbl_ref[b, i]                                # −1 = unmapped
    q = q_ref[0, :, 0, :, :].astype(jnp.float32)        # (T, G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bs, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    if quant:
        k = k * ks_ref[0, :, 0][:, None]
        v = v * vs_ref[0, :, 0][:, None]
    pm = pm_ref[0, :]                                   # (bs,)
    qpos = qpos_ref[0, :]                               # (T,)

    T, G, hd = q.shape
    scores = jax.lax.dot_general(
        q.reshape(T * G, hd), k,
        (((1,), (1,)), ((), ()))).reshape(T, G, -1) * scale   # (T, G, bs)

    # logical positions this block covers; past-length tail of the last
    # block is padding
    j = i * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)  # (1, bs)
    valid = (phys >= 0) & (j < length) & (pm[None, :] >= 0) & \
        (pm[None, :] <= qpos[:, None])                            # (T, bs)
    if window > 0:
        valid = valid & (pm[None, :] > qpos[:, None] - window)
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))    # (T, G)
    alpha = jnp.exp(m_prev - m_new)
    e = jnp.exp(scores - m_new[..., None])              # (T, G, bs)
    e = jnp.where(valid[:, None, :], e, 0.0)
    l_scr[...] = l_scr[...] * alpha + e.sum(axis=-1)
    pv = jax.lax.dot_general(
        e.reshape(T * G, -1), v,
        (((1,), (0,)), ((), ()))).reshape(T, G, hd)
    acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
    m_scr[...] = m_new

    @pl.when(i == pl.num_programs(2) - 1)
    def _done():
        l = l_scr[...]
        out = jnp.where(l[..., None] > 0, acc_scr[...] / jnp.maximum(
            l[..., None], 1e-20), 0.0)
        o_ref[0, :, 0, :, :] = out.astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array,            # (B, T, Hkv, G, hd)
                           k_pool: jax.Array,       # (NB, bs, Hkv, hd)
                           v_pool: jax.Array,
                           k_scale: Optional[jax.Array],  # (NB, bs, Hkv)
                           v_scale: Optional[jax.Array],
                           pos_map: jax.Array,      # (NB, bs)
                           block_table: jax.Array,  # (B, n_log) int32
                           q_pos: jax.Array,        # (B, T)
                           length: int,
                           window: int = 0,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Fused paged GQA flash-decode over ONE layer's pool view. Returns the
    attention context (B, T, Hkv, G, hd) in ``q.dtype`` (the wo projection
    stays outside, in models/attention.py)."""
    interpret = resolve_interpret(interpret)
    B, T, Hkv, G, hd = q.shape
    bs = k_pool.shape[1]
    n_log = block_table.shape[1]
    quant = k_scale is not None

    # unmapped (−1) prefetches clamp to block 0; the kernel masks it out
    def blk(b, h, i, tbl):
        return (jnp.maximum(tbl[b, i], 0), 0, h, 0)

    def blk_pm(b, h, i, tbl):
        return (jnp.maximum(tbl[b, i], 0), 0)

    def blk_scale(b, h, i, tbl):
        return (jnp.maximum(tbl[b, i], 0), 0, h)

    in_specs = [
        pl.BlockSpec((1, T), lambda b, h, i, tbl: (b, 0)),
        pl.BlockSpec((1, T, 1, G, hd), lambda b, h, i, tbl: (b, 0, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, hd), blk),
        pl.BlockSpec((1, bs, 1, hd), blk),
        pl.BlockSpec((1, bs), blk_pm),
    ]
    inputs = [q_pos, q, k_pool, v_pool, pos_map]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, 1), blk_scale),
                     pl.BlockSpec((1, bs, 1), blk_scale)]
        inputs += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_log),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, T, 1, G, hd),
                               lambda b, h, i, tbl: (b, 0, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((T, G), jnp.float32),
                        pltpu.VMEM((T, G), jnp.float32),
                        pltpu.VMEM((T, G, hd), jnp.float32)],
    )
    kern = functools.partial(_paged_decode_kernel, window=window,
                             scale=1.0 / math.sqrt(hd), length=length,
                             bs=bs, quant=quant)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, Hkv, G, hd), q.dtype),
        interpret=interpret,
    )(block_table, *inputs)
