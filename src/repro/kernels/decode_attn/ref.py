"""Pure-jnp oracle for the GQA flash-decode kernel: pos_map-masked attention
of a small query window over a (possibly ring-buffer) KV cache, with
optional sliding window. Mirrors models/attention.py's decode math for one
layer, minus the projections (the kernel operates post-projection)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_reference(q: jax.Array,        # (B, T, H, hd)
                               k: jax.Array,        # (B, S, Hkv, hd)
                               v: jax.Array,        # (B, S, Hkv, hd)
                               pos_map: jax.Array,  # (B, S) int32, -1=empty
                               q_pos: jax.Array,    # (B, T) absolute pos
                               window: int = 0) -> jax.Array:
    B, T, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k) / math.sqrt(hd)
    slot = pos_map[:, None, None, None, :]
    qp = q_pos[:, None, None, :, None]
    valid = (slot >= 0) & (slot <= qp)
    if window > 0:
        valid = valid & (slot > qp - window)
    scores = jnp.where(valid, scores.astype(jnp.float32), -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)   # rows with no valid slot
    out = jnp.einsum("bkgts,bskh->btkgh", w.astype(q.dtype), v)
    return out.reshape(B, T, H, hd)
