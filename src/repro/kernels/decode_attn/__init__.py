from .ops import decode_attention
from .paged import paged_decode_attention
from .ref import decode_attention_reference
