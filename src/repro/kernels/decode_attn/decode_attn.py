"""Pallas TPU flash-decode kernel: GQA attention of a γ+1-token verification
window (or a single decode token) over a long KV cache.

TPU adaptation of flash-decoding: the KV cache streams HBM→VMEM in
(S_TILE, hd) tiles with an online-softmax accumulator held in VMEM scratch
across the (sequential) cache-tile grid dimension. Per grid cell
(batch, kv_head) the query block is (T, G, hd) — all G query heads of one
KV group attend together, so the k-tile is loaded once per group rather than
once per query head (the GQA bandwidth win; this op is memory-bound with
arithmetic intensity ≈ T·G, far below the TPU ridge point).

``pos_map`` masking makes the same kernel serve append caches, ring-buffer
sliding-window caches (`long_500k`), and speculative-rollback stale-entry
exclusion — mask logic identical to models/attention.py.

Block shapes: S_TILE=512 lanes-aligned; hd ∈ {64, 128} both lane-aligned.
MXU use: the (T·G, hd) × (hd, S_TILE) score matmul and the (T·G, S_TILE) ×
(S_TILE, hd) value matmul.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from typing import Optional

from .. import resolve_interpret, tpu_compiler_params

S_TILE = 512
NEG_INF = -1e30


def _decode_attn_kernel(qpos_ref, q_ref, k_ref, v_ref, pm_ref, o_ref,
                        m_scr, l_scr, acc_scr, *, window: int, scale: float):
    """Grid (B, Hkv, S/S_TILE) — last dim sequential (online softmax).

    q: (1, T, 1, G, hd) | k,v: (1, S_TILE, 1, hd) | pm: (1, S_TILE)
    qpos: (1, T) | out: (1, T, 1, G, hd)
    scratch: m,l (T, G) f32; acc (T, G, hd) f32.
    """
    st = pl.program_id(2)

    @pl.when(st == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :, :].astype(jnp.float32)        # (T, G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (ST, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)           # (ST, hd)
    pm = pm_ref[0, :]                                   # (ST,)
    qpos = qpos_ref[0, :]                               # (T,)

    T, G, hd = q.shape
    scores = jax.lax.dot_general(
        q.reshape(T * G, hd), k,
        (((1,), (1,)), ((), ()))).reshape(T, G, -1) * scale   # (T, G, ST)

    valid = (pm[None, :] >= 0) & (pm[None, :] <= qpos[:, None])   # (T, ST)
    if window > 0:
        valid = valid & (pm[None, :] > qpos[:, None] - window)
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))    # (T, G)
    alpha = jnp.exp(m_prev - m_new)
    e = jnp.exp(scores - m_new[..., None])              # (T, G, ST)
    e = jnp.where(valid[:, None, :], e, 0.0)
    l_scr[...] = l_scr[...] * alpha + e.sum(axis=-1)
    pv = jax.lax.dot_general(
        e.reshape(T * G, -1), v,
        (((1,), (0,)), ((), ()))).reshape(T, G, hd)
    acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
    m_scr[...] = m_new

    @pl.when(st == pl.num_programs(2) - 1)
    def _done():
        l = l_scr[...]
        out = jnp.where(l[..., None] > 0, acc_scr[...] / jnp.maximum(
            l[..., None], 1e-20), 0.0)
        o_ref[0, :, 0, :, :] = out.astype(o_ref.dtype)


def decode_attn_call(q: jax.Array,        # (B, T, Hkv, G, hd)
                     k: jax.Array,        # (B, S, Hkv, hd)
                     v: jax.Array,
                     pos_map: jax.Array,  # (B, S)
                     q_pos: jax.Array,    # (B, T)
                     window: int = 0,
                     s_tile: int = S_TILE,
                     interpret: Optional[bool] = None) -> jax.Array:
    interpret = resolve_interpret(interpret)  # None → compiled on TPU only
    B, T, Hkv, G, hd = q.shape
    S = k.shape[1]
    s_tile = min(s_tile, S)
    assert S % s_tile == 0, "ops.py pads the cache to the tile size"
    grid = (B, Hkv, S // s_tile)
    kern = functools.partial(_decode_attn_kernel, window=window,
                             scale=1.0 / math.sqrt(hd))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, h, s: (b, 0)),
            pl.BlockSpec((1, T, 1, G, hd), lambda b, h, s: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, s_tile, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, s_tile, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, s_tile), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, T, 1, G, hd),
                               lambda b, h, s: (b, 0, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, Hkv, G, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((T, G), jnp.float32),
                        pltpu.VMEM((T, G), jnp.float32),
                        pltpu.VMEM((T, G, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_pos, q, k, v, pos_map)
