"""Pallas TPU kernels (validated in interpret mode on CPU):

- verify       — fused speculative-window verification (vocab-tiled)
- decode_attn  — GQA flash-decode over KV caches (+sliding window/ring)
- ssd          — Mamba2/SSD chunked scan
"""
