"""Pallas TPU kernels (validated in interpret mode on CPU):

- verify       — fused speculative-window verification (vocab-tiled)
- decode_attn  — GQA flash-decode over KV caches (+sliding window/ring)
- ssd          — Mamba2/SSD chunked scan
"""

from jax.experimental.pallas import tpu as _pltpu


def tpu_compiler_params(**kwargs):
    """Version-compat shim: newer jax exposes ``pltpu.CompilerParams``,
    older releases call it ``TPUCompilerParams``."""
    cls = getattr(_pltpu, "CompilerParams", None)
    if cls is None:
        cls = _pltpu.TPUCompilerParams
    return cls(**kwargs)
