"""Pallas TPU kernels (validated in interpret mode on CPU):

- verify       — fused speculative-window verification (vocab-tiled)
- decode_attn  — GQA flash-decode over KV caches (+sliding window/ring)
- ssd          — Mamba2/SSD chunked scan
"""

import functools as _functools

import jax as _jax
from jax.experimental.pallas import tpu as _pltpu


def default_interpret() -> bool:
    """Resolve the kernels' shared ``interpret=None`` auto-default: compile
    for real on TPU backends, fall back to the Pallas interpreter on CPU/GPU
    (where Mosaic can't lower). Callers override per-call for A/B tests."""
    return _jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def kernel_op(*static_argnames):
    """Shared jit decorator for the public kernel wrappers: every op takes
    an ``interpret=None`` kwarg (resolved inside the pallas_call layer via
    :func:`resolve_interpret`), so ``interpret`` is always static alongside
    the op's own shape/tiling statics."""
    return _functools.partial(_jax.jit,
                              static_argnames=(*static_argnames, "interpret"))


def tpu_compiler_params(**kwargs):
    """Version-compat shim: newer jax exposes ``pltpu.CompilerParams``,
    older releases call it ``TPUCompilerParams``."""
    cls = getattr(_pltpu, "CompilerParams", None)
    if cls is None:
        cls = _pltpu.TPUCompilerParams
    return cls(**kwargs)
