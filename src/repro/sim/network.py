"""Network links for DSD-Sim.

Links are delay elements attached to send/receive events (paper §3.1):
each message experiences RTT/2 one-way latency plus sampled jitter plus a
serialization term (payload_bytes / bandwidth). Jitter is drawn from a
truncated normal; truncation is SYMMETRIC (±min(0.9·RTT/2, 4·jitter_ms))
so the sampled mean one-way delay equals the analytic
:func:`expected_one_way_ms` and the link never goes acausal.

The draft→target payload of a speculation window is tiny (γ token ids +
metadata ≈ tens of bytes), so serialization only matters when users configure
KV-shipping modes; we still model it for completeness.

The same delay model backs the REAL execution path: the
:class:`repro.distributed.transport.EmulatedLinkTransport` samples
:func:`sample_one_way_ms` with the same :class:`LinkSpec` and imposes the
delay as wall-clock sleep, so DSD-Sim and the real engine see one network.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Any, Callable

from .events import Environment


# Tokens streamed per fused-mode control round trip: the scheduler's
# ``fused_chunk`` default AND the real path's stream-flush quantum
# (``repro.core.session.FUSED_FLUSH_TOKENS``) — one constant so the
# fused-mode link charges cannot silently drift between sim and real.
DEFAULT_FUSED_CHUNK = 8


@dataclass
class LinkSpec:
    rtt_ms: float = 10.0
    jitter_ms: float = 1.0
    bandwidth_gbps: float = 1.0  # edge uplink
    name: str = "edge-cloud"


def sample_one_way_ms(spec: LinkSpec, rng: random.Random,
                      payload_bytes: int = 64) -> float:
    """One-way delay sample: RTT/2 + symmetric truncated jitter + serialization.

    Jitter ~ N(0, (jitter_ms/2)²) truncated to ±min(0.9·RTT/2, 4·jitter_ms).
    Symmetric truncation keeps the sample mean equal to
    ``expected_one_way_ms`` (a one-sided cut would bias the mean upward),
    and the 0.9·RTT/2 bound keeps the delay strictly positive.
    """
    half_rtt = spec.rtt_ms / 2.0
    bound = min(0.9 * half_rtt, 4.0 * spec.jitter_ms)
    jitter = rng.gauss(0.0, spec.jitter_ms / 2.0)
    jitter = max(-bound, min(jitter, bound))
    ser_ms = payload_bytes * 8 / (spec.bandwidth_gbps * 1e9) * 1e3
    return max(0.0, half_rtt + jitter + ser_ms)


class RttTracker:
    """Round-trip estimation over explicitly paired one-way delays.

    Callers complete an exchange (window out + verdict back, or control
    out + stream back) and record the paired sum via :meth:`record_rtt`
    — a single direction's delay is never doubled (which would
    double-count its serialization term and mix window/verdict payload
    sizes), and pairing never depends on delivery order (pipelined
    speculation interleaves directions, so the transport matches the two
    halves by wire ``round_id`` before recording). Shared by the
    simulator's :class:`Link` and the real path's
    :class:`repro.distributed.transport.Transport` so both estimate the
    AWC ``rtt_recent_ms`` feature identically.
    """

    __slots__ = ("_rtts",)

    def __init__(self):
        self._rtts: list[float] = []

    def record_rtt(self, rtt_ms: float) -> None:
        """Record one complete out+back round trip."""
        self._rtts.append(rtt_ms)
        if len(self._rtts) > 256:
            del self._rtts[:128]

    def mean_recent_ms(self, default: float) -> float:
        """Mean of the recently recorded round trips; ``default`` before
        the first completed exchange (an unanswered outbound delivery
        contributes nothing — half a pair is not an RTT)."""
        if not self._rtts:
            return default
        tail = self._rtts[-32:]
        return sum(tail) / len(tail)


class Link:
    """One-way message delivery with RTT/2 + jitter + serialization delay."""

    def __init__(self, env: Environment, spec: LinkSpec, rng: random.Random):
        self.env = env
        self.spec = spec
        self.rng = rng
        self.bytes_sent = 0
        self.messages_sent = 0
        # Measured RTT pairs feed the AWC feature vector (RTT_recent).
        # A Link is SHARED by every drafter routed to its target, so
        # consecutive deliveries do NOT alternate directions (two drafters'
        # outbound windows can interleave) — callers that complete an
        # exchange record the explicitly paired sum via record_rtt();
        # transfer()/send() never auto-pair.
        self._rtt = RttTracker()
        self.last_delay_ms = 0.0   # most recent sampled one-way delay

    def one_way_ms(self, payload_bytes: int = 64) -> float:
        return sample_one_way_ms(self.spec, self.rng, payload_bytes)

    def record_rtt(self, rtt_ms: float) -> None:
        """Record one complete exchange's out+back delay (the caller pairs
        its own two transfers — see the sharing note above)."""
        self._rtt.record_rtt(rtt_ms)

    def send(self, payload_bytes: int, deliver: Callable[[], Any]) -> None:
        """Schedule ``deliver`` after the one-way delay."""
        delay = self.one_way_ms(payload_bytes)
        self.bytes_sent += payload_bytes
        self.messages_sent += 1
        self.last_delay_ms = delay
        self.env._schedule(self.env.now + delay, deliver)

    def charge(self, payload_bytes: int = 64) -> float:
        """Account a delivery whose flight is fully HIDDEN behind other
        work (cross-round pipelining): the bytes cross the wire and the
        sampled delay is returned for RTT bookkeeping, but no simulation
        time elapses at the caller."""
        delay = self.one_way_ms(payload_bytes)
        self.bytes_sent += payload_bytes
        self.messages_sent += 1
        self.last_delay_ms = delay
        return delay

    def transfer(self, payload_bytes: int = 64):
        """Event-style API: ``yield link.transfer(n)`` inside a process.

        ``last_delay_ms`` exposes the sampled delay so callers can account
        link time separately from service time (the AWC TPOT feature must
        not re-absorb the RTT it is paired with) and pair the two halves
        of an exchange for :meth:`record_rtt`."""
        delay = self.one_way_ms(payload_bytes)
        self.bytes_sent += payload_bytes
        self.messages_sent += 1
        self.last_delay_ms = delay
        return self.env.timeout(delay)

    @property
    def recent_rtt_ms(self) -> float:
        """Mean of recent measured round trips (paired outbound+return
        one-way delays). Falls back to the spec RTT before the first
        complete pair."""
        return self._rtt.mean_recent_ms(self.spec.rtt_ms)


def window_payload_bytes(gamma: int, n_nodes: int | None = None) -> int:
    """Draft→target payload: token ids (4B) + per-token draft prob (4B) + header.

    Tree windows (``n_nodes`` = grid entries incl. the anchor) are priced
    per NODE: id + draft prob + a 4B parent index that pins the topology
    — strictly monotone in ``n_nodes``, and a linear chain shipped as a
    degenerate tree (n_nodes = γ + 1) costs slightly MORE than the legacy
    chain framing (the parent table plus the anchor entry are explicit on
    the wire)."""
    if n_nodes is not None:
        return 48 + 12 * n_nodes
    return 48 + 8 * gamma


def verdict_payload_bytes(gamma: int) -> int:
    """Target→draft payload: accept count + corrected/bonus token id (8B)
    plus one 4B target logprob per window position (the draft consumes them
    for distillation / acceptance diagnostics) + header."""
    return 48 + 8 + 4 * gamma


def expected_one_way_ms(spec: LinkSpec, payload_bytes: int = 64) -> float:
    return spec.rtt_ms / 2.0 + payload_bytes * 8 / (spec.bandwidth_gbps * 1e9) * 1e3


def expected_rtt_ms(spec: LinkSpec, out_payload_bytes: int = 64,
                    back_payload_bytes: int = 64) -> float:
    """Analytic round trip for an out+back exchange on ``spec``."""
    return (expected_one_way_ms(spec, out_payload_bytes)
            + expected_one_way_ms(spec, back_payload_bytes))
