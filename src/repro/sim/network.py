"""Network links for DSD-Sim.

Links are delay elements attached to send/receive events (paper §3.1):
each message experiences RTT/2 one-way latency plus sampled jitter plus a
serialization term (payload_bytes / bandwidth). Jitter is drawn from a
truncated normal so the link never goes acausal.

The draft→target payload of a speculation window is tiny (γ token ids +
metadata ≈ tens of bytes), so serialization only matters when users configure
KV-shipping modes; we still model it for completeness.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .events import Environment


@dataclass
class LinkSpec:
    rtt_ms: float = 10.0
    jitter_ms: float = 1.0
    bandwidth_gbps: float = 1.0  # edge uplink
    name: str = "edge-cloud"


class Link:
    """One-way message delivery with RTT/2 + jitter + serialization delay."""

    def __init__(self, env: Environment, spec: LinkSpec, rng: random.Random):
        self.env = env
        self.spec = spec
        self.rng = rng
        self.bytes_sent = 0
        self.messages_sent = 0
        # Running latency stats feed the AWC feature vector (RTT_recent).
        self._recent_delays: list[float] = []

    def one_way_ms(self, payload_bytes: int = 64) -> float:
        half_rtt = self.spec.rtt_ms / 2.0
        jitter = self.rng.gauss(0.0, self.spec.jitter_ms / 2.0)
        jitter = max(-half_rtt * 0.9, min(jitter, self.spec.jitter_ms * 4))
        ser_ms = payload_bytes * 8 / (self.spec.bandwidth_gbps * 1e9) * 1e3
        return max(0.0, half_rtt + jitter + ser_ms)

    def send(self, payload_bytes: int, deliver: Callable[[], Any]) -> None:
        """Schedule ``deliver`` after the one-way delay."""
        delay = self.one_way_ms(payload_bytes)
        self.bytes_sent += payload_bytes
        self.messages_sent += 1
        self._recent_delays.append(delay)
        if len(self._recent_delays) > 256:
            del self._recent_delays[:128]
        self.env._schedule(self.env.now + delay, deliver)

    def transfer(self, payload_bytes: int = 64):
        """Event-style API: ``yield link.transfer(n)`` inside a process."""
        delay = self.one_way_ms(payload_bytes)
        self.bytes_sent += payload_bytes
        self.messages_sent += 1
        self._recent_delays.append(delay)
        if len(self._recent_delays) > 256:
            del self._recent_delays[:128]
        return self.env.timeout(delay)

    @property
    def recent_rtt_ms(self) -> float:
        if not self._recent_delays:
            return self.spec.rtt_ms
        tail = self._recent_delays[-32:]
        return 2.0 * sum(tail) / len(tail)


def window_payload_bytes(gamma: int) -> int:
    """Draft→target payload: token ids (4B) + per-token draft prob (4B) + header."""
    return 48 + 8 * gamma


def verdict_payload_bytes(gamma: int) -> int:
    """Target→draft payload: accept count + corrected/bonus token + logprobs."""
    return 48 + 8


def expected_one_way_ms(spec: LinkSpec, payload_bytes: int = 64) -> float:
    return spec.rtt_ms / 2.0 + payload_bytes * 8 / (spec.bandwidth_gbps * 1e9) * 1e3
