"""Routing and batching policies for DSD-Sim (paper §3.4).

Routing policies pick a target server for each request given a read-only
snapshot of queue depths. Batching policies decide which queued jobs form
the next batch on a target server.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Callable, Optional, Protocol, Sequence


# --------------------------------------------------------------------------
# Routing
# --------------------------------------------------------------------------

class RoutingPolicy(Protocol):
    def route(self, request: Any, queue_depths: Sequence[int]) -> int: ...
    def name(self) -> str: ...


class RandomRouting:
    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def route(self, request: Any, queue_depths: Sequence[int]) -> int:
        return self.rng.randrange(len(queue_depths))

    def name(self) -> str:
        return "random"


class RoundRobinRouting:
    def __init__(self):
        self._next = 0

    def route(self, request: Any, queue_depths: Sequence[int]) -> int:
        i = self._next % len(queue_depths)
        self._next += 1
        return i

    def name(self) -> str:
        return "round_robin"


class JSQRouting:
    """Join-the-Shortest-Queue; ties broken by lowest index (deterministic)."""

    def route(self, request: Any, queue_depths: Sequence[int]) -> int:
        best, best_d = 0, None
        for i, d in enumerate(queue_depths):
            if best_d is None or d < best_d:
                best, best_d = i, d
        return best

    def name(self) -> str:
        return "jsq"


class PinnedRouting:
    """Fixed drafter→target map: request ``r`` on drafter ``d`` always
    verifies on ``target_of_drafter[d]``. This is how a declarative
    topology's draft–target PAIRS materialize in the simulator
    (:func:`repro.topology.build_simulation`): drafter i is pair i, and
    its routing is part of the spec, not a load-balancing decision."""

    def __init__(self, target_of_drafter: Sequence[int]):
        assert len(target_of_drafter) >= 1, "need at least one pair"
        self.target_of_drafter = list(target_of_drafter)

    def route(self, request: Any, queue_depths: Sequence[int]) -> int:
        did = getattr(request, "drafter_id", 0)
        return self.target_of_drafter[did % len(self.target_of_drafter)]

    def name(self) -> str:
        return "pinned"


ROUTING: dict[str, Callable[..., Any]] = {
    "random": RandomRouting,
    "round_robin": RoundRobinRouting,
    "jsq": JSQRouting,
}


# --------------------------------------------------------------------------
# Pair routing (arrival-time lane assignment)
# --------------------------------------------------------------------------

@dataclass
class SimPairView:
    """Read-only per-PAIR snapshot for arrival-time lane assignment.

    One entry per drafter lane (under :class:`PinnedRouting` drafter i IS
    pair i, so this is the sim twin of the real server's
    ``(pairs, free_slots)`` routing view): current queue depth (queued +
    in-service), the lane's recent link RTT, and its rolling acceptance."""
    queue_depths: list[int]
    rtt_ms: list[float]
    alpha: list[float]
    max_batch: int = 16


class PairRoutingPolicy(Protocol):
    """Assigns an unpinned record (``drafter_id < 0``) to a drafter lane
    when it ARRIVES — the analogue of the real server's ``PairRouter``
    (sticky: the lane never changes afterwards). Distinct from
    :class:`RoutingPolicy`, which picks a target server per verify job."""

    def route_pair(self, record: Any, view: SimPairView) -> int: ...
    def name(self) -> str: ...


# --------------------------------------------------------------------------
# Batching
# --------------------------------------------------------------------------

@dataclass
class BatchingConfig:
    max_batch: int = 16
    batch_window_ms: float = 2.0     # wait this long after first arrival
    continuous: bool = True          # iteration-level (ORCA-style) batching
    chunked_prefill: bool = False    # split long prompts into chunks
    prefill_chunk: int = 512


class BatchingPolicy(Protocol):
    def form_batch(self, queue, head: Any, cfg: BatchingConfig) -> list[Any]: ...
    def name(self) -> str: ...


class FIFOBatching:
    """Take the head plus the next max_batch-1 jobs in arrival order."""

    def form_batch(self, queue, head: Any, cfg: BatchingConfig) -> list[Any]:
        batch = [head]
        while queue.items and len(batch) < cfg.max_batch:
            batch.append(queue.items.popleft())
        return batch

    def name(self) -> str:
        return "fifo"


class LengthAwareBatching:
    """LAB (paper §5.3): batch the head-of-line job with queued jobs whose
    context lengths are closest to it, minimizing intra-batch padding."""

    def form_batch(self, queue, head: Any, cfg: BatchingConfig) -> list[Any]:
        batch = [head]
        if not queue.items or len(batch) >= cfg.max_batch:
            return batch
        head_len = getattr(head, "sort_len", 0)
        candidates = sorted(
            queue.items, key=lambda j: abs(getattr(j, "sort_len", 0) - head_len))
        chosen = candidates[: cfg.max_batch - 1]
        chosen_ids = {id(c) for c in chosen}
        # remove chosen from the queue preserving order of the rest
        remaining = [j for j in queue.items if id(j) not in chosen_ids]
        queue.items.clear()
        queue.items.extend(remaining)
        batch.extend(chosen)
        return batch

    def name(self) -> str:
        return "lab"


BATCHING: dict[str, Callable[..., Any]] = {
    "fifo": FIFOBatching,
    "lab": LengthAwareBatching,
}
