"""Performance analyzer for DSD-Sim (paper §3.5).

Collects the two metric families the paper defines and serves the rolling
feature snapshots that window policies (notably AWC) consume:

- **Per-request**: TTFT, TPOT, end-to-end latency, acceptance ratio, routing
  decision, and the per-iteration γ decision sequence.
- **System-level**: throughput, per-target utilization, aggregate network
  queueing delay.

Everything is emitted as structured JSON (``to_json``), usable both for
offline analysis and as AWC training input.
"""

from __future__ import annotations

import json
import math
from collections import deque
from dataclasses import dataclass, field, asdict
from typing import Any, Optional


@dataclass
class RequestMetrics:
    request_id: int
    dataset: str
    drafter_id: int
    target_id: int
    arrival_ms: float
    prompt_length: int
    output_length: int
    first_token_ms: float = math.nan      # absolute time of first verified token
    finish_ms: float = math.nan
    tokens_generated: int = 0
    draft_tokens_proposed: int = 0
    draft_tokens_accepted: int = 0
    iterations: int = 0
    gamma_sequence: list[int] = field(default_factory=list)
    mode_sequence: list[str] = field(default_factory=list)
    queue_wait_ms: float = 0.0            # total time spent in target queues
    request_class: str = ""               # fleet traffic class ("" = dataset)
    slo_ttft_ms: float = 0.0              # per-request TTFT target (0 = none)
    slo_tpot_ms: float = 0.0              # per-request TPOT target (0 = none)

    @property
    def ttft_ms(self) -> float:
        return self.first_token_ms - self.arrival_ms

    @property
    def e2e_ms(self) -> float:
        return self.finish_ms - self.arrival_ms

    @property
    def tpot_ms(self) -> float:
        n = max(1, self.tokens_generated - 1)
        return (self.finish_ms - self.first_token_ms) / n

    @property
    def acceptance_rate(self) -> float:
        return self.draft_tokens_accepted / max(1, self.draft_tokens_proposed)


def _percentile(sorted_vals: list[float], p: float) -> float:
    if not sorted_vals:
        return math.nan
    k = (len(sorted_vals) - 1) * p
    lo, hi = int(math.floor(k)), int(math.ceil(k))
    if lo == hi:
        return sorted_vals[lo]
    return sorted_vals[lo] + (sorted_vals[hi] - sorted_vals[lo]) * (k - lo)


class RollingWindow:
    """Fixed-size rolling mean used for the AWC feature snapshots."""

    def __init__(self, size: int = 64, default: float = 0.0):
        self.buf: deque[float] = deque(maxlen=size)
        self.default = default

    def push(self, v: float) -> None:
        self.buf.append(v)

    def mean(self) -> float:
        if not self.buf:
            return self.default
        return sum(self.buf) / len(self.buf)


class Analyzer:
    """Central metric sink + rolling feature provider."""

    def __init__(self, num_targets: int, queue_capacity_hint: int = 64):
        self.requests: dict[int, RequestMetrics] = {}
        self.num_targets = num_targets
        self.queue_capacity_hint = queue_capacity_hint
        # rolling state for features
        self.alpha_recent: dict[str, RollingWindow] = {}
        self.pipe_recent: dict[str, RollingWindow] = {}
        self.pipeline_hits = 0
        self.pipeline_misses = 0
        self.tpot_recent = RollingWindow(size=128, default=50.0)
        self.queue_depth: list[int] = [0] * num_targets
        self.busy_ms: list[float] = [0.0] * num_targets
        self.batch_sizes: list[int] = []
        self.net_queue_delay_ms: float = 0.0
        self._first_arrival: Optional[float] = None
        self._last_finish: float = 0.0
        self.completed = 0

    # -- recording ----------------------------------------------------------

    def open_request(self, m: RequestMetrics) -> None:
        self.requests[m.request_id] = m
        if self._first_arrival is None or m.arrival_ms < self._first_arrival:
            self._first_arrival = m.arrival_ms

    def record_acceptance(self, pair_key: str, proposed: int, accepted: int) -> None:
        win = self.alpha_recent.get(pair_key)
        if win is None:
            win = self.alpha_recent[pair_key] = RollingWindow(size=32, default=0.7)
        if proposed > 0:
            win.push(accepted / proposed)

    def record_pipeline(self, pair_key: str, hit: bool) -> None:
        """One resolved cross-round speculation: the optimistic window was
        kept (hit — its RTT was hidden) or rolled back (miss)."""
        win = self.pipe_recent.get(pair_key)
        if win is None:
            win = self.pipe_recent[pair_key] = RollingWindow(size=32,
                                                             default=0.0)
        win.push(1.0 if hit else 0.0)
        if hit:
            self.pipeline_hits += 1
        else:
            self.pipeline_misses += 1

    def record_batch(self, target_id: int, size: int, busy_ms: float) -> None:
        self.busy_ms[target_id] += busy_ms
        self.batch_sizes.append(size)

    def record_tpot_sample(self, ms_per_token: float) -> None:
        self.tpot_recent.push(ms_per_token)

    def close_request(self, request_id: int, finish_ms: float) -> None:
        m = self.requests[request_id]
        m.finish_ms = finish_ms
        self.completed += 1
        self._last_finish = max(self._last_finish, finish_ms)

    # -- feature snapshot (AWC §4.1) -----------------------------------------

    def features(self, pair_key: str, target_id: int, rtt_recent_ms: float,
                 gamma_prev: float,
                 branches_prev: float = 1.0) -> "FeatureTuple":
        from ..core.window import FeatureSnapshot
        depth = self.queue_depth[target_id] / max(1, self.queue_capacity_hint)
        alpha = self.alpha_recent.get(pair_key)
        pipe = self.pipe_recent.get(pair_key)
        return FeatureSnapshot(
            q_depth=depth,
            alpha_recent=alpha.mean() if alpha else 0.7,
            rtt_recent_ms=rtt_recent_ms,
            tpot_recent_ms=self.tpot_recent.mean(),
            gamma_prev=gamma_prev,
            pipe_hit_recent=pipe.mean() if pipe else 0.0,
            branches_prev=branches_prev,
        )

    # -- summary --------------------------------------------------------------

    def summary(self) -> dict[str, Any]:
        done = [m for m in self.requests.values() if not math.isnan(m.finish_ms)]
        ttft = sorted(m.ttft_ms for m in done if not math.isnan(m.first_token_ms))
        tpot = sorted(m.tpot_ms for m in done if m.tokens_generated > 1)
        e2e = sorted(m.e2e_ms for m in done)
        span_ms = (self._last_finish - (self._first_arrival or 0.0)) or 1.0
        total_busy = sum(self.busy_ms)
        util = total_busy / (self.num_targets * span_ms) if span_ms > 0 else 0.0
        prop = sum(m.draft_tokens_proposed for m in done)
        acc = sum(m.draft_tokens_accepted for m in done)
        # SLO attainment over requests that carry an SLO (graded with the
        # same repro.fleet.workload.slo_report rule the real server's
        # results are graded with, so attainment is comparable sim↔real);
        # lazy import — fleet.workload has no sim dependency at module level
        from ..fleet.workload import slo_report
        slo = slo_report([
            {"request_class": m.request_class or m.dataset,
             "slo_ttft_ms": m.slo_ttft_ms, "slo_tpot_ms": m.slo_tpot_ms,
             "ttft_ms": m.ttft_ms, "tpot_ms": m.tpot_ms}
            for m in done])
        return {
            "completed": len(done),
            "throughput_rps": len(done) / (span_ms / 1e3),
            "token_throughput_tps":
                sum(m.tokens_generated for m in done) / (span_ms / 1e3),
            "ttft_ms": {"mean": sum(ttft) / len(ttft) if ttft else math.nan,
                        "p50": _percentile(ttft, 0.5),
                        "p99": _percentile(ttft, 0.99)},
            "tpot_ms": {"mean": sum(tpot) / len(tpot) if tpot else math.nan,
                        "p50": _percentile(tpot, 0.5),
                        "p99": _percentile(tpot, 0.99)},
            "e2e_ms": {"mean": sum(e2e) / len(e2e) if e2e else math.nan,
                       "p50": _percentile(e2e, 0.5)},
            "acceptance_rate": acc / max(1, prop),
            "slo": slo,
            "target_utilization": util,
            "mean_batch_size":
                sum(self.batch_sizes) / len(self.batch_sizes)
                if self.batch_sizes else 0.0,
            "net_queue_delay_ms": self.net_queue_delay_ms,
            "pipeline_hits": self.pipeline_hits,
            "pipeline_misses": self.pipeline_misses,
            "mean_gamma":
                (sum(sum(m.gamma_sequence) for m in done)
                 / max(1, sum(len(m.gamma_sequence) for m in done))),
        }

    def to_json(self, path: Optional[str] = None) -> str:
        payload = {
            "summary": self.summary(),
            "requests": [
                {**asdict(m),
                 "ttft_ms": m.ttft_ms, "tpot_ms": m.tpot_ms, "e2e_ms": m.e2e_ms,
                 "acceptance_rate": m.acceptance_rate}
                for m in self.requests.values()
                if not math.isnan(m.finish_ms)
            ],
        }
        blob = json.dumps(payload, indent=1)
        if path:
            with open(path, "w") as f:
                f.write(blob)
        return blob
