"""DSD scheduler — the request lifecycle engine of DSD-Sim (paper §3.3–3.4).

Each request progresses through **Routing → Batching → Speculation →
Verification**, iterating speculation/verification until the target-decided
output length is reached. Draft devices and target servers are concurrent
processes (our SimPy-equivalent, :mod:`repro.sim.events`); network links are
delay elements; per-kernel latencies come from the hardware modeling engine
(:mod:`repro.sim.hwmodel`) behind the ``predict(op, shape, hardware)`` API.

Execution modes (paper §3.3):

- **Distributed** — the edge drafter generates γ tokens sequentially, ships
  them to its routed target server, which verifies the window in one batched
  forward; acceptance outcomes are replayed from the trace's ground-truth
  ``acceptance_seq`` (no probabilistic acceptance model).
- **Fused** — cloud-only: the target generates tokens autoregressively in
  chunks with no drafter and no per-window network hop (γ≤1 under AWC
  hysteresis lands here).
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Any, Optional

from .events import Environment, Store
from .network import (DEFAULT_FUSED_CHUNK, Link, LinkSpec,
                      verdict_payload_bytes, window_payload_bytes)
from .hwmodel import HardwareModel, MODELS
from .policies import (BatchingConfig, BatchingPolicy, FIFOBatching,
                       PairRoutingPolicy, RoutingPolicy, RandomRouting,
                       SimPairView)
from .analyzer import Analyzer, RequestMetrics
from .trace import AcceptanceCursor, TraceRecord
from ..core.window import StaticWindowPolicy, WindowPolicy


# --------------------------------------------------------------------------
# Cluster description
# --------------------------------------------------------------------------

@dataclass
class ClusterSpec:
    """An edge pool of drafters plus a cloud pool of target servers.

    Heterogeneity (paper §5.2: cloud pool of LLaMA2-70B/LLaMA3-70B/Qwen-72B
    on A100/H100/A6000; edge pool of 300 A40 + 300 V100 serving three draft
    models): ``target_pool`` / ``draft_pool`` assign (hw, model[, tp]) per
    server round-robin; when None the homogeneous fields apply. Per-pair
    draft quality (acceptance multiplier vs the trace's base acceptance
    stream) comes from DRAFT_QUALITY — heterogeneous pairs are exactly what
    gives per-pair adaptive window control its edge.
    """
    num_targets: int = 4
    target_hw: str = "A100"
    target_model: str = "llama2-70b"
    target_tp: int = 4                  # tensor-parallel degree per server
    num_drafters: int = 64
    draft_hw: str = "A40"
    draft_model: str = "llama2-7b"
    link: LinkSpec = field(default_factory=LinkSpec)
    target_pool: Optional[list] = None    # [(hw, model, tp), ...]
    draft_pool: Optional[list] = None     # [(hw, model), ...]
    # Heterogeneous PER-PAIR links (multi-link topologies): when set,
    # drafter ``d`` always transfers over ``drafter_link_pool[d]``
    # regardless of routed target — the lane model
    # ``repro.topology.build_simulation`` maps PairSpecs onto (drafter i
    # ⇔ pair i). When None, the per-target ``link`` applies to everyone.
    drafter_link_pool: Optional[list] = None   # [LinkSpec per drafter]

    def target_at(self, tid: int) -> tuple:
        if self.target_pool:
            return tuple(self.target_pool[tid % len(self.target_pool)])
        return (self.target_hw, self.target_model, self.target_tp)

    def draft_at(self, did: int) -> tuple:
        if self.draft_pool:
            return tuple(self.draft_pool[did % len(self.draft_pool)])
        return (self.draft_hw, self.draft_model)


# Relative acceptance quality per draft model (multiplier on the trace's
# ground-truth acceptance stream; captured pairs in §5 differ in how well
# the draft tracks the target).
DRAFT_QUALITY: dict[str, float] = {
    "llama2-7b": 1.0,
    "qwen-7b": 0.82,
    "llama3.1-8b": 1.12,
}

# The paper's heterogeneous pools (§5.2).
PAPER_TARGET_POOL = [("A100", "llama2-70b", 4),
                     ("H100", "qwen-72b", 4),
                     ("A6000", "llama3-70b", 4)]
PAPER_DRAFT_POOL = [("A40", "llama2-7b"), ("V100", "qwen-7b"),
                    ("A40", "llama3.1-8b"), ("V100", "llama2-7b"),
                    ("A40", "qwen-7b"), ("V100", "llama3.1-8b")]


@dataclass
class PolicyStack:
    routing: RoutingPolicy = field(default_factory=RandomRouting)
    batching: BatchingPolicy = field(default_factory=FIFOBatching)
    batching_cfg: BatchingConfig = field(default_factory=BatchingConfig)
    window: WindowPolicy = field(default_factory=StaticWindowPolicy)
    # arrival-time lane assignment for unpinned records (drafter_id < 0);
    # None = shallowest-queue (the real server's least-loaded default)
    pair_routing: Optional[PairRoutingPolicy] = None


@dataclass
class Job:
    """A unit of target-server work."""
    request_id: int
    kind: str                 # "verify" | "fused"
    context_len: int          # KV context already cached at the target
    new_tokens: int           # tokens computed this invocation (γ or prompt+γ)
    chunk: int = 0            # fused: autoregressive tokens to produce
    enqueue_ms: float = 0.0
    done: Any = None          # Event, resolved when the batch finishes
    sort_len: int = 0         # LAB batching key


def _quality_adjusted(bits: list[int], quality: float,
                      rng: random.Random) -> list[int]:
    """Scale a ground-truth acceptance stream for a draft of different
    quality: q<1 drops accepts, q>1 converts some rejects to accepts."""
    if abs(quality - 1.0) < 1e-9:
        return bits
    out = []
    for b in bits:
        if b == 1 and quality < 1.0:
            out.append(1 if rng.random() < quality else 0)
        elif b == 0 and quality > 1.0:
            out.append(1 if rng.random() < (quality - 1.0) else 0)
        else:
            out.append(b)
    return out


class DSDSimulation:
    """Wires workload records + cluster + policies into a runnable simulation."""

    def __init__(self, cluster: ClusterSpec, policies: PolicyStack,
                 records: list[TraceRecord],
                 hwmodel: Optional[HardwareModel] = None,
                 seed: int = 0, fused_chunk: int = DEFAULT_FUSED_CHUNK,
                 pipeline: bool = False):
        self.cluster = cluster
        self.policies = policies
        self.records = records
        self.hw = hwmodel or HardwareModel()
        self.fused_chunk = fused_chunk
        # cross-round pipelining: the drafter speculatively drafts window
        # k+1 (and ships it) while window k is being verified, mirroring
        # the real path's mode_policy="pipeline" overlap model
        self.pipeline = bool(pipeline)
        self.env = Environment()
        self.rng = random.Random(seed)
        self.analyzer = Analyzer(cluster.num_targets,
                                 queue_capacity_hint=policies.batching_cfg.max_batch * 4)
        self.links = [Link(self.env, cluster.link, random.Random(seed + 1 + t))
                      for t in range(cluster.num_targets)]
        # per-drafter links (heterogeneous pair topologies) override the
        # per-target links; each keeps its own RTT tracker so pair-local
        # rtt_recent_ms features stay isolated
        self.drafter_links = None
        if cluster.drafter_link_pool:
            self.drafter_links = [
                Link(self.env, spec, random.Random(seed + 101 + d))
                for d, spec in enumerate(cluster.drafter_link_pool)]
        self.target_queues: list[Store] = [Store(self.env)
                                           for _ in range(cluster.num_targets)]
        self.target_busy = [False] * cluster.num_targets
        self.drafter_queues: dict[int, Store] = {}
        self._drafter_started: set[int] = set()
        self.drafter_active: dict[int, int] = {}   # in-service per lane

    # -- public API ----------------------------------------------------------

    def run(self, until_ms: Optional[float] = None) -> Analyzer:
        for t in range(self.cluster.num_targets):
            self.env.process(self._target_proc(t))
        self.env.process(self._source_proc())
        self.env.run(until=until_ms)
        return self.analyzer

    # -- workload source -------------------------------------------------------

    def _source_proc(self):
        for rec in sorted(self.records, key=lambda r: r.arrival_time_ms):
            delay = rec.arrival_time_ms - self.env.now
            if delay > 0:
                yield self.env.timeout(delay)
            if rec.drafter_id < 0:
                # unpinned record: the pair router assigns the lane AT
                # ARRIVAL (the sim twin of the real server's PairRouter —
                # and, like it, sticky: the lane never changes afterwards)
                did = self._route_pair(rec)
            else:
                did = rec.drafter_id % max(1, self.cluster.num_drafters)
            q = self.drafter_queues.get(did)
            if q is None:
                q = self.drafter_queues[did] = Store(self.env)
            q.put(rec)
            if did not in self._drafter_started:
                self._drafter_started.add(did)
                self.env.process(self._drafter_proc(did))

    # -- arrival-time pair routing -------------------------------------------

    def _pinned_target(self, did: int) -> int:
        pinned = getattr(self.policies.routing, "target_of_drafter", None)
        if pinned:
            return pinned[did % len(pinned)]
        return did % max(1, self.cluster.num_targets)

    def _pair_view(self) -> SimPairView:
        nd = max(1, self.cluster.num_drafters)
        depths, rtts, alphas = [], [], []
        for d in range(nd):
            q = self.drafter_queues.get(d)
            depths.append((len(q) if q is not None else 0)
                          + self.drafter_active.get(d, 0))
            if self.drafter_links is not None:
                link = self.drafter_links[d % len(self.drafter_links)]
            else:
                link = self.links[self._pinned_target(d)]
            rtts.append(link.recent_rtt_ms)
            win = self.analyzer.alpha_recent.get(
                f"{d}->{self._pinned_target(d)}")
            alphas.append(win.mean() if win else 0.7)
        return SimPairView(queue_depths=depths, rtt_ms=rtts, alpha=alphas,
                           max_batch=self.policies.batching_cfg.max_batch)

    def _route_pair(self, rec: TraceRecord) -> int:
        view = self._pair_view()
        router = self.policies.pair_routing
        if router is None:      # least-loaded default, ties to lowest lane
            return min(range(len(view.queue_depths)),
                       key=lambda i: (view.queue_depths[i], i))
        did = router.route_pair(rec, view)
        return did % max(1, self.cluster.num_drafters)

    # -- edge drafter ------------------------------------------------------------

    def _drafter_proc(self, drafter_id: int):
        q = self.drafter_queues[drafter_id]
        while True:
            rec = yield q.get()
            self.drafter_active[drafter_id] = \
                self.drafter_active.get(drafter_id, 0) + 1
            yield self.env.process(self._serve_request(rec, drafter_id))
            self.drafter_active[drafter_id] -= 1

    def _queue_depths(self) -> list[int]:
        return [len(q) + (1 if self.target_busy[i] else 0)
                for i, q in enumerate(self.target_queues)]

    def _serve_request(self, rec: TraceRecord, drafter_id: int):
        cl, pol, env = self.cluster, self.policies, self.env
        target_id = pol.routing.route(rec, self._queue_depths())
        pair_key = f"{drafter_id}->{target_id}"
        if self.drafter_links is not None:
            link = self.drafter_links[drafter_id % len(self.drafter_links)]
        else:
            link = self.links[target_id]
        draft_hw, draft_model = cl.draft_at(drafter_id)
        quality = DRAFT_QUALITY.get(draft_model, 1.0)
        pair_rng = random.Random((rec.request_id << 16) ^ drafter_id)

        m = RequestMetrics(
            request_id=rec.request_id, dataset=rec.dataset,
            drafter_id=drafter_id, target_id=target_id,
            arrival_ms=rec.arrival_time_ms, prompt_length=rec.prompt_length,
            output_length=rec.output_length,
            request_class=rec.request_class or rec.dataset,
            slo_ttft_ms=rec.slo_ttft_ms, slo_tpot_ms=rec.slo_tpot_ms)
        self.analyzer.open_request(m)

        cursor = AcceptanceCursor(_quality_adjusted(
            rec.acceptance_seq, quality, pair_rng))
        # Draft-side prefill of the prompt (edge device is busy during it).
        yield env.timeout(self.hw.prefill_ms(
            draft_hw, draft_model, [rec.prompt_length]))

        generated = 0
        target_ctx = 0            # KV tokens cached on the target
        draft_ctx = rec.prompt_length
        gamma_prev = 4.0
        branches_prev = 1.0
        # cross-round pipelining: True when the previous window was fully
        # accepted, so this round's window was already drafted and shipped
        # during the previous verification (its draft scan + outbound hop
        # are hidden)
        pipelined_credit = False
        while generated < rec.output_length:
            feats = self.analyzer.features(pair_key, target_id,
                                           link.recent_rtt_ms, gamma_prev,
                                           branches_prev=branches_prev)
            dec = pol.window.decide(pair_key, feats)
            m.gamma_sequence.append(dec.gamma)
            m.mode_sequence.append(dec.mode)
            iter_start = env.now
            # TPOT is the TARGET's time-per-output-token (paper §4.1): the
            # sample excludes link time (RTT is its own feature —
            # double-counting it here would self-damp the controller), the
            # drafter's serial proposal time (not target service), and
            # target queue wait (featured separately as q_depth — the same
            # double-count argument applies).
            iter_link_ms = 0.0
            iter_draft_ms = 0.0
            queue_wait_0 = m.queue_wait_ms

            if dec.mode == "fused":
                chunk = min(self.fused_chunk, rec.output_length - generated)
                prefill_extra = rec.prompt_length if target_ctx == 0 else 0
                job = Job(request_id=rec.request_id, kind="fused",
                          context_len=max(target_ctx, rec.prompt_length),
                          new_tokens=prefill_extra, chunk=chunk,
                          done=env.event(), sort_len=target_ctx + generated)
                # read last_delay_ms before yielding — the link is shared
                # and another drafter's transfer would clobber it
                ev = link.transfer(64)
                iter_link_ms += link.last_delay_ms
                yield ev
                self._enqueue(target_id, job)
                yield job.done
                ev = link.transfer(64)
                iter_link_ms += link.last_delay_ms
                link.record_rtt(iter_link_ms)   # explicit out+back pair
                yield ev
                produced = chunk
                target_ctx = rec.prompt_length + generated + chunk
                generated += chunk
                draft_ctx = rec.prompt_length + generated
                gamma_prev = 1.0
                branches_prev = 1.0
                pipelined_credit = False   # fused rounds speculate nothing
            else:
                gamma = dec.gamma
                # tree speculation: b > 1 widens the window to the
                # (γ, b) grid — the draft scan stays γ serial steps
                # (branches advance in LOCKSTEP, one masked pass per
                # depth), but the wire pays per NODE and the verify pass
                # computes the whole grid. Pipelining keeps b = 1 (the
                # real path forbids the combination too).
                branches = max(1, int(getattr(dec, "branches", 1)))
                if self.pipeline:
                    branches = 1
                n_nodes = 1 + gamma * branches
                out_bytes = (window_payload_bytes(gamma, n_nodes=n_nodes)
                             if branches > 1 else window_payload_bytes(gamma))
                per_step = self.hw.decode_ms(draft_hw, draft_model,
                                             [draft_ctx])
                draft_scan_ms = gamma * per_step
                if self.pipeline and pipelined_credit:
                    # this window was drafted AND shipped while the
                    # previous window was being verified: neither the
                    # draft scan nor the outbound hop costs time here —
                    # the bytes still crossed the wire
                    d_out = link.charge(out_bytes)
                else:
                    iter_draft_ms = draft_scan_ms
                    yield env.timeout(draft_scan_ms)
                    ev = link.transfer(out_bytes)
                    d_out = link.last_delay_ms
                    iter_link_ms += d_out
                    yield ev
                prefill_extra = rec.prompt_length if target_ctx == 0 else 0
                job = Job(request_id=rec.request_id, kind="verify",
                          context_len=target_ctx,
                          new_tokens=prefill_extra + gamma * branches,
                          done=env.event(), sort_len=target_ctx + prefill_extra)
                self._enqueue(target_id, job)
                yield job.done
                if self.pipeline:
                    n_acc, all_acc = cursor.consume(gamma)
                    # the NEXT window's speculative draft scan overlaps the
                    # verdict's return flight; on a full accept (hit) the
                    # round's residual exposure is max(draft, return hop),
                    # on a partial accept (miss) the optimistic draft is
                    # wasted work the flight already hid and the fresh
                    # re-draft is paid by the next (unpipelined) round
                    d_back = link.charge(verdict_payload_bytes(gamma))
                    link.record_rtt(d_out + d_back)
                    produced = min(n_acc + 1, rec.output_length - generated)
                    continuing = generated + produced < rec.output_length
                    # the speculative draft only happens when another
                    # window will follow (the real path's opt_done guard):
                    # a terminal all-accept pays just the return hop
                    hit = all_acc and continuing
                    pay_ms = max(draft_scan_ms, d_back) if hit else d_back
                    iter_link_ms += d_back
                    iter_draft_ms += pay_ms - d_back
                    yield env.timeout(pay_ms)
                    if continuing:
                        self.analyzer.record_pipeline(pair_key, all_acc)
                    pipelined_credit = hit
                else:
                    ev = link.transfer(verdict_payload_bytes(gamma))
                    iter_link_ms += link.last_delay_ms
                    link.record_rtt(d_out + link.last_delay_ms)
                    yield ev
                    n_acc, _all = cursor.consume(gamma)
                    if branches > 1 and n_acc == 0:
                        # branch-decay rescue replay (mirrors
                        # core.tree.tree_expected_accepted): the primary
                        # chain died at its root, so an alternative root
                        # — the draft's k-th-best token — gets its shot
                        # with per-rank-decayed probability; a rescued
                        # branch contributes its root plus a fresh
                        # (γ−1)-deep chain from the acceptance stream
                        r = 0.4 * min(0.98, max(0.02, feats.alpha_recent))
                        rescue_p = 1.0 - (1.0 - r) ** (branches - 1)
                        if pair_rng.random() < rescue_p:
                            n_tail = (cursor.consume(gamma - 1)[0]
                                      if gamma > 1 else 0)
                            n_acc = 1 + n_tail
                    produced = min(n_acc + 1, rec.output_length - generated)
                generated += produced
                target_ctx = rec.prompt_length + generated
                draft_ctx = rec.prompt_length + generated
                m.draft_tokens_proposed += gamma
                m.draft_tokens_accepted += n_acc
                self.analyzer.record_acceptance(pair_key, gamma, n_acc)
                gamma_prev = float(gamma)
                branches_prev = float(branches)

            m.iterations += 1
            m.tokens_generated += produced
            if math.isnan(m.first_token_ms):
                m.first_token_ms = env.now
            if produced > 0:
                iter_queue_ms = m.queue_wait_ms - queue_wait_0
                self.analyzer.record_tpot_sample(
                    max(0.0, env.now - iter_start - iter_link_ms
                        - iter_draft_ms - iter_queue_ms) / produced)

        self.analyzer.close_request(rec.request_id, env.now)

    # -- cloud target server -------------------------------------------------------

    def _enqueue(self, target_id: int, job: Job) -> None:
        job.enqueue_ms = self.env.now
        self.analyzer.queue_depth[target_id] += 1
        self.target_queues[target_id].put(job)

    def _target_proc(self, tid: int):
        cl, env = self.cluster, self.env
        q = self.target_queues[tid]
        cfg = self.policies.batching_cfg
        while True:
            head = yield q.get()
            self.analyzer.queue_depth[tid] -= 1
            if cfg.batch_window_ms > 0 and len(q) < cfg.max_batch - 1:
                yield env.timeout(cfg.batch_window_ms)
            batch = self._form_batch(tid, head, cfg)
            self.target_busy[tid] = True
            wait = sum(env.now - j.enqueue_ms for j in batch)
            self.analyzer.net_queue_delay_ms += wait
            for j in batch:
                rm = self.analyzer.requests.get(j.request_id)
                if rm:
                    rm.queue_wait_ms += env.now - j.enqueue_ms

            latency_ms = self._batch_latency_ms(batch, tid)
            yield env.timeout(latency_ms)
            self.target_busy[tid] = False
            self.analyzer.record_batch(tid, len(batch), latency_ms)
            for j in batch:
                j.done.succeed()

    def _form_batch(self, tid: int, head: Job, cfg: BatchingConfig) -> list[Job]:
        """Apply the batching policy over same-kind queued jobs only."""
        q = self.target_queues[tid]
        other_kind = [j for j in q.items if j.kind != head.kind]
        same_kind = [j for j in q.items if j.kind == head.kind]
        q.items.clear()
        q.items.extend(same_kind)
        batch = self.policies.batching.form_batch(q, head, cfg)
        taken = len(same_kind) - len(q.items)
        self.analyzer.queue_depth[tid] -= taken
        # restore non-matching jobs at the front, preserving arrival order
        for j in reversed(other_kind):
            q.items.appendleft(j)
        return batch

    def _batch_latency_ms(self, batch: list[Job], tid: int = 0) -> float:
        cl = self.cluster
        t_hw, t_model, t_tp = cl.target_at(tid)
        if batch[0].kind == "verify":
            ctx = [j.context_len for j in batch]
            new = [max(1, j.new_tokens) for j in batch]
            if self.policies.batching_cfg.chunked_prefill:
                # chunked prefill caps per-pass prefill tokens; model as the
                # same total compute (chunks are serialized inside the pass)
                chunk = self.policies.batching_cfg.prefill_chunk
                new = [min(n, chunk) if n > chunk else n for n in new]
                extra = sum(max(0, j.new_tokens - chunk) for j in batch)
                base = self.hw.decode_ms(t_hw, t_model, ctx, new, tp=t_tp)
                if extra > 0:
                    base += self.hw.prefill_ms(t_hw, t_model, [extra],
                                               tp=t_tp)
                return base
            return self.hw.decode_ms(t_hw, t_model, ctx, new, tp=t_tp)
        # fused: sequential autoregressive chunk on the target
        steps = max(j.chunk for j in batch)
        ctx = [j.context_len for j in batch]
        prefill = sum(j.new_tokens for j in batch)
        per_step = self.hw.decode_ms(t_hw, t_model, ctx, tp=t_tp)
        total = steps * per_step
        if prefill > 0:
            total += self.hw.prefill_ms(t_hw, t_model, [prefill], tp=t_tp)
        return total
