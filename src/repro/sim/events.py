"""Discrete-event simulation core for DSD-Sim.

SimPy is not available in this environment, so this module implements the
subset of SimPy semantics the paper's simulator relies on:

- ``Environment`` with a monotonically increasing virtual clock,
- generator-based *processes* that ``yield`` events,
- ``timeout(delay)`` delay events,
- ``Store`` — an unbounded FIFO channel with blocking ``get`` and
  non-blocking ``put`` (used for device queues),
- process join (``yield env.process(...)`` waits for completion).

The scheduler is deterministic: events scheduled at the same timestamp fire
in insertion order (stable heap via a sequence counter), which makes every
simulation run exactly reproducible given a seed for the workload.
"""

from __future__ import annotations

import heapq
from collections import deque
from typing import Any, Callable, Generator, Optional


class Event:
    """A one-shot event. Callbacks run when the event is triggered."""

    __slots__ = ("env", "callbacks", "value", "triggered", "_scheduled")

    def __init__(self, env: "Environment"):
        self.env = env
        self.callbacks: list[Callable[[Any], None]] = []
        self.value: Any = None
        self.triggered = False
        self._scheduled = False

    def succeed(self, value: Any = None) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self.value = value
        self._flush()
        return self

    def _flush(self) -> None:
        # Callbacks fire at the current simulation time, after any events
        # already queued "now" (FIFO among same-time events).
        if not self._scheduled and self.callbacks:
            self._scheduled = True
            self.env._schedule(self.env.now, self._run_callbacks)

    def _run_callbacks(self) -> None:
        callbacks, self.callbacks = self.callbacks, []
        self._scheduled = False
        for cb in callbacks:
            cb(self.value)

    def add_callback(self, cb: Callable[[Any], None]) -> None:
        if self.triggered:
            self.callbacks.append(cb)
            self._flush()
        else:
            self.callbacks.append(cb)


class Timeout(Event):
    """An event that fires ``delay`` time units after creation."""

    __slots__ = ()

    def __init__(self, env: "Environment", delay: float, value: Any = None):
        if delay < 0:
            raise ValueError(f"negative timeout delay: {delay}")
        super().__init__(env)
        self.value = value
        env._schedule(env.now + delay, self._fire)

    def _fire(self) -> None:
        self.triggered = True
        self._flush()


class Process(Event):
    """Wraps a generator; the process resumes whenever its yielded event fires.

    The Process is itself an Event that triggers (with the generator's return
    value) when the generator completes, enabling ``yield env.process(...)``
    joins.
    """

    __slots__ = ("_gen",)

    def __init__(self, env: "Environment", gen: Generator):
        super().__init__(env)
        self._gen = gen
        env._schedule(env.now, lambda: self._step(None))

    def _step(self, value: Any) -> None:
        try:
            target = self._gen.send(value)
        except StopIteration as stop:
            self.succeed(getattr(stop, "value", None))
            return
        if not isinstance(target, Event):
            raise TypeError(
                f"process yielded {target!r}; processes must yield Event objects"
            )
        target.add_callback(self._step)


class Store:
    """Unbounded FIFO store (queue) with blocking ``get``.

    ``items`` is exposed read-only so batching policies can inspect queue
    contents (e.g. length-aware batching scans waiting requests).
    """

    __slots__ = ("env", "items", "_getters")

    def __init__(self, env: "Environment"):
        self.env = env
        self.items: deque = deque()
        self._getters: deque[Event] = deque()

    def __len__(self) -> int:
        return len(self.items)

    def put(self, item: Any) -> None:
        self.items.append(item)
        self._dispatch()

    def _dispatch(self) -> None:
        while self._getters and self.items:
            getter = self._getters.popleft()
            getter.succeed(self.items.popleft())

    def get(self) -> Event:
        ev = Event(self.env)
        if self.items:
            ev.succeed(self.items.popleft())
        else:
            self._getters.append(ev)
        return ev

    def pop_where(self, pred: Callable[[Any], bool]) -> Optional[Any]:
        """Remove and return the first queued item matching ``pred`` (or None).

        Used by length-aware batching to pull similar-length requests out of
        the middle of the queue.
        """
        for i, item in enumerate(self.items):
            if pred(item):
                del self.items[i]
                return item
        return None


class Environment:
    """Deterministic event loop with a virtual clock."""

    __slots__ = ("now", "_heap", "_seq")

    def __init__(self):
        self.now: float = 0.0
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = 0

    def _schedule(self, at: float, fn: Callable[[], None]) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (at, self._seq, fn))

    def timeout(self, delay: float, value: Any = None) -> Timeout:
        return Timeout(self, delay, value)

    def event(self) -> Event:
        return Event(self)

    def process(self, gen: Generator) -> Process:
        return Process(self, gen)

    def store(self) -> Store:
        return Store(self)

    def run(self, until: Optional[float] = None) -> None:
        """Run until the heap drains or the clock passes ``until``."""
        while self._heap:
            at, _, fn = self._heap[0]
            if until is not None and at > until:
                self.now = until
                return
            heapq.heappop(self._heap)
            self.now = at
            fn()
        if until is not None:
            self.now = max(self.now, until)
