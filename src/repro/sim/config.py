"""Configuration parser for DSD-Sim (paper §3.1).

The paper's simulator ingests a YAML system specification (device types,
network links, runtime policies) and runs an ``auto_topology`` pass that
expands it into explicit draft/target pools with fully-connected links.
PyYAML is not installed here, so this module includes a YAML-subset reader
(nested block mappings, block lists, inline scalars/lists, comments) which is
sufficient for the config schema below:

    cluster:
      targets: {count: 20, hw: A100, model: llama2-70b, tp: 4}
      drafters: {count: 600, hw: A40, model: llama2-7b}
      link: {rtt_ms: 10, jitter_ms: 1}
    policies:
      routing: jsq
      batching: {kind: lab, max_batch: 16, batch_window_ms: 2}
      window: {kind: awc, gamma: 4}
    workload:
      dataset: gsm8k
      rate_per_s: 40
      num_requests: 400
      seed: 0
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Optional

from .network import DEFAULT_FUSED_CHUNK, LinkSpec
from .policies import (BATCHING, ROUTING, BatchingConfig)
from .scheduler import ClusterSpec, PolicyStack, DSDSimulation
from .trace import PROFILES, WorkloadGenerator
from .hwmodel import HardwareModel
from ..core.window import make_window_policy


# --------------------------------------------------------------------------
# Mini-YAML
# --------------------------------------------------------------------------

_SCALAR_RE = re.compile(r"^[+-]?(\d+\.?\d*([eE][+-]?\d+)?|\.\d+)$")


def _parse_scalar(tok: str) -> Any:
    tok = tok.strip()
    if tok.startswith(("'", '"')) and tok.endswith(tok[0]) and len(tok) >= 2:
        return tok[1:-1]
    low = tok.lower()
    if low in ("true", "yes"):
        return True
    if low in ("false", "no"):
        return False
    if low in ("null", "none", "~", ""):
        return None
    if _SCALAR_RE.match(tok):
        try:
            return int(tok)
        except ValueError:
            return float(tok)
    return tok


def _split_inline(body: str) -> list[str]:
    """Split a {...} or [...] body on top-level commas."""
    parts, depth, cur = [], 0, []
    for ch in body:
        if ch in "{[":
            depth += 1
        elif ch in "}]":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p for p in (p.strip() for p in parts) if p]


def _parse_value(tok: str) -> Any:
    tok = tok.strip()
    if tok.startswith("{") and tok.endswith("}"):
        out: dict[str, Any] = {}
        for item in _split_inline(tok[1:-1]):
            k, _, v = item.partition(":")
            out[k.strip()] = _parse_value(v)
        return out
    if tok.startswith("[") and tok.endswith("]"):
        return [_parse_value(i) for i in _split_inline(tok[1:-1])]
    return _parse_scalar(tok)


def _strip_comment(line: str) -> str:
    out, in_q = [], None
    for ch in line:
        if in_q:
            out.append(ch)
            if ch == in_q:
                in_q = None
        elif ch in "'\"":
            in_q = ch
            out.append(ch)
        elif ch == "#":
            break
        else:
            out.append(ch)
    return "".join(out).rstrip()


def loads(text: str) -> Any:
    """Parse the YAML subset into dicts/lists/scalars."""
    lines: list[tuple[int, str]] = []
    for raw in text.splitlines():
        line = _strip_comment(raw)
        if not line.strip():
            continue
        indent = len(line) - len(line.lstrip(" "))
        lines.append((indent, line.strip()))

    def parse_block(idx: int, indent: int) -> tuple[Any, int]:
        result: Any = None
        while idx < len(lines):
            ind, content = lines[idx]
            if ind < indent:
                break
            if ind > indent:
                raise ValueError(f"bad indent at line: {content!r}")
            if content.startswith("- "):
                if result is None:
                    result = []
                item_txt = content[2:].strip()
                if item_txt.endswith(":") or ":" in item_txt and not item_txt.startswith(("{", "[")):
                    # list of mappings: re-parse as a one-line mapping + block
                    k, _, v = item_txt.partition(":")
                    if v.strip():
                        d = {k.strip(): _parse_value(v)}
                        result.append(d)
                        idx += 1
                    else:
                        sub, idx2 = parse_block(idx + 1, indent + 2)
                        d = {k.strip(): sub}
                        result.append(d)
                        idx = idx2
                else:
                    result.append(_parse_value(item_txt))
                    idx += 1
                continue
            key, _, val = content.partition(":")
            key = key.strip()
            if result is None:
                result = {}
            if val.strip():
                result[key] = _parse_value(val)
                idx += 1
            else:
                sub, idx2 = parse_block(idx + 1, ind + 2)
                result[key] = sub if sub is not None else {}
                idx = idx2
        return result, idx

    parsed, _ = parse_block(0, 0)
    return parsed


def load(path: str) -> Any:
    with open(path) as f:
        return loads(f.read())


# --------------------------------------------------------------------------
# auto_topology: high-level spec -> runnable simulation
# --------------------------------------------------------------------------

@dataclass
class SimSpec:
    cluster: ClusterSpec
    policies: PolicyStack
    workload_dataset: str = "gsm8k"
    workload_rate: float = 40.0
    num_requests: int = 200
    seed: int = 0
    fused_chunk: int = DEFAULT_FUSED_CHUNK


def _build_window_policy(w: dict[str, Any], awc_predictor=None):
    """YAML window mapping → policy instance, via the shared factory
    (:func:`repro.core.window.make_window_policy`) so the YAML reader,
    the topology spec layer and the launcher flags construct policies
    through one code path."""
    w = w or {}
    return make_window_policy(str(w.get("kind", "static")),
                              gamma=int(w.get("gamma", 4)),
                              hi=float(w.get("hi", 0.75)),
                              lo=float(w.get("lo", 0.25)),
                              gmax=int(w.get("gmax", 12)),
                              predictor=awc_predictor)


def auto_topology(doc: dict[str, Any], awc_predictor=None) -> SimSpec:
    """Expand a high-level YAML document into an explicit SimSpec.

    Mirrors the paper's auto_topology pass: a pool count + device class
    becomes explicit device pools with per-target links.
    """
    c = doc.get("cluster", {})
    targets = c.get("targets", {})
    drafters = c.get("drafters", {})
    link = c.get("link", {})
    cluster = ClusterSpec(
        num_targets=int(targets.get("count", 4)),
        target_hw=str(targets.get("hw", "A100")),
        target_model=str(targets.get("model", "llama2-70b")),
        target_tp=int(targets.get("tp", 4)),
        num_drafters=int(drafters.get("count", 64)),
        draft_hw=str(drafters.get("hw", "A40")),
        draft_model=str(drafters.get("model", "llama2-7b")),
        link=LinkSpec(rtt_ms=float(link.get("rtt_ms", 10.0)),
                      jitter_ms=float(link.get("jitter_ms", 1.0)),
                      bandwidth_gbps=float(link.get("bandwidth_gbps", 1.0))),
    )
    p = doc.get("policies", {})
    routing = ROUTING[str(p.get("routing", "random"))]
    routing = routing() if routing is not ROUTING["random"] else routing(
        seed=int(doc.get("workload", {}).get("seed", 0)))
    b = p.get("batching", {}) or {}
    batching_cfg = BatchingConfig(
        max_batch=int(b.get("max_batch", 16)),
        batch_window_ms=float(b.get("batch_window_ms", 2.0)),
        continuous=bool(b.get("continuous", True)),
        chunked_prefill=bool(b.get("chunked_prefill", False)),
        prefill_chunk=int(b.get("prefill_chunk", 512)))
    batching = BATCHING[str(b.get("kind", "fifo"))]()
    window = _build_window_policy(p.get("window", {}), awc_predictor)
    policies = PolicyStack(routing=routing, batching=batching,
                           batching_cfg=batching_cfg, window=window)
    w = doc.get("workload", {})
    return SimSpec(
        cluster=cluster, policies=policies,
        workload_dataset=str(w.get("dataset", "gsm8k")),
        workload_rate=float(w.get("rate_per_s", 40.0)),
        num_requests=int(w.get("num_requests", 200)),
        seed=int(w.get("seed", 0)),
        fused_chunk=int(doc.get("fused_chunk", DEFAULT_FUSED_CHUNK)))


def build_simulation(spec: SimSpec,
                     hwmodel: Optional[HardwareModel] = None) -> DSDSimulation:
    gen = WorkloadGenerator(spec.workload_dataset, spec.workload_rate,
                            spec.cluster.num_drafters, seed=spec.seed)
    records = gen.generate(spec.num_requests)
    return DSDSimulation(spec.cluster, spec.policies, records,
                         hwmodel=hwmodel, seed=spec.seed,
                         fused_chunk=spec.fused_chunk)


def simulate_from_yaml(text: str, awc_predictor=None,
                       hwmodel: Optional[HardwareModel] = None):
    """One-call entry: YAML text → Analyzer summary dict."""
    spec = auto_topology(loads(text), awc_predictor)
    sim = build_simulation(spec, hwmodel)
    analyzer = sim.run()
    return analyzer
