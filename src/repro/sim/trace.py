"""Workload traces for DSD-Sim (paper §3.2).

A trace record carries exactly the Table-1 schema:

    prompt_length, output_length, acceptance_seq, arrival_time_ms, drafter_id

``acceptance_seq`` is the *ground-truth* per-draft-token accept/reject stream
for a given draft–target pair. The paper captures these from real GPU
profiling runs; here they come from either (i) real reduced JAX draft/target
pairs executed by ``repro.core.engine`` (see examples/capture_traces.py), or
(ii) a calibrated synthetic process matched to each benchmark's acceptance
regime. The synthetic process is a two-state Markov chain — acceptance in LLM
speculation is empirically bursty (runs of easy tokens accept together), and
burstiness is precisely what gives adaptive γ policies their edge.

Arrivals: trace-driven replay or synthetic Poisson (global rate, uniformly
spread over drafters), per §3.2 "Arrival Process".
"""

from __future__ import annotations

import json
import math
import random
from dataclasses import dataclass, field, asdict
from typing import Iterator, Optional


@dataclass
class TraceRecord:
    request_id: int
    prompt_length: int
    output_length: int
    acceptance_seq: list[int]
    arrival_time_ms: float
    drafter_id: int              # < 0: unpinned — the scheduler's pair
                                 # router assigns the lane at arrival time
    dataset: str = "synthetic"
    request_class: str = ""      # fleet traffic class ("" = dataset name)
    slo_ttft_ms: float = 0.0     # per-request TTFT target (0 = no SLO)
    slo_tpot_ms: float = 0.0     # per-request TPOT target (0 = no SLO)

    def to_json(self) -> str:
        return json.dumps(asdict(self))

    @staticmethod
    def from_json(line: str) -> "TraceRecord":
        return TraceRecord(**json.loads(line))


@dataclass(frozen=True)
class DatasetProfile:
    """Statistical profile of one benchmark workload.

    Lengths are lognormal (empirically heavy-tailed); acceptance is a 2-state
    Markov chain with stationary rate ``alpha`` and stickiness ``rho``
    (P[accept|prev accept] = alpha + rho(1-alpha)).
    """
    name: str
    prompt_mean: float
    prompt_sigma: float     # lognormal sigma of ln(length)
    output_mean: float
    output_sigma: float
    alpha: float            # stationary acceptance rate
    rho: float              # burstiness / autocorrelation in [0,1)
    max_prompt: int = 4096
    max_output: int = 1024


# Profiles matched to the paper's three benchmarks (§3.2, §5): GSM8K is
# reasoning (short prompts, medium outputs, high acceptance — the paper's
# largest AWC win), CNN/DailyMail is summarization (long prompts, short
# outputs), HumanEval is code (medium prompts, long outputs, volatile
# acceptance).
PROFILES: dict[str, DatasetProfile] = {
    "gsm8k":     DatasetProfile("gsm8k",      60, 0.45, 100, 0.50, 0.80, 0.55),
    "cnndm":     DatasetProfile("cnndm",     700, 0.35,  60, 0.45, 0.65, 0.40),
    "humaneval": DatasetProfile("humaneval", 130, 0.50, 180, 0.60, 0.72, 0.65),
}


def _lognormal_int(rng: random.Random, mean: float, sigma: float,
                   lo: int, hi: int) -> int:
    mu = math.log(mean) - 0.5 * sigma * sigma
    val = int(round(math.exp(rng.gauss(mu, sigma))))
    return max(lo, min(hi, val))


def markov_acceptance_seq(rng: random.Random, n: int, alpha: float,
                          rho: float) -> list[int]:
    """Two-state Markov chain with stationary P[accept]=alpha, stickiness rho."""
    p_aa = alpha + rho * (1.0 - alpha)          # accept -> accept
    p_ra = alpha * (1.0 - rho) / max(1e-9, 1.0 - rho * alpha)  # reject -> accept
    p_ra = min(1.0, max(0.0, p_ra))
    seq = []
    state = 1 if rng.random() < alpha else 0
    for _ in range(n):
        seq.append(state)
        p = p_aa if state == 1 else p_ra
        state = 1 if rng.random() < p else 0
    return seq


def empirical_alpha(seq: list[int]) -> float:
    return sum(seq) / max(1, len(seq))


class WorkloadGenerator:
    """Synthetic workload per §3.2: Poisson arrivals, profile-driven records."""

    def __init__(self, profile: DatasetProfile | str, rate_per_s: float,
                 num_drafters: int, seed: int = 0,
                 max_gamma: int = 16):
        self.profile = PROFILES[profile] if isinstance(profile, str) else profile
        self.rate = rate_per_s
        self.num_drafters = num_drafters
        self.rng = random.Random(seed)
        self.max_gamma = max_gamma

    def generate(self, n_requests: int, start_ms: float = 0.0) -> list[TraceRecord]:
        t = start_ms
        records = []
        p = self.profile
        for rid in range(n_requests):
            t += self.rng.expovariate(self.rate) * 1e3
            out_len = _lognormal_int(self.rng, p.output_mean, p.output_sigma,
                                     4, p.max_output)
            # Enough acceptance bits for worst case: every iteration draws up
            # to max_gamma bits and may accept as few as 1 token.
            bits = markov_acceptance_seq(self.rng, out_len * self.max_gamma,
                                         p.alpha, p.rho)
            records.append(TraceRecord(
                request_id=rid,
                prompt_length=_lognormal_int(self.rng, p.prompt_mean,
                                             p.prompt_sigma, 4, p.max_prompt),
                output_length=out_len,
                acceptance_seq=bits,
                arrival_time_ms=t,
                drafter_id=self.rng.randrange(self.num_drafters),
                dataset=p.name,
            ))
        return records


def load_trace(path: str) -> list[TraceRecord]:
    with open(path) as f:
        return [TraceRecord.from_json(line) for line in f if line.strip()]


def save_trace(records: list[TraceRecord], path: str) -> None:
    with open(path, "w") as f:
        for r in records:
            f.write(r.to_json() + "\n")


class AcceptanceCursor:
    """Streams a record's acceptance bits across speculation iterations.

    ``consume(gamma)`` returns (n_accepted_draft_tokens, all_accepted):
    the standard SD semantics — scan γ bits, stop at the first 0.
    If the trace runs dry, recycle from the start (records carry a generous
    bit budget so this is rare).
    """

    def __init__(self, seq: list[int]):
        self.seq = seq or [1]
        self.pos = 0

    def consume(self, gamma: int) -> tuple[int, bool]:
        n_acc = 0
        for _ in range(gamma):
            bit = self.seq[self.pos % len(self.seq)]
            self.pos += 1
            if bit == 1:
                n_acc += 1
            else:
                return n_acc, False
        return n_acc, True
