"""DSD-Sim — request-level discrete-event simulator for distributed
speculative decoding (paper §3)."""

from .events import Environment, Store
from .network import Link, LinkSpec
from .hwmodel import DEVICES, MODELS, HardwareModel, ModelDesc, OpShape, register_model
from .trace import (PROFILES, AcceptanceCursor, DatasetProfile, TraceRecord,
                    WorkloadGenerator, load_trace, save_trace)
from .policies import (BATCHING, ROUTING, BatchingConfig, FIFOBatching,
                       JSQRouting, LengthAwareBatching, RandomRouting,
                       RoundRobinRouting)
from .scheduler import ClusterSpec, DSDSimulation, Job, PolicyStack
from .analyzer import Analyzer, RequestMetrics
from .config import (SimSpec, auto_topology, build_simulation, load, loads,
                     simulate_from_yaml)

__all__ = [
    "Environment", "Store", "Link", "LinkSpec", "DEVICES", "MODELS",
    "HardwareModel", "ModelDesc", "OpShape", "register_model", "PROFILES",
    "AcceptanceCursor", "DatasetProfile", "TraceRecord", "WorkloadGenerator",
    "load_trace", "save_trace", "BATCHING", "ROUTING", "BatchingConfig",
    "FIFOBatching", "JSQRouting", "LengthAwareBatching", "RandomRouting",
    "RoundRobinRouting", "ClusterSpec", "DSDSimulation", "Job", "PolicyStack",
    "Analyzer", "RequestMetrics", "SimSpec", "auto_topology",
    "build_simulation", "load", "loads", "simulate_from_yaml",
]
