"""Hardware performance-modeling engine for DSD-Sim.

The paper plugs VIDUR's empirically-profiled per-op latency predictors into
the scheduler behind a single API: ``predict(op, shape, hardware)``. VIDUR's
GPU profiling tables are not reproducible in this container, so we implement
an *analytical roofline predictor* over a published-spec device catalog and
expose the identical API. A calibration hook (``fit_calibration``) scales the
analytic model against wall-clock measurements (benchmarks/fig4 runs it
against real JAX executions on this host), mirroring the paper's Fig. 4
methodology of validating the modeling engine against real hardware.

Latency model (per batched op):

    t = max(flops / (peak_flops * eff_f), bytes / (hbm_bw * eff_b))
        + tp_comm + overhead

where ``bytes`` counts the weight working set (read once per batch), KV-cache
traffic, and activation traffic; ``tp_comm`` models per-layer tensor-parallel
all-reduces over the intra-server link.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional


# --------------------------------------------------------------------------
# Device catalog (published peak specs; dense fp16/bf16 tensor FLOP/s)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class DeviceSpec:
    name: str
    peak_flops: float          # dense bf16/fp16 FLOP/s per chip
    hbm_bw: float              # bytes/s
    mem_bytes: float
    link_bw: float             # intra-server interconnect, bytes/s per direction
    flops_eff: float = 0.45    # achievable fraction of peak in serving kernels
    bw_eff: float = 0.65
    overhead_s: float = 2.0e-4  # per-dispatch launch/framework overhead


DEVICES: dict[str, DeviceSpec] = {
    # Edge GPUs serve one small model with resident weights; decode kernels
    # stream weights at ~85-90% of HBM bw (calibration note: this constant
    # positions the paper's Fig.6 distributed/fused crossover; see
    # benchmarks/fig6_rtt_crossover.py).
    "A40":   DeviceSpec("A40",   149.7e12, 696e9,  48e9,  32e9, bw_eff=0.88),
    "V100":  DeviceSpec("V100",  125.0e12, 900e9,  32e9,  150e9, bw_eff=0.88),
    "A6000": DeviceSpec("A6000", 154.8e12, 768e9,  48e9,  32e9),
    "A100":  DeviceSpec("A100",  312.0e12, 2039e9, 80e9,  300e9),
    "H100":  DeviceSpec("H100",  989.0e12, 3350e9, 80e9,  450e9),
    # TPU v5e — the target hardware for the JAX framework layers.
    "TPUv5e": DeviceSpec("TPUv5e", 197.0e12, 819e9, 16e9, 50e9),
    # The host this repo runs on; eff factors are fit by fit_calibration().
    "CPU":   DeviceSpec("CPU", 2.0e11, 2.0e10, 64e9, 1e10,
                        flops_eff=0.5, bw_eff=0.5, overhead_s=5e-4),
}


# --------------------------------------------------------------------------
# Model catalog — enough architecture detail for flop/byte accounting
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelDesc:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    n_experts: int = 0          # 0 = dense
    experts_per_tok: int = 0
    dtype_bytes: int = 2

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def params(self) -> int:
        """Total parameter count (attn + ffn + embeddings)."""
        attn = self.n_layers * (
            self.d_model * self.n_heads * self.head_dim        # Q
            + 2 * self.d_model * self.n_kv_heads * self.head_dim  # K,V
            + self.n_heads * self.head_dim * self.d_model      # O
        )
        ffn_mult = max(1, self.n_experts)
        ffn = self.n_layers * ffn_mult * 3 * self.d_model * self.d_ff  # SwiGLU
        emb = 2 * self.vocab * self.d_model
        return attn + ffn + emb

    @property
    def active_params(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.n_experts == 0:
            return self.params
        attn = self.n_layers * (
            self.d_model * self.n_heads * self.head_dim
            + 2 * self.d_model * self.n_kv_heads * self.head_dim
            + self.n_heads * self.head_dim * self.d_model
        )
        ffn = self.n_layers * self.experts_per_tok * 3 * self.d_model * self.d_ff
        emb = 2 * self.vocab * self.d_model
        return attn + ffn + emb

    def kv_bytes_per_token(self) -> int:
        return self.n_layers * 2 * self.n_kv_heads * self.head_dim * self.dtype_bytes


MODELS: dict[str, ModelDesc] = {
    # Paper's edge draft models
    "llama2-7b":   ModelDesc("llama2-7b",   32, 4096, 32, 32, 11008, 32000),
    "qwen-7b":     ModelDesc("qwen-7b",     32, 4096, 32, 32, 11008, 151936),
    "llama3.1-8b": ModelDesc("llama3.1-8b", 32, 4096, 32, 8, 14336, 128256),
    # Paper's cloud target models
    "llama2-70b":  ModelDesc("llama2-70b",  80, 8192, 64, 8, 28672, 32000),
    "llama3-70b":  ModelDesc("llama3-70b",  80, 8192, 64, 8, 28672, 128256),
    "qwen-72b":    ModelDesc("qwen-72b",    80, 8192, 64, 8, 24576, 152064),
}


def register_model(desc: ModelDesc) -> None:
    MODELS[desc.name] = desc


# --------------------------------------------------------------------------
# The predictor
# --------------------------------------------------------------------------

@dataclass
class OpShape:
    """Shape description for one batched op invocation.

    ``context_lens`` — per-sequence KV context length at execution time.
    ``new_tokens``   — tokens computed per sequence this invocation
                       (prompt length for prefill; γ+1 for verify; 1 for decode).
    """
    context_lens: list[int]
    new_tokens: list[int]

    @property
    def batch(self) -> int:
        return len(self.context_lens)

    @property
    def total_new(self) -> int:
        return sum(self.new_tokens)

    @property
    def padded_new(self) -> int:
        """Tokens actually computed under right-padding to the batch max."""
        return self.batch * max(self.new_tokens) if self.new_tokens else 0

    @property
    def padded_context(self) -> int:
        return self.batch * max(self.context_lens) if self.context_lens else 0


class HardwareModel:
    """``predict(op, shape, hardware)`` — the unified VIDUR-style API."""

    def __init__(self, calibration: Optional[dict[str, float]] = None):
        # multiplicative fudge factors fit against real measurements
        self.calibration = dict(calibration or {})

    # -- core roofline -----------------------------------------------------

    def _roofline_s(self, dev: DeviceSpec, flops: float, bytes_: float,
                    tp: int, act_bytes_comm: float) -> float:
        t_compute = flops / (dev.peak_flops * dev.flops_eff * tp)
        t_memory = bytes_ / (dev.hbm_bw * dev.bw_eff * tp)
        # ring all-reduce cost over tp chips: 2(tp-1)/tp of the payload per chip
        t_comm = 0.0
        if tp > 1:
            t_comm = 2.0 * (tp - 1) / tp * act_bytes_comm / dev.link_bw
        return max(t_compute, t_memory) + t_comm + dev.overhead_s

    def predict(self, op: str, shape: OpShape, hardware: str,
                model: str, tp: int = 1) -> float:
        """Latency in **seconds** for one batched invocation of ``op``.

        op ∈ {"prefill", "decode", "verify"}; "verify" is a decode-phase
        forward over γ+1 tokens per sequence (the SD verification step) and
        shares the decode cost model with multi-token new_tokens.
        """
        dev = DEVICES[hardware]
        m = MODELS[model]
        pad_new = max(1, shape.padded_new)
        weight_bytes = m.active_params * m.dtype_bytes
        if m.n_experts > 0:
            # Each token routes to experts_per_tok experts but a *batch* touches
            # min(E, batch·k) expert weight sets; approximate with saturation.
            touched = min(m.n_experts, max(1, shape.total_new) * m.experts_per_tok)
            ffn_w = m.n_layers * touched * 3 * m.d_model * m.d_ff * m.dtype_bytes
            dense_w = (m.active_params
                       - m.n_layers * m.experts_per_tok * 3 * m.d_model * m.d_ff)
            weight_bytes = dense_w * m.dtype_bytes + ffn_w

        # Linear-layer flops: 2 * active_params per computed token.
        flops = 2.0 * m.active_params * pad_new
        # Attention score/value flops: 4 * d_model * context per new token
        # (2 for QK^T, 2 for PV; GQA does not reduce this — all Q heads attend).
        attn_ctx = 0.0
        for ctx, new in zip(shape.context_lens, shape.new_tokens):
            if op == "prefill":
                attn_ctx += new * (new + 1) / 2.0   # causal triangle
            else:
                attn_ctx += new * ctx + new * (new + 1) / 2.0
        flops += 4.0 * m.n_layers * m.d_model * attn_ctx

        # Byte traffic: weights (once per batch) + KV cache read + KV write
        kv_read = sum(c for c in shape.context_lens) * m.kv_bytes_per_token()
        kv_write = shape.total_new * m.kv_bytes_per_token()
        act_bytes = pad_new * m.d_model * m.dtype_bytes * m.n_layers * 2
        bytes_ = weight_bytes + (0 if op == "prefill" else kv_read) + kv_write

        comm_payload = pad_new * m.d_model * m.dtype_bytes * m.n_layers
        t = self._roofline_s(dev, flops, bytes_, tp, comm_payload)
        key = f"{hardware}:{op}"
        cal = self.calibration.get(key, self.calibration.get(hardware))
        if cal is not None:
            if isinstance(cal, (tuple, list)):
                a, b = cal
                t = max(1e-9, a + b * t)
            else:
                t = t * cal
        return t

    # convenience wrappers used by the scheduler --------------------------

    def prefill_ms(self, hardware: str, model: str, prompt_lens: list[int],
                   tp: int = 1) -> float:
        shp = OpShape(context_lens=[0] * len(prompt_lens), new_tokens=list(prompt_lens))
        return self.predict("prefill", shp, hardware, model, tp) * 1e3

    def decode_ms(self, hardware: str, model: str, context_lens: list[int],
                  tokens_per_seq: Optional[list[int]] = None, tp: int = 1) -> float:
        toks = tokens_per_seq or [1] * len(context_lens)
        shp = OpShape(context_lens=list(context_lens), new_tokens=list(toks))
        return self.predict("verify" if max(toks) > 1 else "decode",
                            shp, hardware, model, tp) * 1e3

    # -- calibration -------------------------------------------------------

    def fit_calibration(self, samples: list[tuple[str, str, OpShape, str, float]]
                        ) -> dict[str, object]:
        """Fit per-(hardware, op) affine corrections t ≈ a + b·t_raw from
        measured samples (a captures fixed dispatch overhead, b kernel
        efficiency). Falls back to a geometric-mean ratio with <2 samples.

        ``samples``: (op, hardware, shape, model, measured_seconds).
        """
        by_key: dict[str, list[tuple[float, float]]] = {}
        saved = dict(self.calibration)
        self.calibration = {}
        try:
            for op, hw, shape, model, measured in samples:
                raw = self.predict(op, shape, hw, model)
                if raw > 0 and measured > 0:
                    by_key.setdefault(f"{hw}:{op}", []).append((raw, measured))
        finally:
            self.calibration = saved
        for key, pts in by_key.items():
            if len(pts) >= 2:
                xs = [p for p, _ in pts]
                ys = [m for _, m in pts]
                n = len(pts)
                mx, my = sum(xs) / n, sum(ys) / n
                sxx = sum((x - mx) ** 2 for x in xs)
                b = (sum((x - mx) * (y - my) for x, y in pts) / sxx
                     if sxx > 1e-18 else 1.0)
                if b <= 0:       # degenerate fit: fall back to ratio
                    b = my / mx if mx > 0 else 1.0
                    a = 0.0
                else:
                    a = my - b * mx
                self.calibration[key] = (max(0.0, a), b)
            else:
                raw, meas = pts[0]
                self.calibration[key] = meas / raw
        return dict(self.calibration)

    def mean_abs_pct_error(self, samples: list[tuple[str, str, OpShape, str, float]]
                           ) -> float:
        errs = []
        for op, hw, shape, model, measured in samples:
            pred = self.predict(op, shape, hw, model)
            errs.append(abs(pred - measured) / measured)
        return 100.0 * sum(errs) / max(1, len(errs))
