"""Topology-first deployment API: ONE declarative, JSON-round-trippable
:class:`ClusterSpec` that builds the real execution path, the serving
layer, AND DSD-Sim.

The paper's premise is *agile* serving across heterogeneous edge-cloud
deployments — which draft model sits behind which link to which target is
the first-class input, not an emergent property of launcher flags. This
module is that input:

- :class:`NodeSpec`   — one device in the deployment (role ``draft`` or
  ``target``, real-model config name, device/hardware hints for the real
  and simulated paths);
- :class:`PairSpec`   — one draft→target lane: node references, its
  :class:`~repro.sim.network.LinkSpec` (``None`` = colocated), its window
  policy (:class:`WindowSpec`) and its mode policy;
- :class:`ClusterSpec` — nodes + pairs + serving/batching knobs
  (:class:`ServingSpec`) + a workload description (:class:`WorkloadSpec`),
  with ``validate()`` and exact ``to_json()``/``from_json()``.

Two factories consume the SAME spec, making sim↔real parity a property of
the spec rather than of per-benchmark plumbing:

- :func:`build_deployment` → a :class:`Deployment` of runtime
  :class:`~repro.serving.ServingPair` lanes (engines with shared per-node
  params, one transport + one window-policy stabilizer per pair) driving
  the real-model :class:`~repro.serving.SpecDecodeServer`;
- :func:`build_simulation` → a matching :class:`~repro.sim.DSDSimulation`
  (one sim drafter per pair, pair-pinned routing, per-pair links).

``launch.serve --topology cluster.json`` feeds a spec straight in; the
legacy flag surface compiles down to a one-pair spec through
:func:`one_pair_spec` and the same factories, so old invocations stay
behaviorally identical.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional

from .sim.network import LinkSpec
from .fleet.workload import TraceSpec, WorkloadError

MODE_POLICIES = ("auto", "distributed", "fused", "pipeline")
WINDOW_KINDS = ("static", "dynamic", "awc")
ROLES = ("draft", "target")

# role defaults for the DSD-Sim mapping (hardware class, hwmodel name, tp)
_SIM_ROLE_DEFAULTS = {"target": ("A100", "llama2-70b", 4),
                     "draft": ("A40", "llama2-7b", 1)}


class TopologyError(ValueError):
    """A ClusterSpec failed validation."""


@dataclass
class NodeSpec:
    """One device in the deployment.

    ``model`` names a registered real-model config
    (:func:`repro.configs.get_config`, reduced for host runs) unless the
    factory is handed an override via ``model_configs``. ``device`` is a
    placement hint for the real path; ``address``/``port`` place
    process-backed worker hosts (:mod:`repro.distributed.host`);
    ``hw``/``sim_model``/``tp`` feed the DSD-Sim hardware model and
    default per role when empty/0."""
    id: str
    role: str                    # "draft" | "target"
    model: str = ""
    device: str = ""             # e.g. "cpu", "tpu:0", "edge-phone"
    hw: str = ""                 # sim hardware class (A100/A40/...)
    sim_model: str = ""          # sim hwmodel name (llama2-7b/...)
    tp: int = 0                  # sim tensor-parallel degree (0 = default)
    address: str = ""            # host address for process-backed pairs
                                 # ("" = 127.0.0.1)
    port: int = 0                # listen port for process-backed pairs
                                 # (0 = ephemeral, handshaken over stdout)

    def sim_tuple(self) -> tuple:
        hw, model, tp = _SIM_ROLE_DEFAULTS[self.role]
        return (self.hw or hw, self.sim_model or model, self.tp or tp)


@dataclass
class WindowSpec:
    """Declarative window policy for one pair
    (:func:`repro.core.window.make_window_policy` arguments)."""
    kind: str = "static"         # static | dynamic | awc
    gamma: int = 4               # static γ / dynamic γ0
    hi: float = 0.75             # dynamic raise threshold
    lo: float = 0.25             # dynamic lower threshold
    gmax: int = 12               # dynamic upper bound


@dataclass
class PairSpec:
    """One draft→target lane: who talks to whom, over what link, under
    which window/mode policy. ``link=None`` declares a colocated pair (no
    transport; the engine's virtual ``rtt_ms`` accounting applies);
    ``link.rtt_ms == 0`` declares a zero-delay in-process transport (the
    bit-identity anchor)."""
    id: str
    draft: str                   # NodeSpec id (role "draft")
    target: str                  # NodeSpec id (role "target")
    link: Optional[LinkSpec] = None
    window: WindowSpec = field(default_factory=WindowSpec)
    mode_policy: str = "auto"    # auto | distributed | fused | pipeline
    process: bool = False        # run draft/target as separate OS processes
                                 # over a SocketTransport (greedy + static
                                 # window + distributed mode only)


@dataclass
class ServingSpec:
    """Serving/batching/engine knobs shared by every pair."""
    max_batch: int = 4           # slot-pool capacity per pair
    length_aware: bool = True    # LAB admission (vs FIFO)
    pad_to: int = 16
    max_prompt_len: Optional[int] = None
    max_new_cap: Optional[int] = None
    eos_id: int = -1
    sync_every: int = 8
    gamma_max: int = 12          # compile-once window bound
    temperature: float = 0.0
    rtt_ms: float = 0.0          # colocated pairs' virtual RTT charge
    router: str = "least-loaded"  # repro.serving.PAIR_ROUTERS key
    server: str = "continuous"   # continuous | wave (wave: 1 colocated pair)


@dataclass
class WorkloadSpec:
    """Request stream description (drives ``launch.serve`` defaults and
    :func:`build_simulation`'s generated records when no captured traces
    are supplied).

    ``trace`` upgrades the stream to a fleet
    :class:`~repro.fleet.workload.TraceSpec` — request classes with
    per-class length distributions and TTFT/TPOT SLOs, diurnal/burst/replay
    load shapes — and supersedes the flat ``num_requests``/``rate_per_s``/
    ``prompt_lo``/``prompt_hi`` surface when present (``max_new`` still
    caps nothing: per-class output distributions decide lengths)."""
    dataset: str = "gsm8k"
    num_requests: int = 8
    max_new: int = 32
    rate_per_s: float = 0.0      # Poisson arrivals (0 = all at t=0)
    prompt_lo: int = 8           # synthetic prompt-length range: lengths
    prompt_hi: int = 48          # drawn from [prompt_lo, prompt_hi) —
                                 # EXCLUSIVE upper bound (numpy integers
                                 # semantics, the legacy launcher's rule)
    trace: Optional["TraceSpec"] = None   # fleet trace (classes+SLOs+shape)


@dataclass
class ClusterSpec:
    """The whole deployment: nodes + pairs + serving knobs + workload."""
    nodes: list[NodeSpec] = field(default_factory=list)
    pairs: list[PairSpec] = field(default_factory=list)
    serving: ServingSpec = field(default_factory=ServingSpec)
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    seed: int = 0

    # -- validation ----------------------------------------------------------

    def node(self, node_id: str) -> NodeSpec:
        for n in self.nodes:
            if n.id == node_id:
                return n
        raise TopologyError(f"unknown node id {node_id!r}")

    def validate(self) -> "ClusterSpec":
        """Structural validation; raises :class:`TopologyError` with the
        first violation. Returns self for chaining."""
        if not self.nodes:
            raise TopologyError("a cluster needs at least one node")
        if not self.pairs:
            raise TopologyError("a cluster needs at least one pair")
        seen: set[str] = set()
        for n in self.nodes:
            if not n.id or not isinstance(n.id, str):
                raise TopologyError(f"node id must be a non-empty string, "
                                    f"got {n.id!r}")
            if n.id in seen:
                raise TopologyError(f"duplicate node id {n.id!r}")
            seen.add(n.id)
            if n.role not in ROLES:
                raise TopologyError(
                    f"node {n.id!r}: role must be one of {ROLES}, "
                    f"got {n.role!r}")
            if n.tp < 0:
                raise TopologyError(f"node {n.id!r}: tp must be >= 0")
            if not (0 <= n.port <= 65535):
                raise TopologyError(
                    f"node {n.id!r}: port must be in [0, 65535], "
                    f"got {n.port}")
        pair_ids: set[str] = set()
        for p in self.pairs:
            if not p.id or not isinstance(p.id, str):
                raise TopologyError(f"pair id must be a non-empty string, "
                                    f"got {p.id!r}")
            if p.id in pair_ids:
                raise TopologyError(f"duplicate pair id {p.id!r}")
            pair_ids.add(p.id)
            for ref, role in ((p.draft, "draft"), (p.target, "target")):
                if ref not in seen:
                    raise TopologyError(
                        f"pair {p.id!r}: unknown node ref {ref!r}")
                if self.node(ref).role != role:
                    raise TopologyError(
                        f"pair {p.id!r}: node {ref!r} has role "
                        f"{self.node(ref).role!r}, expected {role!r}")
            if p.link is not None:
                if p.link.rtt_ms < 0:
                    raise TopologyError(
                        f"pair {p.id!r}: negative rtt_ms {p.link.rtt_ms}")
                if p.link.jitter_ms < 0:
                    raise TopologyError(
                        f"pair {p.id!r}: negative jitter_ms "
                        f"{p.link.jitter_ms}")
                if p.link.bandwidth_gbps <= 0:
                    raise TopologyError(
                        f"pair {p.id!r}: bandwidth_gbps must be > 0")
            if p.mode_policy not in MODE_POLICIES:
                raise TopologyError(
                    f"pair {p.id!r}: mode_policy must be one of "
                    f"{MODE_POLICIES}, got {p.mode_policy!r}")
            if p.mode_policy == "pipeline" and p.link is None:
                raise TopologyError(
                    f"pair {p.id!r}: pipeline mode overlaps rounds across "
                    "a transport; declare a link (rtt_ms 0 = in-process)")
            w = p.window
            if w.kind not in WINDOW_KINDS:
                raise TopologyError(
                    f"pair {p.id!r}: window kind must be one of "
                    f"{WINDOW_KINDS}, got {w.kind!r}")
            if w.gamma < 1 or w.gmax < 1:
                raise TopologyError(
                    f"pair {p.id!r}: window gamma/gmax must be >= 1")
            if p.process:
                # the same restrictions the worker hosts enforce
                from .distributed.host import validate_process_pair
                validate_process_pair(self, p)
                if self.serving.server != "continuous":
                    raise TopologyError(
                        f"pair {p.id!r}: process-backed pairs need "
                        "serving.server='continuous'")
        s = self.serving
        if s.max_batch < 1:
            raise TopologyError("serving.max_batch must be >= 1")
        if s.sync_every < 1:
            raise TopologyError("serving.sync_every must be >= 1")
        if s.pad_to < 1:
            raise TopologyError("serving.pad_to must be >= 1")
        min_gmax = 2 if any(p.mode_policy == "pipeline"
                            for p in self.pairs) else 1
        if s.gamma_max < min_gmax:
            raise TopologyError(
                f"serving.gamma_max must be >= {min_gmax} "
                "(pipeline reserves one proposal slot)")
        if s.temperature < 0:
            raise TopologyError("serving.temperature must be >= 0")
        if s.rtt_ms < 0:
            raise TopologyError("serving.rtt_ms must be >= 0")
        from .serving import PAIR_ROUTERS   # the registry deployment uses
        if s.router not in PAIR_ROUTERS:
            raise TopologyError(
                f"unknown serving.router {s.router!r}; "
                f"available: {sorted(PAIR_ROUTERS)}")
        if s.server not in ("continuous", "wave"):
            raise TopologyError(f"unknown serving.server {s.server!r}")
        if s.server == "wave" and (len(self.pairs) != 1
                                   or self.pairs[0].link is not None):
            raise TopologyError("serving.server='wave' is the single-pair "
                                "colocated baseline")
        w = self.workload
        if w.num_requests < 0 or w.max_new < 1 or w.rate_per_s < 0:
            raise TopologyError("workload: num_requests >= 0, max_new >= 1, "
                                "rate_per_s >= 0 required")
        if not (1 <= w.prompt_lo < w.prompt_hi):
            raise TopologyError("workload: need 1 <= prompt_lo < prompt_hi "
                                "(prompt_hi is exclusive)")
        if w.trace is not None:
            try:
                w.trace.validate()
            except WorkloadError as e:
                raise TopologyError(f"workload.trace: {e}") from e
        return self

    # -- JSON round trip -----------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent) + "\n"

    @classmethod
    def from_dict(cls, d: dict) -> "ClusterSpec":
        def build(dc_cls, obj):
            fields = {f.name: f for f in dataclasses.fields(dc_cls)}
            kw = {}
            for k, v in obj.items():
                if k not in fields:
                    raise TopologyError(
                        f"unknown field {k!r} for {dc_cls.__name__}")
                kw[k] = v
            return dc_cls(**kw)

        nodes = [build(NodeSpec, n) for n in d.get("nodes", [])]
        pairs = []
        for p in d.get("pairs", []):
            p = dict(p)
            link = p.pop("link", None)
            window = p.pop("window", None)
            pair = build(PairSpec, p)
            if link is not None:
                pair.link = build(LinkSpec, link)
            if window is not None:
                pair.window = build(WindowSpec, window)
            pairs.append(pair)
        serving = build(ServingSpec, d.get("serving", {}))
        w = dict(d.get("workload", {}))
        trace = w.pop("trace", None)
        workload = build(WorkloadSpec, w)
        if trace is not None:
            try:
                workload.trace = TraceSpec.from_dict(trace)
            except WorkloadError as e:
                raise TopologyError(f"workload.trace: {e}") from e
        return cls(nodes=nodes, pairs=pairs, serving=serving,
                   workload=workload, seed=int(d.get("seed", 0)))

    @classmethod
    def from_json(cls, text: str) -> "ClusterSpec":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str) -> "ClusterSpec":
        with open(path) as f:
            return cls.from_json(f.read())


# --------------------------------------------------------------------------
# one-pair spec from the legacy flag surface
# --------------------------------------------------------------------------

def one_pair_spec(target: str = "qwen3-14b", draft: str = "qwen2.5-3b",
                  policy: str = "static", gamma: int = 4,
                  gamma_max: int = 12, max_batch: int = 4,
                  sync_every: int = 8, temperature: float = 0.0,
                  rtt_ms: float = 10.0,
                  link_rtt_ms: Optional[float] = None,
                  link_jitter_ms: float = 1.0, link_bw_gbps: float = 1.0,
                  mode_policy: str = "auto", server: str = "continuous",
                  requests: int = 8, max_new: int = 32,
                  arrival_rate: float = 0.0, seed: int = 0) -> ClusterSpec:
    """Compile the legacy ``launch.serve`` flag surface down to an
    equivalent one-pair :class:`ClusterSpec` — the backcompat shim. Every
    pre-existing flag combination maps here (including ``--link-rtt-ms 0``
    → a zero-delay in-process link, and ``--mode-policy pipeline``), and
    the deployment built from the result is behaviorally identical to the
    hand-wired engine+transport the launcher used to construct."""
    link = None
    if link_rtt_ms is not None:
        link = LinkSpec(rtt_ms=float(link_rtt_ms),
                        jitter_ms=float(link_jitter_ms),
                        bandwidth_gbps=float(link_bw_gbps))
    return ClusterSpec(
        nodes=[NodeSpec(id="edge0", role="draft", model=draft),
               NodeSpec(id="cloud0", role="target", model=target)],
        pairs=[PairSpec(id="pair0", draft="edge0", target="cloud0",
                        link=link,
                        window=WindowSpec(kind=policy, gamma=gamma),
                        mode_policy=mode_policy)],
        serving=ServingSpec(max_batch=max_batch, sync_every=sync_every,
                            gamma_max=gamma_max, temperature=temperature,
                            rtt_ms=rtt_ms, server=server),
        workload=WorkloadSpec(num_requests=requests, max_new=max_new,
                              rate_per_s=arrival_rate),
        seed=seed)


# --------------------------------------------------------------------------
# real-path factory
# --------------------------------------------------------------------------

@dataclass
class Deployment:
    """The real execution path built from a spec: one
    :class:`~repro.serving.ServingPair` per :class:`PairSpec` (engines
    share per-node params; each pair owns its transport and its window
    policy instance), plus the resolved vocab and router."""
    spec: ClusterSpec
    pairs: list                  # list[repro.serving.ServingPair]
    node_configs: dict           # node id -> ModelConfig (vocab-unified)
    vocab: int
    router: Any

    def server_config(self):
        """A :class:`~repro.serving.ServerConfig` carrying the spec's
        serving knobs (the per-pair transport/mode live on the pairs)."""
        from .serving import ServerConfig
        s = self.spec.serving
        return ServerConfig(max_batch=s.max_batch,
                            length_aware=s.length_aware, pad_to=s.pad_to,
                            max_prompt_len=s.max_prompt_len,
                            max_new_cap=s.max_new_cap, eos_id=s.eos_id,
                            sync_every=s.sync_every)

    def build_server(self):
        """A ready :class:`~repro.serving.SpecDecodeServer` over the
        deployment's pairs."""
        from .serving import SpecDecodeServer
        return SpecDecodeServer(cfg=self.server_config(), pairs=self.pairs,
                                router=self.router)

    def shutdown(self) -> None:
        """Terminate the worker-host processes of every process-backed
        pair (no-op for fully in-process deployments)."""
        for p in self.pairs:
            host = getattr(p, "host", None)
            if host is not None:
                host.shutdown()


def build_deployment(spec: ClusterSpec, *,
                     model_configs: Optional[dict] = None,
                     node_params: Optional[dict] = None,
                     key=None, sleep_links: bool = True,
                     reduced: bool = True) -> Deployment:
    """Instantiate the real path from a validated spec.

    - each node's ``model`` resolves through ``model_configs`` (name →
      :class:`~repro.configs.base.ModelConfig`, for tests/benches with
      hand-built tiny configs) or :func:`repro.configs.get_config`
      (``.reduced()`` unless ``reduced=False``); vocabularies are unified
      to the minimum across nodes (one tokenizer — exactly the legacy
      launcher rule);
    - parameters are built ONCE per node (``node_params`` overrides by
      node id) and shared by every pair that references the node: the
      PRNG scheme (``kd, kt = split(key)``; first draft/target node uses
      ``kd``/``kt`` directly) reproduces the legacy
      ``SpecDecodeEngine(..., key=key)`` initialization bit-for-bit for
      a one-pair spec;
    - each pair gets its own engine (cached per (draft, target) node
      pair), its own transport from its :class:`LinkSpec`
      (:func:`repro.distributed.make_transport`; ``sleep_links=False``
      routes emulated delays to the virtual clock for fast tests), and
      its own window-policy instance — per-pair stabilizer isolation is
      structural, not an accident of pair keys.
    """
    import jax

    from .configs import get_config
    from .core.engine import SpecDecodeEngine
    from .core.window import make_window_policy
    from .distributed import make_transport
    from .serving import PAIR_ROUTERS, ServingPair

    spec.validate()
    model_configs = model_configs or {}
    node_params = node_params or {}
    s = spec.serving

    def resolve(node: NodeSpec):
        if node.model in model_configs:
            return model_configs[node.model]
        cfg = get_config(node.model)
        return cfg.reduced() if reduced else cfg

    raw = {n.id: resolve(n) for n in spec.nodes}
    vocab = min(c.vocab for c in raw.values())
    configs = {nid: (c if c.vocab == vocab
                     else dataclasses.replace(c, vocab=vocab))
               for nid, c in raw.items()}

    process_pairs = [p for p in spec.pairs if p.process]
    if process_pairs and key is not None:
        raise TopologyError(
            "process-backed pairs rebuild parameters from spec.seed inside "
            "the worker hosts; an explicit PRNG key cannot cross the process "
            "boundary — drop key= or set process=False")
    # nodes referenced by at least one in-process pair need local params;
    # process-only nodes are rebuilt inside their hosts from spec.seed
    # (the role-index sweep below still walks EVERY node so indices match
    # what the hosts derive).
    local_nodes = {nid for p in spec.pairs if not p.process
                   for nid in (p.draft, p.target)}

    base = jax.random.PRNGKey(spec.seed) if key is None else key
    kd, kt = jax.random.split(base)
    role_index = {"draft": 0, "target": 0}
    params: dict[str, Any] = {}
    for n in spec.nodes:
        i = role_index[n.role]
        role_index[n.role] += 1
        if n.id in node_params:
            params[n.id] = node_params[n.id]
            continue
        if n.id not in local_nodes:
            continue
        from .models.model import build_model
        k = kd if n.role == "draft" else kt
        if i > 0:
            k = jax.random.fold_in(k, i)
        params[n.id] = build_model(configs[n.id]).init_params(k)

    engines: dict[tuple[str, str], SpecDecodeEngine] = {}
    pairs = []
    for i, p in enumerate(spec.pairs):
        if p.process:
            from .distributed.host import spawn_pair
            handle = spawn_pair(
                spec, p, model_configs=model_configs,
                node_params={nid: node_params[nid]
                             for nid in (p.draft, p.target)
                             if nid in node_params})
            w = p.window
            policy = make_window_policy(w.kind, gamma=w.gamma, hi=w.hi,
                                        lo=w.lo, gmax=w.gmax)
            pairs.append(ServingPair(pair_id=p.id, engine=None, policy=policy,
                                     transport=None,
                                     mode_policy=p.mode_policy, host=handle))
            continue
        ekey = (p.draft, p.target)
        eng = engines.get(ekey)
        if eng is None:
            eng = engines[ekey] = SpecDecodeEngine(
                configs[p.draft], configs[p.target],
                draft_params=params[p.draft],
                target_params=params[p.target],
                temperature=s.temperature, rtt_ms=s.rtt_ms,
                gamma_max=s.gamma_max, sync_every=s.sync_every,
                key=jax.random.PRNGKey(spec.seed))
        w = p.window
        policy = make_window_policy(w.kind, gamma=w.gamma, hi=w.hi, lo=w.lo,
                                    gmax=w.gmax)
        transport = make_transport(p.link, seed=spec.seed + i,
                                   sleep=sleep_links)
        pairs.append(ServingPair(pair_id=p.id, engine=eng, policy=policy,
                                 transport=transport,
                                 mode_policy=p.mode_policy))
    router = PAIR_ROUTERS[s.router]()
    return Deployment(spec=spec, pairs=pairs, node_configs=configs,
                      vocab=vocab, router=router)


# --------------------------------------------------------------------------
# sim factory
# --------------------------------------------------------------------------

class PairDispatchWindowPolicy:
    """Window policy for multi-pair simulations: dispatches each decision
    to the pair's OWN policy instance by the sim's ``"did->tid"`` pair
    key (drafter i is pair i under :func:`build_simulation`'s mapping),
    so heterogeneous per-pair window declarations survive the shared
    ``PolicyStack.window`` slot."""

    def __init__(self, per_pair: list):
        self.per_pair = list(per_pair)

    def _policy_for(self, pair_key: str):
        did = int(str(pair_key).split("->", 1)[0])
        return self.per_pair[did % len(self.per_pair)]

    def decide(self, pair_key: str, feats):
        return self._policy_for(pair_key).decide(pair_key, feats)

    def gamma_bound(self) -> int:
        return max(p.gamma_bound() for p in self.per_pair)

    def name(self) -> str:
        return "per-pair(" + ",".join(p.name() for p in self.per_pair) + ")"


def build_simulation(spec: ClusterSpec, records: Optional[list] = None, *,
                     hwmodel=None, pipeline: Optional[bool] = None,
                     predictor=None, pair_router=None):
    """A :class:`~repro.sim.DSDSimulation` matching the spec's topology.

    Mapping: sim drafter i ⇔ ``spec.pairs[i]`` (its link becomes drafter
    i's per-pair link via the scheduler's ``drafter_link_pool``); unique
    target NODES become sim target servers; routing is pair-pinned, so a
    request handed to drafter i verifies on pair i's declared target over
    pair i's declared link — the same lanes the real deployment runs.

    ``records`` replays captured acceptance traces (``TraceRecord`` with
    ``drafter_id`` = pair index, or < 0 for "assign at arrival"); when
    ``None``, the spec's :class:`WorkloadSpec` generates a synthetic
    stream — from its fleet ``trace`` (class-aware arrivals with SLOs,
    every record unpinned so the pair router assigns lanes) when one is
    declared, else the flat legacy surface. ``pair_router`` is the
    arrival-time lane policy for unpinned records: an instance, a
    ``repro.fleet.routing.SIM_PAIR_ROUTERS`` key, or None for the
    spec's ``serving.router`` when that name has a sim analogue
    (least-loaded/smart; shallowest-queue otherwise). ``pipeline``
    defaults to True iff every pair declares ``mode_policy="pipeline"``
    (the sim's overlap model is simulation-global). Pairs forced
    ``fused`` simulate under an always-fused oracle policy; pairs forced
    ``distributed`` keep their window policy's γ but never enter fused
    mode (matching the real session's mode override).
    """
    from .core.window import OracleStaticPolicy, make_window_policy
    from .sim.network import LinkSpec as SimLinkSpec
    from .sim.policies import (BatchingConfig, FIFOBatching,
                               LengthAwareBatching, PinnedRouting)
    from .sim.scheduler import ClusterSpec as SimClusterSpec
    from .sim.scheduler import DSDSimulation, PolicyStack
    from .sim.trace import WorkloadGenerator

    spec.validate()
    s = spec.serving

    target_ids: list[str] = []
    for p in spec.pairs:
        if p.target not in target_ids:
            target_ids.append(p.target)
    target_pool = [spec.node(t).sim_tuple() for t in target_ids]
    draft_pool = [spec.node(p.draft).sim_tuple()[:2] for p in spec.pairs]
    pinned = [target_ids.index(p.target) for p in spec.pairs]
    drafter_links = [p.link if p.link is not None
                     else SimLinkSpec(rtt_ms=0.0, jitter_ms=0.0)
                     for p in spec.pairs]

    per_pair_policies = []
    for p in spec.pairs:
        if p.mode_policy == "fused":
            per_pair_policies.append(OracleStaticPolicy(1, fused=True))
            continue
        w = p.window
        pol = make_window_policy(w.kind, gamma=w.gamma, hi=w.hi, lo=w.lo,
                                 gmax=w.gmax, predictor=predictor)
        if p.mode_policy == "distributed":
            pol = _ForceDistributed(pol)
        per_pair_policies.append(pol)
    window = (per_pair_policies[0] if len(per_pair_policies) == 1
              else PairDispatchWindowPolicy(per_pair_policies))

    cluster = SimClusterSpec(
        num_targets=len(target_ids),
        num_drafters=len(spec.pairs),
        link=drafter_links[0],
        target_pool=target_pool,
        draft_pool=draft_pool,
        drafter_link_pool=drafter_links)
    if pair_router is None and s.router in ("least-loaded", "smart"):
        pair_router = s.router
    if isinstance(pair_router, str):
        from .fleet.routing import SIM_PAIR_ROUTERS
        pair_router = SIM_PAIR_ROUTERS[pair_router]()
    policies = PolicyStack(
        routing=PinnedRouting(pinned),
        batching=(LengthAwareBatching() if s.length_aware
                  else FIFOBatching()),
        batching_cfg=BatchingConfig(max_batch=s.max_batch, continuous=True),
        window=window,
        pair_routing=pair_router)
    if records is None and spec.workload.trace is not None:
        # fleet trace: class-aware arrivals with SLOs; unpinned records
        # (drafter_id = -1) let the pair router assign lanes at arrival —
        # the sim twin of the real server's PairRouter admission
        from .fleet.workload import fleet_trace_records, generate_requests
        records = fleet_trace_records(generate_requests(spec.workload.trace),
                                      seed=spec.seed)
    elif records is None:
        # rate 0 means "all at t=0" on the real path; the generator needs
        # a positive rate, so approximate with effectively-simultaneous
        # arrivals
        rate = spec.workload.rate_per_s or 1e6
        gen = WorkloadGenerator(spec.workload.dataset, rate,
                                len(spec.pairs), seed=spec.seed)
        records = gen.generate(spec.workload.num_requests)
        # synthetic streams exercise every declared lane: drafter i is
        # pair i, so spread requests round-robin across pairs (captured
        # traces passed via ``records`` keep their own drafter ids)
        for i, rec in enumerate(records):
            rec.drafter_id = i % len(spec.pairs)
    if pipeline is None:
        pipeline = all(p.mode_policy == "pipeline" for p in spec.pairs)
    return DSDSimulation(cluster, policies, records, hwmodel=hwmodel,
                         seed=spec.seed, pipeline=bool(pipeline))


class _ForceDistributed:
    """Mode override wrapper mirroring the real session's
    ``mode_policy="distributed"``: the wrapped policy's γ stands, fused
    decisions are coerced to distributed (γ clamped to ≥ 1)."""

    def __init__(self, inner):
        self.inner = inner

    def decide(self, pair_key, feats):
        from .core.window import WindowDecision
        d = self.inner.decide(pair_key, feats)
        if d.mode == "fused":
            return WindowDecision(max(1, d.gamma), "distributed")
        return d

    def gamma_bound(self) -> int:
        return self.inner.gamma_bound()

    def name(self) -> str:
        return f"forced-distributed({self.inner.name()})"
