"""repro — DSD: Distributed Speculative Decoding for Edge-Cloud LLM serving.

Reproduction + beyond-paper TPU framework. Public API surface:

- ``repro.core``     — speculative decoding algorithm, AWC window control
- ``repro.sim``      — DSD-Sim discrete-event simulator
- ``repro.models``   — model zoo (dense / MoE / SSM / hybrid / enc-dec / VLM)
- ``repro.configs``  — assigned architecture configs
- ``repro.launch``   — mesh / dryrun / serve / train entry points
"""

__version__ = "0.1.0"
