"""repro — DSD: Distributed Speculative Decoding for Edge-Cloud LLM serving.

Reproduction + beyond-paper TPU framework. Public API surface:

- ``repro.core``     — speculative decoding algorithm, AWC window control
- ``repro.topology`` — declarative ClusterSpec: one spec builds the real
  deployment (multi-pair serving) AND the matching DSD-Sim run
- ``repro.serving``  — continuous multi-pair server with pair routing
- ``repro.sim``      — DSD-Sim discrete-event simulator
- ``repro.models``   — model zoo (dense / MoE / SSM / hybrid / enc-dec / VLM)
- ``repro.configs``  — assigned architecture configs
- ``repro.launch``   — mesh / dryrun / serve / train entry points
"""

__version__ = "0.1.0"
