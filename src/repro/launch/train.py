"""Training launcher.

Two modes:
- default (host): trains a REDUCED variant of ``--arch`` on this machine's
  devices with the synthetic LM pipeline — the runnable end-to-end driver
  (examples/train_draft.py drives a ~100M model a few hundred steps).
- ``--production-lower``: builds the full-size train step against the
  production mesh and lowers+compiles it (the train_4k dry-run path) —
  useful for iterating on shardings without running the whole dry-run.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 100 --batch 8 --seq 256 [--reduced/--full] [--ckpt out.npz]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config
from ..models.model import build_model
from ..training import (AdamWConfig, DataConfig, SyntheticLM, checkpoint,
                        cosine_schedule, init_train_state, make_train_step)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced smoke variant)")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override d_model of the reduced config (e.g. a "
                         "~100M-param draft model)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--micro-steps", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
        import dataclasses
        over = {}
        if args.d_model:
            heads = max(1, args.d_model // 64)
            over = dict(d_model=args.d_model, n_heads=min(heads, 16),
                        n_kv_heads=min(heads, 16),
                        head_dim=args.d_model // min(heads, 16),
                        d_ff=args.d_model * 4)
        if args.layers:
            over["n_layers"] = args.layers
        if over:
            cfg = dataclasses.replace(cfg, **over)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    opt = AdamWConfig(lr=args.lr,
                      schedule=cosine_schedule(args.lr, warmup=20,
                                               total=args.steps))
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt, micro_steps=args.micro_steps))

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, batch=args.batch, seed=0,
        frontend_tokens=(cfg.n_frontend_tokens
                         if cfg.arch_type in ("vlm", "encdec") else 0),
        frontend_dim=cfg.d_model))
    it = data.batches()

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, m = step(state, batch, jax.random.PRNGKey(i))
        if (i + 1) % args.log_every == 0 or i == 0:
            print(f"step {int(m['step']):5d}  loss {float(m['loss']):.4f}  "
                  f"ce {float(m['ce']):.4f}  aux {float(m['aux']):.4f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.ckpt:
        checkpoint.save(state.params, args.ckpt)
        print("saved", args.ckpt)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
