"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax import;
tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods × 256 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int | None = None):
    """Debug mesh over whatever devices exist on this host (usually 1)."""
    n = len(jax.devices())
    m = model_axis or 1
    return jax.make_mesh((n // m, m), ("data", "model"))
