"""Assigned input shapes + ShapeDtypeStruct input_specs() builders.

The four production shapes:

    train_4k     seq_len=4096    global_batch=256   (training: train_step)
    prefill_32k  seq_len=32768   global_batch=32    (inference prefill)
    decode_32k   seq_len=32768   global_batch=128   (serve_step: 1 new token,
                                                     KV/state cache of 32k)
    long_500k    seq_len=524288  global_batch=1     (serve_step: sub-quadratic
                                                     — SSM/hybrid state, or
                                                     ring-buffer sliding
                                                     window for dense archs)

Everything here is ``jax.ShapeDtypeStruct`` — weak-type-correct, shardable,
no device allocation (the dry-run contract).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import Model


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"
    sliding: bool = False        # decode via ring-buffer sliding window


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode", sliding=True),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def cache_specs(model: Model, batch: int, slots: int, ring: bool,
                enc_frames: int = 0) -> Any:
    """ShapeDtypeStruct pytree matching model.init_cache (no allocation)."""
    return jax.eval_shape(
        lambda: model.init_cache(batch, slots, ring=ring,
                                 enc_frames=enc_frames))


def input_specs(cfg: ModelConfig, shape: InputShape, model: Model
                ) -> dict[str, Any]:
    """Returns the ShapeDtypeStruct stand-ins for every input of the step
    function selected by ``shape.kind`` (tokens/labels for train; tokens for
    prefill; token/cache/pos for decode), plus metadata the dry-run needs."""
    B, S = shape.global_batch, shape.seq_len
    out: dict[str, Any] = {"kind": shape.kind}

    if shape.kind == "train":
        text = S
        if cfg.arch_type == "vlm":
            # patches + text together fill the backbone's 4096 positions
            text = S - cfg.n_frontend_tokens
            out["frontend"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                   jnp.float32)
        if cfg.arch_type == "encdec":
            out["frontend"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                   jnp.float32)
        out["tokens"] = _sds((B, text), jnp.int32)
        out["labels"] = _sds((B, text), jnp.int32)
        return out

    if shape.kind == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32)
        if cfg.arch_type == "encdec":
            out["frontend"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                   jnp.float32)
        out["slots"] = S + 128
        return out

    # decode: ONE new token against a seq_len cache
    ring = False
    window = 0
    slots = S
    if shape.sliding and cfg.arch_type not in ("ssm",):
        # sub-quadratic serving for attention archs: ring-buffer sliding
        # window (SSM/hybrid mamba state is O(1) natively)
        ring = True
        window = cfg.serve_sliding_window
        slots = cfg.serve_sliding_window
    enc_frames = cfg.n_frontend_tokens if cfg.arch_type == "encdec" else 0
    out["token"] = _sds((B,), jnp.int32)
    out["pos"] = _sds((B,), jnp.int32)
    out["cache"] = cache_specs(model, B, slots, ring, enc_frames)
    out["window"] = window
    out["ring"] = ring
    return out
