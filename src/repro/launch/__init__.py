"""Launchers: production mesh, multi-pod dry-run, train and serve drivers."""

from .mesh import make_host_mesh, make_production_mesh
from .shapes import SHAPES, InputShape, input_specs
